//! Quickstart: DySTop on a simulated 20-worker edge network, through the
//! unified Experiment builder API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dystop::config::{BackendKind, ExperimentConfig};
use dystop::experiment::Experiment;

fn main() {
    // Defaults are the paper's §VI-A setup scaled down; every field can
    // also come from a config file via the `dystop train` CLI, and the
    // backend from `--set run.backend=sim|testbed`.
    let cfg = ExperimentConfig {
        workers: 20,
        rounds: 150,
        phi: 0.7, // mildly non-IID
        class_sep: 3.0,
        target_accuracy: 0.80,
        ..Default::default()
    };
    println!(
        "DySTop quickstart: {} workers, {} rounds, φ={}",
        cfg.workers, cfg.rounds, cfg.phi
    );
    println!(
        "active workload: model={} dataset={}",
        cfg.workload.model.name(),
        cfg.workload.dataset.name(),
    );

    let res = Experiment::builder(cfg)
        .backend(BackendKind::Sim)
        .run()
        .unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(1);
        });

    println!("\n  round  time(s)  accuracy   loss    comm(GB)");
    for e in &res.evals {
        println!(
            "  {:>5}  {:>7.1}  {:>8.3}  {:>6.3}  {:>8.4}",
            e.round,
            e.time_s,
            e.avg_accuracy,
            e.avg_loss,
            e.cum_bytes / 1e9
        );
    }
    println!(
        "\nbest accuracy {:.3} | total comm {:.4} GB | mean staleness {:.2}",
        res.best_accuracy(),
        res.total_comm_gb(),
        res.mean_staleness()
    );
    if let Some(t) = res.time_to_accuracy(0.80) {
        println!("completion time to 80%: {t:.1}s (virtual)");
    }
}
