//! Lossy-link quickstart: DySTop over unreliable links, from pristine
//! lab wiring to a hostile jammer, with the reliable delivery layer's
//! ack/retry protocol switched on and off.
//!
//! Shows the fault knobs (`ExperimentConfig::faults` /
//! `--set faults.profile=cellular` on the CLI), the per-round delivery
//! ledger in the round records (`retransmissions` / `dropped_msgs` /
//! `corrupt_detected`), the retransmission surcharge on measured
//! bytes, and the graceful per-round degradation (dead-letter events)
//! when the retry budget runs dry.
//!
//! ```bash
//! cargo run --release --example lossy
//! ```

use dystop::config::{
    BackendKind, ExperimentConfig, FaultConfig, FaultProfile,
};
use dystop::experiment::Experiment;
use dystop::metrics::RunResult;

fn run(faults: FaultConfig) -> RunResult {
    let cfg = ExperimentConfig {
        workers: 20,
        rounds: 80,
        phi: 0.7,
        class_sep: 3.0,
        eval_every: 10,
        target_accuracy: 2.0, // full curve
        faults,
        ..Default::default()
    };
    Experiment::builder(cfg)
        .backend(BackendKind::Sim)
        .run()
        .unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(1);
        })
}

fn ledger(res: &RunResult) -> (usize, usize, usize, f64) {
    let retrans: usize =
        res.rounds.iter().map(|r| r.retransmissions).sum();
    let dropped: usize = res.rounds.iter().map(|r| r.dropped_msgs).sum();
    let corrupt: usize =
        res.rounds.iter().map(|r| r.corrupt_detected).sum();
    let gb: f64 =
        res.rounds.iter().map(|r| r.bytes_sent).sum::<f64>() / 1e9;
    (retrans, dropped, corrupt, gb)
}

fn main() {
    println!("lossy quickstart: 20 workers, 80 rounds, dystop\n");
    let mut clean_gb = 0.0;
    let mut hostile_gb = 0.0;
    for profile in [
        FaultProfile::Clean,
        FaultProfile::Wifi,
        FaultProfile::Cellular,
        FaultProfile::Hostile,
    ] {
        let res = run(FaultConfig::preset(profile));
        let (retrans, dropped, corrupt, gb) = ledger(&res);
        println!(
            "  profile={:<9} retrans={retrans:<5} dropped={dropped:<4} \
             corrupt={corrupt:<4} comm={gb:.3} GB  best accuracy {:.3}",
            profile.name(),
            res.best_accuracy()
        );
        match profile {
            FaultProfile::Clean => {
                clean_gb = gb;
                assert_eq!(
                    (retrans, dropped, corrupt),
                    (0, 0, 0),
                    "clean links must leave the ledger empty"
                );
            }
            FaultProfile::Hostile => {
                hostile_gb = gb;
                assert!(
                    retrans > 0,
                    "hostile links must force retransmissions"
                );
            }
            _ => {}
        }
    }
    assert!(
        hostile_gb > clean_gb,
        "every retransmitted frame is charged real bytes"
    );

    // retries=0 switches the ack/retry protocol off: lost frames
    // dead-letter immediately and the receiver aggregates what arrived
    let noretry = run(FaultConfig {
        retries: 0,
        ..FaultConfig::preset(FaultProfile::Hostile)
    });
    let (retrans, dropped, _, _) = ledger(&noretry);
    let dead = noretry
        .events
        .iter()
        .filter(|e| e.kind == "dead-letter")
        .count();
    println!(
        "\n  hostile, retries=0: retrans={retrans} dropped={dropped} \
         dead-lettered pulls={dead}  best accuracy {:.3}",
        noretry.best_accuracy()
    );
    assert_eq!(retrans, 0, "retries=0 must never retransmit");
    assert!(
        dead > 0,
        "without retries, hostile loss must dead-letter some pulls"
    );
    assert!(
        noretry.evals.iter().all(|e| e.avg_accuracy.is_finite()),
        "degraded rounds still aggregate what arrived"
    );
    println!("ok: lossy links degrade gracefully and every byte is accounted");
}
