//! Testbed example — the §VII analog: 15 real OS threads with the
//! Table II Jetson speed profile, real message passing, and wall-clock
//! delays (compressed 100×), coordinated by DySTop — through the unified
//! Experiment builder with the threaded backend.
//!
//! ```bash
//! cargo run --release --example testbed
//! ```

use dystop::config::{ExperimentConfig, NetworkConfig, SchedulerKind};
use dystop::experiment::{Experiment, TestbedOptions, ThreadedBackend};

fn main() {
    let cfg = ExperimentConfig {
        workers: 15, // 4× Nano, 3× Orin Nano, 4× Orin NX, 3× Orin, 1× AGX
        rounds: 60,
        phi: 0.5, // the paper's non-IID testbed level
        class_sep: 3.0,
        compute_mean_s: 0.5,
        eval_every: 10,
        target_accuracy: 2.0,
        scheduler: SchedulerKind::DySTop,
        network: NetworkConfig { comm_range_m: 80.0, ..Default::default() },
        ..Default::default()
    };
    let opts = TestbedOptions { time_scale: 10.0, profile: true };
    println!(
        "testbed: {} worker threads (Table II speed profile), φ={}, \
         time compressed {}×",
        cfg.workers,
        cfg.phi,
        1000.0 / opts.time_scale
    );

    let res = Experiment::builder(cfg)
        .backend_impl(Box::new(ThreadedBackend::with_options(opts)))
        .run()
        .unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(1);
        });

    println!("\n  round  wall(s)  accuracy   loss");
    for e in &res.evals {
        println!(
            "  {:>5}  {:>7.2}  {:>8.3}  {:>6.3}",
            e.round, e.time_s, e.avg_accuracy, e.avg_loss
        );
    }
    println!(
        "\nbest accuracy {:.3} | {} transfers | mean staleness {:.2}",
        res.best_accuracy(),
        res.total_transfers(),
        res.mean_staleness()
    );
}
