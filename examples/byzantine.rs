//! Byzantine quickstart: DySTop with a fifth of the fleet flipping the
//! sign of every model it serves, defended (or not) by the coordinator
//! aggregation rule.
//!
//! Shows the adversary knobs (`ExperimentConfig::adversary` /
//! `--set adversary.attack=signflip` on the CLI), the per-round
//! adversary tally in the round records, the attack-activation events
//! in the run result, and the accuracy gap between plain `mean` and
//! the robust rules.
//!
//! ```bash
//! cargo run --release --example byzantine
//! ```

use dystop::config::{
    AdversaryConfig, AggregatorKind, AttackKind, BackendKind,
    ExperimentConfig,
};
use dystop::experiment::Experiment;

fn run(aggregator: AggregatorKind) -> f64 {
    let cfg = ExperimentConfig {
        workers: 20,
        rounds: 120,
        phi: 0.7,
        class_sep: 3.0,
        eval_every: 10,
        target_accuracy: 2.0, // full curve
        adversary: AdversaryConfig {
            frac: 0.2,
            attack: AttackKind::SignFlip,
            aggregator,
            ..Default::default()
        },
        ..Default::default()
    };
    let res = Experiment::builder(cfg)
        .backend(BackendKind::Sim)
        .run()
        .unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(1);
        });

    let adv = res.rounds.first().map(|r| r.adversaries).unwrap_or(0);
    let fired = res
        .events
        .iter()
        .filter(|e| e.kind.starts_with("attack-"))
        .count();
    println!(
        "  agg={:<12} adversaries={adv}/20  activations={fired}  \
         best accuracy {:.3}",
        aggregator.name(),
        res.best_accuracy()
    );
    res.best_accuracy()
}

fn main() {
    println!(
        "byzantine quickstart: 20 workers, 120 rounds, \
         attack=signflip frac=0.2\n"
    );
    let mean = run(AggregatorKind::Mean);
    let trimmed = run(AggregatorKind::TrimmedMean);
    let median = run(AggregatorKind::CoordinateMedian);
    let krum = run(AggregatorKind::Krum);

    let best_robust = trimmed.max(median).max(krum);
    println!(
        "\nplain mean {:.3} vs best robust rule {:.3}",
        mean, best_robust
    );
    assert!(
        best_robust > mean,
        "a robust rule should beat plain mean under sign-flip"
    );
    println!("ok: robust aggregation recovers the poisoned run");
}
