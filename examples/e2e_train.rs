//! End-to-end driver over the REAL three-layer stack: the DySTop
//! coordinator (L3, Rust) schedules workers whose local training, model
//! aggregation and evaluation all execute the AOT-compiled JAX+Pallas
//! artifacts (L2/L1) through PJRT. Python is not involved at runtime.
//!
//! Trains the MLP variant across a simulated 10-worker edge network for
//! 150 rounds on the synthetic corpus and logs the loss/accuracy curve
//! (recorded in EXPERIMENTS.md §End-to-end).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train
//! ```

use dystop::config::{ExperimentConfig, ModelKind, SchedulerKind, TrainerKind};
use dystop::experiment::{Experiment, VirtualClockBackend};
use dystop::runtime::PjrtTrainer;
use std::path::PathBuf;

fn main() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        // a skip is not a failure: CI builds and runs every example, and
        // artifact generation (python + jax) isn't part of that job
        println!(
            "skipping e2e_train: artifacts/manifest.json missing — run \
             `make artifacts` first"
        );
        return;
    }
    let trainer = match PjrtTrainer::new(&dir, ModelKind::Mlp) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: load + compile HLO artifacts: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "loaded {}: P={} params, train batch {}, K_max {}",
        trainer.manifest().name,
        trainer.manifest().param_count,
        trainer.manifest().train_batch,
        trainer.manifest().k_max,
    );

    let cfg = ExperimentConfig {
        workers: 10,
        rounds: 500,
        phi: 0.7,
        class_sep: 3.0,
        local_steps: 6,
        lr: 0.15,
        train_per_worker: 128,
        test_samples: 512,
        eval_every: 10,
        trainer: TrainerKind::Pjrt,
        scheduler: SchedulerKind::DySTop,
        target_accuracy: 2.0, // run the full curve
        ..Default::default()
    };
    println!(
        "e2e: {} workers × {} rounds, DySTop over PJRT (CPU)",
        cfg.workers, cfg.rounds
    );

    let wall = std::time::Instant::now();
    let res = Experiment::builder(cfg)
        .trainer(Box::new(trainer))
        .backend_impl(Box::new(VirtualClockBackend::full_curves()))
        .run()
        .unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(1);
        });
    let wall_s = wall.elapsed().as_secs_f64();

    println!("\n  round  vtime(s)  accuracy   loss");
    for e in res.evals.iter().step_by(3) {
        println!(
            "  {:>5}  {:>8.1}  {:>8.3}  {:>6.3}",
            e.round, e.time_s, e.avg_accuracy, e.avg_loss
        );
    }
    let steps: usize = res.rounds.iter().map(|r| r.active * 6).sum();
    println!(
        "\nbest accuracy {:.3} | {} SGD steps through PJRT | wall {:.1}s ({:.1} steps/s)",
        res.best_accuracy(),
        steps,
        wall_s,
        steps as f64 / wall_s
    );
    res.write_eval_csv(&PathBuf::from("results/e2e_train_eval.csv"))
        .expect("write csv");
    println!("curve written to results/e2e_train_eval.csv");
}
