//! Churn quickstart: DySTop on a simulated edge network whose worker
//! population follows the `diurnal` scenario preset — devices leave and
//! rejoin tracking a day/night wave, with light random churn on top.
//!
//! Shows the scenario knobs (`ExperimentConfig::scenario` /
//! `--set scenario.preset=...` on the CLI), the per-round population in
//! the round records, and the applied event log in the run result.
//!
//! ```bash
//! cargo run --release --example churn
//! ```

use dystop::config::{
    BackendKind, ExperimentConfig, ScenarioConfig, ScenarioPreset,
};
use dystop::experiment::Experiment;

fn main() {
    let cfg = ExperimentConfig {
        workers: 24,
        rounds: 120,
        phi: 0.7,
        class_sep: 3.0,
        eval_every: 10,
        target_accuracy: 2.0, // full curve
        scenario: ScenarioConfig::preset(ScenarioPreset::Diurnal),
        ..Default::default()
    };
    println!(
        "churn quickstart: {} workers, {} rounds, scenario={} \
         (churn_rate={}, mean_downtime={} rounds)",
        cfg.workers,
        cfg.rounds,
        cfg.scenario.preset.name(),
        cfg.scenario.churn_rate,
        cfg.scenario.mean_downtime_rounds,
    );

    let res = Experiment::builder(cfg)
        .backend(BackendKind::Sim)
        .run()
        .unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(1);
        });

    println!("\n  round  population  accuracy   loss");
    for e in &res.evals {
        let pop = res
            .rounds
            .iter()
            .find(|r| r.round == e.round)
            .map(|r| r.population)
            .unwrap_or(0);
        println!(
            "  {:>5}  {:>10}  {:>8.3}  {:>6.3}",
            e.round, pop, e.avg_accuracy, e.avg_loss
        );
    }

    let (lo, hi) = res.population_range();
    let count = |k: &str| res.events.iter().filter(|e| e.kind == k).count();
    println!(
        "\npopulation ranged {lo}–{hi} across {} applied events \
         ({} leave, {} crash, {} rejoin, {} join)",
        res.events.len(),
        count("leave"),
        count("crash"),
        count("rejoin"),
        count("join"),
    );
    println!(
        "best accuracy {:.3} | total comm {:.4} GB | mean staleness {:.2}",
        res.best_accuracy(),
        res.total_comm_gb(),
        res.mean_staleness()
    );
    assert!(
        !res.events.is_empty() && lo < hi,
        "diurnal scenario should have churned the population"
    );
    println!("ok: event log accounts for {} population changes", res.events.len());
}
