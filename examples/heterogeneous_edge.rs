//! Heterogeneous-edge scenario: the workload the paper's introduction
//! motivates — strongly heterogeneous compute (lognormal, ~10× spread),
//! mobile workers, dropping links, non-IID data — comparing all four
//! mechanisms head-to-head.
//!
//! ```bash
//! cargo run --release --example heterogeneous_edge
//! ```

use dystop::config::{ExperimentConfig, SchedulerKind};
use dystop::experiment::{Experiment, VirtualClockBackend};

fn main() {
    let base = ExperimentConfig {
        workers: 50,
        rounds: 260,
        phi: 0.4,        // strongly non-IID (paper's hardest level)
        class_sep: 3.0,
        compute_jitter: 1.0, // extreme heterogeneity (≳10× spread)
        target_accuracy: 2.0,
        network: dystop::config::NetworkConfig {
            mobility_m: 2.0,      // faster-moving workers
            link_drop_prob: 0.05, // flakier links
            ..Default::default()
        },
        ..Default::default()
    };
    println!(
        "heterogeneous edge: {} workers, φ={}, lognormal(σ={}) compute, \
         {}m/round mobility, {:.0}% link drops\n",
        base.workers,
        base.phi,
        base.compute_jitter,
        base.network.mobility_m,
        base.network.link_drop_prob * 100.0
    );

    println!(
        "{:>10} | {:>9} | {:>9} | {:>10} | {:>9} | {:>7}",
        "mechanism", "best acc", "t@75%", "comm@75%", "mean τ", "max τ"
    );
    for kind in [
        SchedulerKind::DySTop,
        SchedulerKind::AsyDfl,
        SchedulerKind::SaAdfl,
        SchedulerKind::Matcha,
    ] {
        let mut cfg = base.clone();
        cfg.scheduler = kind;
        let res = Experiment::builder(cfg)
            .backend_impl(Box::new(VirtualClockBackend::full_curves()))
            .run()
            .unwrap_or_else(|e| {
                eprintln!("error: {e}");
                std::process::exit(1);
            });
        let max_tau = res.rounds.iter().map(|r| r.max_staleness).max().unwrap();
        println!(
            "{:>10} | {:>9.3} | {:>9} | {:>10} | {:>9.2} | {:>7}",
            res.label,
            res.best_accuracy(),
            res.time_to_accuracy(0.75)
                .map(|t| format!("{t:.0}s"))
                .unwrap_or("—".into()),
            res.comm_to_accuracy(0.75)
                .map(|c| format!("{c:.3}GB"))
                .unwrap_or("—".into()),
            res.mean_staleness(),
            max_tau
        );
    }
    println!(
        "\nExpected shape (paper Figs. 4–13): DySTop reaches the target \
         fastest;\nMATCHA suffers stragglers; SA-ADFL burns bandwidth on \
         push-to-all;\nAsyDFL's staleness goes uncontrolled."
    );
}
