"""Layer-2 JAX model: per-worker compute for DySTop, built on the L1 kernels.

Three jitted entry points per model variant, each AOT-lowered to an HLO
artifact (see ``aot.py``) that the Rust coordinator executes via PJRT:

* ``train_step(params, x, y, lr)``  → ``(params', loss)``      — Eq. (5)
* ``eval_step(params, x, y)``       → ``(loss_sum, correct)``
* ``aggregate(stacked, weights)``   → ``params``               — Eq. (4)

Models operate on a single flattened float32 parameter vector so the Rust
side can treat models as opaque ``[P]`` buffers (aggregation, transfer,
staleness bookkeeping never need the structure). ``PARAM_SPECS`` defines
the packing layout; the manifest emitted by ``aot.py`` carries the counts.

Variants:

* ``mlp`` — 2-hidden-layer MLP; every layer is the Pallas
  :func:`fused_linear` kernel (forward *and* backward — the custom VJP
  re-tiles the transposed matmuls through Pallas).
* ``cnn`` — small convnet on 8×8×1 inputs (conv at L2 via lax.conv, dense
  head through the Pallas kernel), standing in for the paper's
  CNN/ResNet-18 (DESIGN.md §2 substitutions).
"""

import math
from functools import partial

import jax
import jax.numpy as jnp

from .kernels import aggregate_pallas, fused_linear


# --------------------------------------------------------------------------
# Model variant declarations
# --------------------------------------------------------------------------

class ModelSpec:
    """Static description of one model variant (shapes, batch sizes)."""

    def __init__(self, name, input_dim, num_classes, params, train_batch,
                 eval_batch, k_max):
        self.name = name
        self.input_dim = input_dim
        self.num_classes = num_classes
        # list of (param_name, shape) in packing order
        self.params = params
        self.train_batch = train_batch
        self.eval_batch = eval_batch
        self.k_max = k_max

    @property
    def param_count(self):
        return sum(math.prod(s) for _, s in self.params)

    def offsets(self):
        """(name, start, shape) triples of the flat layout."""
        out, off = [], 0
        for name, shape in self.params:
            out.append((name, off, shape))
            off += math.prod(shape)
        return out


def mlp_spec(input_dim=32, hidden=64, num_classes=10, train_batch=32,
             eval_batch=256, k_max=16):
    return ModelSpec(
        "mlp", input_dim, num_classes,
        [
            ("w1", (input_dim, hidden)),
            ("b1", (hidden,)),
            ("w2", (hidden, hidden)),
            ("b2", (hidden,)),
            ("w3", (hidden, num_classes)),
            ("b3", (num_classes,)),
        ],
        train_batch, eval_batch, k_max,
    )


def cnn_spec(side=8, c1=8, c2=16, num_classes=10, train_batch=32,
             eval_batch=256, k_max=16):
    # input_dim = side*side, reshaped to [B, side, side, 1] inside forward.
    return ModelSpec(
        "cnn", side * side, num_classes,
        [
            ("k1", (3, 3, 1, c1)),
            ("cb1", (c1,)),
            ("k2", (3, 3, c1, c2)),
            ("cb2", (c2,)),
            ("w1", (c2 * (side // 2) * (side // 2), 32)),
            ("b1", (32,)),
            ("w2", (32, num_classes)),
            ("b2", (num_classes,)),
        ],
        train_batch, eval_batch, k_max,
    )


SPECS = {"mlp": mlp_spec(), "cnn": cnn_spec()}


# --------------------------------------------------------------------------
# Packing
# --------------------------------------------------------------------------

def unpack(spec, flat):
    """Flat ``[P]`` vector → dict of named parameter arrays."""
    out = {}
    for name, off, shape in spec.offsets():
        n = math.prod(shape)
        out[name] = flat[off:off + n].reshape(shape)
    return out


def pack(spec, tree):
    """Dict of named parameter arrays → flat ``[P]`` vector."""
    return jnp.concatenate(
        [tree[name].reshape(-1) for name, _ in spec.params]
    ).astype(jnp.float32)


def init_params(spec, seed=0):
    """He-initialised flat parameter vector (used by tests and aot smoke)."""
    key = jax.random.PRNGKey(seed)
    tree = {}
    for name, shape in spec.params:
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            tree[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = math.prod(shape[:-1])
            std = math.sqrt(2.0 / fan_in)
            tree[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return pack(spec, tree)


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------

def _forward_mlp(spec, tree, x):
    h = fused_linear(x, tree["w1"], tree["b1"], "relu")
    h = fused_linear(h, tree["w2"], tree["b2"], "relu")
    return fused_linear(h, tree["w3"], tree["b3"], "none")


def _forward_cnn(spec, tree, x):
    side = int(math.isqrt(spec.input_dim))
    img = x.reshape(-1, side, side, 1)
    h = jax.lax.conv_general_dilated(
        img, tree["k1"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    h = jnp.maximum(h + tree["cb1"], 0.0)
    h = jax.lax.conv_general_dilated(
        h, tree["k2"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    h = jnp.maximum(h + tree["cb2"], 0.0)
    # 2x2 mean pool
    h = jax.lax.reduce_window(
        h, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
    h = h.reshape(h.shape[0], -1)
    h = fused_linear(h, tree["w1"], tree["b1"], "relu")
    return fused_linear(h, tree["w2"], tree["b2"], "none")


def forward(spec, flat, x):
    """Logits ``[B, C]`` for flat params and batch ``x [B, D]``."""
    tree = unpack(spec, flat)
    if spec.name == "mlp":
        return _forward_mlp(spec, tree, x)
    if spec.name == "cnn":
        return _forward_cnn(spec, tree, x)
    raise ValueError(f"unknown model {spec.name!r}")


def _xent(logits, y):
    """Mean softmax cross-entropy; y int32 labels."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(logz - gold)


# --------------------------------------------------------------------------
# AOT entry points
# --------------------------------------------------------------------------

def make_train_step(spec):
    """(params [P], x [B,D], y [B] i32, lr [] f32) → (params' [P], loss [])."""

    def loss_fn(flat, x, y):
        return _xent(forward(spec, flat, x), y)

    def train_step(flat, x, y, lr):
        loss, grad = jax.value_and_grad(loss_fn)(flat, x, y)
        return (flat - lr * grad, loss)

    return train_step


def make_eval_step(spec):
    """(params [P], x [Be,D], y [Be] i32) → (loss_sum [], correct [] f32).

    Returns *sums* so the Rust side can stream an arbitrary-size test set
    through fixed-shape executions and divide once.
    """

    def eval_step(flat, x, y):
        logits = forward(spec, flat, x)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
        loss_sum = jnp.sum(logz - gold)
        correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return (loss_sum, correct)

    return eval_step


def make_aggregate(spec):
    """(stacked [K_max, P], weights [K_max]) → (params [P],) — Eq. (4).

    Unused rows carry weight 0; the Pallas kernel makes padding exact.
    """

    def aggregate(stacked, weights):
        return (aggregate_pallas(stacked, weights),)

    return aggregate


def entry_points(spec):
    """All jittable entry points with their example-argument shapes."""
    P = spec.param_count
    B, Be = spec.train_batch, spec.eval_batch
    D, K = spec.input_dim, spec.k_max
    f32, i32 = jnp.float32, jnp.int32
    return {
        "train": (
            make_train_step(spec),
            (
                jax.ShapeDtypeStruct((P,), f32),
                jax.ShapeDtypeStruct((B, D), f32),
                jax.ShapeDtypeStruct((B,), i32),
                jax.ShapeDtypeStruct((), f32),
            ),
        ),
        "eval": (
            make_eval_step(spec),
            (
                jax.ShapeDtypeStruct((P,), f32),
                jax.ShapeDtypeStruct((Be, D), f32),
                jax.ShapeDtypeStruct((Be,), i32),
            ),
        ),
        "agg": (
            make_aggregate(spec),
            (
                jax.ShapeDtypeStruct((K, P), f32),
                jax.ShapeDtypeStruct((K,), f32),
            ),
        ),
    }
