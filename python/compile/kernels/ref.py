"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package must match its oracle to float32 tolerance;
``python/tests/`` enforces this with hypothesis shape/value sweeps. The
oracles are also what the kernels' *gradients* are validated against.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Oracle for :func:`..fused_linear.matmul_pallas`."""
    return jnp.matmul(
        x.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def fused_linear_ref(x, w, b, activation="relu"):
    """Oracle for :func:`..fused_linear.fused_linear`."""
    out = matmul_ref(x, w) + b.astype(jnp.float32)[None, :]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "tanh":
        out = jnp.tanh(out)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return out


def aggregate_ref(stacked, weights):
    """Oracle for :func:`..aggregate.aggregate_pallas` (Eq. 4)."""
    return jnp.einsum(
        "k,kp->p", weights.astype(jnp.float32), stacked.astype(jnp.float32)
    )
