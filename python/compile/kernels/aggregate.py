"""Pallas weighted model aggregation kernel — Eq. (4) of the paper.

An activated worker v_i pulls the (possibly stale) models of its selected
in-neighbors and computes

    ŵ_t^i = Σ_j σ_t^{i,j} · w_t^j ,   σ from relative data sizes.

Here the neighbor models arrive as a stacked ``[K_max, P]`` float32 matrix
of flattened parameter vectors plus a ``[K_max]`` weight vector. The
topology is dynamic, so the *actual* neighbor count varies per round; the
HLO artifact has a fixed shape, and callers zero-pad the unused rows
(weight 0 ⇒ exact no-op — tested on both the Python and Rust sides).

TPU-style tiling: the parameter axis is split into VMEM-sized ``bp``
columns; each grid step loads the full ``[K_max, bp]`` slab (K_max is
small — ≤ the paper's neighbor cap s) and reduces it against the weight
vector in one pass, i.e. the reduction is K-stationary and the model slab
streams HBM→VMEM exactly once.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BP = 1024


def _aggregate_kernel(stacked_ref, w_ref, o_ref):
    # stacked_ref: [K, bp] slab, w_ref: [1, K] weights, o_ref: [1, bp].
    # One fused reduction: weights contract against the model slab.
    o_ref[...] = jnp.dot(
        w_ref[...], stacked_ref[...], preferred_element_type=jnp.float32
    )


def aggregate_pallas(stacked, weights, *, bp=DEFAULT_BP):
    """Weighted sum of stacked flattened models.

    Args:
      stacked: ``[K, P]`` float32 — one flattened model per row.
      weights: ``[K]`` float32 — aggregation weights (zero rows are padding).

    Returns:
      ``[P]`` float32 aggregated model.
    """
    k, p = stacked.shape
    assert weights.shape == (k,), f"weights {weights.shape} != ({k},)"
    rem = (-p) % bp
    sp = jnp.pad(stacked.astype(jnp.float32), ((0, 0), (0, rem)))
    pp = p + rem
    out = pl.pallas_call(
        _aggregate_kernel,
        out_shape=jax.ShapeDtypeStruct((1, pp), jnp.float32),
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((k, bp), lambda i: (0, i)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda i: (0, i)),
        interpret=True,
    )(sp, weights.astype(jnp.float32)[None, :])
    return out[0, :p]
