"""Layer-1 Pallas kernels for the DySTop compute path.

Every kernel here is authored for TPU-style tiling (VMEM blocks via
BlockSpec) but executed with ``interpret=True`` so the lowered HLO runs on
any PJRT backend, including the Rust CPU client (see DESIGN.md
§Hardware-Adaptation).
"""

from .fused_linear import fused_linear, matmul_pallas
from .aggregate import aggregate_pallas

__all__ = ["fused_linear", "matmul_pallas", "aggregate_pallas"]
