"""Pallas fused dense layer: tiled ``act(x @ w + b)``.

This is the compute hot-spot of the per-worker local training step
(Eq. (5) of the paper): every layer of the worker model funnels through
this kernel in both the forward and the backward pass.

TPU-style design (see DESIGN.md §Hardware-Adaptation):

* The grid is ``(M/bm, N/bn, K/bk)`` with the contraction dimension
  innermost, so each ``(i, j)`` output tile stays resident in VMEM while
  the kernel accumulates partial products over ``k`` — the classic
  MXU-feeding schedule (output-stationary, double-buffered HBM→VMEM loads
  handled by the Pallas pipeline).
* Bias add and activation are fused into the final ``k`` step so the
  activation never round-trips to HBM.
* Inputs are zero-padded to tile multiples in the wrapper; zero padding is
  exact for matmul+bias+ReLU and the wrapper slices the result back.

``interpret=True`` everywhere: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.

The kernel is differentiable via an explicit ``jax.custom_vjp`` whose
backward pass reuses the same tiled matmul kernel (``dx = g' @ wᵀ``,
``dw = xᵀ @ g'``), so the *whole* train step lowers to Pallas-generated
HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM tile sizes. On a real TPU these would be multiples of the
# (8, 128) float32 native tile; we keep the same aspect logic but smaller
# absolute sizes so interpret-mode tests stay fast. They are parameters
# everywhere, so the TPU retune is a config change.
DEFAULT_BM = 32
DEFAULT_BN = 64
DEFAULT_BK = 64


def _pad_to(x, axis, multiple):
    """Zero-pad ``x`` along ``axis`` up to the next multiple of ``multiple``."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def _matmul_kernel(x_ref, w_ref, o_ref, *, n_k):
    """Grid (i, j, k): accumulate x_tile @ w_tile into the (i, j) out tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _fused_kernel(x_ref, w_ref, b_ref, o_ref, *, n_k, activation):
    """Matmul accumulation with bias + activation fused into the last step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...]
        if activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        elif activation == "tanh":
            acc = jnp.tanh(acc)
        # "none": identity
        o_ref[...] = acc


def _tiled_call(kernel, out_shape, grid, x, w, extra_inputs=(), *, bm, bn, bk):
    """Shared pallas_call plumbing for the matmul-shaped kernels."""
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    for _ in extra_inputs:
        # bias: one (1, bn) row per j tile, broadcast over rows.
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
    return pl.pallas_call(
        kernel,
        out_shape=out_shape,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        interpret=True,
    )(x, w, *extra_inputs)


def matmul_pallas(x, w, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Tiled ``x @ w`` via the Pallas kernel (float32).

    Shapes: ``x: [M, K]``, ``w: [K, N]`` → ``[M, N]``. Arbitrary sizes;
    padding to tile multiples happens internally.
    """
    m, k0 = x.shape
    k1, n = w.shape
    assert k0 == k1, f"contraction mismatch {x.shape} @ {w.shape}"
    xp = _pad_to(_pad_to(x.astype(jnp.float32), 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), 0, bk), 1, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = _tiled_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid,
        xp,
        wp,
        bm=bm,
        bn=bn,
        bk=bk,
    )
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, activation="relu"):
    """Fused ``act(x @ w + b)`` as a single Pallas kernel.

    Args:
      x: ``[M, K]`` float32 input activations.
      w: ``[K, N]`` float32 weights.
      b: ``[N]`` float32 bias.
      activation: ``"relu"``, ``"tanh"`` or ``"none"`` (static).

    Differentiable: backward reuses :func:`matmul_pallas` so gradients are
    also Pallas-tiled.
    """
    return _fused_forward(x, w, b, activation)


def _fused_forward(x, w, b, activation, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    m, k0 = x.shape
    k1, n = w.shape
    assert k0 == k1, f"contraction mismatch {x.shape} @ {w.shape}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    xp = _pad_to(_pad_to(x.astype(jnp.float32), 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), 0, bk), 1, bn)
    bp = _pad_to(b.astype(jnp.float32)[None, :], 1, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = _tiled_call(
        functools.partial(_fused_kernel, n_k=grid[2], activation=activation),
        jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid,
        xp,
        wp,
        extra_inputs=(bp,),
        bm=bm,
        bn=bn,
        bk=bk,
    )
    return out[:m, :n]


def _fused_fwd(x, w, b, activation):
    out = _fused_forward(x, w, b, activation)
    return out, (x, w, out)


def _fused_bwd(activation, res, g):
    x, w, out = res
    if activation == "relu":
        g = g * (out > 0.0).astype(g.dtype)
    elif activation == "tanh":
        g = g * (1.0 - out * out)
    # "none": g unchanged
    dx = matmul_pallas(g, w.T)
    dw = matmul_pallas(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


fused_linear.defvjp(_fused_fwd, _fused_bwd)
