"""AOT compile path: lower L2/L1 to HLO text artifacts + manifest.

Interchange format is HLO *text*, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    python -m compile.aot --out-dir ../artifacts [--models mlp,cnn]

Emits ``<model>_{train,eval,agg}.hlo.txt`` plus ``manifest.json`` with the
shape/layout contract the Rust runtime reads.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(spec, out_dir):
    """Lower all entry points of one model variant; return manifest entry."""
    files = {}
    for kind, (fn, args) in M.entry_points(spec).items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{spec.name}_{kind}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[kind] = fname
    return {
        "param_count": spec.param_count,
        "input_dim": spec.input_dim,
        "num_classes": spec.num_classes,
        "train_batch": spec.train_batch,
        "eval_batch": spec.eval_batch,
        "k_max": spec.k_max,
        "layout": [
            {"name": n, "offset": off, "shape": list(shape)}
            for n, off, shape in spec.offsets()
        ],
        "artifacts": files,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="mlp,cnn",
                    help="comma-separated subset of: " + ",".join(M.SPECS))
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "version": 1, "models": {}}
    for name in args.models.split(","):
        spec = M.SPECS[name.strip()]
        manifest["models"][spec.name] = lower_model(spec, args.out_dir)
        print(f"lowered {spec.name}: P={spec.param_count}")
    path = os.path.join(args.out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
