"""L1 correctness: Pallas fused_linear / matmul vs pure-jnp oracle.

Hypothesis sweeps shapes (including non-tile-multiple edges) and values;
gradients of the custom VJP are validated against autodiff of the oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_linear, matmul_pallas
from compile.kernels.ref import fused_linear_ref, matmul_ref

DIM = st.integers(min_value=1, max_value=80)
ACT = st.sampled_from(["relu", "tanh", "none"])


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        matmul_pallas(x, w), matmul_ref(x, w), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=DIM, k=DIM, n=DIM, act=ACT, seed=st.integers(0, 2**31 - 1))
def test_fused_linear_matches_ref(m, k, n, act, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    b = _rand(seed + 2, (n,))
    np.testing.assert_allclose(
        fused_linear(x, w, b, act), fused_linear_ref(x, w, b, act),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("act", ["relu", "tanh", "none"])
@pytest.mark.parametrize("shape", [(5, 7, 3), (32, 64, 64), (33, 65, 10)])
def test_gradients_match_ref(act, shape):
    m, k, n = shape
    x = _rand(10, (m, k))
    w = _rand(11, (k, n))
    b = _rand(12, (n,))
    # scalar-valued wrappers so jax.grad applies
    f = lambda x, w, b: jnp.sum(jnp.sin(fused_linear(x, w, b, act)))
    g = lambda x, w, b: jnp.sum(jnp.sin(fused_linear_ref(x, w, b, act)))
    got = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
    want = jax.grad(g, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(got, want):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


def test_exact_tile_multiples_no_padding_effects():
    # shapes exactly on tile boundaries must also match
    x = _rand(20, (64, 128))
    w = _rand(21, (128, 128))
    b = _rand(22, (128,))
    np.testing.assert_allclose(
        fused_linear(x, w, b, "relu"), fused_linear_ref(x, w, b, "relu"),
        rtol=1e-5, atol=1e-5)


def test_block_size_invariance():
    # the result must not depend on the tiling choice
    x = _rand(30, (40, 50))
    w = _rand(31, (50, 30))
    a = matmul_pallas(x, w, bm=8, bn=16, bk=32)
    c = matmul_pallas(x, w, bm=32, bn=64, bk=64)
    np.testing.assert_allclose(a, c, rtol=1e-5, atol=1e-5)


def test_contraction_mismatch_raises():
    with pytest.raises(AssertionError):
        matmul_pallas(jnp.zeros((2, 3)), jnp.zeros((4, 5)))


def test_jittable_and_lowers_to_hlo():
    # the kernel must survive jit + lowering (the aot path)
    f = jax.jit(lambda x, w, b: fused_linear(x, w, b, "relu"))
    lowered = f.lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8,), jnp.float32))
    assert "stablehlo" in str(lowered.compiler_ir("stablehlo"))
