"""L2 correctness: model entry points (train/eval/aggregate) per variant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(params=["mlp", "cnn"])
def spec(request):
    return M.SPECS[request.param]


def _toy_batch(spec, n, seed=0):
    """Linearly-separable-ish toy data so a few SGD steps visibly help."""
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    y = jax.random.randint(ky, (n,), 0, spec.num_classes)
    centers = jax.random.normal(
        jax.random.PRNGKey(99), (spec.num_classes, spec.input_dim))
    x = centers[y] + 0.3 * jax.random.normal(kx, (n, spec.input_dim))
    return x.astype(jnp.float32), y.astype(jnp.int32)


def test_pack_unpack_roundtrip(spec):
    flat = M.init_params(spec, seed=3)
    assert flat.shape == (spec.param_count,)
    again = M.pack(spec, M.unpack(spec, flat))
    np.testing.assert_array_equal(flat, again)


def test_layout_offsets_are_contiguous(spec):
    off = 0
    for name, start, shape in spec.offsets():
        assert start == off
        off += int(np.prod(shape))
    assert off == spec.param_count


def test_forward_shapes(spec):
    flat = M.init_params(spec)
    x, _ = _toy_batch(spec, spec.train_batch)
    logits = M.forward(spec, flat, x)
    assert logits.shape == (spec.train_batch, spec.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_train_step_reduces_loss(spec):
    train = jax.jit(M.make_train_step(spec))
    flat = M.init_params(spec, seed=1)
    x, y = _toy_batch(spec, spec.train_batch)
    lr = jnp.float32(0.1)
    flat1, loss0 = train(flat, x, y, lr)
    losses = [float(loss0)]
    for _ in range(20):
        flat1, loss = train(flat1, x, y, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses
    assert flat1.shape == flat.shape


def test_eval_step_counts(spec):
    ev = jax.jit(M.make_eval_step(spec))
    flat = M.init_params(spec, seed=2)
    x, y = _toy_batch(spec, spec.eval_batch)
    loss_sum, correct = ev(flat, x, y)
    # manual recompute
    logits = M.forward(spec, flat, x)
    pred = jnp.argmax(logits, axis=-1)
    np.testing.assert_allclose(
        float(correct), float(jnp.sum(pred == y)), atol=0)
    assert 0 <= float(correct) <= spec.eval_batch
    assert float(loss_sum) > 0


def test_eval_correct_after_training(spec):
    """Accuracy on the training batch should rise well above chance."""
    train = jax.jit(M.make_train_step(spec))
    ev = jax.jit(M.make_eval_step(spec))
    flat = M.init_params(spec, seed=4)
    x, y = _toy_batch(spec, spec.train_batch, seed=5)
    xe = jnp.tile(x, (spec.eval_batch // spec.train_batch, 1))
    ye = jnp.tile(y, (spec.eval_batch // spec.train_batch,))
    for _ in range(40):
        flat, _ = train(flat, x, y, jnp.float32(0.1))
    _, correct = ev(flat, xe, ye)
    acc = float(correct) / spec.eval_batch
    assert acc > 0.5, acc


def test_aggregate_entry_point(spec):
    agg = jax.jit(M.make_aggregate(spec))
    k = spec.k_max
    models = jnp.stack([M.init_params(spec, seed=s) for s in range(3)])
    stacked = jnp.concatenate(
        [models, jnp.zeros((k - 3, spec.param_count))])
    w = jnp.concatenate([jnp.full(3, 1.0 / 3), jnp.zeros(k - 3)])
    (out,) = agg(stacked, w)
    np.testing.assert_allclose(
        out, jnp.mean(models, axis=0), rtol=1e-5, atol=1e-5)


def test_aggregate_of_identical_models_is_identity(spec):
    agg = jax.jit(M.make_aggregate(spec))
    flat = M.init_params(spec, seed=6)
    stacked = jnp.tile(flat, (spec.k_max, 1))
    w = jnp.full(spec.k_max, 1.0 / spec.k_max)
    (out,) = agg(stacked, w)
    np.testing.assert_allclose(out, flat, rtol=1e-5, atol=1e-5)


def test_gradient_matches_numerical(spec):
    """Spot-check d loss/d params against central differences."""
    x, y = _toy_batch(spec, 8)
    x = x[:8]
    y = y[:8]

    def loss(flat):
        logits = M.forward(spec, flat, x)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    flat = M.init_params(spec, seed=7)
    g = jax.grad(loss)(flat)
    rng = np.random.default_rng(0)
    idx = rng.choice(spec.param_count, size=5, replace=False)
    eps = 1e-3
    for i in idx:
        e = jnp.zeros_like(flat).at[i].set(eps)
        num = (float(loss(flat + e)) - float(loss(flat - e))) / (2 * eps)
        np.testing.assert_allclose(float(g[i]), num, rtol=2e-2, atol=2e-3)
