"""L1 correctness: Pallas aggregation kernel (Eq. 4) vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import aggregate_pallas
from compile.kernels.ref import aggregate_ref


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 16),
    p=st.integers(1, 5000),
    seed=st.integers(0, 2**31 - 1),
)
def test_aggregate_matches_ref(k, p, seed):
    stacked = _rand(seed, (k, p))
    w = jax.random.dirichlet(jax.random.PRNGKey(seed + 1), jnp.ones(k))
    np.testing.assert_allclose(
        aggregate_pallas(stacked, w), aggregate_ref(stacked, w),
        rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(k=st.integers(1, 8), pad=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_zero_weight_padding_is_exact(k, pad, seed):
    """Padded rows with weight 0 must not change the result at all.

    This is the contract the Rust side relies on: the agg artifact is
    compiled for K_max and callers zero-pad (DESIGN.md §6).
    """
    p = 257
    real = _rand(seed, (k, p))
    w = jax.random.dirichlet(jax.random.PRNGKey(seed + 1), jnp.ones(k))
    # padding rows contain garbage — only the zero weight protects us
    garbage = 1e6 * _rand(seed + 2, (pad, p))
    stacked = jnp.concatenate([real, garbage])
    wp = jnp.concatenate([w, jnp.zeros(pad)])
    np.testing.assert_allclose(
        aggregate_pallas(stacked, wp), aggregate_ref(real, w),
        rtol=1e-5, atol=1e-4)


def test_identity_on_single_model():
    m = _rand(7, (1, 1234))
    np.testing.assert_allclose(
        aggregate_pallas(m, jnp.ones(1)), m[0], rtol=1e-6, atol=1e-6)


def test_uniform_weights_are_mean():
    stacked = _rand(8, (4, 333))
    got = aggregate_pallas(stacked, jnp.full(4, 0.25))
    np.testing.assert_allclose(got, jnp.mean(stacked, axis=0),
                               rtol=1e-5, atol=1e-5)


def test_block_size_invariance():
    stacked = _rand(9, (5, 2049))
    w = jax.random.dirichlet(jax.random.PRNGKey(10), jnp.ones(5))
    a = aggregate_pallas(stacked, w, bp=128)
    b = aggregate_pallas(stacked, w, bp=1024)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
