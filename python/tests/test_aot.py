"""AOT pipeline: artifacts lower, parse as HLO text, manifest is consistent."""

import json
import os

import jax
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def out_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    entry = aot.lower_model(M.SPECS["mlp"], str(d))
    manifest = {"format": "hlo-text", "version": 1, "models": {"mlp": entry}}
    with open(d / "manifest.json", "w") as f:
        json.dump(manifest, f)
    return d


def test_artifact_files_exist(out_dir):
    for kind in ("train", "eval", "agg"):
        p = out_dir / f"mlp_{kind}.hlo.txt"
        assert p.exists() and p.stat().st_size > 100


def test_hlo_text_has_entry_computation(out_dir):
    for kind in ("train", "eval", "agg"):
        text = (out_dir / f"mlp_{kind}.hlo.txt").read_text()
        assert "ENTRY" in text, kind
        assert "HloModule" in text, kind


def test_manifest_matches_spec(out_dir):
    manifest = json.loads((out_dir / "manifest.json").read_text())
    spec = M.SPECS["mlp"]
    entry = manifest["models"]["mlp"]
    assert entry["param_count"] == spec.param_count
    assert entry["input_dim"] == spec.input_dim
    assert entry["k_max"] == spec.k_max
    total = sum(
        int(__import__("math").prod(l["shape"])) for l in entry["layout"])
    assert total == spec.param_count


def test_train_artifact_param_shapes(out_dir):
    """The HLO entry signature must carry the manifest shapes."""
    spec = M.SPECS["mlp"]
    text = (out_dir / "mlp_train.hlo.txt").read_text()
    assert f"f32[{spec.param_count}]" in text
    assert f"f32[{spec.train_batch},{spec.input_dim}]" in text


def test_lowered_train_step_executes_like_eager(out_dir):
    """Round-trip sanity: jit-compiled == eager for the same inputs."""
    import jax.numpy as jnp
    import numpy as np

    spec = M.SPECS["mlp"]
    train = M.make_train_step(spec)
    flat = M.init_params(spec, seed=11)
    x = jax.random.normal(
        jax.random.PRNGKey(0), (spec.train_batch, spec.input_dim))
    y = jax.random.randint(
        jax.random.PRNGKey(1), (spec.train_batch,), 0, spec.num_classes)
    lr = jnp.float32(0.05)
    p1, l1 = train(flat, x, y, lr)
    p2, l2 = jax.jit(train)(flat, x, y, lr)
    np.testing.assert_allclose(p1, p2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
