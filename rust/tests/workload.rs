//! Workload subsystem contracts:
//!
//! * registry: every `workload.model` × `workload.dataset` pair runs
//!   through the builder; inactive knobs are inert on the default
//!   (linear × synthetic) bit-identity pair;
//! * codec round-trips: for every registered model,
//!   `aggregate(encode→decode(params))` is bit-exact under the dense
//!   codec and within the documented error bounds under topk/int8
//!   (property-tested over random seeds);
//! * determinism: the `thread_count_never_changes_results` witness runs
//!   once per registered model (the CI matrix additionally routes the
//!   `DYSTOP_WORKLOAD_MODEL` env knob through the end-to-end smoke);
//! * scenarios: `Join` re-initialises parameters from the *model's*
//!   layout (model-described re-init), `Rejoin` keeps the stale vector;
//! * Fig. 28's claim: `mlp` and `cnn-s` reach strictly higher accuracy
//!   than `linear` on the shifted-cluster workload.

use dystop::config::{
    BackendKind, CodecKind, DatasetKind, ExperimentConfig, ModelArch,
    TransportConfig, WorkloadConfig,
};
use dystop::data::SyntheticSpec;
use dystop::experiment::{Experiment, ExperimentError, VirtualClockEngine};
use dystop::scenario::{Scenario, ScenarioEvent};
use dystop::transport::Transport;
use dystop::util::prop::forall_seeded;
use dystop::util::rng::Pcg;
use dystop::worker::{aggregate_native, NativeTrainer, Trainer};
use dystop::workload::{build_model, clusters_corpus, Model, MODELS};

fn wl_cfg(model: ModelArch, dataset: DatasetKind) -> ExperimentConfig {
    ExperimentConfig {
        workers: 8,
        rounds: 6,
        train_per_worker: 48,
        test_samples: 80,
        eval_every: 3,
        seed: 42,
        target_accuracy: 2.0,
        workload: WorkloadConfig { model, dataset, ..Default::default() },
        ..Default::default()
    }
}

fn workload_of(model: ModelArch) -> WorkloadConfig {
    WorkloadConfig { model, ..Default::default() }
}

#[test]
fn every_model_dataset_pair_runs_through_the_builder() {
    for arch in MODELS {
        for ds in [
            DatasetKind::Synthetic,
            DatasetKind::Clusters,
            DatasetKind::Drift,
        ] {
            let res = Experiment::builder(wl_cfg(arch, ds))
                .backend(BackendKind::Sim)
                .run()
                .unwrap_or_else(|e| {
                    panic!("{} × {}: {e}", arch.name(), ds.name())
                });
            assert_eq!(res.rounds.len(), 6, "{} × {}", arch.name(), ds.name());
            assert!(
                res.evals.iter().all(|e| e.avg_loss.is_finite()),
                "{} × {}",
                arch.name(),
                ds.name()
            );
        }
    }
}

#[test]
fn inactive_workload_knobs_are_inert_on_the_default_pair() {
    // linear × synthetic is the bit-identity pair: mlp/cnn/dataset knobs
    // that aren't selected must not change a single bit of the run
    let a = Experiment::builder(wl_cfg(
        ModelArch::Linear,
        DatasetKind::Synthetic,
    ))
    .backend(BackendKind::Sim)
    .run()
    .unwrap();
    let mut cfg = wl_cfg(ModelArch::Linear, DatasetKind::Synthetic);
    cfg.workload.hidden = 64;
    cfg.workload.conv_filters = 3;
    cfg.workload.conv_kernel = 7;
    cfg.workload.conv_stride = 1;
    cfg.workload.cluster_skew = 0.1;
    cfg.workload.drift_deg = 123.0;
    let b = Experiment::builder(cfg)
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    assert!(a.bits_eq(&b), "inactive workload knobs changed the run");
}

#[test]
fn thread_count_never_changes_results_for_every_model() {
    // the parallel-engine invariant, once per registered model: pool
    // slots clone the trainer (and so the model's scratch) — no clone
    // may diverge a run for any architecture
    for arch in MODELS {
        let run_with = |threads: usize| {
            let mut cfg = wl_cfg(arch, DatasetKind::Synthetic);
            cfg.threads = threads;
            Experiment::builder(cfg)
                .backend(BackendKind::Sim)
                .run()
                .unwrap()
        };
        let sequential = run_with(1);
        for threads in [2usize, 4] {
            assert!(
                sequential.bits_eq(&run_with(threads)),
                "{}: threads=1 vs threads={threads} diverged",
                arch.name()
            );
        }
    }
}

#[test]
fn codec_roundtrip_property_for_every_model() {
    for arch in MODELS {
        let model = build_model(&workload_of(arch), 32, 10);
        let p_count = model.param_count();
        let dense_bits = p_count as f64 * 32.0;
        forall_seeded(17, 12, |rng| {
            let params = model.init(rng.next_u64());
            // dense: encode→view→aggregate is bit-exact
            let mut t = Transport::new(
                TransportConfig::default(),
                2,
                p_count,
                dense_bits,
            );
            t.encode(0, &params);
            let view: Vec<f32> = t.view(0, &params).to_vec();
            let agg = aggregate_native(&[&view], &[1.0]);
            for (i, (a, p)) in agg.iter().zip(&params).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    p.to_bits(),
                    "{} dense roundtrip at {i}",
                    model.name()
                );
            }
            // topk: repeated sends of frozen params drain the
            // error-feedback residual (documented convergence bound)
            let mut t = Transport::new(
                TransportConfig {
                    codec: CodecKind::TopK,
                    ..Default::default()
                },
                2,
                p_count,
                dense_bits,
            );
            for _ in 0..14 {
                t.encode(0, &params);
            }
            let agg =
                aggregate_native(&[t.decoded(0).unwrap()], &[1.0]);
            let err = agg
                .iter()
                .zip(&params)
                .map(|(a, p)| (a - p).abs())
                .fold(0.0f32, f32::max);
            assert!(
                err < 1e-4,
                "{} topk residual not drained: {err}",
                model.name()
            );
            // int8: decode error ≤ clip/255 for in-range values
            let clip = 1.0f32;
            let clipped: Vec<f32> =
                params.iter().map(|v| v.clamp(-clip, clip)).collect();
            let mut t = Transport::new(
                TransportConfig {
                    codec: CodecKind::Int8,
                    int8_clip: clip as f64,
                    ..Default::default()
                },
                2,
                p_count,
                dense_bits,
            );
            t.encode(0, &clipped);
            let agg =
                aggregate_native(&[t.decoded(0).unwrap()], &[1.0]);
            let bound = clip / 255.0;
            for (i, (a, p)) in agg.iter().zip(&clipped).enumerate() {
                assert!(
                    (a - p).abs() <= bound * 1.001 + 1e-7,
                    "{} int8 at {i}: |{a} - {p}| > clip/255",
                    model.name()
                );
            }
        });
    }
}

#[test]
fn scenario_join_reinit_is_model_described_and_rejoin_keeps_stale_params() {
    // a Leave→Join slot must restart from the *model's* init (the
    // pre-workload engine would have re-initialised a linear vector);
    // a Leave→Rejoin slot must keep its stale vector frozen. The CI
    // matrix routes the architecture through DYSTOP_WORKLOAD_MODEL
    // (default mlp) — the expectations below are model-generic.
    let mut cfg = wl_cfg(
        ModelArch::from_env_or(ModelArch::Mlp),
        DatasetKind::Synthetic,
    );
    cfg.workers = 10;
    cfg.rounds = 8;
    let script = Scenario::from_events(vec![
        (2, ScenarioEvent::Leave { worker: 3 }),
        (2, ScenarioEvent::Leave { worker: 5 }),
        (4, ScenarioEvent::Join { worker: 3 }),
        (5, ScenarioEvent::Rejoin { worker: 5 }),
    ]);
    let exp = Experiment::builder(cfg.clone())
        .scenario(script)
        .build()
        .unwrap();
    let mut eng = VirtualClockEngine::new(exp);
    eng.step(); // round 1: everyone present
    let pre_leave_3 = eng.workers[3].params.clone();
    let pre_leave_5 = eng.workers[5].params.clone();
    eng.step(); // round 2: leaves apply
    assert!(!eng.present_ids().contains(&3));
    assert!(!eng.present_ids().contains(&5));
    eng.step(); // round 3: absent → params frozen
    assert_eq!(eng.workers[3].params, pre_leave_3);
    assert_eq!(eng.workers[5].params, pre_leave_5);

    let trainer = NativeTrainer::from_config(&cfg);
    let expected_init = trainer.init(cfg.seed.wrapping_add(3));
    let plan4 = eng.step(); // round 4: Join{3}
    assert!(eng.present_ids().contains(&3));
    // layout is model-described in every case; the exact re-init vector
    // is only observable when the scheduler didn't activate the fresh
    // worker in its first round back
    assert_eq!(eng.workers[3].params.len(), expected_init.len());
    if !plan4.active.contains(&3) {
        assert_eq!(eng.workers[3].params, expected_init);
    }
    let plan5 = eng.step(); // round 5: Rejoin{5}
    assert!(eng.present_ids().contains(&5));
    if !plan5.active.contains(&5) {
        // stale vector kept — precisely what the device left with
        assert_eq!(eng.workers[5].params, pre_leave_5);
        // and its staleness advanced through the downtime
        assert!(
            eng.workers[5].staleness >= 3,
            "τ = {}",
            eng.workers[5].staleness
        );
    }
    // the event log accounts for all four population changes
    let kinds: Vec<&str> =
        eng.result().events.iter().map(|e| e.kind).collect();
    assert_eq!(kinds, vec!["leave", "leave", "join", "rejoin"]);
}

#[test]
fn mlp_and_cnn_beat_linear_on_the_shifted_cluster_workload() {
    // the Fig. 28 claim at trainer level: antipodal cluster pairs cap a
    // linear separator near the majority-cluster share, while the
    // nonlinear models resolve both modes
    let spec = SyntheticSpec {
        train_samples: 2000,
        test_samples: 500,
        class_sep: 3.0,
        ..Default::default()
    };
    let (train, test) = clusters_corpus(&spec, 0.6);
    let acc_of = |arch: ModelArch| {
        let mut t = NativeTrainer::with_model(build_model(
            &workload_of(arch),
            spec.dim,
            spec.num_classes,
        ));
        let p0 = t.init(0);
        let mut rng = Pcg::seeded(7);
        let (p1, _) = t.train(&p0, &train, 500, 32, 0.15, &mut rng);
        t.evaluate(&p1, &test).1
    };
    let linear = acc_of(ModelArch::Linear);
    let mlp = acc_of(ModelArch::Mlp);
    let cnn = acc_of(ModelArch::CnnS);
    // the linear ceiling is real (antipodal modes are irreconcilable)…
    assert!(linear < 0.85, "linear {linear} suspiciously high");
    // …and both nonlinear models clear it strictly (observed margins
    // are ≥ +0.15; asserted with slack for sampling noise)
    assert!(mlp > linear + 0.10, "mlp {mlp} vs linear {linear}");
    assert!(cnn > linear + 0.05, "cnn-s {cnn} vs linear {linear}");
}

#[test]
fn env_selected_model_runs_the_clusters_workload_end_to_end() {
    // the CI matrix leg: DYSTOP_WORKLOAD_MODEL picks the architecture
    // this end-to-end smoke trains (default mlp)
    let arch = ModelArch::from_env_or(ModelArch::Mlp);
    let mut cfg = wl_cfg(arch, DatasetKind::Clusters);
    cfg.rounds = 8;
    let res = Experiment::builder(cfg)
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    assert_eq!(res.rounds.len(), 8);
    assert!(res.best_accuracy() > 0.0);
    assert!(res.evals.iter().all(|e| e.avg_loss.is_finite()));
}

#[test]
fn file_corpus_adopts_its_own_shape_through_the_builder() {
    let dir = std::env::temp_dir()
        .join(format!("dystop_wl_file_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("corpus.csv");
    let mut text = String::new();
    for i in 0..120 {
        let y = i % 4;
        // class-dependent features so the corpus is learnable
        text.push_str(&format!(
            "{y},{},{},{}\n",
            y as f64 * 0.8 + (i % 7) as f64 * 0.01,
            1.0 - y as f64 * 0.3,
            (i % 5) as f64 * 0.1
        ));
    }
    std::fs::write(&p, text).unwrap();

    let mut cfg = wl_cfg(ModelArch::Mlp, DatasetKind::File);
    cfg.workload.path = p.to_str().unwrap().to_string();
    cfg.test_samples = 20;
    // 100 train rows over 8 workers: a small batch keeps the per-worker
    // floor (batch.max(train_per_worker/4) = 12) within the corpus, so
    // the builder's coverage check passes
    cfg.batch = 8;
    // deliberately wrong in the config: the file defines the shape
    cfg.feature_dim = 32;
    cfg.num_classes = 10;
    let exp = Experiment::builder(cfg).build().unwrap();
    assert_eq!(exp.cfg.feature_dim, 3);
    assert_eq!(exp.cfg.num_classes, 4);
    // worker params follow the adopted mlp layout: 3·32 + 32 + 32·4 + 4
    assert_eq!(exp.workers[0].params.len(), 3 * 32 + 32 + 32 * 4 + 4);
    assert_eq!(exp.test.len(), 20);

    // a cnn-s whose kernel exceeds the adopted dim is a clean error
    let mut cfg = wl_cfg(ModelArch::CnnS, DatasetKind::File);
    cfg.workload.path = p.to_str().unwrap().to_string();
    cfg.test_samples = 20;
    cfg.batch = 8;
    match Experiment::builder(cfg).build() {
        Err(ExperimentError::InvalidConfig(m)) => {
            assert!(m.contains("conv_kernel"), "{m}");
        }
        other => panic!(
            "expected InvalidConfig for kernel>dim, got {:?}",
            other.map(|_| "Ok")
        ),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
