//! Delivery-layer contracts:
//!
//! * **conservation** — every round's recorded ledger
//!   (`retransmissions`/`dropped_msgs`/`corrupt_detected`, the byte
//!   surcharge, the dead-letter events) matches an *independent*
//!   recomputation from the captured round plans via the pure per-edge
//!   resolution, and every planned pull edge ends delivered or
//!   dead-lettered with every frame accounted exactly once;
//! * **integrity** — the CRC32 frame check catches every injected
//!   single-bit flip;
//! * **idempotence** — duplicated frames are charged wire bytes but
//!   never double-aggregate (the model trajectory is bit-identical to a
//!   duplicate-free run);
//! * **knob-inertness** — zero fault rates are bit-identical regardless
//!   of the protocol knobs, across codecs and models;
//! * **determinism** — an actively-faulty run is bit-identical across
//!   thread counts.
//!
//! Because both backends charge the ledger through the same pure
//! function of `(seed, round, plan)` — pinned here against the
//! recomputation witness for each backend separately — two backends
//! given the same seed and plans necessarily produce the same
//! delivery/byte ledger.
//!
//! The CI fault matrix re-runs this suite with `DYSTOP_FAULTS_PROFILE`
//! varied; [`FaultProfile::from_env_or`] routes that knob through the
//! end-to-end smoke below.

use dystop::config::{
    BackendKind, CodecKind, ExperimentConfig, FaultConfig, FaultProfile,
    ModelArch, SchedulerKind,
};
use dystop::coordinator::RoundPlan;
use dystop::delivery::{Delivery, DeliveryTally, Frame};
use dystop::experiment::{
    Experiment, RoundObserver, TestbedOptions, ThreadedBackend,
};
use dystop::metrics::RunResult;
use dystop::scenario::{Scenario, ScenarioEvent};
use dystop::util::prop::forall_seeded;
use std::cell::RefCell;
use std::rc::Rc;

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig {
        workers: 10,
        rounds: 8,
        train_per_worker: 48,
        test_samples: 64,
        eval_every: 4,
        seed: 42,
        target_accuracy: 2.0,
        ..Default::default()
    }
}

/// Observer capturing every validated (global-id) round plan.
struct PlanTap(Rc<RefCell<Vec<RoundPlan>>>);

impl RoundObserver for PlanTap {
    fn on_plan(&mut self, _round: usize, plan: &RoundPlan) {
        self.0.borrow_mut().push(plan.clone());
    }
}

fn run_with_plans(
    cfg: ExperimentConfig,
    backend: BackendKind,
) -> (RunResult, Vec<RoundPlan>) {
    let plans = Rc::new(RefCell::new(Vec::new()));
    let builder =
        Experiment::builder(cfg).observer(Box::new(PlanTap(plans.clone())));
    let res = match backend {
        BackendKind::Sim => builder.backend(BackendKind::Sim).run().unwrap(),
        BackendKind::Testbed => builder
            .backend_impl(Box::new(ThreadedBackend::with_options(
                TestbedOptions { time_scale: 2.0, profile: false },
            )))
            .run()
            .unwrap(),
    };
    let captured = plans.borrow().clone();
    (res, captured)
}

/// Recompute the ledger a backend must have charged for `plans` straight
/// from the pure per-edge resolution — the independent witness that
/// conservation and cross-backend agreement rest on.
fn expected_tallies(
    faults: &FaultConfig,
    seed: u64,
    plans: &[RoundPlan],
) -> Vec<DeliveryTally> {
    let delivery = Delivery::from_config(faults, seed);
    plans
        .iter()
        .enumerate()
        .map(|(r, plan)| {
            let round = (r + 1) as u64;
            let mut t = DeliveryTally::default();
            for (k, &i) in plan.active.iter().enumerate() {
                for &j in &plan.pulls_from[k] {
                    t.add(&delivery.resolve(round, j, i));
                }
            }
            t
        })
        .collect()
}

fn assert_ledger_matches(
    res: &RunResult,
    plans: &[RoundPlan],
    expect: &[DeliveryTally],
) {
    assert_eq!(plans.len(), res.rounds.len());
    let msg_bytes = res.model_bits / 8.0; // dense codec
    for (rec, (want, plan)) in
        res.rounds.iter().zip(expect.iter().zip(plans))
    {
        let r = rec.round;
        assert_eq!(rec.retransmissions, want.retransmissions, "round {r}");
        assert_eq!(rec.dropped_msgs, want.dropped_msgs(), "round {r}");
        assert_eq!(rec.corrupt_detected, want.corrupt, "round {r}");
        // conservation: every planned pull edge ends delivered or
        // dead-lettered; every frame is accepted, discarded as a
        // duplicate, dropped in transit, or rejected by CRC
        let pull_edges: usize =
            plan.pulls_from.iter().map(|v| v.len()).sum();
        assert_eq!(want.delivered + want.dead_lettered, pull_edges);
        assert_eq!(
            want.frames,
            want.delivered + want.duplicates + want.lost + want.corrupt
        );
        assert_eq!(want.frames, pull_edges + want.retransmissions);
        // retransmitted frames are charged real measured bytes
        let expect_bytes =
            (plan.transfers() + want.retransmissions) as f64 * msg_bytes;
        assert!(
            (rec.bytes_sent - expect_bytes).abs()
                <= 1e-6 * expect_bytes.max(1.0),
            "round {r}: bytes {} != {expect_bytes}",
            rec.bytes_sent
        );
    }
    let dead_events =
        res.events.iter().filter(|e| e.kind == "dead-letter").count();
    let dead_total: usize = expect.iter().map(|t| t.dead_lettered).sum();
    assert_eq!(dead_events, dead_total, "dead-letter event ledger");
}

// --- conservation + ledger agreement, both backends ------------------

#[test]
fn sim_ledger_matches_independent_edge_resolution() {
    for profile in [FaultProfile::Wifi, FaultProfile::Hostile] {
        let mut cfg = base_cfg();
        cfg.faults = FaultConfig::preset(profile);
        let (faults, seed) = (cfg.faults, cfg.seed);
        let (res, plans) = run_with_plans(cfg, BackendKind::Sim);
        let expect = expected_tallies(&faults, seed, &plans);
        assert_ledger_matches(&res, &plans, &expect);
    }
}

#[test]
fn threaded_ledger_matches_independent_edge_resolution() {
    let mut cfg = base_cfg();
    cfg.rounds = 5;
    cfg.compute_mean_s = 0.5;
    cfg.faults = FaultConfig::preset(FaultProfile::Cellular);
    let (faults, seed) = (cfg.faults, cfg.seed);
    let (res, plans) = run_with_plans(cfg, BackendKind::Testbed);
    let expect = expected_tallies(&faults, seed, &plans);
    assert_ledger_matches(&res, &plans, &expect);
}

// --- CRC integrity ----------------------------------------------------

#[test]
fn crc_detects_every_injected_single_bit_flip() {
    forall_seeded(0xC2C, 16, |rng| {
        let len = 1 + rng.below_usize(64);
        let payload: Vec<u8> =
            (0..len).map(|_| rng.below_usize(256) as u8).collect();
        let frame = Frame::new(rng.below_usize(1 << 20) as u64, payload);
        assert!(frame.check());
        for bit in 0..len * 8 {
            let mut f = frame.clone();
            f.flip_bit(bit);
            assert!(!f.check(), "bit {bit} of {len} bytes went undetected");
        }
    });
}

// --- duplicate suppression --------------------------------------------

#[test]
fn duplicate_frames_never_double_aggregate() {
    let clean = Experiment::builder(base_cfg())
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    let mut cfg = base_cfg();
    cfg.faults.dup = 1.0; // every delivery trails a suppressed duplicate
    let dup = Experiment::builder(cfg)
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    // the model trajectory is bit-identical: duplicates are discarded by
    // the sequence check before aggregation
    assert_eq!(clean.evals.len(), dup.evals.len());
    for (a, b) in clean.evals.iter().zip(&dup.evals) {
        assert_eq!(a.avg_accuracy.to_bits(), b.avg_accuracy.to_bits());
        assert_eq!(a.avg_loss.to_bits(), b.avg_loss.to_bits());
    }
    let mut surcharge = 0usize;
    for (a, b) in clean.rounds.iter().zip(&dup.rounds) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
        assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
        assert_eq!(a.transfers, b.transfers);
        // ...but every duplicate is charged on the wire
        assert_eq!(a.retransmissions, 0);
        assert!(b.bytes_sent >= a.bytes_sent);
        assert_eq!(b.dropped_msgs, 0);
        assert_eq!(b.corrupt_detected, 0);
        surcharge += b.retransmissions;
    }
    assert!(surcharge > 0, "dup=1.0 must retransmit on every pull edge");
}

// --- knob-inertness of the clean profile ------------------------------

#[test]
fn clean_profile_is_knob_inert_across_codec_and_model() {
    for (codec, model) in [
        (CodecKind::Dense, ModelArch::Linear),
        (CodecKind::TopK, ModelArch::Linear),
        (CodecKind::Int8, ModelArch::Mlp),
    ] {
        let mut cfg = base_cfg();
        cfg.rounds = 5;
        cfg.transport.codec = codec;
        cfg.workload.model = model;
        let base = Experiment::builder(cfg.clone())
            .backend(BackendKind::Sim)
            .run()
            .unwrap();
        // zero fault rates ⇒ inactive, whatever the protocol knobs say
        let mut knobbed = cfg.clone();
        knobbed.faults = FaultConfig {
            retries: 9,
            backoff_base_s: 7.0,
            backoff_cap_s: 30.0,
            jitter: 0.9,
            delay_spike_factor: 16.0,
            ..FaultConfig::preset(FaultProfile::Clean)
        };
        let tweaked = Experiment::builder(knobbed)
            .backend(BackendKind::Sim)
            .run()
            .unwrap();
        assert!(
            base.bits_eq(&tweaked),
            "clean not inert under codec={codec:?} model={model:?}"
        );
        assert!(base.rounds.iter().all(|r| r.retransmissions == 0
            && r.dropped_msgs == 0
            && r.corrupt_detected == 0));
        // the pin is meaningful: an active profile must diverge
        let mut lossy = cfg.clone();
        lossy.faults = FaultConfig::preset(FaultProfile::Hostile);
        let hostile = Experiment::builder(lossy)
            .backend(BackendKind::Sim)
            .run()
            .unwrap();
        assert!(
            !base.bits_eq(&hostile),
            "hostile left no trace under codec={codec:?} model={model:?}"
        );
    }
}

// --- determinism under active faults ----------------------------------

#[test]
fn determinism_lossy_threads_1_vs_4() {
    let mk = |threads: usize| {
        let mut cfg = base_cfg();
        cfg.workers = 12;
        cfg.rounds = 6;
        cfg.threads = threads;
        cfg.faults = FaultConfig::preset(FaultProfile::Cellular);
        Experiment::builder(cfg)
            .backend(BackendKind::Sim)
            .run()
            .unwrap()
    };
    let a = mk(1);
    let b = mk(4);
    assert!(a.bits_eq(&b), "lossy run must be thread-count invariant");
    // the witness is live: faults actually fired
    assert!(a.rounds.iter().any(|r| r.retransmissions > 0
        || r.dropped_msgs > 0
        || r.corrupt_detected > 0));
}

// --- scenario interplay: crash drops route through the ledger ---------

#[test]
fn crash_in_flight_models_land_in_the_dropped_ledger() {
    // SA-ADFL: round 1 activates exactly one worker, which pushes to all
    // its neighbors; nothing is consumed before the round-2 boundary. A
    // scripted crash of that worker at round 2 therefore drops exactly
    // round 1's pushes — the in-flight models that used to vanish
    // without a trace.
    let mut cfg = base_cfg();
    cfg.scheduler = SchedulerKind::SaAdfl;
    // bench-top geometry: everyone in range, so round 1 has pushes
    cfg.network.region_m = 20.0;
    cfg.network.comm_range_m = 30.0;
    cfg.network.mobility_m = 0.0;
    let (probe, plans) = run_with_plans(cfg.clone(), BackendKind::Sim);
    let w = plans[0].active[0];
    let pushed = plans[0].pushes.len();
    assert!(pushed > 0, "round 1 pushed nothing; widen the network");
    assert!(probe.rounds.iter().all(|r| r.dropped_msgs == 0));
    let script =
        Scenario::from_events(vec![(2, ScenarioEvent::Crash { worker: w })]);
    let res = Experiment::builder(cfg)
        .backend(BackendKind::Sim)
        .scenario(script)
        .run()
        .unwrap();
    assert_eq!(res.rounds[1].round, 2);
    assert_eq!(
        res.rounds[1].dropped_msgs, pushed,
        "every in-flight model dropped by the crash must be accounted"
    );
    // crash-routed, not transit loss: no retransmissions, no corruption
    assert!(res.rounds.iter().all(|r| r.retransmissions == 0
        && r.corrupt_detected == 0));
    assert!(res.events.iter().any(|e| e.kind == "crash"));
}

// --- graceful degradation under extreme loss --------------------------

#[test]
fn extreme_loss_degrades_gracefully_without_stalling() {
    let mut cfg = base_cfg();
    cfg.rounds = 6;
    cfg.faults.loss = 0.95;
    cfg.faults.retries = 0; // nearly every pull edge dead-letters
    let res = Experiment::builder(cfg)
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    assert_eq!(res.rounds.len(), 6);
    assert!(res
        .evals
        .iter()
        .all(|e| e.avg_accuracy.is_finite() && e.avg_loss.is_finite()));
    let dropped: usize = res.rounds.iter().map(|r| r.dropped_msgs).sum();
    assert!(dropped > 0, "95% loss must drop something");
    assert!(res.events.iter().any(|e| e.kind == "dead-letter"));
}

// --- CI fault matrix entry point --------------------------------------

/// The CI matrix legs re-run this with `DYSTOP_FAULTS_PROFILE` set to
/// wifi/cellular/hostile; locally it exercises the cellular preset.
#[test]
fn env_routed_profile_runs_end_to_end_with_an_exact_ledger() {
    let profile = FaultProfile::from_env_or(FaultProfile::Cellular);
    let mut cfg = base_cfg();
    cfg.rounds = 6;
    cfg.faults = FaultConfig::preset(profile);
    let (faults, seed) = (cfg.faults, cfg.seed);
    let (res, plans) = run_with_plans(cfg, BackendKind::Sim);
    assert_eq!(res.rounds.len(), 6);
    assert!(res.evals.iter().all(|e| e.avg_loss.is_finite()));
    let expect = expected_tallies(&faults, seed, &plans);
    assert_ledger_matches(&res, &plans, &expect);
}
