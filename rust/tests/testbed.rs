//! Integration over the thread-per-worker testbed runtime (§VII analog):
//! real concurrency, real message passing, compressed wall-clock delays.

use dystop::config::{ExperimentConfig, SchedulerKind};
use dystop::experiment::{Experiment, TestbedOptions, ThreadedBackend};
use dystop::metrics::RunResult;

/// Run the threaded backend through the builder (ex `run_testbed`).
fn run_testbed(cfg: ExperimentConfig, opts: TestbedOptions) -> RunResult {
    Experiment::builder(cfg)
        .backend_impl(Box::new(ThreadedBackend::with_options(opts)))
        .run()
        .expect("testbed run failed")
}

fn cfg(scheduler: SchedulerKind) -> ExperimentConfig {
    ExperimentConfig {
        workers: 15, // Table II testbed size
        rounds: 40,
        train_per_worker: 64,
        test_samples: 200,
        eval_every: 10,
        target_accuracy: 2.0,
        scheduler,
        compute_mean_s: 0.5,
        network: dystop::config::NetworkConfig {
            comm_range_m: 80.0,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn opts() -> TestbedOptions {
    // aggressive compression so the suite stays fast: 1 virtual s = 2 ms
    TestbedOptions { time_scale: 2.0, profile: true }
}

#[test]
fn testbed_dystop_runs_and_learns() {
    let res = run_testbed(cfg(SchedulerKind::DySTop), opts());
    assert_eq!(res.rounds.len(), 40);
    assert!(!res.evals.is_empty());
    let first = res.evals.first().unwrap().avg_accuracy;
    let best = res.best_accuracy();
    assert!(best > first, "no learning: {first} → {best}");
    assert!(best > 0.4, "best {best}");
}

#[test]
fn testbed_wall_clock_advances_monotonically() {
    let res = run_testbed(cfg(SchedulerKind::DySTop), opts());
    let mut prev = 0.0;
    for r in &res.rounds {
        assert!(r.time_s >= prev);
        prev = r.time_s;
    }
    assert!(prev > 0.0);
}

#[test]
fn testbed_runs_all_mechanisms() {
    for k in [
        SchedulerKind::AsyDfl,
        SchedulerKind::SaAdfl,
        SchedulerKind::Matcha,
    ] {
        let mut c = cfg(k);
        c.rounds = 15;
        let res = run_testbed(c, opts());
        assert_eq!(res.rounds.len(), 15, "{}", res.label);
        // smoke only: 15 rounds is far too few for SA-ADFL's one-worker-
        // per-round cadence to converge — just require sane metrics
        assert!(
            res.evals.iter().all(|e| e.avg_loss.is_finite()
                && (0.0..=1.0).contains(&e.avg_accuracy)),
            "{}",
            res.label
        );
    }
}

#[test]
fn testbed_staleness_tracked() {
    let res = run_testbed(cfg(SchedulerKind::DySTop), opts());
    // staleness must move (asynchrony) but stay controlled
    let max_tau = res.rounds.iter().map(|r| r.max_staleness).max().unwrap();
    assert!(max_tau > 0, "no asynchrony observed");
    assert!(max_tau < 40, "staleness unbounded: {max_tau}");
}
