//! Telemetry subsystem contracts:
//!
//! * **histogram algebra** — the log-linear buckets are a lattice:
//!   merging is associative and commutative, the bucket edges tile the
//!   u64 line with no gaps or overlaps, and any quantile read off the
//!   edges lands in the same bucket as the exact order statistic (so
//!   it is within one bucket width — ≤ 12.5% relative — of it);
//! * **inertness** — the hard invariant of the whole subsystem: the
//!   registry is write-only from every backend's perspective, so
//!   enabling telemetry moves **zero bits** in the run ledger. Pinned
//!   per backend: sim (dense and event engines) and socket via full
//!   `RunResult::bits_eq`, the testbed via plan + ledger fields that
//!   are pure functions of the seed (its wall-clock realization is
//!   legitimately nondeterministic, telemetry or not);
//! * **exposures** — the JSONL snapshot sink writes on cadence plus an
//!   unconditional end-of-run summary with every subsystem populated,
//!   and the /metrics endpoint serves valid Prometheus text exposition
//!   live, before and after the run it instruments;
//! * **event-engine traces** — `engine=event` feeds the activation
//!   observer stream exactly like the dense sweep: every activated
//!   worker gets a complete span in the Perfetto trace.

use dystop::config::{
    BackendKind, EngineKind, ExperimentConfig, SchedulerKind,
    SocketTransportKind,
};
use dystop::coordinator::RoundPlan;
use dystop::experiment::{
    Backend, Experiment, RoundObserver, VirtualClockBackend,
};
use dystop::metrics::RunResult;
use dystop::telemetry::hist::{
    bucket_index, bucket_lower, bucket_upper, Hist, BUCKETS,
};
use dystop::util::json::Json;
use dystop::util::prop::forall_seeded;
use dystop::util::rng::Pcg;
use std::cell::RefCell;
use std::rc::Rc;

// --- histogram algebra ------------------------------------------------

/// Random value spanning the full bucket range (shifted so sums cannot
/// saturate: saturating adds would blur the merge-equality checks).
fn rand_val(rng: &mut Pcg) -> u64 {
    rng.next_u64() >> (8 + rng.next_u32() % 56)
}

fn rand_hist(rng: &mut Pcg, n: usize) -> Hist {
    let mut h = Hist::new();
    for _ in 0..n {
        h.record(rand_val(rng));
    }
    h
}

#[test]
fn hist_merge_is_associative_and_commutative() {
    forall_seeded(0x7E1E, 32, |rng| {
        let a = rand_hist(rng, (rng.next_u32() % 64) as usize);
        let b = rand_hist(rng, (rng.next_u32() % 64) as usize);
        let c = rand_hist(rng, (rng.next_u32() % 64) as usize);
        // commutative: a ⊕ b == b ⊕ a
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "merge is not commutative");
        // associative: (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c)
        let mut ab_c = ab.clone();
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "merge is not associative");
        // identity: merging an empty histogram changes nothing
        let mut a_e = a.clone();
        a_e.merge(&Hist::new());
        assert_eq!(a_e, a, "empty histogram is not a merge identity");
    });
}

#[test]
fn hist_bucket_edges_tile_and_index_is_monotone() {
    for i in 0..BUCKETS - 1 {
        assert!(bucket_lower(i) < bucket_upper(i), "bucket {i} is empty");
        assert_eq!(
            bucket_upper(i),
            bucket_lower(i + 1),
            "gap or overlap after bucket {i}"
        );
        assert_eq!(
            bucket_index(bucket_lower(i)),
            i,
            "lower edge of bucket {i} maps elsewhere"
        );
    }
    assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    forall_seeded(0x0B0B, 64, |rng| {
        let (a, b) = (rand_val(rng), rand_val(rng));
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(
            bucket_index(lo) <= bucket_index(hi),
            "bucket_index not monotone: {lo} -> {}, {hi} -> {}",
            bucket_index(lo),
            bucket_index(hi)
        );
    });
}

#[test]
fn hist_quantile_is_within_one_bucket_of_exact() {
    assert_eq!(Hist::new().quantile(0.5), None, "empty hist has no quantile");
    forall_seeded(0x9A11, 32, |rng| {
        let n = 1 + (rng.next_u32() % 300) as usize;
        let mut h = Hist::new();
        let mut vals = Vec::with_capacity(n);
        for _ in 0..n {
            let v = rand_val(rng);
            h.record(v);
            vals.push(v);
        }
        vals.sort_unstable();
        for &q in &[0.0, 0.5, 0.9, 0.99, 1.0] {
            let rank = ((q * n as f64).ceil() as u64).clamp(1, n as u64);
            let exact = vals[rank as usize - 1];
            let got = h.quantile(q).expect("non-empty hist");
            // same bucket as the exact order statistic — hence within
            // one bucket width (≤ 12.5% relative beyond the unit range)
            let bi = bucket_index(exact);
            assert_eq!(
                bucket_index(got),
                bi,
                "q={q} n={n}: got {got}, exact {exact}"
            );
            assert!(got >= bucket_lower(bi) && got < bucket_upper(bi));
        }
    });
}

// --- inertness witnesses ----------------------------------------------

fn sim_cfg(workers: usize, rounds: usize) -> ExperimentConfig {
    ExperimentConfig {
        workers,
        rounds,
        seed: 11,
        train_per_worker: 48,
        test_samples: 64,
        eval_every: 7, // deliberately not a divisor of rounds
        target_accuracy: 2.0,
        ..Default::default()
    }
}

fn with_telemetry(mut cfg: ExperimentConfig) -> ExperimentConfig {
    cfg.telemetry.enabled = true;
    cfg
}

#[test]
fn telemetry_is_inert_on_the_sim_dense_ledger() {
    let cfg = sim_cfg(60, 20);
    let off = Experiment::builder(cfg.clone()).run().unwrap();
    let on = Experiment::builder(with_telemetry(cfg)).run().unwrap();
    assert!(
        off.bits_eq(&on),
        "enabling telemetry moved bits in the dense sim ledger"
    );
    assert!(
        off.rounds.iter().any(|r| r.transfers > 0),
        "a run with zero transfers pins nothing"
    );
}

#[test]
fn telemetry_is_inert_on_the_sim_event_ledger() {
    let mut cfg = sim_cfg(60, 20);
    cfg.engine = EngineKind::Event;
    let off = Experiment::builder(cfg.clone()).run().unwrap();
    let on = Experiment::builder(with_telemetry(cfg)).run().unwrap();
    assert!(
        off.bits_eq(&on),
        "enabling telemetry moved bits in the event-engine ledger"
    );
}

#[test]
fn telemetry_is_inert_on_the_socket_ledger() {
    // TCP so the witness runs on every platform; virtual seconds map to
    // ~0 wall ms — the ledger rides the virtual clock either way
    let mut cfg = sim_cfg(6, 4);
    cfg.seed = 42;
    cfg.eval_every = 2;
    cfg.socket.time_scale = 0.001;
    cfg.socket.transport = SocketTransportKind::Tcp;
    let off = Experiment::builder(cfg.clone())
        .backend(BackendKind::Socket)
        .run()
        .unwrap();
    let on = Experiment::builder(with_telemetry(cfg))
        .backend(BackendKind::Socket)
        .run()
        .unwrap();
    assert!(
        off.bits_eq(&on),
        "enabling telemetry moved bits in the socket ledger"
    );
}

/// Observer capturing every validated (global-id) round plan.
struct PlanTap(Rc<RefCell<Vec<RoundPlan>>>);

impl RoundObserver for PlanTap {
    fn on_plan(&mut self, _round: usize, plan: &RoundPlan) {
        self.0.borrow_mut().push(plan.clone());
    }
}

fn run_with_plans(
    cfg: ExperimentConfig,
    backend: BackendKind,
) -> (RunResult, Vec<RoundPlan>) {
    let plans = Rc::new(RefCell::new(Vec::new()));
    let res = Experiment::builder(cfg)
        .observer(Box::new(PlanTap(plans.clone())))
        .backend(backend)
        .run()
        .unwrap();
    let captured = plans.borrow().clone();
    (res, captured)
}

/// The testbed's wall-clock realization (durations, staleness, losses)
/// is legitimately nondeterministic run-to-run, telemetry or not — the
/// witness is everything that *is* a pure function of the seed:
/// SA-ADFL's timing-independent plans and the plan/delivery-derived
/// ledger fields.
#[test]
fn telemetry_is_inert_on_the_testbed_plans_and_ledger() {
    let mut cfg = sim_cfg(10, 6);
    cfg.seed = 42;
    cfg.eval_every = 3;
    cfg.scheduler = SchedulerKind::SaAdfl;
    // bench-top geometry: everyone in range, so transfers happen
    cfg.network.region_m = 20.0;
    cfg.network.comm_range_m = 30.0;
    cfg.network.mobility_m = 0.0;
    cfg.testbed.time_scale = 2.0;
    cfg.testbed.profile = false;
    let (off, off_plans) = run_with_plans(cfg.clone(), BackendKind::Testbed);
    let (on, on_plans) =
        run_with_plans(with_telemetry(cfg), BackendKind::Testbed);
    assert_eq!(off_plans.len(), on_plans.len(), "round counts differ");
    for (r, (a, b)) in off_plans.iter().zip(&on_plans).enumerate() {
        assert_eq!(a.active, b.active, "active set, round {}", r + 1);
        assert_eq!(a.pulls_from, b.pulls_from, "pulls, round {}", r + 1);
        assert_eq!(a.pushes, b.pushes, "pushes, round {}", r + 1);
    }
    assert_eq!(off.rounds.len(), on.rounds.len());
    for (a, b) in off.rounds.iter().zip(&on.rounds) {
        let r = a.round;
        assert_eq!(a.round, b.round);
        assert_eq!(a.active, b.active, "round {r}");
        assert_eq!(a.population, b.population, "round {r}");
        assert_eq!(a.adversaries, b.adversaries, "round {r}");
        assert_eq!(a.transfers, b.transfers, "round {r}");
        assert_eq!(a.dropped_msgs, b.dropped_msgs, "round {r}");
        assert_eq!(a.corrupt_detected, b.corrupt_detected, "round {r}");
    }
    assert_eq!(off.evals.len(), on.evals.len());
    for (a, b) in off.evals.iter().zip(&on.evals) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.cum_transfers, b.cum_transfers, "eval @{}", a.round);
    }
    assert!(
        off.rounds.iter().any(|r| r.transfers > 0),
        "a run with zero transfers pins nothing"
    );
}

// --- event-engine trace coverage --------------------------------------

/// `engine=event` must feed the activation observer stream on par with
/// the dense sweep: every activated worker gets at least one complete
/// ("X") span on its own Perfetto track.
#[test]
fn event_engine_trace_covers_every_activated_worker() {
    let trace_path = std::env::temp_dir().join(format!(
        "dystop-event-trace-{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&trace_path);
    let mut cfg = sim_cfg(10, 5);
    cfg.engine = EngineKind::Event;
    cfg.trace.out = trace_path.display().to_string();
    let (_res, plans) = run_with_plans(cfg, BackendKind::Sim);
    let text = std::fs::read_to_string(&trace_path).unwrap();
    let json = Json::parse(&text).unwrap();
    let events = json
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("top-level traceEvents array");
    assert!(!events.is_empty());
    for ev in events {
        assert!(ev.get("ph").and_then(Json::as_str).is_some(), "{ev}");
    }
    let activated: std::collections::BTreeSet<usize> =
        plans.iter().flat_map(|p| p.active.iter().copied()).collect();
    assert!(!activated.is_empty());
    for w in activated {
        let tid = (w + 1) as f64;
        assert!(
            events.iter().any(|ev| {
                ev.get("ph").and_then(Json::as_str) == Some("X")
                    && ev.get("tid").and_then(Json::as_f64) == Some(tid)
            }),
            "activated worker {w} has no span on tid {tid}"
        );
    }
    let _ = std::fs::remove_file(&trace_path);
}

// --- exposures --------------------------------------------------------

#[test]
fn snapshot_sink_writes_cadence_and_final_summary() {
    let dir = std::env::temp_dir()
        .join(format!("dystop-telemetry-snap-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("telemetry.jsonl");
    let mut cfg = sim_cfg(20, 6);
    cfg.eval_every = 3;
    // bench-top geometry so every subsystem sees traffic
    cfg.network.region_m = 20.0;
    cfg.network.comm_range_m = 30.0;
    cfg.network.mobility_m = 0.0;
    cfg.telemetry.out = path.display().to_string();
    cfg.telemetry.snapshot_every = 2;
    let res = Experiment::builder(cfg).run().unwrap();
    assert_eq!(res.rounds.len(), 6);
    assert!(res.total_transfers() > 0, "no traffic, nothing pinned");

    let body = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> =
        body.lines().filter(|l| !l.trim().is_empty()).collect();
    // cadence lines at rounds 2, 4, 6 plus the unconditional final one
    assert!(lines.len() >= 4, "expected >= 4 snapshots, got {}", lines.len());
    let last = Json::parse(lines.last().unwrap()).expect("final snapshot");
    assert_eq!(last.get("kind").and_then(Json::as_str), Some("telemetry"));
    assert_eq!(last.get("round").and_then(Json::as_f64), Some(6.0));

    let counters = last.get("counters").expect("counters object");
    let counter =
        |k: &str| counters.get(k).and_then(Json::as_f64).unwrap_or(-1.0);
    assert_eq!(counter("rounds"), 6.0);
    assert!(counter("activations") > 0.0, "no activations counted");
    assert!(counter("codec_encodes") > 0.0, "no codec encodes counted");
    assert!(counter("delivery_msgs") > 0.0, "no delivery msgs counted");
    assert_eq!(
        counter("sched_view_rebuilds") + counter("sched_view_patches"),
        6.0,
        "every round is either a view rebuild or a patch"
    );

    let phases = last.get("phases").expect("phases object");
    let phase_count = |k: &str| {
        phases
            .get(k)
            .and_then(|p| p.get("count"))
            .and_then(Json::as_f64)
            .unwrap_or(-1.0)
    };
    assert_eq!(phase_count("round"), 6.0, "one round phase sample per round");
    assert!(phase_count("train") > 0.0, "no train phase samples");
    assert!(phase_count("aggregate") > 0.0, "no aggregate phase samples");

    let gauges = last.get("gauges").expect("gauges object");
    assert_eq!(
        gauges.get("population").and_then(Json::as_f64),
        Some(20.0)
    );
    let _ = std::fs::remove_dir_all(&dir);
}

fn scrape(addr: std::net::SocketAddr) -> String {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).expect("connect /metrics");
    s.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
    write!(s, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    s.flush().unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).expect("read exposition");
    out
}

/// The /metrics endpoint serves the live registry: a scrape before the
/// run sees the static run labels at zero counts, a scrape after sees
/// every phase histogram populated — same process, same registry, no
/// restart in between.
#[test]
fn metrics_endpoint_serves_live_exposition() {
    let mut cfg = sim_cfg(20, 6);
    cfg.eval_every = 3;
    cfg.network.region_m = 20.0;
    cfg.network.comm_range_m = 30.0;
    cfg.network.mobility_m = 0.0;
    cfg.telemetry.addr = "127.0.0.1:0".to_string();
    let exp = Experiment::builder(cfg).build().unwrap();
    // a clone keeps the registry (and its server) alive past the run
    let tel = exp.telemetry.clone();
    let addr = tel.server_addr().expect("server bound on telemetry.addr");

    let before = scrape(addr);
    assert!(before.contains("dystop_run_info{"), "{before}");
    assert!(before.contains("backend=\"sim\""), "{before}");
    assert!(before.contains("dystop_rounds_total 0"), "{before}");
    assert!(before.contains("# TYPE dystop_phase_ns histogram"));

    let mut backend = VirtualClockBackend::new();
    let res = backend.run(exp).unwrap();
    assert_eq!(res.rounds.len(), 6);

    let after = scrape(addr);
    assert!(after.contains("dystop_rounds_total 6"), "{after}");
    assert!(
        after.contains("dystop_phase_ns_count{phase=\"round\"} 6"),
        "{after}"
    );
    assert!(
        after.contains("dystop_phase_ns_bucket{phase=\"round\",le=\"+Inf\"} 6"),
        "{after}"
    );
    // counters from distinct subsystems all landed in one exposition
    for family in [
        "dystop_activations_total",
        "dystop_codec_encodes_total",
        "dystop_delivery_msgs_total",
        "dystop_train_samples_total",
    ] {
        let populated = after.lines().any(|l| {
            l.strip_prefix(family)
                .and_then(|rest| rest.trim().parse::<u64>().ok())
                .is_some_and(|v| v > 0)
        });
        assert!(populated, "{family} has no samples:\n{after}");
    }
}
