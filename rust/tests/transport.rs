//! Transport-layer integration: codec effects on live runs through both
//! backends — measured byte ledgers, compression factors, accuracy
//! bounds, and the thread-count determinism witness with stateful
//! codecs active.

use dystop::config::{
    BackendKind, CodecKind, ExperimentConfig, ScenarioConfig,
    ScenarioPreset, TransportConfig,
};
use dystop::experiment::{Experiment, TestbedOptions, ThreadedBackend};
use dystop::metrics::RunResult;

fn codec_cfg(codec: CodecKind) -> ExperimentConfig {
    ExperimentConfig {
        workers: 12,
        rounds: 60,
        train_per_worker: 64,
        test_samples: 200,
        eval_every: 10,
        target_accuracy: 2.0,
        transport: TransportConfig { codec, ..Default::default() },
        ..Default::default()
    }
}

fn run(cfg: ExperimentConfig) -> RunResult {
    Experiment::builder(cfg)
        .backend(BackendKind::Sim)
        .run()
        .expect("codec run failed")
}

/// Measured wire bytes per transfer edge.
fn bytes_per_transfer(res: &RunResult) -> f64 {
    res.cum_bytes() / res.total_transfers() as f64
}

#[test]
fn topk_cuts_measured_bytes_at_least_4x() {
    let dense = run(codec_cfg(CodecKind::Dense));
    let topk = run(codec_cfg(CodecKind::TopK));
    // per-transfer: the codec's compression profile, exactly — at
    // topk_frac=0.1 each message is ~5× smaller than the dense payload
    let factor = bytes_per_transfer(&dense) / bytes_per_transfer(&topk);
    assert!(factor >= 4.0, "per-transfer compression only {factor:.2}×");
    // same traffic pattern priced dense would cost ≥4× the measured
    // bytes (the old transfers × model_bits ledger)
    let dense_priced =
        topk.total_transfers() as f64 * topk.model_bits / 8.0;
    assert!(
        dense_priced >= 4.0 * topk.cum_bytes(),
        "dense-priced {dense_priced} vs measured {}",
        topk.cum_bytes()
    );
    // cross-run totals move with plan drift, but nowhere near 5×
    assert!(
        topk.cum_bytes() < dense.cum_bytes() / 2.0,
        "topk {} vs dense {}",
        topk.cum_bytes(),
        dense.cum_bytes()
    );
    // the accuracy trajectory stays within the existing qualitative
    // bounds (the all-schedulers-learn floor)
    assert!(
        topk.best_accuracy() > 0.4,
        "topk best acc {}",
        topk.best_accuracy()
    );
    assert!(
        dense.best_accuracy() > 0.5,
        "dense best acc {}",
        dense.best_accuracy()
    );
}

#[test]
fn int8_cuts_bytes_and_still_learns() {
    let dense = run(codec_cfg(CodecKind::Dense));
    let int8 = run(codec_cfg(CodecKind::Int8));
    let factor = bytes_per_transfer(&dense) / bytes_per_transfer(&int8);
    assert!(factor > 3.9, "int8 per-transfer compression only {factor:.2}×");
    // quantization noise at clip/255 is far below the signal: accuracy
    // holds the dense-level floor
    assert!(
        int8.best_accuracy() > 0.5,
        "int8 best acc {}",
        int8.best_accuracy()
    );
}

#[test]
fn byte_ledger_is_internally_consistent() {
    for codec in [CodecKind::Dense, CodecKind::TopK, CodecKind::Int8] {
        let res = run(codec_cfg(codec));
        // rounds carry a constant per-message size: bytes = transfers × m
        let m = bytes_per_transfer(&res);
        for r in &res.rounds {
            assert_eq!(
                r.bytes_sent.to_bits(),
                (r.transfers as f64 * m).to_bits(),
                "round {} of {}",
                r.round,
                res.label
            );
        }
        // eval snapshots accumulate the same ledger
        let last = res.evals.last().unwrap();
        assert_eq!(last.cum_bytes.to_bits(), res.cum_bytes().to_bits());
        assert_eq!(last.cum_transfers, res.total_transfers());
    }
}

#[test]
fn codec_runs_are_thread_count_invariant() {
    // the determinism contract with stateful codecs active: encode
    // order is coordinator-fixed, so run.threads never changes bits
    for codec in [CodecKind::TopK, CodecKind::Int8] {
        let run_with = |threads: usize| {
            let mut cfg = codec_cfg(codec);
            cfg.workers = 10;
            cfg.rounds = 8;
            cfg.train_per_worker = 48;
            cfg.test_samples = 120;
            cfg.eval_every = 2;
            cfg.threads = threads;
            run(cfg)
        };
        let seq = run_with(1);
        for threads in [2usize, 4] {
            assert!(
                seq.bits_eq(&run_with(threads)),
                "codec {codec:?} diverged at threads={threads}"
            );
        }
    }
}

#[test]
fn topk_stays_deterministic_under_churn() {
    // scenario events (incl. Join's codec-state reset) compose with the
    // transport layer without breaking thread-count determinism
    for preset in [ScenarioPreset::Diurnal, ScenarioPreset::FlashCrowd] {
        let run_with = |threads: usize| {
            let mut cfg = codec_cfg(CodecKind::TopK);
            cfg.workers = 20;
            cfg.rounds = 30;
            cfg.train_per_worker = 48;
            cfg.test_samples = 100;
            cfg.eval_every = 6;
            cfg.threads = threads;
            cfg.scenario = ScenarioConfig::preset(preset);
            run(cfg)
        };
        let a = run_with(1);
        let b = run_with(4);
        assert!(a.bits_eq(&b), "topk × {preset:?} diverged across threads");
    }
}

#[test]
fn threaded_backend_routes_pulls_through_codec() {
    let mut cfg = codec_cfg(CodecKind::TopK);
    cfg.workers = 6;
    cfg.rounds = 6;
    cfg.train_per_worker = 48;
    cfg.test_samples = 120;
    cfg.eval_every = 2;
    cfg.compute_mean_s = 0.5;
    // aggressive compression (1 virtual s = 2 ms) keeps the suite fast
    let opts = TestbedOptions { time_scale: 2.0, profile: false };
    let res = Experiment::builder(cfg)
        .backend_impl(Box::new(ThreadedBackend::with_options(opts)))
        .run()
        .expect("threaded codec run failed");
    assert_eq!(res.rounds.len(), 6);
    // the channel-cost ledger is the codec's message size, not the
    // dense payload: topk_frac=0.1 → k = ceil(0.1 × bits/32) entries
    // at 8 bytes each + 8-byte header
    let expect =
        (0.1 * res.model_bits / 32.0).ceil() * 8.0 + 8.0;
    for r in &res.rounds {
        assert_eq!(
            r.bytes_sent.to_bits(),
            (r.transfers as f64 * expect).to_bits(),
            "round {}",
            r.round
        );
    }
    assert!(expect < res.model_bits / 8.0 / 4.0, "not compressed");
    assert!(res.evals.iter().all(|e| e.avg_loss.is_finite()));
    assert_eq!(
        res.evals.last().unwrap().cum_bytes.to_bits(),
        res.cum_bytes().to_bits()
    );
}
