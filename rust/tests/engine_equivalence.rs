//! Cross-engine equivalence: the discrete-event core (`run.engine=event`)
//! must reproduce the dense sweep (`run.engine=dense`) **bitwise** — same
//! seed, same knobs, same `RunResult` down to every f64 bit and every
//! event record. The event engine is a performance rewrite, not a model
//! change; any drift here is a bug in the lazy/cached paths, not a
//! tolerable approximation.
//!
//! Coverage axes (ISSUE 8 acceptance):
//! * N ∈ {60, 200} at default knobs;
//! * every backend-compatible subsystem riding through unchanged —
//!   scenario=diurnal (churn: membership compaction + lazy staleness
//!   catch-up), faults=cellular (per-edge delivery streams + retry
//!   timeouts through the event queue), transport.codec=topk (stateful
//!   codec history), adversary attack=signflip (exchange-boundary
//!   rewrites);
//! * the cached fast path: mobility=0 / budget_jitter=0 / link_drop=0
//!   keeps geometry and budgets frozen, while a churn scenario forces a
//!   *mix* of cached and rebuilt rounds in one run;
//! * threads=1 vs threads=4 determinism on the event engine itself.

use dystop::config::{
    AdversaryConfig, AttackKind, CodecKind, EngineKind, ExperimentConfig,
    FaultConfig, FaultProfile, ScenarioConfig, ScenarioPreset,
    TransportConfig,
};
use dystop::experiment::Experiment;
use dystop::metrics::RunResult;

fn base(workers: usize, rounds: usize) -> ExperimentConfig {
    ExperimentConfig {
        workers,
        rounds,
        seed: 11,
        train_per_worker: 48,
        test_samples: 64,
        eval_every: 7, // deliberately not a divisor of rounds
        target_accuracy: 2.0,
        ..Default::default()
    }
}

fn run_engine(mut cfg: ExperimentConfig, engine: EngineKind) -> RunResult {
    cfg.engine = engine;
    Experiment::builder(cfg).run().expect("engine run failed")
}

/// Assert dense and event runs of the same config are bit-identical.
fn assert_engines_agree(cfg: ExperimentConfig, label: &str) {
    let dense = run_engine(cfg.clone(), EngineKind::Dense);
    let event = run_engine(cfg, EngineKind::Event);
    assert!(
        dense.bits_eq(&event),
        "dense vs event diverged ({label}): \
         dense rounds={} evals={} events={} | event rounds={} evals={} events={}",
        dense.rounds.len(),
        dense.evals.len(),
        dense.events.len(),
        event.rounds.len(),
        event.evals.len(),
        event.events.len(),
    );
}

#[test]
fn default_knobs_agree_at_n60() {
    assert_engines_agree(base(60, 40), "N=60 defaults");
}

#[test]
fn default_knobs_agree_at_n200() {
    assert_engines_agree(base(200, 20), "N=200 defaults");
}

#[test]
fn diurnal_churn_agrees_at_n60() {
    let mut cfg = base(60, 40);
    cfg.scenario = ScenarioConfig::preset(ScenarioPreset::Diurnal);
    assert_engines_agree(cfg, "N=60 scenario=diurnal");
}

#[test]
fn diurnal_churn_agrees_at_n200() {
    let mut cfg = base(200, 20);
    cfg.scenario = ScenarioConfig::preset(ScenarioPreset::Diurnal);
    assert_engines_agree(cfg, "N=200 scenario=diurnal");
}

#[test]
fn cellular_faults_agree_at_n60() {
    let mut cfg = base(60, 40);
    cfg.faults = FaultConfig::preset(FaultProfile::Cellular);
    assert_engines_agree(cfg, "N=60 faults=cellular");
}

#[test]
fn cellular_faults_agree_at_n200() {
    let mut cfg = base(200, 20);
    cfg.faults = FaultConfig::preset(FaultProfile::Cellular);
    assert_engines_agree(cfg, "N=200 faults=cellular");
}

#[test]
fn topk_codec_agrees_at_n60() {
    let mut cfg = base(60, 40);
    cfg.transport =
        TransportConfig { codec: CodecKind::TopK, ..Default::default() };
    assert_engines_agree(cfg, "N=60 codec=topk");
}

#[test]
fn topk_codec_agrees_at_n200() {
    let mut cfg = base(200, 20);
    cfg.transport =
        TransportConfig { codec: CodecKind::TopK, ..Default::default() };
    assert_engines_agree(cfg, "N=200 codec=topk");
}

#[test]
fn signflip_adversaries_agree_at_n60() {
    let mut cfg = base(60, 40);
    cfg.adversary = AdversaryConfig {
        frac: 0.2,
        attack: AttackKind::SignFlip,
        ..Default::default()
    };
    assert_engines_agree(cfg, "N=60 attack=signflip");
}

#[test]
fn signflip_adversaries_agree_at_n200() {
    let mut cfg = base(200, 20);
    cfg.adversary = AdversaryConfig {
        frac: 0.2,
        attack: AttackKind::SignFlip,
        ..Default::default()
    };
    assert_engines_agree(cfg, "N=200 attack=signflip");
}

/// Frozen geometry + churn: the event engine's cached-view fast path is
/// only legal when mobility, budget jitter and link drops are all off —
/// this config turns them off so cached rounds actually happen, and
/// layers a churn scenario on top so membership flips force rebuilds in
/// *some* rounds. The run therefore interleaves cached and rebuilt
/// rounds, which is exactly where a stale-view bug would surface.
#[test]
fn cached_fast_path_with_churn_agrees() {
    let mut cfg = base(60, 50);
    cfg.network.mobility_m = 0.0;
    cfg.network.budget_jitter = 0.0;
    cfg.network.link_drop_prob = 0.0;
    cfg.scenario = ScenarioConfig::preset(ScenarioPreset::Diurnal);
    assert_engines_agree(cfg, "N=60 frozen-geometry + diurnal churn");
}

/// Pure cached path: with no churn either, every round after the first
/// reuses the cached view (only the per-round state patch runs).
#[test]
fn pure_cached_fast_path_agrees() {
    let mut cfg = base(60, 40);
    cfg.network.mobility_m = 0.0;
    cfg.network.budget_jitter = 0.0;
    cfg.network.link_drop_prob = 0.0;
    assert_engines_agree(cfg, "N=60 frozen geometry, no churn");
}

/// The event engine inherits the parallel round executor; its results
/// must not depend on `run.threads`.
#[test]
fn event_engine_is_thread_count_invariant() {
    let mut c1 = base(60, 30);
    c1.engine = EngineKind::Event;
    c1.threads = 1;
    let mut c4 = c1.clone();
    c4.threads = 4;
    let a = Experiment::builder(c1).run().expect("threads=1 run");
    let b = Experiment::builder(c4).run().expect("threads=4 run");
    assert!(
        a.bits_eq(&b),
        "event engine diverged between threads=1 and threads=4"
    );
}

/// The streaming sinks are observers: attaching one must not perturb the
/// run itself (same bits with and without a JSONL sink), and the sink
/// must leave a non-empty artifact behind.
#[test]
fn jsonl_sink_does_not_perturb_the_run() {
    let dir = std::env::temp_dir().join("dystop_engine_equiv_sink");
    let _ = std::fs::remove_dir_all(&dir);
    let plain = run_engine(base(60, 20), EngineKind::Event);
    let mut cfg = base(60, 20);
    cfg.metrics.sink = dystop::config::SinkKind::Jsonl;
    cfg.metrics.out = dir.join("run.jsonl").to_string_lossy().into_owned();
    let streamed = run_engine(cfg, EngineKind::Event);
    assert!(
        plain.bits_eq(&streamed),
        "attaching a JSONL sink changed the run"
    );
    let body = std::fs::read_to_string(dir.join("run.jsonl"))
        .expect("sink artifact missing");
    assert!(
        body.lines().count() >= 20,
        "JSONL sink wrote too few lines: {}",
        body.lines().count()
    );
    let _ = std::fs::remove_dir_all(&dir);
}
