//! Integration: the full AOT bridge — python-lowered HLO artifacts loaded
//! and executed from Rust via PJRT, wrapped as a [`Trainer`].
//!
//! Requires `make artifacts`; tests skip (with a notice) if absent.
//! Compiled only with the `pjrt` feature (the default) — the
//! `--no-default-features` CI leg drops the PJRT surface entirely.
#![cfg(feature = "pjrt")]

use dystop::config::ModelKind;
use dystop::data::{make_corpus, SyntheticSpec};
use dystop::runtime::PjrtTrainer;
use dystop::util::rng::Pcg;
use dystop::worker::Trainer;
use std::path::PathBuf;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping PJRT test: run `make artifacts` first");
        None
    }
}

fn corpus(dim: usize) -> (dystop::data::Dataset, dystop::data::Dataset) {
    make_corpus(&SyntheticSpec {
        dim,
        train_samples: 320,
        test_samples: 256,
        class_sep: 2.5,
        ..Default::default()
    })
}

#[test]
fn mlp_artifact_trains_and_loss_drops() {
    let Some(dir) = artifact_dir() else { return };
    let mut t = PjrtTrainer::new(&dir, ModelKind::Mlp).unwrap();
    let dim = t.manifest().input_dim;
    let (train, test) = corpus(dim);
    let mut rng = Pcg::seeded(1);
    let p0 = t.init(0);
    assert_eq!(p0.len(), t.param_count());
    let (l0, a0) = t.evaluate(&p0, &test);
    let (p1, _loss) = t.train(&p0, &train, 150, 32, 0.1, &mut rng);
    let (l1, a1) = t.evaluate(&p1, &test);
    assert!(l1 < l0 * 0.7, "loss {l0} → {l1}");
    assert!(a1 > a0 + 0.2, "acc {a0} → {a1}");
    assert!(a1 > 0.55, "final acc {a1}");
}

#[test]
fn cnn_artifact_executes() {
    let Some(dir) = artifact_dir() else { return };
    let mut t = PjrtTrainer::new(&dir, ModelKind::Cnn).unwrap();
    let dim = t.manifest().input_dim; // 64 = 8×8
    let (train, test) = corpus(dim);
    let mut rng = Pcg::seeded(2);
    let p0 = t.init(0);
    let (l0, _) = t.evaluate(&p0, &test);
    let (p1, loss) = t.train(&p0, &train, 10, 32, 0.1, &mut rng);
    assert!(loss.is_finite());
    let (l1, _) = t.evaluate(&p1, &test);
    assert!(l1 < l0, "cnn loss {l0} → {l1}");
}

#[test]
fn pjrt_aggregate_matches_native() {
    let Some(dir) = artifact_dir() else { return };
    let mut t = PjrtTrainer::new(&dir, ModelKind::Mlp).unwrap();
    let p = t.param_count();
    let mut rng = Pcg::seeded(3);
    let a: Vec<f32> = rng.normal_vec(p, 0.0, 1.0);
    let b: Vec<f32> = rng.normal_vec(p, 0.0, 1.0);
    let c: Vec<f32> = rng.normal_vec(p, 0.0, 1.0);
    let weights = [0.5f32, 0.3, 0.2];
    let got = t.aggregate(&[&a, &b, &c], &weights);
    let want = dystop::worker::aggregate_native(&[&a, &b, &c], &weights);
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 1e-5, "{g} vs {w}");
    }
}

#[test]
fn pjrt_aggregate_falls_back_above_kmax() {
    let Some(dir) = artifact_dir() else { return };
    let mut t = PjrtTrainer::new(&dir, ModelKind::Mlp).unwrap();
    let k_max = t.manifest().k_max;
    let p = t.param_count();
    let n = k_max + 3;
    let models: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32; p]).collect();
    let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
    let w = vec![1.0 / n as f32; n];
    let got = t.aggregate(&refs, &w);
    let mean = (0..n).map(|i| i as f32).sum::<f32>() / n as f32;
    assert!((got[0] - mean).abs() < 1e-4);
}

#[test]
fn deterministic_training() {
    let Some(dir) = artifact_dir() else { return };
    let mut t = PjrtTrainer::new(&dir, ModelKind::Mlp).unwrap();
    let (train, _) = corpus(t.manifest().input_dim);
    let p0 = t.init(7);
    let (a, la) = t.train(&p0, &train, 3, 32, 0.1, &mut Pcg::seeded(9));
    let (b, lb) = t.train(&p0, &train, 3, 32, 0.1, &mut Pcg::seeded(9));
    assert_eq!(a, b);
    assert_eq!(la, lb);
}

#[test]
fn sim_engine_runs_on_pjrt_trainer() {
    let Some(dir) = artifact_dir() else { return };
    use dystop::config::{ExperimentConfig, SchedulerKind, TrainerKind};
    use dystop::experiment::{Experiment, VirtualClockBackend};
    let t = PjrtTrainer::new(&dir, ModelKind::Mlp).unwrap();
    let cfg = ExperimentConfig {
        workers: 6,
        rounds: 60,
        train_per_worker: 64,
        test_samples: 256,
        eval_every: 10,
        local_steps: 6,
        lr: 0.2,
        scheduler: SchedulerKind::DySTop,
        trainer: TrainerKind::Pjrt,
        target_accuracy: 2.0,
        ..Default::default()
    };
    let res = Experiment::builder(cfg)
        .trainer(Box::new(t))
        .backend_impl(Box::new(VirtualClockBackend::full_curves()))
        .run()
        .expect("pjrt experiment failed");
    assert_eq!(res.rounds.len(), 60);
    // DFL cold-start on a fresh MLP is slow; the signal we need is that
    // the stack *learns* through the artifacts, not that it converges.
    assert!(res.best_accuracy() > 0.25, "acc {}", res.best_accuracy());
    let first = res.evals.first().unwrap().avg_accuracy;
    assert!(res.best_accuracy() > first, "no improvement over {first}");
    assert!(res
        .evals
        .iter()
        .all(|e| e.avg_loss.is_finite() && e.avg_accuracy <= 1.0));
}
