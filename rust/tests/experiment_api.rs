//! The unified Experiment API: builder errors, backend dispatch, observer
//! hooks, and the load-bearing bit-identity pins:
//!
//! * seeded runs are a pure function of the config — same config, same
//!   bits (the parity contract that replaced the legacy `SimEngine`
//!   facade, deleted in this PR after all callers migrated);
//! * `run.threads=1` vs `run.threads=N` — the parallel round executor
//!   must be bit-identical for every thread count;
//! * the early-stop path (`run`) agrees with the full-curve path when
//!   the target is unreachable.
//!
//! Also folds in the engine-behaviour tests that used to live in
//! `sim::tests` (training, staleness bounds, scheduler orderings).

use dystop::config::{BackendKind, ExperimentConfig, SchedulerKind, TrainerKind};
use dystop::coordinator::RoundPlan;
use dystop::experiment::{
    Experiment, ExperimentError, RoundObserver, TestbedOptions,
    ThreadedBackend, VirtualClockBackend,
};
use dystop::metrics::{EvalRecord, RoundRecord, RunResult};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn small_cfg() -> ExperimentConfig {
    ExperimentConfig {
        workers: 6,
        rounds: 10,
        train_per_worker: 48,
        test_samples: 120,
        eval_every: 2,
        seed: 42,
        scheduler: SchedulerKind::DySTop,
        target_accuracy: 0.8, // exercise the early-stop path too
        ..Default::default()
    }
}

/// The engine-test scale the old `sim::tests` used.
fn engine_cfg(scheduler: SchedulerKind) -> ExperimentConfig {
    ExperimentConfig {
        workers: 12,
        rounds: 60,
        train_per_worker: 64,
        test_samples: 200,
        eval_every: 10,
        scheduler,
        target_accuracy: 2.0, // never early-stop
        ..Default::default()
    }
}

/// Full-curve run through the builder (ex `SimEngine::run_full`).
fn run_full(cfg: ExperimentConfig) -> RunResult {
    Experiment::builder(cfg)
        .backend_impl(Box::new(VirtualClockBackend::full_curves()))
        .run()
        .expect("experiment failed")
}

/// Field-by-field asserts (readable failure messages) backed by the one
/// shared definition of "bit-identical run", `RunResult::bits_eq` — the
/// same predicate the bench determinism witness records.
fn assert_bit_identical(a: &RunResult, b: &RunResult) {
    assert_eq!(a.label, b.label);
    assert_eq!(a.model_bits.to_bits(), b.model_bits.to_bits());
    assert_eq!(a.events, b.events, "scenario event log");
    assert_eq!(a.rounds.len(), b.rounds.len(), "round count");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.time_s.to_bits(), y.time_s.to_bits(), "round {}", x.round);
        assert_eq!(x.duration_s.to_bits(), y.duration_s.to_bits());
        assert_eq!(x.active, y.active);
        assert_eq!(x.population, y.population);
        assert_eq!(x.adversaries, y.adversaries);
        assert_eq!(x.transfers, y.transfers);
        assert_eq!(x.bytes_sent.to_bits(), y.bytes_sent.to_bits());
        assert_eq!(x.avg_staleness.to_bits(), y.avg_staleness.to_bits());
        assert_eq!(x.max_staleness, y.max_staleness);
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
    }
    assert_eq!(a.evals.len(), b.evals.len(), "eval count");
    for (x, y) in a.evals.iter().zip(&b.evals) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.time_s.to_bits(), y.time_s.to_bits());
        assert_eq!(x.avg_accuracy.to_bits(), y.avg_accuracy.to_bits());
        assert_eq!(x.avg_loss.to_bits(), y.avg_loss.to_bits());
        assert_eq!(x.cum_transfers, y.cum_transfers);
        assert_eq!(x.cum_bytes.to_bits(), y.cum_bytes.to_bits());
    }
    // the shared predicate must agree with the field-by-field asserts
    assert!(a.bits_eq(b), "bits_eq diverged from field asserts");
}

#[test]
fn seeded_runs_are_bit_identical() {
    let a = Experiment::builder(small_cfg())
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    let b = Experiment::builder(small_cfg())
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    assert_bit_identical(&a, &b);
    assert!(!a.rounds.is_empty());
    // the default (stable) scenario keeps the population constant
    assert!(a.events.is_empty());
    assert!(a.rounds.iter().all(|r| r.population == 6));
}

#[test]
fn early_stop_agrees_with_full_curves_when_target_unreachable() {
    for kind in [SchedulerKind::DySTop, SchedulerKind::SaAdfl] {
        let mut cfg = small_cfg();
        cfg.scheduler = kind;
        cfg.target_accuracy = 2.0;
        let full = run_full(cfg.clone());
        // `run()` early-stops at target 2.0 → never fires → identical
        let stopped = Experiment::builder(cfg)
            .backend(BackendKind::Sim)
            .run()
            .unwrap();
        assert_bit_identical(&full, &stopped);
    }
}

#[test]
fn thread_count_never_changes_results() {
    // the tentpole invariant of the parallel virtual-clock engine:
    // per-activation RNG streams + plan-order reduction make the run a
    // pure function of the config, not of the thread schedule
    let run_with = |threads: usize| {
        let mut cfg = small_cfg();
        cfg.workers = 10;
        cfg.rounds = 8;
        cfg.target_accuracy = 2.0;
        cfg.threads = threads;
        Experiment::builder(cfg)
            .backend(BackendKind::Sim)
            .run()
            .unwrap()
    };
    let sequential = run_with(1);
    for threads in [2usize, 4, 7] {
        let parallel = run_with(threads);
        assert_bit_identical(&sequential, &parallel);
    }
    // threads=0 (auto = available parallelism) included
    assert_bit_identical(&sequential, &run_with(0));
}

#[test]
fn dense_codec_reproduces_the_model_bits_ledger_exactly() {
    // the transport acceptance pin: `transport.codec=dense` (the
    // default) is bit-identical to the pre-transport engine for every
    // `run.threads` and scenario preset — its measured byte ledger IS
    // the old `transfers × model_bits` accounting, and the trajectories
    // (times, losses, staleness) are untouched by the layer existing
    use dystop::config::{ScenarioConfig, ScenarioPreset};
    for preset in [ScenarioPreset::Stable, ScenarioPreset::Diurnal] {
        let run_with = |threads: usize| {
            let mut cfg = small_cfg();
            cfg.workers = 12;
            cfg.rounds = 16;
            cfg.target_accuracy = 2.0;
            cfg.threads = threads;
            cfg.scenario = ScenarioConfig::preset(preset);
            Experiment::builder(cfg)
                .backend(BackendKind::Sim)
                .run()
                .unwrap()
        };
        let res = run_with(1);
        let msg_bytes = res.model_bits / 8.0;
        for r in &res.rounds {
            assert_eq!(
                r.bytes_sent.to_bits(),
                (r.transfers as f64 * msg_bytes).to_bits(),
                "round {} under {preset:?}",
                r.round
            );
        }
        for e in &res.evals {
            assert_eq!(
                e.cum_bytes.to_bits(),
                (e.cum_transfers as f64 * msg_bytes).to_bits(),
                "eval @ round {} under {preset:?}",
                e.round
            );
        }
        // the measured-bytes comm_to_accuracy equals the old formula
        if let Some(gb) = res.comm_to_accuracy(0.0) {
            let old = res.evals[0].cum_transfers as f64 * res.model_bits
                / 8.0
                / 1e9;
            assert_eq!(gb.to_bits(), old.to_bits());
        }
        // and parallel execution doesn't change a single bit of it
        assert_bit_identical(&res, &run_with(4));
    }
}

#[test]
fn dense_codec_ignores_inactive_codec_knobs() {
    // topk/int8 knobs must be inert while the codec is dense: the same
    // run, bit for bit
    let a = Experiment::builder(small_cfg())
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    let mut cfg = small_cfg();
    cfg.transport.topk_frac = 0.7;
    cfg.transport.int8_clip = 9.0;
    let b = Experiment::builder(cfg)
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    assert_bit_identical(&a, &b);
}

#[test]
fn benign_adversary_knobs_are_inert() {
    // adversary.frac=0 (the default) + aggregator=mean must reproduce
    // the pre-adversary engine bit for bit — for every thread count,
    // whatever the other adversary.* knobs say
    use dystop::config::AttackKind;
    let run_with = |threads: usize, touch_knobs: bool| {
        let mut cfg = small_cfg();
        cfg.workers = 10;
        cfg.rounds = 8;
        cfg.target_accuracy = 2.0;
        cfg.threads = threads;
        if touch_knobs {
            // frac=0 ⇒ no cast ⇒ every other attack knob is dead
            cfg.adversary.attack = AttackKind::SignFlip;
            cfg.adversary.scale = -50.0;
            cfg.adversary.stale_tau = 3;
            cfg.adversary.trim_frac = 0.4;
            cfg.adversary.krum_f = 2;
        }
        Experiment::builder(cfg)
            .backend(BackendKind::Sim)
            .run()
            .unwrap()
    };
    let baseline = run_with(1, false);
    assert!(baseline.rounds.iter().all(|r| r.adversaries == 0));
    for threads in [1usize, 4] {
        assert_bit_identical(&baseline, &run_with(threads, true));
    }
}

#[test]
fn active_adversary_stays_thread_count_deterministic() {
    // with a real cast mounted, runs must still be a pure function of
    // the config — transmit happens coordinator-side in fixed order
    use dystop::config::{AggregatorKind, AttackKind};
    let run_with = |threads: usize| {
        let mut cfg = small_cfg();
        cfg.workers = 10;
        cfg.rounds = 8;
        cfg.target_accuracy = 2.0;
        cfg.threads = threads;
        cfg.adversary.frac = 0.3;
        cfg.adversary.attack = AttackKind::SignFlip;
        cfg.adversary.aggregator = AggregatorKind::TrimmedMean;
        Experiment::builder(cfg)
            .backend(BackendKind::Sim)
            .run()
            .unwrap()
    };
    let sequential = run_with(1);
    assert!(sequential.rounds.iter().all(|r| r.adversaries == 3));
    // attack activations land in the event log at most once per
    // attacker (a cast member that never serves a pull/push stays dark)
    let fired = sequential
        .events
        .iter()
        .filter(|e| e.kind == "attack-signflip")
        .count();
    assert!(
        (1..=3).contains(&fired),
        "activations {fired}, events: {:?}",
        sequential.events
    );
    for threads in [2usize, 4] {
        assert_bit_identical(&sequential, &run_with(threads));
    }
}

#[test]
fn invalid_config_surfaces_as_error() {
    let mut cfg = small_cfg();
    cfg.batch = 0;
    match Experiment::builder(cfg).build() {
        Err(ExperimentError::InvalidConfig(_)) => {}
        Err(other) => panic!("expected InvalidConfig, got {other:?}"),
        Ok(_) => panic!("expected InvalidConfig, got Ok"),
    }
}

#[test]
fn pjrt_mismatch_surfaces_as_error() {
    let mut cfg = small_cfg();
    cfg.trainer = TrainerKind::Pjrt;
    assert!(matches!(
        Experiment::builder(cfg).build(),
        Err(ExperimentError::TrainerRequired(_))
    ));
}

#[derive(Default)]
struct Counts {
    plans: AtomicUsize,
    rounds: AtomicUsize,
    evals: AtomicUsize,
}

struct CountingObserver(Arc<Counts>);

impl RoundObserver for CountingObserver {
    fn on_plan(&mut self, _round: usize, _plan: &RoundPlan) {
        self.0.plans.fetch_add(1, Ordering::Relaxed);
    }
    fn on_round_end(&mut self, _rec: &RoundRecord) {
        self.0.rounds.fetch_add(1, Ordering::Relaxed);
    }
    fn on_eval(&mut self, _rec: &EvalRecord) {
        self.0.evals.fetch_add(1, Ordering::Relaxed);
    }
}

#[test]
fn observers_fire_on_every_round_and_eval() {
    let counts = Arc::new(Counts::default());
    let mut cfg = small_cfg();
    cfg.target_accuracy = 2.0;
    let res = Experiment::builder(cfg)
        .observer(Box::new(CountingObserver(counts.clone())))
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    assert_eq!(counts.plans.load(Ordering::Relaxed), res.rounds.len());
    assert_eq!(counts.rounds.load(Ordering::Relaxed), res.rounds.len());
    assert_eq!(counts.evals.load(Ordering::Relaxed), res.evals.len());
    assert_eq!(res.rounds.len(), 10);
}

#[test]
fn threaded_backend_runs_through_builder() {
    let mut cfg = small_cfg();
    cfg.rounds = 6;
    cfg.target_accuracy = 2.0;
    cfg.compute_mean_s = 0.5;
    let counts = Arc::new(Counts::default());
    // aggressive compression (1 virtual s = 2 ms) keeps the suite fast
    let opts = TestbedOptions { time_scale: 2.0, profile: false };
    let res = Experiment::builder(cfg)
        .observer(Box::new(CountingObserver(counts.clone())))
        .backend_impl(Box::new(ThreadedBackend::with_options(opts)))
        .run()
        .unwrap();
    assert_eq!(res.rounds.len(), 6);
    assert_eq!(counts.rounds.load(Ordering::Relaxed), 6);
    assert!(res.label.starts_with("testbed-"));
    assert!(res.evals.iter().all(|e| e.avg_loss.is_finite()));
}

#[test]
fn threaded_backend_rejects_pjrt_configs() {
    let mut cfg = small_cfg();
    cfg.trainer = TrainerKind::Pjrt;
    // even with an explicit trainer, the threaded backend can't ship it
    // across worker threads — must be a clean Unsupported error
    let trainer = dystop::worker::default_trainer(&ExperimentConfig {
        trainer: TrainerKind::Native,
        ..small_cfg()
    })
    .unwrap();
    let opts = TestbedOptions { time_scale: 2.0, profile: false };
    let err = Experiment::builder(cfg)
        .trainer(trainer)
        .backend_impl(Box::new(ThreadedBackend::with_options(opts)))
        .run()
        .unwrap_err();
    assert!(matches!(err, ExperimentError::Unsupported(_)), "{err}");
}

// --- engine behaviour, folded in from the deleted `sim::tests` ---

#[test]
fn dystop_sim_trains() {
    let res = run_full(engine_cfg(SchedulerKind::DySTop));
    assert_eq!(res.rounds.len(), 60);
    assert!(!res.evals.is_empty());
    let first = res.evals.first().unwrap().avg_accuracy;
    let best = res.best_accuracy();
    assert!(best > first, "no learning: {first} → {best}");
    assert!(best > 0.5, "best acc {best}");
}

#[test]
fn staleness_stays_bounded_under_dystop() {
    let mut cfg = engine_cfg(SchedulerKind::DySTop);
    cfg.rounds = 80;
    cfg.tau_bound = 4;
    let res = run_full(cfg);
    // after warmup, staleness must hover near the bound
    let late: Vec<&RoundRecord> = res.rounds.iter().skip(30).collect();
    let avg = late.iter().map(|r| r.avg_staleness).sum::<f64>()
        / late.len() as f64;
    assert!(avg < 8.0, "avg staleness {avg} too high for bound 4");
}

#[test]
fn all_schedulers_run_and_learn() {
    for k in [
        SchedulerKind::DySTop,
        SchedulerKind::SaAdfl,
        SchedulerKind::AsyDfl,
        SchedulerKind::Matcha,
    ] {
        let res = run_full(engine_cfg(k));
        assert!(
            res.best_accuracy() > 0.4,
            "{}: best acc {}",
            res.label,
            res.best_accuracy()
        );
    }
}

#[test]
fn clock_monotone_and_positive() {
    let res = run_full(engine_cfg(SchedulerKind::DySTop));
    let mut prev = 0.0;
    for r in &res.rounds {
        assert!(r.time_s > prev);
        assert!(r.duration_s > 0.0);
        prev = r.time_s;
    }
}

#[test]
fn matcha_is_synchronous_straggler_bound() {
    let res_m = run_full(engine_cfg(SchedulerKind::Matcha));
    let res_d = run_full(engine_cfg(SchedulerKind::DySTop));
    // per-round duration of MATCHA ≈ slowest worker; DySTop's mean
    // round must be meaningfully shorter
    let mean = |r: &RunResult| {
        r.rounds.iter().map(|x| x.duration_s).sum::<f64>()
            / r.rounds.len() as f64
    };
    assert!(
        mean(&res_d) < mean(&res_m),
        "dystop {} vs matcha {}",
        mean(&res_d),
        mean(&res_m)
    );
}

#[test]
fn sa_adfl_uses_more_comm_per_round_than_dystop() {
    let res_s = run_full(engine_cfg(SchedulerKind::SaAdfl));
    let res_d = run_full(engine_cfg(SchedulerKind::DySTop));
    let per_active = |r: &RunResult| {
        r.rounds.iter().map(|x| x.transfers).sum::<usize>() as f64
            / r.rounds.iter().map(|x| x.active).sum::<usize>() as f64
    };
    assert!(
        per_active(&res_s) > per_active(&res_d),
        "sa-adfl {} vs dystop {}",
        per_active(&res_s),
        per_active(&res_d)
    );
}
