//! The unified Experiment API: builder errors, backend dispatch, observer
//! hooks, and two load-bearing bit-identity pins:
//!
//! * the legacy `SimEngine::run` facade vs. the
//!   `Experiment::builder → VirtualClockBackend` path for a seeded
//!   config (re-pinned for the parallel engine: per-activation RNG
//!   streams changed every trajectory once, in this PR);
//! * `run.threads=1` vs. `run.threads=N` — the parallel round executor
//!   must be bit-identical for every thread count.

use dystop::config::{BackendKind, ExperimentConfig, SchedulerKind, TrainerKind};
use dystop::coordinator::RoundPlan;
use dystop::experiment::{
    Experiment, ExperimentError, RoundObserver, TestbedOptions,
    ThreadedBackend,
};
use dystop::metrics::{EvalRecord, RoundRecord, RunResult};
use dystop::sim::SimEngine;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn small_cfg() -> ExperimentConfig {
    ExperimentConfig {
        workers: 6,
        rounds: 10,
        train_per_worker: 48,
        test_samples: 120,
        eval_every: 2,
        seed: 42,
        scheduler: SchedulerKind::DySTop,
        target_accuracy: 0.8, // exercise the early-stop path too
        ..Default::default()
    }
}

/// Field-by-field asserts (readable failure messages) backed by the one
/// shared definition of "bit-identical run", `RunResult::bits_eq` — the
/// same predicate the bench determinism witness records.
fn assert_bit_identical(a: &RunResult, b: &RunResult) {
    assert_eq!(a.label, b.label);
    assert_eq!(a.model_bits.to_bits(), b.model_bits.to_bits());
    assert_eq!(a.rounds.len(), b.rounds.len(), "round count");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.time_s.to_bits(), y.time_s.to_bits(), "round {}", x.round);
        assert_eq!(x.duration_s.to_bits(), y.duration_s.to_bits());
        assert_eq!(x.active, y.active);
        assert_eq!(x.transfers, y.transfers);
        assert_eq!(x.avg_staleness.to_bits(), y.avg_staleness.to_bits());
        assert_eq!(x.max_staleness, y.max_staleness);
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits());
    }
    assert_eq!(a.evals.len(), b.evals.len(), "eval count");
    for (x, y) in a.evals.iter().zip(&b.evals) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.time_s.to_bits(), y.time_s.to_bits());
        assert_eq!(x.avg_accuracy.to_bits(), y.avg_accuracy.to_bits());
        assert_eq!(x.avg_loss.to_bits(), y.avg_loss.to_bits());
        assert_eq!(x.cum_transfers, y.cum_transfers);
    }
    // the shared predicate must agree with the field-by-field asserts
    assert!(a.bits_eq(b), "bits_eq diverged from field asserts");
}

#[test]
fn builder_backend_matches_legacy_sim_engine_bit_for_bit() {
    // legacy path (early-stopping `run`, as the CLI `train` used it)
    let legacy = SimEngine::new(small_cfg()).run();
    // new path: builder + virtual-clock backend
    let new = Experiment::builder(small_cfg())
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    assert_bit_identical(&legacy, &new);
    assert!(!new.rounds.is_empty());
}

#[test]
fn parity_holds_for_full_curves_across_schedulers() {
    for kind in [SchedulerKind::DySTop, SchedulerKind::SaAdfl] {
        let mut cfg = small_cfg();
        cfg.scheduler = kind;
        cfg.target_accuracy = 2.0;
        let legacy = SimEngine::new(cfg.clone()).run_full();
        let new = Experiment::builder(cfg)
            .backend(BackendKind::Sim)
            .run()
            .unwrap();
        // `run()` early-stops at target 2.0 → never fires → identical
        assert_bit_identical(&legacy, &new);
    }
}

#[test]
fn thread_count_never_changes_results() {
    // the tentpole invariant of the parallel virtual-clock engine:
    // per-activation RNG streams + plan-order reduction make the run a
    // pure function of the config, not of the thread schedule
    let run_with = |threads: usize| {
        let mut cfg = small_cfg();
        cfg.workers = 10;
        cfg.rounds = 8;
        cfg.target_accuracy = 2.0;
        cfg.threads = threads;
        Experiment::builder(cfg)
            .backend(BackendKind::Sim)
            .run()
            .unwrap()
    };
    let sequential = run_with(1);
    for threads in [2usize, 4, 7] {
        let parallel = run_with(threads);
        assert_bit_identical(&sequential, &parallel);
    }
    // threads=0 (auto = available parallelism) included
    assert_bit_identical(&sequential, &run_with(0));
}

#[test]
fn invalid_config_surfaces_as_error() {
    let mut cfg = small_cfg();
    cfg.batch = 0;
    match Experiment::builder(cfg).build() {
        Err(ExperimentError::InvalidConfig(_)) => {}
        Err(other) => panic!("expected InvalidConfig, got {other:?}"),
        Ok(_) => panic!("expected InvalidConfig, got Ok"),
    }
}

#[test]
fn pjrt_mismatch_surfaces_as_error() {
    let mut cfg = small_cfg();
    cfg.trainer = TrainerKind::Pjrt;
    assert!(matches!(
        Experiment::builder(cfg).build(),
        Err(ExperimentError::TrainerRequired(_))
    ));
}

#[derive(Default)]
struct Counts {
    plans: AtomicUsize,
    rounds: AtomicUsize,
    evals: AtomicUsize,
}

struct CountingObserver(Arc<Counts>);

impl RoundObserver for CountingObserver {
    fn on_plan(&mut self, _round: usize, _plan: &RoundPlan) {
        self.0.plans.fetch_add(1, Ordering::Relaxed);
    }
    fn on_round_end(&mut self, _rec: &RoundRecord) {
        self.0.rounds.fetch_add(1, Ordering::Relaxed);
    }
    fn on_eval(&mut self, _rec: &EvalRecord) {
        self.0.evals.fetch_add(1, Ordering::Relaxed);
    }
}

#[test]
fn observers_fire_on_every_round_and_eval() {
    let counts = Arc::new(Counts::default());
    let mut cfg = small_cfg();
    cfg.target_accuracy = 2.0;
    let res = Experiment::builder(cfg)
        .observer(Box::new(CountingObserver(counts.clone())))
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    assert_eq!(counts.plans.load(Ordering::Relaxed), res.rounds.len());
    assert_eq!(counts.rounds.load(Ordering::Relaxed), res.rounds.len());
    assert_eq!(counts.evals.load(Ordering::Relaxed), res.evals.len());
    assert_eq!(res.rounds.len(), 10);
}

#[test]
fn threaded_backend_runs_through_builder() {
    let mut cfg = small_cfg();
    cfg.rounds = 6;
    cfg.target_accuracy = 2.0;
    cfg.compute_mean_s = 0.5;
    let counts = Arc::new(Counts::default());
    // aggressive compression (1 virtual s = 2 ms) keeps the suite fast
    let opts = TestbedOptions { time_scale: 2.0, profile: false };
    let res = Experiment::builder(cfg)
        .observer(Box::new(CountingObserver(counts.clone())))
        .backend_impl(Box::new(ThreadedBackend::with_options(opts)))
        .run()
        .unwrap();
    assert_eq!(res.rounds.len(), 6);
    assert_eq!(counts.rounds.load(Ordering::Relaxed), 6);
    assert!(res.label.starts_with("testbed-"));
    assert!(res.evals.iter().all(|e| e.avg_loss.is_finite()));
}

#[test]
fn threaded_backend_rejects_pjrt_configs() {
    let mut cfg = small_cfg();
    cfg.trainer = TrainerKind::Pjrt;
    // even with an explicit trainer, the threaded backend can't ship it
    // across worker threads — must be a clean Unsupported error
    let trainer = dystop::worker::default_trainer(&ExperimentConfig {
        trainer: TrainerKind::Native,
        ..small_cfg()
    })
    .unwrap();
    let opts = TestbedOptions { time_scale: 2.0, profile: false };
    let err = Experiment::builder(cfg)
        .trainer(trainer)
        .backend_impl(Box::new(ThreadedBackend::with_options(opts)))
        .run()
        .unwrap_err();
    assert!(matches!(err, ExperimentError::Unsupported(_)), "{err}");
}
