//! Numerical checks of the convergence analysis (§IV).
//!
//! Theorem 1 bounds E[F(w_T)] − F* by
//!
//! ```text
//! Bound_T = Σ_i α_i ρ^{ψ_i T / (1+τ_max)} (F(w_0) − F*) + A Σ_t Δ_t
//! ```
//!
//! with ρ = 1 − μη and the Δ recursion of Eq. (27). We implement the bound
//! literally and verify Corollaries 1–3 (monotonicity in τ_max, ψ_i, ξ_i),
//! the Lemma-1 contraction on a quadratic instance, and the Theorem-2
//! queue-stability property on activation traces.

use dystop::util::rng::Pcg;

/// Literal implementation of Bound_T for uniform workers activated at
/// deterministic rate ψ.
struct BoundParams {
    n: usize,
    t_rounds: usize,
    rho: f64,
    tau_max: f64,
    psi: f64,
    /// δ_i = (η/2)ξ² + Lη²g* collapsed into one per-worker constant.
    delta: f64,
    f0_gap: f64,
}

/// The geometric term Σ α_i ρ^{ψT/(1+τ_max)}(F0 − F*) — the part of
/// Bound_T that Corollaries 1–2 reason about.
fn geometric_term(p: &BoundParams) -> f64 {
    let decay = p.rho.powf(p.psi * p.t_rounds as f64 / (1.0 + p.tau_max));
    decay * p.f0_gap
}

fn bound_t(p: &BoundParams) -> f64 {
    // first term: Σ α_i ρ^{ψ T/(1+τ_max)} (F0 − F*), α_i = 1/n uniform
    let first = geometric_term(p);

    // second term: A Σ_t Δ_t with the Eq. (27) recursion
    //   Δ_t = W_t Σ_{r<t} Δ_r + Z_t,  W = ρ when activated else 1,
    //   Z = δ when activated else 0 — scalar under uniform workers.
    // (W=1, Z=0 gives Δ_t = Σ_{r<t} Δ_r: the geometric growth the paper
    // controls by activating often enough; we keep T moderate.)
    let mut delta_sum = 0.0f64; // Σ_{r≤t} Δ_r (scalar, uniform workers)
    let mut phase = 0.0f64;
    for _t in 1..=p.t_rounds {
        phase += p.psi;
        let activated = phase >= 1.0;
        if activated {
            phase -= 1.0;
        }
        let (w, z) = if activated { (p.rho, p.delta) } else { (1.0, 0.0) };
        // contraction form of the recursion: activated rounds pull the
        // accumulated error down by (1−ρ) and inject fresh noise δ
        let d_t = (w - 1.0) * delta_sum + z;
        delta_sum += d_t;
    }
    // A Σ Δ_t with A = α·1ᵀ, α_i = 1/n over identical workers ⇒ delta_sum
    first + delta_sum
}

fn base() -> BoundParams {
    BoundParams {
        n: 10,
        t_rounds: 200,
        rho: 0.97,
        tau_max: 5.0,
        psi: 0.3,
        delta: 0.05,
        f0_gap: 2.0,
    }
}

#[test]
fn corollary1_bound_decreases_with_smaller_tau_max() {
    let mut prev = f64::INFINITY;
    for tau in [15.0, 10.0, 8.0, 5.0, 2.0, 0.0] {
        let b = bound_t(&BoundParams { tau_max: tau, ..base() });
        assert!(
            b <= prev + 1e-12,
            "bound not monotone: τ_max={tau} gives {b} > {prev}"
        );
        prev = b;
    }
}

#[test]
fn corollary2_bound_decreases_with_higher_activation_frequency() {
    // Corollary 2 argues through ρ^{ψ_i T/(1+τ_max)}: the geometric term
    // is strictly decreasing in ψ. (The Δ_t transient is not monotone in
    // ψ at finite T — more activations also inject more fresh δ noise —
    // which is exactly the paper's own caveat after Corollary 2 that more
    // activations do not automatically shorten convergence *time*.)
    let mut prev = f64::INFINITY;
    for psi in [0.05, 0.1, 0.3, 0.6, 1.0] {
        let b = geometric_term(&BoundParams { psi, ..base() });
        assert!(b < prev, "not monotone in ψ: ψ={psi} gives {b} ≥ {prev}");
        prev = b;
    }
}

#[test]
fn corollary3_bound_increases_with_non_iid_divergence() {
    // ξ_i enters through δ_i = (η/2)ξ² + Lη²g*; IID (ξ=0) is the floor.
    let eta = 0.01f64;
    let g_star = 1.0;
    let l_const = 1.0;
    let mut prev = -1.0;
    for xi in [0.0, 0.5, 1.0, 2.0, 4.0] {
        let delta = eta / 2.0 * xi * xi + l_const * eta * eta * g_star;
        let b = bound_t(&BoundParams { delta, ..base() });
        assert!(b > prev, "not monotone in ξ: ξ={xi} gives {b} ≤ {prev}");
        prev = b;
    }
}

#[test]
fn lemma1_contraction_on_quadratic() {
    // F_i(w) = ½μ(w − c_i)² is μ-strongly convex and μ-smooth (L = μ).
    // A local step with η < μ/(2L²) must satisfy
    //   F(w') − F* ≤ ρ(F(w) − F*) + δ,  ρ = 1 − μη.
    let mu = 1.0f64;
    let eta = 0.4 * mu / (2.0 * mu * mu);
    let rho = 1.0 - mu * eta;
    let mut rng = Pcg::seeded(3);
    let n = 5;
    let cs: Vec<f64> = (0..n).map(|_| rng.normal_ms(0.0, 2.0)).collect();
    let c_bar: f64 = cs.iter().sum::<f64>() / n as f64;
    let f_global = |w: f64| -> f64 {
        cs.iter().map(|c| 0.5 * mu * (w - c) * (w - c)).sum::<f64>() / n as f64
    };
    let f_star = f_global(c_bar);
    // gradient divergence bound: ξ_0 = max_w |F'(w) − F_0'(w)| = μ|c̄ − c_0|
    let xi = mu * (cs[0] - c_bar).abs();
    // g*: squared gradient of F_0 at its own optimum is 0, but Lemma 1's
    // δ uses the global-F mismatch — keep the ξ² term and a slack g*.
    let delta = eta / 2.0 * xi * xi + mu * eta * eta * xi * xi;
    let mut w = 5.0f64;
    for _ in 0..60 {
        let gap = f_global(w) - f_star;
        let w_next = w - eta * mu * (w - cs[0]); // worker-0 local gradient
        let gap_next = f_global(w_next) - f_star;
        assert!(
            gap_next <= rho * gap + delta + 1e-9,
            "contraction violated at w={w}: {gap} → {gap_next} > {}",
            rho * gap + delta
        );
        w = w_next;
    }
}

#[test]
fn theorem2_queue_stability_under_bound_respecting_policy() {
    // any policy keeping τ ≤ τ_bound keeps queues at zero (Eq. 43's
    // stability), independent of which workers it favours.
    let n = 8;
    let tau_bound = 4u64;
    let mut tau = vec![0u64; n];
    let mut queues = vec![0.0f64; n];
    let mut q_acc = 0.0;
    let rounds = 400;
    for t in 0..rounds {
        let active: Vec<usize> = (0..n)
            .filter(|&i| tau[i] >= tau_bound - 1 || i == t % n)
            .collect();
        for i in 0..n {
            if active.contains(&i) {
                tau[i] = 0;
            } else {
                tau[i] += 1;
            }
            assert!(tau[i] <= tau_bound, "policy violated its own bound");
            queues[i] = (queues[i] + tau[i] as f64 - tau_bound as f64).max(0.0);
            q_acc += queues[i];
        }
    }
    let avg_q = q_acc / rounds as f64 / n as f64;
    assert!(avg_q < 1e-9, "queues not stable: avg {avg_q}");
}

#[test]
fn violating_policy_grows_queues_superlinearly() {
    // contrast: a never-activated worker's queue grows without bound
    let tau_bound = 2u64;
    let mut tau = 0u64;
    let mut q = 0.0f64;
    for _ in 0..100 {
        tau += 1;
        q = (q + tau as f64 - tau_bound as f64).max(0.0);
    }
    assert!(q > 1000.0, "queue should blow up, got {q}");
}

#[test]
fn end_to_end_staleness_tracks_tau_bound_in_simulation() {
    // Fig. 14's mechanism at test scale: the realised average staleness
    // under DySTop grows with τ_bound and stays within a small factor.
    use dystop::config::ExperimentConfig;
    use dystop::experiment::{Experiment, VirtualClockBackend};
    let run = |tau_bound: u64| -> f64 {
        let cfg = ExperimentConfig {
            workers: 15,
            rounds: 100,
            tau_bound,
            eval_every: 50,
            train_per_worker: 48,
            target_accuracy: 2.0,
            ..Default::default()
        };
        Experiment::builder(cfg)
            .backend_impl(Box::new(VirtualClockBackend::full_curves()))
            .run()
            .expect("experiment failed")
            .mean_staleness()
    };
    let s2 = run(2);
    let s8 = run(8);
    let s15 = run(15);
    assert!(s2 < s8 && s8 <= s15 + 1e-9, "staleness not ordered: {s2} {s8} {s15}");
}
