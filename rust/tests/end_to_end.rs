//! End-to-end integration over the full simulator: the paper's headline
//! claims at test scale — DySTop converges faster than the baselines,
//! with less communication, while keeping staleness controlled.

use dystop::config::{ExperimentConfig, SchedulerKind};
use dystop::experiment::{Experiment, VirtualClockBackend};
use dystop::metrics::RunResult;

/// Full-curve run through the builder (ex `SimEngine::run_full`).
fn run_full(cfg: ExperimentConfig) -> RunResult {
    Experiment::builder(cfg)
        .backend_impl(Box::new(VirtualClockBackend::full_curves()))
        .run()
        .expect("experiment failed")
}

fn cfg(scheduler: SchedulerKind, phi: f64, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        // mechanism gaps (stragglers, push-to-all cost, staleness) only
        // open up at moderate scale — N≈40 is the smallest reliable size
        workers: 40,
        rounds: 240,
        phi,
        seed,
        train_per_worker: 96,
        test_samples: 256,
        eval_every: 8,
        class_sep: 3.0,
        target_accuracy: 2.0,
        scheduler,
        ..Default::default()
    }
}

fn run(scheduler: SchedulerKind, phi: f64, seed: u64) -> RunResult {
    run_full(cfg(scheduler, phi, seed))
}

/// Time to reach the given accuracy, or the final time if never reached
/// (penalises non-convergence without unwrapping panics).
fn tta(res: &RunResult, target: f64) -> f64 {
    res.time_to_accuracy(target)
        .unwrap_or_else(|| res.final_time_s() * 4.0)
}

#[test]
fn all_mechanisms_converge_iid() {
    for k in [
        SchedulerKind::DySTop,
        SchedulerKind::AsyDfl,
        SchedulerKind::SaAdfl,
        SchedulerKind::Matcha,
    ] {
        let res = run(k, 1.0, 3);
        assert!(
            res.best_accuracy() > 0.6,
            "{}: best {}",
            res.label,
            res.best_accuracy()
        );
    }
}

#[test]
fn dystop_beats_matcha_on_completion_time() {
    // the headline Fig. 4 ordering: DySTop ≪ MATCHA (straggler-bound)
    let d = run(SchedulerKind::DySTop, 0.7, 5);
    let m = run(SchedulerKind::Matcha, 0.7, 5);
    let target = 0.80;
    let td = tta(&d, target);
    let tm = tta(&m, target);
    assert!(
        td < tm,
        "dystop {td:.1}s should beat matcha {tm:.1}s to {target}"
    );
}

#[test]
fn dystop_beats_saadfl_on_communication() {
    // Fig. 7 ordering: DySTop uses less comm than SA-ADFL at equal
    // accuracy. The gap opens with scale (SA-ADFL pushes to *all* workers
    // in range — Θ(N) per round); sum over two seeds at N=60 to smooth
    // eval-granularity noise.
    let target = 0.80;
    let mut cd_sum = 0.0;
    let mut cs_sum = 0.0;
    for seed in [7u64, 8] {
        let mut c = cfg(SchedulerKind::DySTop, 1.0, seed);
        c.workers = 60;
        let d = run_full(c);
        let mut c = cfg(SchedulerKind::SaAdfl, 1.0, seed);
        c.workers = 60;
        let s = run_full(c);
        cd_sum += d.comm_to_accuracy(target).expect("dystop must converge");
        cs_sum += s
            .comm_to_accuracy(target)
            .unwrap_or_else(|| s.total_comm_gb() * 2.0);
        // structural check: per-activation transfer count — SA-ADFL's
        // push-to-all moves far more models per activation than DySTop's
        // s-capped pulls
        let per_act = |r: &RunResult| {
            r.rounds.iter().map(|x| x.transfers).sum::<usize>() as f64
                / r.rounds.iter().map(|x| x.active).sum::<usize>() as f64
        };
        assert!(
            per_act(&s) > 2.0 * per_act(&d),
            "per-activation comm: sa-adfl {} vs dystop {}",
            per_act(&s),
            per_act(&d)
        );
    }
    assert!(
        cd_sum < cs_sum,
        "dystop {cd_sum} GB should be < sa-adfl {cs_sum} GB"
    );
}

#[test]
fn non_iid_degrades_all_mechanisms() {
    // Fig. 4: completion time grows as φ falls (harder data)
    let easy = run(SchedulerKind::DySTop, 1.0, 9);
    let hard = run(SchedulerKind::DySTop, 0.4, 9);
    assert!(
        hard.best_accuracy() <= easy.best_accuracy() + 0.05,
        "non-IID should not be easier: {} vs {}",
        hard.best_accuracy(),
        easy.best_accuracy()
    );
}

#[test]
fn dystop_controls_staleness_asydfl_does_not() {
    // Table I: DySTop "Good" staleness handling, AsyDFL "Poor"
    let d = run(SchedulerKind::DySTop, 1.0, 11);
    let a = run(SchedulerKind::AsyDfl, 1.0, 11);
    let max_d = d.rounds.iter().map(|r| r.max_staleness).max().unwrap();
    let max_a = a.rounds.iter().map(|r| r.max_staleness).max().unwrap();
    assert!(
        max_d < max_a,
        "dystop max staleness {max_d} should be < asydfl {max_a}"
    );
}

#[test]
fn ptca_combined_beats_single_phases_on_noniid() {
    // Fig. 3's claim at test scale: combined ≥ max(phase1, phase2) in
    // final accuracy (allow small tolerance — stochastic at this scale)
    let comb = run(SchedulerKind::DySTop, 0.4, 13);
    let p1 = run(SchedulerKind::DySTopPhase1Only, 0.4, 13);
    let p2 = run(SchedulerKind::DySTopPhase2Only, 0.4, 13);
    let best = p1.best_accuracy().max(p2.best_accuracy());
    assert!(
        comb.best_accuracy() > best - 0.05,
        "combined {:.3} vs best single-phase {:.3}",
        comb.best_accuracy(),
        best
    );
}

#[test]
fn tau_bound_sweep_orders_average_staleness() {
    // Fig. 14 mechanism
    let s = |tau: u64| {
        let mut c = cfg(SchedulerKind::DySTop, 1.0, 15);
        c.tau_bound = tau;
        c.rounds = 100;
        run_full(c).mean_staleness()
    };
    let lo = s(2);
    let hi = s(15);
    assert!(lo < hi, "τ_bound=2 gives {lo}, τ_bound=15 gives {hi}");
}

#[test]
fn results_reproducible_across_identical_runs() {
    let a = run(SchedulerKind::DySTop, 0.7, 17);
    let b = run(SchedulerKind::DySTop, 0.7, 17);
    assert_eq!(a.total_transfers(), b.total_transfers());
    assert_eq!(a.final_time_s(), b.final_time_s());
    let ea: Vec<f64> = a.evals.iter().map(|e| e.avg_accuracy).collect();
    let eb: Vec<f64> = b.evals.iter().map(|e| e.avg_accuracy).collect();
    assert_eq!(ea, eb);
}
