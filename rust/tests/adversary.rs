//! Adversary subsystem contracts:
//!
//! * robust-aggregator properties: trimmed-mean / coordinate-median /
//!   Krum are permutation-invariant (bitwise — column sorts and score
//!   sums do not depend on input order); all rules collapse to the
//!   common vector on identical inputs; Krum picks an honest model
//!   whenever `n ≥ 2f + 3` and the `f` outliers are gross;
//! * end-to-end: the seeded `adversary.frac` cast shows up in every
//!   round's `adversaries` tally on both backends, activation events
//!   are recorded once per firing attacker, and scripted casts route
//!   through the builder (wrong-length scripts are `InvalidConfig`).
//!
//! The CI adversary matrix re-runs this suite with
//! `DYSTOP_ADVERSARY_ATTACK` varied; [`AttackKind::from_env_or`] routes
//! that knob through the end-to-end smoke below.

use dystop::adversary::{AdversaryPolicy, Aggregator};
use dystop::config::{
    AdversaryConfig, AggregatorKind, AttackKind, BackendKind,
    ExperimentConfig,
};
use dystop::experiment::{
    Experiment, ExperimentError, TestbedOptions, ThreadedBackend,
};
use dystop::metrics::RunResult;
use dystop::util::prop::forall_seeded;
use dystop::util::rng::Pcg;
use dystop::worker::{NativeTrainer, Params};

const DIM: usize = 7;

fn agg_with(kind: AggregatorKind, krum_f: usize) -> Aggregator {
    Aggregator::from_config(&AdversaryConfig {
        aggregator: kind,
        krum_f,
        ..Default::default()
    })
}

fn trainer() -> NativeTrainer {
    NativeTrainer::new(2, 2)
}

fn rand_models(rng: &mut Pcg, n: usize, dim: usize) -> Vec<Params> {
    (0..n)
        .map(|_| {
            (0..dim).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect()
        })
        .collect()
}

fn shuffled(rng: &mut Pcg, n: usize) -> Vec<usize> {
    let mut perm: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        perm.swap(i, rng.below_usize(i + 1));
    }
    perm
}

fn run_agg(
    agg: &mut Aggregator,
    models: &[Params],
    order: &[usize],
) -> Params {
    let refs: Vec<&[f32]> = order.iter().map(|&i| &models[i][..]).collect();
    // the mean path (and krum's n<3 fallback) routes through the
    // trainer, whose weights must sum to 1
    let weights = vec![1.0 / refs.len() as f32; refs.len()];
    let mut t = trainer();
    let mut out = Params::new();
    agg.aggregate_into(&mut t, &refs, &weights, &mut out);
    out
}

// --- aggregator properties -------------------------------------------

#[test]
fn robust_rules_are_permutation_invariant_bitwise() {
    for kind in [
        AggregatorKind::TrimmedMean,
        AggregatorKind::CoordinateMedian,
        AggregatorKind::Krum,
    ] {
        forall_seeded(0xA6 + kind.name().len() as u64, 32, |rng| {
            let n = 3 + rng.below_usize(8); // 3..=10 models
            let models = rand_models(rng, n, DIM);
            let mut agg = agg_with(kind, 1);
            let identity: Vec<usize> = (0..n).collect();
            let base = run_agg(&mut agg, &models, &identity);
            let perm = shuffled(rng, n);
            let permuted = run_agg(&mut agg, &models, &perm);
            assert_eq!(
                base.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                permuted.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{} not permutation-invariant (n={n}, perm={perm:?})",
                kind.name()
            );
        });
    }
}

#[test]
fn all_rules_collapse_to_the_common_vector_on_identical_inputs() {
    forall_seeded(0xB3, 32, |rng| {
        let n = 3 + rng.below_usize(8);
        let v = rand_models(rng, 1, DIM).remove(0);
        let models = vec![v.clone(); n];
        let identity: Vec<usize> = (0..n).collect();

        // the order-statistic rules see n identical order statistics
        let median = run_agg(
            &mut agg_with(AggregatorKind::CoordinateMedian, 1),
            &models,
            &identity,
        );
        assert_eq!(median, v, "median must be exact on identical inputs");
        // krum copies the winner verbatim
        let krum = run_agg(
            &mut agg_with(AggregatorKind::Krum, 1),
            &models,
            &identity,
        );
        assert_eq!(krum, v, "krum must copy a model verbatim");
        // trimmed mean and plain mean re-average n copies: allow the
        // summation rounding, nothing more
        for kind in [AggregatorKind::TrimmedMean, AggregatorKind::Mean] {
            let got = run_agg(&mut agg_with(kind, 1), &models, &identity);
            for (g, want) in got.iter().zip(&v) {
                assert!(
                    (g - want).abs() <= 1e-5 * (1.0 + want.abs()),
                    "{}: {g} != {want} on identical inputs",
                    kind.name()
                );
            }
        }
    });
}

#[test]
fn krum_selects_an_honest_model_under_gross_outliers() {
    // n ≥ 2f + 3 is Krum's admissibility bound: enough honest
    // neighbours that every honest score ignores all f outliers.
    forall_seeded(0xC9, 32, |rng| {
        let f = 1 + rng.below_usize(2); // f ∈ {1, 2}
        let n = 2 * f + 3 + rng.below_usize(3);
        let honest: Vec<Params> = (0..n - f)
            .map(|_| {
                (0..DIM)
                    .map(|_| 1.0 + rng.range_f64(-0.01, 0.01) as f32)
                    .collect()
            })
            .collect();
        let mut models = honest.clone();
        for _ in 0..f {
            models.push(
                (0..DIM)
                    .map(|_| rng.range_f64(500.0, 1000.0) as f32)
                    .collect(),
            );
        }
        let order = shuffled(rng, n);
        let picked =
            run_agg(&mut agg_with(AggregatorKind::Krum, f), &models, &order);
        assert!(
            honest.contains(&picked),
            "krum picked an outlier: {picked:?} (f={f}, n={n})"
        );
    });
}

// --- end-to-end: cast, tallies, events, both backends ----------------

fn adv_cfg(attack: AttackKind) -> ExperimentConfig {
    ExperimentConfig {
        workers: 8,
        rounds: 6,
        train_per_worker: 48,
        test_samples: 80,
        eval_every: 3,
        seed: 42,
        target_accuracy: 2.0,
        adversary: AdversaryConfig {
            frac: 0.25,
            attack,
            aggregator: AggregatorKind::TrimmedMean,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn assert_adversary_run(res: &RunResult, attack: AttackKind) {
    assert_eq!(res.rounds.len(), 6);
    let expected = if attack == AttackKind::None { 0 } else { 2 };
    for r in &res.rounds {
        assert_eq!(
            r.adversaries, expected,
            "round {} adversary tally",
            r.round
        );
    }
    let fired = res
        .events
        .iter()
        .filter(|e| e.kind.starts_with("attack-"))
        .count();
    if attack == AttackKind::None {
        assert_eq!(fired, 0, "no activations without a cast");
    } else {
        // every non-honest policy latches an activation on its first
        // transmit (label-flip included — the event marks the cast
        // even though its poison is applied at build time)
        assert!(
            (1..=expected).contains(&fired),
            "activation events: {fired} of {expected} attackers"
        );
        let want = AdversaryPolicy::from_attack(attack).event_kind();
        for e in res.events.iter().filter(|e| e.kind.starts_with("attack-"))
        {
            assert_eq!(e.kind, want);
            assert!(e.worker.is_some(), "activation must name the worker");
        }
    }
}

/// The CI matrix leg re-runs this with `DYSTOP_ADVERSARY_ATTACK` set;
/// locally it exercises sign-flip.
#[test]
fn seeded_cast_runs_end_to_end_on_the_sim_backend() {
    let attack = AttackKind::from_env_or(AttackKind::SignFlip);
    let res = Experiment::builder(adv_cfg(attack))
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    assert_adversary_run(&res, attack);
    assert!(res.evals.iter().all(|e| e.avg_loss.is_finite()));
}

#[test]
fn seeded_cast_runs_end_to_end_on_the_threaded_backend() {
    let attack = AttackKind::from_env_or(AttackKind::SignFlip);
    let mut cfg = adv_cfg(attack);
    cfg.compute_mean_s = 0.5;
    let opts = TestbedOptions { time_scale: 2.0, profile: false };
    let res = Experiment::builder(cfg)
        .backend_impl(Box::new(ThreadedBackend::with_options(opts)))
        .run()
        .unwrap();
    assert_adversary_run(&res, attack);
}

#[test]
fn scripted_cast_overrides_the_seeded_assignment() {
    let mut policies = vec![AdversaryPolicy::Honest; 8];
    policies[1] = AdversaryPolicy::FreeRide;
    policies[5] = AdversaryPolicy::LabelFlip;
    policies[6] = AdversaryPolicy::Scale;
    // cfg knobs say "no adversary" — the script wins
    let mut cfg = adv_cfg(AttackKind::None);
    cfg.adversary.frac = 0.0;
    let res = Experiment::builder(cfg)
        .backend(BackendKind::Sim)
        .adversary(policies)
        .run()
        .unwrap();
    for r in &res.rounds {
        assert_eq!(r.adversaries, 3, "scripted cast tally");
    }
}

#[test]
fn wrong_length_script_is_invalid_config() {
    let err = Experiment::builder(adv_cfg(AttackKind::None))
        .backend(BackendKind::Sim)
        .adversary(vec![AdversaryPolicy::SignFlip; 3]) // workers = 8
        .run()
        .unwrap_err();
    assert!(matches!(err, ExperimentError::InvalidConfig(_)), "{err}");
}

#[test]
fn stale_bomb_replays_old_parameters() {
    let mut policies = vec![AdversaryPolicy::Honest; 6];
    policies[2] = AdversaryPolicy::StaleBomb;
    let mut cfg = adv_cfg(AttackKind::None);
    cfg.workers = 6;
    cfg.adversary.stale_tau = 2;
    let res = Experiment::builder(cfg)
        .backend(BackendKind::Sim)
        .adversary(policies)
        .run()
        .unwrap();
    assert_eq!(res.rounds.len(), 6);
    for r in &res.rounds {
        assert_eq!(r.adversaries, 1);
    }
}
