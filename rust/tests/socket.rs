//! Socket deployment backend contracts:
//!
//! * **wire integrity** — the framed wire format round-trips arbitrary
//!   payloads, rejects garbage prefixes and truncated streams with
//!   typed errors, and surfaces every injected payload bit-flip
//!   through the CRC check;
//! * **ledger agreement** — the cross-backend anchor: given the same
//!   seed, the socket backend and the virtual-clock simulator emit the
//!   same round plans and the same event/byte ledger (transfers,
//!   retransmissions, dead-letters, dropped messages, `cum_bytes`),
//!   bit-for-bit, including under a scripted mid-run crash whose
//!   in-flight pushed models must be charged as `crash_dropped` on
//!   every backend;
//! * **observability** — `trace.out` produces valid Trace Event JSON
//!   with at least one complete span on every activated worker's
//!   track.
//!
//! UDS runs are unix-gated; the TCP smoke runs everywhere.

use dystop::config::{
    BackendKind, ExperimentConfig, SchedulerKind, SocketTransportKind,
};
use dystop::coordinator::RoundPlan;
use dystop::delivery::Frame;
use dystop::experiment::{Experiment, RoundObserver};
use dystop::metrics::RunResult;
use dystop::scenario::{Scenario, ScenarioEvent};
use dystop::transport::wire::{read_frame, write_frame};
use dystop::util::json::Json;
use dystop::util::prop::forall_seeded;
use std::cell::RefCell;
use std::rc::Rc;

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        workers: 10,
        rounds: 8,
        train_per_worker: 48,
        test_samples: 64,
        eval_every: 4,
        seed: 42,
        target_accuracy: 2.0,
        ..Default::default()
    };
    // virtual seconds map to ~0 wall milliseconds: the emulated sleeps
    // truncate away, the virtual-time ledger is unaffected
    cfg.socket.time_scale = 0.001;
    cfg
}

/// Observer capturing every validated (global-id) round plan.
struct PlanTap(Rc<RefCell<Vec<RoundPlan>>>);

impl RoundObserver for PlanTap {
    fn on_plan(&mut self, _round: usize, plan: &RoundPlan) {
        self.0.borrow_mut().push(plan.clone());
    }
}

fn run_with_plans(
    cfg: ExperimentConfig,
    backend: BackendKind,
    scenario: Option<Scenario>,
) -> (RunResult, Vec<RoundPlan>) {
    let plans = Rc::new(RefCell::new(Vec::new()));
    let mut builder = Experiment::builder(cfg)
        .observer(Box::new(PlanTap(plans.clone())))
        .backend(backend);
    if let Some(s) = scenario {
        builder = builder.scenario(s);
    }
    let res = builder.run().unwrap();
    let captured = plans.borrow().clone();
    (res, captured)
}

fn assert_plans_equal(sim: &[RoundPlan], sock: &[RoundPlan]) {
    assert_eq!(sim.len(), sock.len(), "round counts differ");
    for (r, (a, b)) in sim.iter().zip(sock).enumerate() {
        assert_eq!(a.active, b.active, "active set, round {}", r + 1);
        assert_eq!(
            a.pulls_from,
            b.pulls_from,
            "pull topology, round {}",
            r + 1
        );
        assert_eq!(a.pushes, b.pushes, "push edges, round {}", r + 1);
    }
}

/// The cross-backend anchor: every plan-derived and delivery-derived
/// quantity of the round/eval ledger agrees bit-for-bit.
fn assert_ledgers_agree(sim: &RunResult, sock: &RunResult) {
    assert_eq!(sim.rounds.len(), sock.rounds.len());
    for (a, b) in sim.rounds.iter().zip(&sock.rounds) {
        let r = a.round;
        assert_eq!(a.round, b.round);
        assert_eq!(a.active, b.active, "round {r}");
        assert_eq!(a.population, b.population, "round {r}");
        assert_eq!(a.transfers, b.transfers, "round {r}");
        assert_eq!(a.retransmissions, b.retransmissions, "round {r}");
        assert_eq!(a.dropped_msgs, b.dropped_msgs, "round {r}");
        assert_eq!(a.corrupt_detected, b.corrupt_detected, "round {r}");
        assert_eq!(
            a.bytes_sent.to_bits(),
            b.bytes_sent.to_bits(),
            "round {r} bytes"
        );
        assert_eq!(
            a.duration_s.to_bits(),
            b.duration_s.to_bits(),
            "round {r} duration"
        );
        assert_eq!(
            a.time_s.to_bits(),
            b.time_s.to_bits(),
            "round {r} clock"
        );
        assert_eq!(
            a.avg_staleness.to_bits(),
            b.avg_staleness.to_bits(),
            "round {r} avg tau"
        );
        assert_eq!(a.max_staleness, b.max_staleness, "round {r} max tau");
    }
    assert_eq!(sim.evals.len(), sock.evals.len());
    for (a, b) in sim.evals.iter().zip(&sock.evals) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.cum_transfers, b.cum_transfers, "eval @{}", a.round);
        assert_eq!(
            a.cum_bytes.to_bits(),
            b.cum_bytes.to_bits(),
            "eval @{}",
            a.round
        );
    }
}

// --- wire format properties ------------------------------------------

#[test]
fn wire_round_trips_arbitrary_payloads() {
    forall_seeded(0xD15F, 64, |rng| {
        let len = (rng.next_u32() % 2048) as usize;
        let payload: Vec<u8> =
            (0..len).map(|_| rng.next_u32() as u8).collect();
        let frame = Frame::new(rng.next_u64(), payload);
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        let back = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(back.seq, frame.seq);
        assert_eq!(back.payload, frame.payload);
        assert!(back.check(), "round-tripped frame must pass CRC");
    });
}

#[test]
fn wire_surfaces_every_payload_bit_flip() {
    forall_seeded(0xF11B, 64, |rng| {
        let len = 1 + (rng.next_u32() % 512) as usize;
        let payload: Vec<u8> =
            (0..len).map(|_| rng.next_u32() as u8).collect();
        let frame = Frame::new(rng.next_u64(), payload);
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        // flip one random bit inside the payload region (after the
        // 16-byte header, before the trailing CRC)
        let byte = 16 + (rng.next_u32() as usize % len);
        buf[byte] ^= 1 << (rng.next_u32() % 8);
        let back = read_frame(&mut buf.as_slice()).unwrap();
        assert!(!back.check(), "bit flip at byte {byte} must fail CRC");
    });
}

#[test]
fn wire_rejects_garbage_prefix_and_truncation() {
    forall_seeded(0x6A3B, 64, |rng| {
        let payload: Vec<u8> =
            (0..(rng.next_u32() % 256)).map(|_| rng.next_u32() as u8).collect();
        let frame = Frame::new(rng.next_u64(), payload);
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        // garbage prefix: corrupt one magic byte — typed InvalidData
        let mut garbled = buf.clone();
        garbled[rng.next_u32() as usize % 4] ^= 0xA5;
        let err = read_frame(&mut garbled.as_slice()).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        // truncation at a random strict prefix — typed UnexpectedEof
        let cut = rng.next_u32() as usize % buf.len();
        let err = read_frame(&mut &buf[..cut]).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    });
}

// --- cross-backend ledger agreement ----------------------------------

#[cfg(unix)]
#[test]
fn socket_backend_matches_sim_event_and_byte_ledger() {
    let cfg = base_cfg();
    let (sim, sim_plans) = run_with_plans(cfg.clone(), BackendKind::Sim, None);
    let (sock, sock_plans) = run_with_plans(cfg, BackendKind::Socket, None);
    assert_plans_equal(&sim_plans, &sock_plans);
    assert_ledgers_agree(&sim, &sock);
    assert!(
        sim.rounds.iter().any(|r| r.transfers > 0),
        "a run with zero transfers pins nothing"
    );
}

#[cfg(unix)]
#[test]
fn socket_ledger_agreement_survives_faulty_links() {
    use dystop::config::{FaultConfig, FaultProfile};
    let mut cfg = base_cfg();
    cfg.faults = FaultConfig::preset(FaultProfile::Wifi);
    let (sim, sim_plans) = run_with_plans(cfg.clone(), BackendKind::Sim, None);
    let (sock, sock_plans) = run_with_plans(cfg, BackendKind::Socket, None);
    assert_plans_equal(&sim_plans, &sock_plans);
    assert_ledgers_agree(&sim, &sock);
    assert!(
        sim.rounds.iter().any(|r| r.retransmissions > 0),
        "wifi profile should exercise the retry path"
    );
}

#[cfg(unix)]
#[test]
fn crash_inflight_drops_agree_across_all_backends() {
    // SA-ADFL pushes post-training models; a scripted crash at round 2
    // must drop round 1's in-flight pushes through crash_dropped — the
    // same count, on every backend.
    let mut cfg = base_cfg();
    cfg.scheduler = SchedulerKind::SaAdfl;
    // bench-top geometry: everyone in range, so round 1 has pushes
    cfg.network.region_m = 20.0;
    cfg.network.comm_range_m = 30.0;
    cfg.network.mobility_m = 0.0;
    cfg.testbed.time_scale = 2.0;
    cfg.testbed.profile = false;
    let (probe, plans) = run_with_plans(cfg.clone(), BackendKind::Sim, None);
    let w = plans[0].active[0];
    let pushed = plans[0].pushes.len();
    assert!(pushed > 0, "round 1 pushed nothing; widen the network");
    assert!(probe.rounds.iter().all(|r| r.dropped_msgs == 0));
    let script = || {
        Scenario::from_events(vec![(2, ScenarioEvent::Crash { worker: w })])
    };
    let (sim, _) =
        run_with_plans(cfg.clone(), BackendKind::Sim, Some(script()));
    let (sock, _) =
        run_with_plans(cfg.clone(), BackendKind::Socket, Some(script()));
    let (testbed, _) =
        run_with_plans(cfg, BackendKind::Testbed, Some(script()));
    assert_eq!(sim.rounds[1].round, 2);
    assert_eq!(
        sim.rounds[1].dropped_msgs, pushed,
        "every in-flight model dropped by the crash must be accounted"
    );
    assert_ledgers_agree(&sim, &sock);
    // the testbed's wall-clock realization differs, but the crash
    // accounting is the same pure function of (seed, plans, scenario)
    let drops = |r: &RunResult| -> Vec<usize> {
        r.rounds.iter().map(|x| x.dropped_msgs).collect()
    };
    assert_eq!(drops(&sim), drops(&testbed));
}

#[test]
fn tcp_socket_backend_matches_sim_ledger() {
    let mut cfg = base_cfg();
    cfg.workers = 6;
    cfg.rounds = 4;
    cfg.eval_every = 2;
    cfg.socket.transport = SocketTransportKind::Tcp;
    let (sim, sim_plans) = run_with_plans(cfg.clone(), BackendKind::Sim, None);
    let (sock, sock_plans) = run_with_plans(cfg, BackendKind::Socket, None);
    assert_plans_equal(&sim_plans, &sock_plans);
    assert_ledgers_agree(&sim, &sock);
}

// --- trace observability ---------------------------------------------

#[cfg(unix)]
#[test]
fn trace_output_is_valid_and_covers_activated_workers() {
    let trace_path = std::env::temp_dir().join(format!(
        "dystop-socket-trace-{}.json",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&trace_path);
    let mut cfg = base_cfg();
    cfg.rounds = 4;
    cfg.trace.out = trace_path.display().to_string();
    let (_res, plans) = run_with_plans(cfg, BackendKind::Socket, None);
    let text = std::fs::read_to_string(&trace_path).unwrap();
    let json = Json::parse(&text).unwrap();
    let events = json
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("top-level traceEvents array");
    assert!(!events.is_empty());
    // every event is an object with a phase; every activated worker got
    // at least one complete ("X") span on its own track (tid = id + 1)
    for ev in events {
        assert!(ev.get("ph").and_then(Json::as_str).is_some(), "{ev}");
    }
    let activated: std::collections::BTreeSet<usize> =
        plans.iter().flat_map(|p| p.active.iter().copied()).collect();
    assert!(!activated.is_empty());
    for w in activated {
        let tid = (w + 1) as f64;
        assert!(
            events.iter().any(|ev| {
                ev.get("ph").and_then(Json::as_str) == Some("X")
                    && ev.get("tid").and_then(Json::as_f64) == Some(tid)
            }),
            "activated worker {w} has no span on tid {tid}"
        );
    }
    let _ = std::fs::remove_file(&trace_path);
}
