//! Scenario-engine integration: dynamic worker populations threaded
//! through the network, the schedulers and both backends.
//!
//! The load-bearing properties:
//!
//! * every scheduler's plan under randomized churn timelines references
//!   only present workers (membership compaction is scheduler-agnostic);
//! * `threads=1` vs `threads=N` stay bit-identical with scenarios
//!   active (events apply on the coordinator only);
//! * the recorded event log accounts for every population change;
//! * `Rejoin` resumes from stale parameters with τ advanced, `Leave`
//!   freezes a worker out of planning.
//!
//! The failure-injection suite (degenerate edge conditions: total link
//! loss, starved bandwidth, single-worker networks, hyper-mobility)
//! lives at the bottom of this file — it is the same "simulator stays
//! correct under hostile populations" surface as the churn tests.

use dystop::config::{
    BackendKind, ExperimentConfig, NetworkConfig, ScenarioConfig,
    ScenarioPreset, SchedulerKind,
};
use dystop::experiment::{
    Experiment, TestbedOptions, ThreadedBackend, VirtualClockBackend,
    VirtualClockEngine,
};
use dystop::metrics::RunResult;
use dystop::scenario::{Scenario, ScenarioEvent};
use dystop::util::rng::Pcg;

fn tiny_cfg(scheduler: SchedulerKind) -> ExperimentConfig {
    ExperimentConfig {
        workers: 12,
        rounds: 30,
        train_per_worker: 48,
        test_samples: 64,
        eval_every: 10,
        seed: 7,
        scheduler,
        target_accuracy: 2.0,
        ..Default::default()
    }
}

const ALL_SCHEDULERS: [SchedulerKind; 6] = [
    SchedulerKind::DySTop,
    SchedulerKind::DySTopPhase1Only,
    SchedulerKind::DySTopPhase2Only,
    SchedulerKind::SaAdfl,
    SchedulerKind::AsyDfl,
    SchedulerKind::Matcha,
];

/// Replay the event log over the round records: every `EventRecord`
/// must carry the correct running population, and every `RoundRecord`
/// must report the population left after its boundary events.
fn assert_event_log_accounts_for_population(res: &RunResult, n0: usize) {
    let mut pop = n0 as i64;
    let mut ev_idx = 0;
    for r in &res.rounds {
        while ev_idx < res.events.len() && res.events[ev_idx].round <= r.round {
            let e = &res.events[ev_idx];
            pop += match e.kind {
                "leave" | "crash" => -1,
                "join" | "rejoin" => 1,
                _ => 0,
            };
            assert_eq!(
                e.population as i64, pop,
                "event {ev_idx} ({}) population mismatch",
                e.kind
            );
            ev_idx += 1;
        }
        assert_eq!(
            r.population as i64, pop,
            "round {} population mismatch",
            r.round
        );
    }
    assert_eq!(ev_idx, res.events.len(), "events after the last round");
}

#[test]
fn stable_preset_keeps_population_constant() {
    let res = Experiment::builder(tiny_cfg(SchedulerKind::DySTop))
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    assert!(res.events.is_empty());
    assert!(res.rounds.iter().all(|r| r.population == 12));
}

#[test]
fn churn_presets_run_all_schedulers_to_completion() {
    // the acceptance criterion: a churn preset runs all six schedulers
    // to completion with workers joining/leaving mid-run, and the event
    // log accounts for every population change
    for kind in ALL_SCHEDULERS {
        let mut cfg = tiny_cfg(kind);
        cfg.workers = 15;
        cfg.rounds = 40;
        cfg.scenario = ScenarioConfig::preset(ScenarioPreset::Diurnal);
        let res = Experiment::builder(cfg)
            .backend(BackendKind::Sim)
            .run()
            .unwrap();
        assert_eq!(res.rounds.len(), 40, "{}", res.label);
        assert!(!res.events.is_empty(), "{}: no churn happened", res.label);
        let (lo, hi) = res.population_range();
        assert!(lo < hi, "{}: population never varied", res.label);
        assert!(lo >= 1, "{}", res.label);
        assert_event_log_accounts_for_population(&res, 15);
        assert!(
            res.evals.iter().all(|e| e.avg_loss.is_finite()),
            "{}",
            res.label
        );
    }
}

#[test]
fn plans_reference_only_present_workers_under_randomized_churn() {
    // property test: randomized churn knobs × every scheduler; after
    // each step the realised (global-id) plan must validate against the
    // network's membership mask
    let mut rng = Pcg::seeded(91);
    for trial in 0..6 {
        let kind = ALL_SCHEDULERS[trial % ALL_SCHEDULERS.len()];
        let mut cfg = tiny_cfg(kind);
        cfg.seed = 100 + trial as u64;
        cfg.rounds = 25;
        cfg.scenario = ScenarioConfig {
            preset: ScenarioPreset::Stable,
            churn_rate: 0.05 + rng.f64() * 0.2,
            mean_downtime_rounds: 1.0 + rng.f64() * 8.0,
            crash_frac: rng.f64(),
        };
        let exp = Experiment::builder(cfg.clone()).build().unwrap();
        assert!(!exp.scenario.is_empty(), "churn must generate events");
        let mut eng = VirtualClockEngine::new(exp);
        for _ in 0..cfg.rounds {
            let plan = eng.step();
            plan.validate_present(eng.net.present_mask()).unwrap_or_else(
                |e| panic!("{kind:?} trial {trial}: invalid plan: {e}"),
            );
            assert_eq!(eng.population(), eng.net.present_count());
            assert!(eng.population() >= 1);
        }
    }
}

#[test]
fn hand_scripted_timeline_with_bogus_events_is_guarded() {
    // double-leaves, arrivals of present workers, leaves of absent ones:
    // the engine applies only state-changing events and records exactly
    // those, so the log still accounts for the population
    let script = Scenario::from_events(vec![
        (2, ScenarioEvent::Leave { worker: 3 }),
        (3, ScenarioEvent::Leave { worker: 3 }),  // already gone: no-op
        (3, ScenarioEvent::Rejoin { worker: 5 }), // present: no-op
        (4, ScenarioEvent::Crash { worker: 0 }),
        (6, ScenarioEvent::Rejoin { worker: 3 }),
        (7, ScenarioEvent::Join { worker: 0 }),
        (8, ScenarioEvent::BandwidthShift { factor: 0.5 }),
    ]);
    let cfg = tiny_cfg(SchedulerKind::DySTop);
    let res = Experiment::builder(cfg)
        .scenario(script)
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    // 5 state-changing events survive (2 population no-ops dropped)
    assert_eq!(res.events.len(), 5);
    let kinds: Vec<&str> = res.events.iter().map(|e| e.kind).collect();
    assert_eq!(
        kinds,
        vec!["leave", "crash", "rejoin", "join", "bandwidth-shift"]
    );
    assert_event_log_accounts_for_population(&res, 12);
}

#[test]
fn rejoin_resumes_stale_params_with_advanced_staleness() {
    let script = Scenario::from_events(vec![
        (2, ScenarioEvent::Leave { worker: 4 }),
        (6, ScenarioEvent::Rejoin { worker: 4 }),
    ]);
    let cfg = tiny_cfg(SchedulerKind::DySTop);
    let exp = Experiment::builder(cfg).scenario(script).build().unwrap();
    let mut eng = VirtualClockEngine::new(exp);
    eng.step(); // round 1: everyone present
    assert!(eng.net.is_present(4));
    let plan2 = eng.step(); // round 2: worker 4 departs at the boundary
    assert!(!eng.net.is_present(4));
    assert!(!plan2.active.contains(&4));
    assert_eq!(eng.population(), 11);
    let params_at_leave = eng.workers[4].params.clone();
    for _ in 3..=5 {
        let plan = eng.step();
        assert!(!plan.active.contains(&4));
        assert!(plan.pulls_from.iter().all(|l| !l.contains(&4)));
    }
    // absent workers never train: parameters frozen, staleness advancing
    assert_eq!(eng.workers[4].params, params_at_leave);
    assert!(
        eng.workers[4].staleness >= 4,
        "τ {} must include the downtime",
        eng.workers[4].staleness
    );
    eng.step(); // round 6: rejoin
    assert!(eng.net.is_present(4));
    assert_eq!(eng.population(), 12);
}

#[test]
fn thread_count_never_changes_results_with_scenarios_active() {
    for preset in [
        ScenarioPreset::Diurnal,
        ScenarioPreset::FlashCrowd,
        ScenarioPreset::Degraded,
    ] {
        let run_with = |threads: usize| {
            let mut cfg = tiny_cfg(SchedulerKind::DySTop);
            cfg.workers = 14;
            cfg.rounds = 20;
            cfg.threads = threads;
            cfg.scenario = ScenarioConfig::preset(preset);
            Experiment::builder(cfg)
                .backend(BackendKind::Sim)
                .run()
                .unwrap()
        };
        let sequential = run_with(1);
        assert!(!sequential.events.is_empty(), "{preset:?}: no events");
        for threads in [2usize, 5, 0] {
            let parallel = run_with(threads);
            assert!(
                sequential.bits_eq(&parallel),
                "{preset:?}: threads=1 vs threads={threads} diverged"
            );
        }
    }
}

#[test]
fn threaded_backend_applies_scenarios() {
    let mut cfg = tiny_cfg(SchedulerKind::DySTop);
    cfg.workers = 10;
    cfg.rounds = 20;
    cfg.compute_mean_s = 0.5;
    cfg.scenario = ScenarioConfig {
        preset: ScenarioPreset::Stable,
        churn_rate: 0.15,
        mean_downtime_rounds: 4.0,
        crash_frac: 0.3,
    };
    let opts = TestbedOptions { time_scale: 2.0, profile: false };
    let res = Experiment::builder(cfg)
        .backend_impl(Box::new(ThreadedBackend::with_options(opts)))
        .run()
        .unwrap();
    assert_eq!(res.rounds.len(), 20);
    assert!(!res.events.is_empty(), "churn must reach the testbed");
    assert_event_log_accounts_for_population(&res, 10);
    let (lo, hi) = res.population_range();
    assert!(lo < hi, "population never varied");
    assert!(res.evals.iter().all(|e| e.avg_loss.is_finite()));
}

#[test]
fn event_logs_identical_across_backends() {
    // the applied-event log is a function of the timeline and the
    // membership guards alone, so both backends must record the exact
    // same sequence for the same config
    let mk = || {
        let mut cfg = tiny_cfg(SchedulerKind::DySTop);
        cfg.workers = 10;
        cfg.rounds = 15;
        cfg.compute_mean_s = 0.3;
        cfg.scenario = ScenarioConfig {
            preset: ScenarioPreset::Stable,
            churn_rate: 0.12,
            mean_downtime_rounds: 4.0,
            crash_frac: 0.5,
        };
        cfg
    };
    let sim = Experiment::builder(mk())
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    let opts = TestbedOptions { time_scale: 2.0, profile: false };
    let testbed = Experiment::builder(mk())
        .backend_impl(Box::new(ThreadedBackend::with_options(opts)))
        .run()
        .unwrap();
    assert!(!sim.events.is_empty());
    assert_eq!(sim.events, testbed.events);
}

#[test]
fn scripted_timeline_with_out_of_range_worker_is_rejected() {
    let script = Scenario::from_events(vec![(
        1,
        ScenarioEvent::Leave { worker: 99 },
    )]);
    let err = Experiment::builder(tiny_cfg(SchedulerKind::DySTop))
        .scenario(script)
        .build()
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("worker 99"), "{msg}");
}

// --- failure injection (folded in from `failure_injection.rs`): the
// --- simulator must stay correct — not merely not crash — under
// --- degenerate edge conditions

/// Full-curve run through the builder (ex `SimEngine::run_full`).
fn run_full(cfg: ExperimentConfig) -> RunResult {
    Experiment::builder(cfg)
        .backend_impl(Box::new(VirtualClockBackend::full_curves()))
        .run()
        .expect("experiment failed")
}

fn chaos_base() -> ExperimentConfig {
    ExperimentConfig {
        workers: 10,
        rounds: 40,
        train_per_worker: 48,
        test_samples: 128,
        class_sep: 3.0,
        eval_every: 10,
        target_accuracy: 2.0,
        ..Default::default()
    }
}

#[test]
fn survives_total_link_loss() {
    // every link drops every round: no pulls possible, workers train solo
    let mut cfg = chaos_base();
    cfg.network.link_drop_prob = 1.0;
    let res = run_full(cfg);
    assert_eq!(res.rounds.len(), 40);
    assert_eq!(res.total_transfers(), 0, "no transfers over dead links");
    // local training alone still improves over init
    let first = res.evals.first().unwrap().avg_accuracy;
    assert!(res.best_accuracy() > first.max(0.2), "acc {}", res.best_accuracy());
}

#[test]
fn survives_zero_bandwidth_budgets() {
    let mut cfg = chaos_base();
    cfg.network.budget_models = 0.0;
    cfg.network.budget_jitter = 0.0;
    let res = run_full(cfg);
    // budgets floor at 1.0 transfer/round (EdgeNetwork::refresh_budgets),
    // so communication is heavily throttled but the run proceeds
    assert_eq!(res.rounds.len(), 40);
    assert!(res.evals.iter().all(|e| e.avg_loss.is_finite()));
}

#[test]
fn single_worker_network_degenerates_to_local_sgd() {
    let mut cfg = chaos_base();
    cfg.workers = 1;
    cfg.scheduler = SchedulerKind::DySTop;
    let res = run_full(cfg);
    assert_eq!(res.total_transfers(), 0);
    assert!(res.best_accuracy() > 0.3, "acc {}", res.best_accuracy());
    // the lone worker is always activated ⇒ staleness pinned at 0
    assert!(res.rounds.iter().all(|r| r.max_staleness == 0));
}

#[test]
fn out_of_range_workers_never_communicate() {
    // region much larger than range: most workers are isolated
    let mut cfg = chaos_base();
    cfg.network = NetworkConfig {
        region_m: 10_000.0,
        comm_range_m: 10.0,
        mobility_m: 0.0,
        ..Default::default()
    };
    let res = run_full(cfg);
    assert_eq!(res.rounds.len(), 40);
    // isolated workers still train locally; transfers near zero
    assert!(res.total_transfers() < 40);
}

#[test]
fn hyper_mobility_keeps_invariants() {
    let mut cfg = chaos_base();
    cfg.network.mobility_m = 50.0; // teleporting workers
    cfg.network.link_drop_prob = 0.3;
    let res = run_full(cfg);
    let mut prev = 0.0;
    for r in &res.rounds {
        assert!(r.time_s >= prev && r.duration_s >= 0.0);
        prev = r.time_s;
    }
}

#[test]
fn all_schedulers_survive_chaos() {
    for k in ALL_SCHEDULERS {
        let mut cfg = chaos_base();
        cfg.rounds = 20;
        cfg.scheduler = k;
        cfg.network.link_drop_prob = 0.5;
        cfg.network.mobility_m = 20.0;
        cfg.network.budget_jitter = 1.0;
        // chaos now includes population chaos: heavy crash-y churn on
        // top of the flaky links and teleporting workers
        cfg.scenario = ScenarioConfig {
            preset: ScenarioPreset::Stable,
            churn_rate: 0.2,
            mean_downtime_rounds: 3.0,
            crash_frac: 0.8,
        };
        let res = run_full(cfg);
        assert_eq!(res.rounds.len(), 20, "{}", res.label);
        assert!(
            res.evals.iter().all(|e| e.avg_loss.is_finite()),
            "{}",
            res.label
        );
    }
}

#[test]
fn extreme_non_iid_each_worker_one_class() {
    // φ→0 approximates one-class-per-worker; training must still move
    let mut cfg = chaos_base();
    cfg.phi = 0.01;
    cfg.workers = 10;
    let res = run_full(cfg);
    let first = res.evals.first().unwrap().avg_accuracy;
    assert!(res.best_accuracy() >= first);
    assert!(res.best_accuracy() > 0.2, "acc {}", res.best_accuracy());
}

#[test]
fn tau_bound_zero_forces_frequent_activation() {
    let mut cfg = chaos_base();
    cfg.tau_bound = 0;
    cfg.rounds = 60;
    let res = run_full(cfg);
    // queues punish ANY staleness: activation pressure keeps τ tiny
    let late: Vec<_> = res.rounds.iter().skip(20).collect();
    let avg = late.iter().map(|r| r.avg_staleness).sum::<f64>() / late.len() as f64;
    assert!(avg < 2.0, "avg staleness {avg} under τ_bound=0");
}

#[test]
fn degraded_environment_still_learns() {
    let mut cfg = tiny_cfg(SchedulerKind::DySTop);
    cfg.workers = 15;
    cfg.rounds = 60;
    cfg.eval_every = 10;
    cfg.scenario = ScenarioConfig::preset(ScenarioPreset::Degraded);
    let res = Experiment::builder(cfg)
        .backend(BackendKind::Sim)
        .run()
        .unwrap();
    assert_eq!(res.rounds.len(), 60);
    let first = res.evals.first().unwrap().avg_accuracy;
    assert!(
        res.best_accuracy() > first,
        "no learning under degraded scenario: {first} → {}",
        res.best_accuracy()
    );
}
