//! Failure injection: the simulator must stay correct (not merely not
//! crash) under degenerate edge conditions — total link loss, starved
//! bandwidth, single-worker networks, immobile/hyper-mobile topologies.

use dystop::config::{ExperimentConfig, NetworkConfig, SchedulerKind};
use dystop::experiment::{Experiment, VirtualClockBackend};
use dystop::metrics::RunResult;

/// Full-curve run through the builder (ex `SimEngine::run_full`).
fn run_full(cfg: ExperimentConfig) -> RunResult {
    Experiment::builder(cfg)
        .backend_impl(Box::new(VirtualClockBackend::full_curves()))
        .run()
        .expect("experiment failed")
}

fn base() -> ExperimentConfig {
    ExperimentConfig {
        workers: 10,
        rounds: 40,
        train_per_worker: 48,
        test_samples: 128,
        class_sep: 3.0,
        eval_every: 10,
        target_accuracy: 2.0,
        ..Default::default()
    }
}

#[test]
fn survives_total_link_loss() {
    // every link drops every round: no pulls possible, workers train solo
    let mut cfg = base();
    cfg.network.link_drop_prob = 1.0;
    let res = run_full(cfg);
    assert_eq!(res.rounds.len(), 40);
    assert_eq!(res.total_transfers(), 0, "no transfers over dead links");
    // local training alone still improves over init
    let first = res.evals.first().unwrap().avg_accuracy;
    assert!(res.best_accuracy() > first.max(0.2), "acc {}", res.best_accuracy());
}

#[test]
fn survives_zero_bandwidth_budgets() {
    let mut cfg = base();
    cfg.network.budget_models = 0.0;
    cfg.network.budget_jitter = 0.0;
    let res = run_full(cfg);
    // budgets floor at 1.0 transfer/round (EdgeNetwork::refresh_budgets),
    // so communication is heavily throttled but the run proceeds
    assert_eq!(res.rounds.len(), 40);
    assert!(res.evals.iter().all(|e| e.avg_loss.is_finite()));
}

#[test]
fn single_worker_network_degenerates_to_local_sgd() {
    let mut cfg = base();
    cfg.workers = 1;
    cfg.scheduler = SchedulerKind::DySTop;
    let res = run_full(cfg);
    assert_eq!(res.total_transfers(), 0);
    assert!(res.best_accuracy() > 0.3, "acc {}", res.best_accuracy());
    // the lone worker is always activated ⇒ staleness pinned at 0
    assert!(res.rounds.iter().all(|r| r.max_staleness == 0));
}

#[test]
fn out_of_range_workers_never_communicate() {
    // region much larger than range: most workers are isolated
    let mut cfg = base();
    cfg.network = NetworkConfig {
        region_m: 10_000.0,
        comm_range_m: 10.0,
        mobility_m: 0.0,
        ..Default::default()
    };
    let res = run_full(cfg);
    assert_eq!(res.rounds.len(), 40);
    // isolated workers still train locally; transfers near zero
    assert!(res.total_transfers() < 40);
}

#[test]
fn hyper_mobility_keeps_invariants() {
    let mut cfg = base();
    cfg.network.mobility_m = 50.0; // teleporting workers
    cfg.network.link_drop_prob = 0.3;
    let res = run_full(cfg);
    let mut prev = 0.0;
    for r in &res.rounds {
        assert!(r.time_s >= prev && r.duration_s >= 0.0);
        prev = r.time_s;
    }
}

#[test]
fn all_schedulers_survive_chaos() {
    for k in [
        SchedulerKind::DySTop,
        SchedulerKind::AsyDfl,
        SchedulerKind::SaAdfl,
        SchedulerKind::Matcha,
        SchedulerKind::DySTopPhase1Only,
        SchedulerKind::DySTopPhase2Only,
    ] {
        let mut cfg = base();
        cfg.rounds = 20;
        cfg.scheduler = k;
        cfg.network.link_drop_prob = 0.5;
        cfg.network.mobility_m = 20.0;
        cfg.network.budget_jitter = 1.0;
        // chaos now includes population chaos: heavy crash-y churn on top
        // of the flaky links and teleporting workers
        cfg.scenario = dystop::config::ScenarioConfig {
            preset: dystop::config::ScenarioPreset::Stable,
            churn_rate: 0.2,
            mean_downtime_rounds: 3.0,
            crash_frac: 0.8,
        };
        let res = run_full(cfg);
        assert_eq!(res.rounds.len(), 20, "{}", res.label);
        assert!(
            res.evals.iter().all(|e| e.avg_loss.is_finite()),
            "{}",
            res.label
        );
    }
}

#[test]
fn extreme_non_iid_each_worker_one_class() {
    // φ→0 approximates one-class-per-worker; training must still move
    let mut cfg = base();
    cfg.phi = 0.01;
    cfg.workers = 10;
    let res = run_full(cfg);
    let first = res.evals.first().unwrap().avg_accuracy;
    assert!(res.best_accuracy() >= first);
    assert!(res.best_accuracy() > 0.2, "acc {}", res.best_accuracy());
}

#[test]
fn tau_bound_zero_forces_frequent_activation() {
    let mut cfg = base();
    cfg.tau_bound = 0;
    cfg.rounds = 60;
    let res = run_full(cfg);
    // queues punish ANY staleness: activation pressure keeps τ tiny
    let late: Vec<_> = res.rounds.iter().skip(20).collect();
    let avg = late.iter().map(|r| r.avg_staleness).sum::<f64>() / late.len() as f64;
    assert!(avg < 2.0, "avg staleness {avg} under τ_bound=0");
}
