//! Bench: end-to-end system performance, emitting `BENCH_sim.json` so
//! the perf trajectory is tracked across PRs.
//!
//! * `sim_round` — whole-round throughput at N ∈ {60, 200, 500} for
//!   threads=1 vs threads=auto (the cost behind every figure
//!   regeneration — Figs. 4–18 all run through this loop), plus the
//!   scheduler, codec, workload-model and adversary variants (the
//!   `model={linear,mlp,cnn-s}` rows track per-model round cost; the
//!   `attack=…/agg=…` rows track the exchange-boundary rewrite and the
//!   robust-aggregation rules);
//! * native-trainer hot-path microbenches (train step / aggregate /
//!   eval) — the per-activation inner loop;
//! * PJRT hot-path latencies when artifacts are present;
//! * threads=1 vs threads=4 bit-identity checks (the parallel engine's
//!   core invariant) — base, churn, stateful-codec, one per registered
//!   non-default workload model, a mounted sign-flip cast, and an
//!   active cellular fault profile — recorded in the report.
//!
//! `DYSTOP_BENCH_QUICK=1` shrinks warmup/measure budgets for CI smoke
//! runs; the report schema is identical. `DYSTOP_BENCH_OUT=path.json`
//! redirects the report (default `BENCH_sim.json` in the CWD) so CI
//! artifact uploads can't silently grab a stale file; the CI
//! `bench-regression` job diffs it against the checked-in
//! `BENCH_baseline.json` via `dystop bench-diff`.

use dystop::bench::{bench_with, write_json_report, BenchResult};
use dystop::config::{
    AdversaryConfig, AggregatorKind, AttackKind, BackendKind, CodecKind,
    EngineKind, ExperimentConfig, FaultConfig, FaultProfile, ModelArch,
    ScenarioConfig, ScenarioPreset, SchedulerKind, SinkKind, SocketConfig,
    SocketTransportKind, TransportConfig, WorkloadConfig,
};
use dystop::data::{make_corpus, SyntheticSpec};
use dystop::experiment::{Experiment, VirtualClockEngine};
use dystop::util::json::Json;
use dystop::util::rng::Pcg;
use dystop::worker::{NativeTrainer, Params, Trainer};
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

fn sim_engine(n: usize, threads: usize, kind: SchedulerKind) -> VirtualClockEngine {
    scenario_sim_engine(n, threads, kind, ScenarioConfig::default())
}

fn scenario_sim_engine(
    n: usize,
    threads: usize,
    kind: SchedulerKind,
    scenario: ScenarioConfig,
) -> VirtualClockEngine {
    let cfg = ExperimentConfig {
        workers: n,
        rounds: 10_000, // never reached; we step manually
        train_per_worker: 64,
        eval_every: usize::MAX,
        target_accuracy: 2.0,
        scheduler: kind,
        threads,
        scenario,
        ..Default::default()
    };
    let exp = Experiment::builder(cfg).build().expect("valid bench config");
    VirtualClockEngine::new(exp)
}

fn model_sim_engine(n: usize, model: ModelArch) -> VirtualClockEngine {
    let cfg = ExperimentConfig {
        workers: n,
        rounds: 10_000,
        train_per_worker: 64,
        eval_every: usize::MAX,
        target_accuracy: 2.0,
        workload: WorkloadConfig { model, ..Default::default() },
        ..Default::default()
    };
    let exp = Experiment::builder(cfg).build().expect("valid bench config");
    VirtualClockEngine::new(exp)
}

fn adversary_sim_engine(
    n: usize,
    attack: AttackKind,
    aggregator: AggregatorKind,
) -> VirtualClockEngine {
    let frac = if attack == AttackKind::None { 0.0 } else { 0.2 };
    let cfg = ExperimentConfig {
        workers: n,
        rounds: 10_000,
        train_per_worker: 64,
        eval_every: usize::MAX,
        target_accuracy: 2.0,
        adversary: AdversaryConfig {
            frac,
            attack,
            aggregator,
            ..Default::default()
        },
        ..Default::default()
    };
    let exp = Experiment::builder(cfg).build().expect("valid bench config");
    VirtualClockEngine::new(exp)
}

fn faults_sim_engine(n: usize, profile: FaultProfile) -> VirtualClockEngine {
    let cfg = ExperimentConfig {
        workers: n,
        rounds: 10_000,
        train_per_worker: 64,
        eval_every: usize::MAX,
        target_accuracy: 2.0,
        faults: FaultConfig::preset(profile),
        ..Default::default()
    };
    let exp = Experiment::builder(cfg).build().expect("valid bench config");
    VirtualClockEngine::new(exp)
}

fn codec_sim_engine(n: usize, codec: CodecKind) -> VirtualClockEngine {
    let cfg = ExperimentConfig {
        workers: n,
        rounds: 10_000,
        train_per_worker: 64,
        eval_every: usize::MAX,
        target_accuracy: 2.0,
        transport: TransportConfig { codec, ..Default::default() },
        ..Default::default()
    };
    let exp = Experiment::builder(cfg).build().expect("valid bench config");
    VirtualClockEngine::new(exp)
}

/// Event-engine instance on the constant-density scale profile
/// ([`dystop::figures::scale_cfg`]): frozen geometry keeps the cached
/// view legal, the huge τ-bound fixes activations at one per round, so
/// per-round p50s are comparable across N. `jsonl_out` attaches the
/// streaming sink (the CI smoke's bounded-memory artifact).
fn scale_sim_engine(n: usize, jsonl_out: Option<String>) -> VirtualClockEngine {
    let mut cfg = dystop::figures::scale_cfg(n, 1);
    cfg.engine = EngineKind::Event;
    if let Some(out) = jsonl_out {
        cfg.metrics.sink = SinkKind::Jsonl;
        cfg.metrics.out = out;
        // full history streams to disk; keep only a tail in memory
        cfg.metrics.window = 8;
    }
    let exp = Experiment::builder(cfg).build().expect("valid scale config");
    VirtualClockEngine::new(exp)
}

fn scale_enabled() -> bool {
    matches!(
        std::env::var("DYSTOP_BENCH_SCALE").as_deref(),
        Ok(v) if !v.is_empty() && v != "0"
    )
}

/// Scale rows for the discrete-event core. N=200 and N=10k always run —
/// their baseline rows pin the O(activations) claim (at a fixed one
/// activation per round, 50× more workers must not cost 50× more per
/// round). N=100k and N=1M only run under `DYSTOP_BENCH_SCALE=1` (the
/// CI `scale-smoke` job) and are deliberately absent from
/// `BENCH_baseline.json`: a baseline row missing from a fresh report
/// fails the regression gate, and the default bench job doesn't run
/// them.
fn scale_benches(results: &mut Vec<BenchResult>, warm: usize, budget: f64) {
    println!(
        "\n== sim_round at scale (engine=event, constant density, \
         1 activation/round) =="
    );
    for &n in &[200usize, 10_000] {
        let label = if n == 200 { "N=200" } else { "N=10k" };
        let mut eng = scale_sim_engine(n, None);
        results.push(bench_with(
            &format!("sim_round {label} dystop engine=event"),
            warm,
            budget,
            &mut || {
                std::hint::black_box(eng.step());
            },
        ));
    }
    if !scale_enabled() {
        println!("(DYSTOP_BENCH_SCALE unset — skipping N=100k and N=1M rows)");
        return;
    }
    // N=100k streams its rounds to the JSONL artifact the CI smoke
    // uploads; N=1M is the memory-ceiling witness (sparse ledger +
    // bounded recorder keep it resident-flat)
    let jsonl = std::env::var("DYSTOP_BENCH_SCALE_JSONL")
        .unwrap_or_else(|_| "target/bench/scale_N100k.jsonl".to_string());
    let mut big = scale_sim_engine(100_000, Some(jsonl.clone()));
    results.push(bench_with(
        "sim_round N=100k dystop engine=event",
        warm,
        budget,
        &mut || {
            std::hint::black_box(big.step());
        },
    ));
    drop(big); // flush the sink before CI grabs the artifact
    println!("  (streamed N=100k rounds to {jsonl})");
    let mut huge = scale_sim_engine(1_000_000, None);
    results.push(bench_with(
        "sim_round N=1M dystop engine=event",
        warm,
        budget,
        &mut || {
            std::hint::black_box(huge.step());
        },
    ));
}

/// Peak resident set (VmHWM) in bytes — the scale smoke's memory
/// ceiling witness. Linux-only; elsewhere the assertion is skipped.
#[cfg(target_os = "linux")]
fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line
        .trim_start_matches("VmHWM:")
        .trim()
        .trim_end_matches("kB")
        .trim()
        .parse()
        .ok()?;
    Some(kb * 1024)
}

#[cfg(not(target_os = "linux"))]
fn peak_rss_bytes() -> Option<u64> {
    None
}

fn sim_round_benches(
    results: &mut Vec<BenchResult>,
    warm: usize,
    budget: f64,
) {
    println!("== sim_round: one full coordinator round (Figs. 4–18 inner loop) ==");
    for &n in &[60usize, 200, 500] {
        // threads=auto first under the historical name (cross-PR
        // comparisons key on it), then the sequential baseline
        let mut auto = sim_engine(n, 0, SchedulerKind::DySTop);
        let width = auto.threads();
        results.push(bench_with(
            &format!("sim_round N={n} dystop"),
            warm,
            budget,
            &mut || {
                std::hint::black_box(auto.step());
            },
        ));
        println!("  (threads=auto resolved to {width})");
        let mut seq = sim_engine(n, 1, SchedulerKind::DySTop);
        results.push(bench_with(
            &format!("sim_round N={n} dystop threads=1"),
            warm,
            budget,
            &mut || {
                std::hint::black_box(seq.step());
            },
        ));
    }
    println!("\n== sim_round scheduler variants (N=60, threads=auto) ==");
    for kind in [
        SchedulerKind::AsyDfl,
        SchedulerKind::SaAdfl,
        SchedulerKind::Matcha,
    ] {
        let mut eng = sim_engine(60, 0, kind);
        results.push(bench_with(
            &format!("sim_round N=60 {}", kind.name()),
            warm,
            budget,
            &mut || {
                std::hint::black_box(eng.step());
            },
        ));
    }

    // churn overhead: the same round loop with the diurnal scenario
    // active (membership compaction + event application on the hot path)
    println!("\n== sim_round under churn (N=200, scenario=diurnal) ==");
    let mut churn = scenario_sim_engine(
        200,
        0,
        SchedulerKind::DySTop,
        ScenarioConfig::preset(ScenarioPreset::Diurnal),
    );
    results.push(bench_with(
        "sim_round N=200 dystop scenario=diurnal",
        warm,
        budget,
        &mut || {
            std::hint::black_box(churn.step());
        },
    ));
    println!("  (population after benched rounds: {})", churn.population());

    // transport codecs: encode/decode overhead (topk selection, int8
    // quantization) and the wire-size effect on realised transfer math —
    // `codec=dense` is the control row on the identity transport
    println!("\n== sim_round under transport codecs (N=200, dystop) ==");
    for codec in [CodecKind::Dense, CodecKind::TopK, CodecKind::Int8] {
        let mut eng = codec_sim_engine(200, codec);
        results.push(bench_with(
            &format!("sim_round N=200 dystop codec={}", codec.name()),
            warm,
            budget,
            &mut || {
                std::hint::black_box(eng.step());
            },
        ));
    }

    // delivery faults: per-pull-edge fault resolution + retry/backoff
    // accounting on the hot path — `faults=clean` is the branch-free
    // control (the inactive gate must keep it at parity with the plain
    // N=200 row); `faults=cellular` pays the per-edge RNG stream and
    // the retransmission ledger
    println!("\n== sim_round under lossy delivery (N=200, dystop) ==");
    for profile in [FaultProfile::Clean, FaultProfile::Cellular] {
        let mut eng = faults_sim_engine(200, profile);
        results.push(bench_with(
            &format!("sim_round N=200 dystop faults={}", profile.name()),
            warm,
            budget,
            &mut || {
                std::hint::black_box(eng.step());
            },
        ));
    }

    // adversary axis: attack-payload rewrites at the exchange boundary
    // (attack=none agg=mean is the branch-free control — the is_active
    // gate must keep it at parity with the plain N=200 row) and the
    // robust-aggregation rules' per-round cost (krum's pairwise
    // distances are the worst case)
    println!("\n== sim_round under adversaries (N=200, dystop) ==");
    for (attack, agg) in [
        (AttackKind::None, AggregatorKind::Mean),
        (AttackKind::None, AggregatorKind::Krum),
        (AttackKind::SignFlip, AggregatorKind::Mean),
        (AttackKind::SignFlip, AggregatorKind::Krum),
    ] {
        let mut eng = adversary_sim_engine(200, attack, agg);
        results.push(bench_with(
            &format!(
                "sim_round N=200 dystop attack={} agg={}",
                attack.name(),
                agg.name()
            ),
            warm,
            budget,
            &mut || {
                std::hint::black_box(eng.step());
            },
        ));
    }

    // workload models: per-model round cost (linear is the historical
    // control; mlp/cnn-s track the forward/backward of the deeper
    // architectures — the cnn-s row is the bench job's smoke row)
    println!("\n== sim_round per workload model (N=200, dystop) ==");
    for arch in [ModelArch::Linear, ModelArch::Mlp, ModelArch::CnnS] {
        let mut eng = model_sim_engine(200, arch);
        results.push(bench_with(
            &format!("sim_round N=200 dystop model={}", arch.name()),
            warm,
            budget,
            &mut || {
                std::hint::black_box(eng.step());
            },
        ));
    }
}

/// Telemetry self-profiling overhead: the same N=200 round loop with a
/// live registry (every phase tick/tock, counter and gauge on the hot
/// path) against a freshly measured inert-handle control. The control
/// is re-measured here — back to back with the instrumented row, same
/// warmup and budget — rather than reusing the earlier `sim_round
/// N=200 dystop` row, so thermal drift between bench sections can't
/// masquerade as telemetry cost. Returns the relative p50 overhead;
/// `main` records it in the report meta and gates it at 2% (plus a
/// small absolute floor for scheduler/timer noise on quick CI budgets).
fn telemetry_overhead_bench(
    results: &mut Vec<BenchResult>,
    warm: usize,
    budget: f64,
) -> (f64, f64) {
    println!("\n== telemetry self-profiling overhead (N=200, dystop) ==");
    let engine = |enabled: bool| {
        let mut cfg = ExperimentConfig {
            workers: 200,
            rounds: 10_000,
            train_per_worker: 64,
            eval_every: usize::MAX,
            target_accuracy: 2.0,
            ..Default::default()
        };
        cfg.telemetry.enabled = enabled;
        let exp =
            Experiment::builder(cfg).build().expect("valid bench config");
        VirtualClockEngine::new(exp)
    };
    let mut off = engine(false);
    let control = bench_with(
        "sim_round N=200 telemetry control (unrecorded)",
        warm,
        budget,
        &mut || {
            std::hint::black_box(off.step());
        },
    );
    let mut on = engine(true);
    let row = bench_with(
        "sim_round N=200 dystop telemetry=on",
        warm,
        budget,
        &mut || {
            std::hint::black_box(on.step());
        },
    );
    results.push(row.clone());
    println!(
        "  (telemetry=on p50 overhead vs inert control: {:+.2}%)",
        (row.p50_ns / control.p50_ns - 1.0) * 100.0
    );
    (control.p50_ns, row.p50_ns)
}

/// One full deployment round over real sockets: spawn N worker threads,
/// bring the listener up, run a single round (connect + HELLO + framed
/// EXECUTE/DONE exchange for every activation) and tear it down. The
/// row tracks deployment overhead per round end-to-end — wire
/// serialization, kernel socket hops, thread churn — against the
/// in-process `sim_round N=200` rows above.
fn socket_backend_benches(
    results: &mut Vec<BenchResult>,
    warm: usize,
    budget: f64,
) {
    println!("\n== socket deployment backend (N=200, one round per iter) ==");
    let transport = if cfg!(unix) {
        SocketTransportKind::Uds
    } else {
        SocketTransportKind::Tcp
    };
    let cfg = || ExperimentConfig {
        workers: 200,
        rounds: 1,
        train_per_worker: 16,
        test_samples: 16,
        eval_every: usize::MAX,
        target_accuracy: 2.0,
        socket: SocketConfig {
            transport,
            // virtual seconds truncate to 0 wall ms: the row measures
            // deployment overhead, not the emulated waits
            time_scale: 0.001,
            ..Default::default()
        },
        ..Default::default()
    };
    results.push(bench_with(
        "sim_round N=200 backend=socket",
        warm,
        budget,
        &mut || {
            let res = Experiment::builder(cfg())
                .backend(BackendKind::Socket)
                .run()
                .expect("socket bench run");
            std::hint::black_box(res.rounds.len());
        },
    ));
}

fn native_trainer_benches(
    results: &mut Vec<BenchResult>,
    warm: usize,
    budget: f64,
) {
    println!("\n== native trainer hot path (per-activation inner loop) ==");
    let spec = SyntheticSpec {
        train_samples: 600,
        test_samples: 300,
        class_sep: 2.5,
        ..Default::default()
    };
    let (train, test) = make_corpus(&spec);
    let mut t = NativeTrainer::new(spec.dim, spec.num_classes);
    let p0 = t.init(0);
    let mut rng = Pcg::seeded(7);
    results.push(bench_with(
        "native train_step batch=32 (softmax reg)",
        warm,
        budget,
        &mut || {
            std::hint::black_box(t.train(&p0, &train, 1, 32, 0.1, &mut rng));
        },
    ));
    let models: Vec<Params> = (0..8u64).map(|s| t.init(s)).collect();
    let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
    let w = vec![0.125f32; 8];
    let mut agg = Params::new();
    results.push(bench_with("native aggregate K=8", warm, budget, &mut || {
        t.aggregate_into(&refs, &w, &mut agg);
        std::hint::black_box(agg.len());
    }));
    results.push(bench_with(
        "native eval 300 samples",
        warm,
        budget,
        &mut || {
            std::hint::black_box(t.evaluate(&p0, &test));
        },
    ));
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_benches(_results: &mut Vec<BenchResult>) {
    println!("\n(built without the `pjrt` feature — skipping PJRT hot-path benches)");
}

#[cfg(feature = "pjrt")]
fn pjrt_benches(results: &mut Vec<BenchResult>) {
    use dystop::config::ModelKind;
    println!("\n== PJRT hot path (L1/L2 via HLO artifacts) ==");
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("(artifacts missing — skipping PJRT hot-path benches; run `make artifacts`)");
        return;
    }
    use dystop::runtime::PjrtTrainer;

    let mut t = PjrtTrainer::new(&dir, ModelKind::Mlp).expect("load artifacts");
    let dim = t.manifest().input_dim;
    let b = t.manifest().train_batch;
    let (_train, test) = make_corpus(&SyntheticSpec {
        dim,
        train_samples: 512,
        test_samples: 256,
        ..Default::default()
    });
    let params = t.init(0);

    // L2/L1 train step through PJRT (the per-worker hot path)
    let x: Vec<f32> = (0..b * dim).map(|i| (i % 7) as f32 * 0.1).collect();
    let y: Vec<i32> = (0..b).map(|i| (i % 10) as i32).collect();
    results.push(bench_with("pjrt train_batch (mlp)", 5, 1.0, &mut || {
        std::hint::black_box(t.train_batch(&params, &x, &y, 0.1).unwrap());
    }));

    // aggregation via the Pallas kernel artifact (K_max padded)
    let models: Vec<Vec<f32>> = (0..4).map(|s| t.init(s as u64)).collect();
    let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
    let w = vec![0.25f32; 4];
    results.push(bench_with("pjrt aggregate K=4 (pallas)", 5, 1.0, &mut || {
        std::hint::black_box(t.aggregate(&refs, &w));
    }));

    // eval chunk
    results.push(bench_with("pjrt eval 256 samples (mlp)", 3, 1.0, &mut || {
        std::hint::black_box(t.evaluate(&params, &test));
    }));
}

/// Cross-engine witness: the discrete-event core must reproduce the
/// dense sweep bitwise. The full matrix lives in
/// `tests/engine_equivalence.rs`; this run records the invariant in the
/// bench report, next to the perf numbers it licenses.
fn engine_equivalence_check() -> bool {
    let run_with = |engine: EngineKind| {
        let cfg = ExperimentConfig {
            workers: 60,
            rounds: 12,
            train_per_worker: 48,
            test_samples: 64,
            eval_every: 5,
            target_accuracy: 2.0,
            engine,
            ..Default::default()
        };
        Experiment::builder(cfg).run().expect("equivalence run")
    };
    run_with(EngineKind::Dense).bits_eq(&run_with(EngineKind::Event))
}

/// The parallel engine's core invariant: a seeded run is bit-identical
/// for any `run.threads` setting — with or without an active scenario,
/// a stateful transport codec, a deeper workload model, a mounted
/// Byzantine cast, or an active lossy-link fault profile. Checked here
/// so the recorded perf numbers always come with a correctness witness.
fn determinism_check(
    scenario: ScenarioConfig,
    transport: TransportConfig,
    model: ModelArch,
    adversary: AdversaryConfig,
    faults: FaultConfig,
) -> bool {
    let run_with = |threads: usize| {
        let cfg = ExperimentConfig {
            workers: 20,
            rounds: 6,
            train_per_worker: 48,
            test_samples: 64,
            eval_every: 3,
            target_accuracy: 2.0,
            threads,
            scenario,
            transport,
            workload: WorkloadConfig { model, ..Default::default() },
            adversary,
            faults,
            ..Default::default()
        };
        Experiment::builder(cfg).run().expect("determinism run")
    };
    let a = run_with(1);
    let b = run_with(4);
    a.bits_eq(&b)
}

fn main() {
    let quick = matches!(
        std::env::var("DYSTOP_BENCH_QUICK").as_deref(),
        Ok(v) if !v.is_empty() && v != "0"
    );
    let (warm, budget) = if quick { (1, 0.03) } else { (3, 0.5) };
    let available = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let mut results: Vec<BenchResult> = Vec::new();

    sim_round_benches(&mut results, warm, budget);
    let (tel_off_p50, tel_on_p50) =
        telemetry_overhead_bench(&mut results, warm, budget);
    socket_backend_benches(&mut results, warm, budget.min(0.3));
    scale_benches(&mut results, warm, budget);
    native_trainer_benches(&mut results, warm, budget.min(0.3));
    pjrt_benches(&mut results);

    let engine_eq_ok = engine_equivalence_check();
    println!(
        "\nengine equivalence dense vs event: {}",
        if engine_eq_ok { "bit-identical" } else { "MISMATCH" }
    );

    let det_ok = determinism_check(
        ScenarioConfig::default(),
        TransportConfig::default(),
        ModelArch::Linear,
        AdversaryConfig::default(),
        FaultConfig::default(),
    );
    println!(
        "\ndeterminism threads=1 vs threads=4: {}",
        if det_ok { "bit-identical" } else { "MISMATCH" }
    );
    let det_churn_ok = determinism_check(
        ScenarioConfig::preset(ScenarioPreset::Diurnal),
        TransportConfig::default(),
        ModelArch::Linear,
        AdversaryConfig::default(),
        FaultConfig::default(),
    );
    println!(
        "determinism threads=1 vs threads=4 (scenario=diurnal): {}",
        if det_churn_ok { "bit-identical" } else { "MISMATCH" }
    );
    // stateful codec active: encode order must stay coordinator-fixed
    let det_topk_ok = determinism_check(
        ScenarioConfig::default(),
        TransportConfig { codec: CodecKind::TopK, ..Default::default() },
        ModelArch::Linear,
        AdversaryConfig::default(),
        FaultConfig::default(),
    );
    println!(
        "determinism threads=1 vs threads=4 (transport.codec=topk): {}",
        if det_topk_ok { "bit-identical" } else { "MISMATCH" }
    );
    // deeper workload models: the witness runs once per registered
    // non-default model so pool-cloned scratch can never diverge a run
    let det_mlp_ok = determinism_check(
        ScenarioConfig::default(),
        TransportConfig::default(),
        ModelArch::Mlp,
        AdversaryConfig::default(),
        FaultConfig::default(),
    );
    println!(
        "determinism threads=1 vs threads=4 (workload.model=mlp): {}",
        if det_mlp_ok { "bit-identical" } else { "MISMATCH" }
    );
    let det_cnn_ok = determinism_check(
        ScenarioConfig::default(),
        TransportConfig::default(),
        ModelArch::CnnS,
        AdversaryConfig::default(),
        FaultConfig::default(),
    );
    println!(
        "determinism threads=1 vs threads=4 (workload.model=cnn-s): {}",
        if det_cnn_ok { "bit-identical" } else { "MISMATCH" }
    );
    // mounted Byzantine cast: transmit must stay coordinator-ordered
    let det_signflip_ok = determinism_check(
        ScenarioConfig::default(),
        TransportConfig::default(),
        ModelArch::Linear,
        AdversaryConfig {
            frac: 0.2,
            attack: AttackKind::SignFlip,
            ..Default::default()
        },
        FaultConfig::default(),
    );
    println!(
        "determinism threads=1 vs threads=4 (adversary=signflip): {}",
        if det_signflip_ok { "bit-identical" } else { "MISMATCH" }
    );
    // active lossy links: per-edge fault draws and retry accounting
    // must stay keyed on (seed, round, edge), never on worker order
    let det_lossy_ok = determinism_check(
        ScenarioConfig::default(),
        TransportConfig::default(),
        ModelArch::Linear,
        AdversaryConfig::default(),
        FaultConfig::preset(FaultProfile::Cellular),
    );
    println!(
        "determinism threads=1 vs threads=4 (faults=cellular): {}",
        if det_lossy_ok { "bit-identical" } else { "MISMATCH" }
    );

    let meta = vec![
        ("bench".to_string(), Json::Str("sim".to_string())),
        ("quick".to_string(), Json::Bool(quick)),
        (
            "available_parallelism".to_string(),
            Json::Num(available as f64),
        ),
        (
            "determinism_threads_1_vs_4".to_string(),
            Json::Bool(det_ok),
        ),
        (
            "determinism_diurnal_threads_1_vs_4".to_string(),
            Json::Bool(det_churn_ok),
        ),
        (
            "determinism_topk_threads_1_vs_4".to_string(),
            Json::Bool(det_topk_ok),
        ),
        (
            "determinism_mlp_threads_1_vs_4".to_string(),
            Json::Bool(det_mlp_ok),
        ),
        (
            "determinism_cnn_s_threads_1_vs_4".to_string(),
            Json::Bool(det_cnn_ok),
        ),
        (
            "determinism_signflip_threads_1_vs_4".to_string(),
            Json::Bool(det_signflip_ok),
        ),
        (
            "determinism_lossy_threads_1_vs_4".to_string(),
            Json::Bool(det_lossy_ok),
        ),
        (
            "engine_equivalence_dense_vs_event".to_string(),
            Json::Bool(engine_eq_ok),
        ),
        (
            "telemetry_on_p50_overhead".to_string(),
            Json::Num(tel_on_p50 / tel_off_p50 - 1.0),
        ),
        ("scale_rows".to_string(), Json::Bool(scale_enabled())),
        (
            "peak_rss_gb".to_string(),
            match peak_rss_bytes() {
                Some(b) => Json::Num(b as f64 / 1e9),
                None => Json::Null,
            },
        ),
    ];
    // explicit output path so CI artifact steps can't pick up a stale
    // file from an unexpected working directory
    let out = std::env::var("DYSTOP_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_sim.json".to_string());
    let out = Path::new(&out);
    let parent = out.parent().filter(|d| !d.as_os_str().is_empty());
    if let Some(dir) = parent {
        std::fs::create_dir_all(dir).expect("create bench output dir");
    }
    write_json_report(out, meta, &results).expect("write bench report");
    println!("wrote {} ({} cases)", out.display(), results.len());
    assert!(det_ok, "threads=1 vs threads=4 results diverged");
    assert!(
        det_churn_ok,
        "threads=1 vs threads=4 diverged under scenario=diurnal"
    );
    assert!(
        det_topk_ok,
        "threads=1 vs threads=4 diverged under transport.codec=topk"
    );
    assert!(
        det_mlp_ok,
        "threads=1 vs threads=4 diverged under workload.model=mlp"
    );
    assert!(
        det_cnn_ok,
        "threads=1 vs threads=4 diverged under workload.model=cnn-s"
    );
    assert!(
        det_signflip_ok,
        "threads=1 vs threads=4 diverged under adversary attack=signflip"
    );
    assert!(
        det_lossy_ok,
        "threads=1 vs threads=4 diverged under faults=cellular"
    );
    assert!(
        engine_eq_ok,
        "run.engine=event diverged from run.engine=dense"
    );
    // the telemetry registry's overhead budget: a live registry may not
    // cost more than 2% of round p50 (plus a 50 µs absolute floor so
    // scheduler/timer noise on the quick CI budget can't flake the gate)
    assert!(
        tel_on_p50 <= tel_off_p50 * 1.02 + 50_000.0,
        "telemetry=on round p50 {} vs inert control {} exceeds the 2% \
         overhead budget",
        dystop::bench::fmt_ns(tel_on_p50),
        dystop::bench::fmt_ns(tel_off_p50),
    );
    // the scale smoke's memory ceiling: streaming sinks + the sparse
    // pull ledger must keep even the N=1M row under a bounded RSS
    // (ceiling overridable via DYSTOP_BENCH_RSS_GB; linux-only probe)
    if scale_enabled() {
        let ceiling_gb: f64 = std::env::var("DYSTOP_BENCH_RSS_GB")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(8.0);
        if let Some(b) = peak_rss_bytes() {
            let gb = b as f64 / 1e9;
            println!("peak RSS {gb:.2} GB (ceiling {ceiling_gb} GB)");
            assert!(
                gb < ceiling_gb,
                "scale smoke peak RSS {gb:.2} GB breached the \
                 {ceiling_gb} GB ceiling"
            );
        }
    }
}
