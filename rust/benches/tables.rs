//! Bench: end-to-end system performance.
//!
//! * whole-round throughput per mechanism (the cost behind every figure
//!   regeneration — Figs. 4–18 series all run through this loop);
//! * PJRT hot-path latencies (train step / aggregate / eval chunk) when
//!   artifacts are present — the L1/L2 request-path numbers for
//!   EXPERIMENTS.md §Perf.

use dystop::bench::{bench, bench_with};
use dystop::config::{ExperimentConfig, ModelKind, SchedulerKind};
use dystop::sim::SimEngine;
use std::path::PathBuf;

fn sim_round_bench(kind: SchedulerKind) {
    let cfg = ExperimentConfig {
        workers: 60,
        rounds: 10_000, // never reached; we step manually
        train_per_worker: 64,
        eval_every: usize::MAX,
        target_accuracy: 2.0,
        scheduler: kind,
        ..Default::default()
    };
    let mut sim = SimEngine::new(cfg);
    // warmup handled by bench(); each call = one full coordinator round
    bench(&format!("sim_round N=60 {}", kind.name()), || {
        std::hint::black_box(sim.step());
    });
}

fn pjrt_benches() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("(artifacts missing — skipping PJRT hot-path benches; run `make artifacts`)");
        return;
    }
    use dystop::data::{make_corpus, SyntheticSpec};
    use dystop::runtime::PjrtTrainer;
    use dystop::util::rng::Pcg;
    use dystop::worker::Trainer;

    let mut t = PjrtTrainer::new(&dir, ModelKind::Mlp).expect("load artifacts");
    let dim = t.manifest().input_dim;
    let b = t.manifest().train_batch;
    let (train, test) = make_corpus(&SyntheticSpec {
        dim,
        train_samples: 512,
        test_samples: 256,
        ..Default::default()
    });
    let mut rng = Pcg::seeded(1);
    let params = t.init(0);

    // L2/L1 train step through PJRT (the per-worker hot path)
    let x: Vec<f32> = (0..b * dim).map(|i| (i % 7) as f32 * 0.1).collect();
    let y: Vec<i32> = (0..b).map(|i| (i % 10) as i32).collect();
    bench_with("pjrt train_batch (mlp)", 5, 1.0, &mut || {
        std::hint::black_box(t.train_batch(&params, &x, &y, 0.1).unwrap());
    });

    // aggregation via the Pallas kernel artifact (K_max padded)
    let models: Vec<Vec<f32>> = (0..4).map(|s| t.init(s as u64)).collect();
    let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
    let w = vec![0.25f32; 4];
    bench_with("pjrt aggregate K=4 (pallas)", 5, 1.0, &mut || {
        std::hint::black_box(t.aggregate(&refs, &w));
    });

    // eval chunk
    bench_with("pjrt eval 256 samples (mlp)", 3, 1.0, &mut || {
        std::hint::black_box(t.evaluate(&params, &test));
    });

    // native-vs-pjrt train comparison point
    let mut nt = dystop::worker::NativeTrainer::new(dim, 10);
    let np = nt.init(0);
    bench_with("native train step (softmax reg)", 5, 0.5, &mut || {
        std::hint::black_box(nt.train(&np, &train, 1, 32, 0.1, &mut rng));
    });
}

fn main() {
    println!("== end-to-end round throughput (Figs. 4–18 inner loop) ==");
    for kind in [
        SchedulerKind::DySTop,
        SchedulerKind::AsyDfl,
        SchedulerKind::SaAdfl,
        SchedulerKind::Matcha,
    ] {
        sim_round_bench(kind);
    }
    println!("\n== PJRT hot path (L1/L2 via HLO artifacts) ==");
    pjrt_benches();
}
