//! Bench: Worker Activation Algorithm (Alg. 2) — the per-round cost of
//! the coordinator's activation decision at paper scale (N=100) and 10×.
//!
//! The substrate (geometry, budgets, shards → label distributions) comes
//! from [`Experiment::builder`] — the same construction path the engines
//! use — instead of a hand-rolled copy; only the per-round scheduler
//! inputs (staleness, queues, H estimates) are synthetic.

use dystop::bench::bench;
use dystop::config::ExperimentConfig;
use dystop::coordinator::{waa_select, PullLedger, SchedView, SchedulerParams};
use dystop::experiment::Experiment;
use dystop::network::EdgeNetwork;
use dystop::util::rng::Pcg;

struct Fix {
    net: EdgeNetwork,
    tau: Vec<u64>,
    queues: Vec<f64>,
    h_cmp: Vec<f64>,
    h_est: Vec<f64>,
    data_sizes: Vec<usize>,
    ids: Vec<usize>,
    label_dist: Vec<Vec<f64>>,
    candidates: Vec<Vec<usize>>,
    budgets: Vec<f64>,
    pulls: PullLedger,
}

fn fixture(n: usize, seed: u64) -> Fix {
    let cfg = ExperimentConfig {
        workers: n,
        seed,
        train_per_worker: 64,
        test_samples: 64,
        ..Default::default()
    };
    let exp = Experiment::builder(cfg).build().expect("bench substrate");
    let mut rng = Pcg::new(seed, 7);
    let mut buf = Vec::new();
    let candidates: Vec<Vec<usize>> = (0..n)
        .map(|i| {
            exp.net.in_range_into(i, &mut buf);
            buf.clone()
        })
        .collect();
    Fix {
        tau: (0..n).map(|_| rng.below(8)).collect(),
        queues: (0..n).map(|_| rng.f64() * 4.0).collect(),
        h_cmp: (0..n).map(|_| rng.f64() * 2.0).collect(),
        h_est: (0..n).map(|_| 0.3 + rng.f64() * 3.0).collect(),
        data_sizes: exp.workers.iter().map(|w| w.data_size()).collect(),
        ids: (0..n).collect(),
        label_dist: exp.label_dist,
        candidates,
        budgets: exp.net.budgets.clone(),
        pulls: PullLedger::dense(n),
        net: exp.net,
    }
}

fn view(f: &Fix) -> SchedView<'_> {
    SchedView {
        round: 10,
        tau: &f.tau,
        queues: &f.queues,
        h_cmp: &f.h_cmp,
        h_est: &f.h_est,
        data_sizes: &f.data_sizes,
        ids: &f.ids,
        label_dist: &f.label_dist,
        candidates: &f.candidates,
        budgets: &f.budgets,
        pulls: &f.pulls,
        net: &f.net,
        params: SchedulerParams { tau_bound: 5, v: 10.0, neighbor_cap: 7, t_thre: 60 },
    }
}

fn main() {
    println!("== WAA (Alg. 2) per-round cost ==");
    for n in [100usize, 400, 1000] {
        let f = fixture(n, 42);
        let v = view(&f);
        bench(&format!("waa_select N={n}"), || {
            std::hint::black_box(waa_select(&v));
        });
    }
}
