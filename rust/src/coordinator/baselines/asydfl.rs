//! AsyDFL baseline \[14\]: event-driven asynchronous DFL with
//! data-utility neighbor selection and **no staleness control**.
//!
//! Workers activate as soon as their local training finishes (the workers
//! with the smallest residual compute this round); each selects up to `s`
//! neighbors by a data-utility score (label-distribution divergence —
//! AsyDFL/AsyNG's non-IID handling) subject to its own bandwidth budget.
//! Staleness is left unmanaged, which is exactly the weakness DySTop's
//! WAA addresses (Table I: "Handling Staleness: Poor").

use crate::coordinator::{RoundPlan, SchedView, Scheduler};
use crate::data::emd;
use crate::util::rng::Pcg;

pub struct AsyDfl {
    /// Event-loop slack: only workers within `slack_s` seconds of the
    /// earliest finisher activate together. Kept tight — AsyDFL is
    /// coordinator-free, each completion is its own event; batching whole
    /// cohorts would turn it semi-synchronous.
    pub slack_s: f64,
}

impl Default for AsyDfl {
    fn default() -> Self {
        AsyDfl { slack_s: 0.005 }
    }
}

impl Scheduler for AsyDfl {
    fn name(&self) -> &'static str {
        "asydfl"
    }

    fn plan(&mut self, view: &SchedView<'_>, _rng: &mut Pcg) -> RoundPlan {
        let n = view.n();
        // earliest finisher(s); residuals clamp at 0 when a worker sat
        // idle, so FIFO by staleness and cap the cohort — each completion
        // is its own event in the real (coordinator-free) AsyDFL loop
        let min_res = view
            .h_cmp
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        let mut ready: Vec<usize> = (0..n)
            .filter(|&i| view.h_cmp[i] <= min_res + self.slack_s)
            .collect();
        // FIFO among finishers: the longest-waiting completions are the
        // earliest events. The cohort cap models the serial event loop —
        // activations beyond it fall into later rounds, so staleness
        // grows freely (no control — Table I's charge against AsyDFL).
        ready.sort_by_key(|&i| std::cmp::Reverse(view.tau[i]));
        let cap = (n / 10).max(1);
        ready.truncate(cap);
        let mut active = ready;
        active.sort_unstable();

        let s_cap = view.params.neighbor_cap;
        let mut used_bw = vec![0.0f64; n];
        let mut pulls_from = Vec::with_capacity(active.len());
        for &i in &active {
            // data-utility: prefer divergent label distributions
            let mut cands: Vec<usize> = view.candidates[i]
                .iter()
                .copied()
                .filter(|&j| j != i)
                .collect();
            cands.sort_by(|&a, &b| {
                let ua = emd(view.labels(i), view.labels(a));
                let ub = emd(view.labels(i), view.labels(b));
                ub.partial_cmp(&ua).unwrap()
            });
            let mut picked = Vec::new();
            for j in cands {
                if picked.len() >= s_cap {
                    break;
                }
                if used_bw[i] + 1.0 > view.budgets[i]
                    || used_bw[j] + 1.0 > view.budgets[j]
                {
                    continue;
                }
                used_bw[i] += 1.0;
                used_bw[j] += 1.0;
                picked.push(j);
            }
            pulls_from.push(picked);
        }
        RoundPlan { active, pulls_from, pushes: Vec::new() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::Fixture;
    use crate::util::prop::forall;

    #[test]
    fn activates_earliest_finishers() {
        let mut rng = Pcg::seeded(13);
        let mut fix = Fixture::random(6, &mut rng);
        fix.h_cmp = vec![3.0, 0.0, 2.0, 0.0, 5.0, 1.0];
        fix.tau = vec![0, 2, 0, 5, 0, 0]; // 3 waited longer than 1
        let plan = AsyDfl::default().plan(&fix.view(), &mut rng);
        // cohort cap = max(6/10, 1) = 1: the longest-waiting finisher
        assert_eq!(plan.active, vec![3]);
        // worker 1 (also finished, shorter wait) goes next round
        fix.tau = vec![0, 7, 0, 5, 0, 0];
        let plan = AsyDfl::default().plan(&fix.view(), &mut rng);
        assert_eq!(plan.active, vec![1]);
    }

    #[test]
    fn respects_budgets_and_cap() {
        forall(81, |rng| {
            let n = 4 + rng.below_usize(25);
            let mut fix = Fixture::random(n, rng);
            fix.params.neighbor_cap = 1 + rng.below_usize(5);
            fix.budgets = vec![1.0 + rng.f64() * 6.0; n];
            let view = fix.view();
            let plan = AsyDfl::default().plan(&view, rng);
            plan.validate(n).unwrap();
            let mut bw = vec![0.0; n];
            for (k, lst) in plan.pulls_from.iter().enumerate() {
                assert!(lst.len() <= fix.params.neighbor_cap);
                for &j in lst {
                    bw[plan.active[k]] += 1.0;
                    bw[j] += 1.0;
                }
            }
            for i in 0..n {
                assert!(bw[i] <= view.budgets[i] + 1e-9);
            }
        });
    }

    #[test]
    fn picks_most_divergent_neighbors() {
        let mut rng = Pcg::seeded(14);
        let mut fix = Fixture::random(4, &mut rng);
        fix.h_cmp = vec![0.0, 9.0, 9.0, 9.0];
        fix.candidates = vec![vec![1, 2, 3], vec![0], vec![0], vec![0]];
        let oh = |k: usize| {
            let mut v = vec![0.0; 10];
            v[k] = 1.0;
            v
        };
        fix.label_dist = vec![oh(0), oh(0), oh(1), oh(0)];
        fix.params.neighbor_cap = 1;
        let plan = AsyDfl::default().plan(&fix.view(), &mut rng);
        assert_eq!(plan.active, vec![0]);
        assert_eq!(plan.pulls_from[0], vec![2]);
    }
}
