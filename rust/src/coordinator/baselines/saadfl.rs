//! SA-ADFL baseline \[15\] — the authors' previous work.
//!
//! Dynamic staleness control, but coarse: exactly *one* worker is
//! activated per round (chosen by the same drift-plus-penalty criterion,
//! restricted to singleton active sets), and it exchanges models with
//! **all** neighbors within its communication range — it pulls everyone's
//! model for aggregation and pushes its updated model back to everyone.
//! That is the "significant communication + no fine-grained non-IID
//! handling" behaviour DySTop improves on (§II-C, Table I).

use crate::coordinator::{lyapunov, RoundPlan, SchedView, Scheduler};
use crate::util::rng::Pcg;

#[derive(Default)]
pub struct SaAdfl;

impl Scheduler for SaAdfl {
    fn name(&self) -> &'static str {
        "sa-adfl"
    }

    fn plan(&mut self, view: &SchedView<'_>, _rng: &mut Pcg) -> RoundPlan {
        let n = view.n();
        let p = view.params;

        // drift of "skip everyone"
        let base_drift: f64 = (0..n)
            .map(|i| {
                view.queues[i]
                    * (lyapunov::staleness_after(view.tau[i], false) as f64
                        - p.tau_bound as f64)
            })
            .sum();

        // best singleton: drift change −q_i(τ_i+1), penalty V·H_t^i
        let best = (0..n)
            .min_by(|&a, &b| {
                let sa = base_drift - view.queues[a] * (view.tau[a] as f64 + 1.0)
                    + p.v * view.h_est[a];
                let sb = base_drift - view.queues[b] * (view.tau[b] as f64 + 1.0)
                    + p.v * view.h_est[b];
                sa.partial_cmp(&sb).unwrap()
            })
            .expect("no workers");

        // SA-ADFL is push-based: the activated worker aggregates whatever
        // was pushed to it so far (its inbox) with its own model, then
        // sends the update to ALL neighbors within communication range —
        // no neighbor subset selection (Table I: "Communication: High").
        let neighbors: Vec<usize> = view.candidates[best]
            .iter()
            .copied()
            .filter(|&j| j != best)
            .collect();
        let pushes: Vec<(usize, usize)> =
            neighbors.iter().map(|&j| (best, j)).collect();
        RoundPlan {
            active: vec![best],
            pulls_from: vec![Vec::new()],
            pushes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::Fixture;
    use crate::util::prop::forall;

    #[test]
    fn single_worker_full_range() {
        forall(71, |rng| {
            let n = 3 + rng.below_usize(30);
            let fix = Fixture::random(n, rng);
            let view = fix.view();
            let mut s = SaAdfl;
            let plan = s.plan(&view, rng);
            plan.validate(n).unwrap();
            assert_eq!(plan.active.len(), 1);
            let w = plan.active[0];
            // push-based: no pulls, one push to every in-range neighbor
            let expected: Vec<usize> = view.candidates[w]
                .iter()
                .copied()
                .filter(|&j| j != w)
                .collect();
            assert!(plan.pulls_from[0].is_empty());
            assert_eq!(plan.pushes.len(), expected.len());
            for (f, t) in &plan.pushes {
                assert_eq!(*f, w);
                assert!(expected.contains(t));
            }
        });
    }

    #[test]
    fn stale_hot_queue_worker_wins() {
        let mut rng = Pcg::seeded(8);
        let mut fix = Fixture::random(6, &mut rng);
        fix.queues = vec![0.0, 0.0, 50.0, 0.0, 0.0, 0.0];
        fix.tau = vec![0, 0, 9, 0, 0, 0];
        fix.h_est = vec![1.0; 6];
        let plan = SaAdfl.plan(&fix.view(), &mut rng);
        assert_eq!(plan.active, vec![2]);
    }
}
