//! MATCHA baseline \[9\]: synchronous decentralized SGD over sampled
//! matching decompositions.
//!
//! Each round, the base graph (all links within communication range) is
//! decomposed into disjoint matchings; a random subset (budget `frac`) is
//! activated. *Every* worker is active every round — the synchronization
//! barrier means the round lasts until the slowest worker finishes
//! (straggler-bound, the drawback §II-A calls out). Communication is low
//! (matchings are sparse) — the paper treats MATCHA as the communication
//! lower bound.

use crate::coordinator::{RoundPlan, SchedView, Scheduler};
use crate::topology::{greedy_matching_decomposition, sample_matchings};
use crate::util::rng::Pcg;

pub struct Matcha {
    /// Fraction of matchings activated per round (MATCHA's C_b).
    pub frac: f64,
    /// Base-topology degree: each worker keeps edges to its `base_degree`
    /// nearest in-range peers. MATCHA decomposes a *sparse* predefined
    /// base graph, not the full radio graph — this is what makes it the
    /// paper's communication lower bound.
    pub base_degree: usize,
}

impl Default for Matcha {
    fn default() -> Self {
        Matcha { frac: 0.5, base_degree: 4 }
    }
}

impl Scheduler for Matcha {
    fn name(&self) -> &'static str {
        "matcha"
    }

    fn plan(&mut self, view: &SchedView<'_>, rng: &mut Pcg) -> RoundPlan {
        let n = view.n();
        // sparse base graph: each worker's `base_degree` nearest in-range
        // peers (symmetric closure), the predefined topology MATCHA
        // decomposes
        let mut keep = vec![std::collections::BTreeSet::new(); n];
        for i in 0..n {
            let mut near: Vec<usize> = view.candidates[i]
                .iter()
                .copied()
                .filter(|&j| view.candidates[j].contains(&i))
                .collect();
            near.sort_by(|&a, &b| {
                view.dist(i, a).partial_cmp(&view.dist(i, b)).unwrap()
            });
            for &j in near.iter().take(self.base_degree) {
                keep[i].insert(j);
            }
        }
        // symmetric closure: edge if either endpoint kept the other
        let mut pairs = std::collections::BTreeSet::new();
        for i in 0..n {
            for &j in &keep[i] {
                pairs.insert((i.min(j), i.max(j)));
            }
        }
        let edges: Vec<(usize, usize)> = pairs.into_iter().collect();
        let matchings = greedy_matching_decomposition(n, &edges);
        let sampled = sample_matchings(&matchings, self.frac, rng);

        // synchronous: everyone is active; neighbors = matched partners
        let mut pulls_from: Vec<Vec<usize>> = vec![Vec::new(); n];
        for m in &sampled {
            for &(a, b) in &m.pairs {
                // matched pair exchanges models both ways
                pulls_from[a].push(b);
                pulls_from[b].push(a);
            }
        }
        RoundPlan {
            active: (0..n).collect(),
            pulls_from,
            pushes: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::testutil::Fixture;

    #[test]
    fn everyone_active_and_degrees_bounded() {
        let mut rng = Pcg::seeded(3);
        let fix = Fixture::random(20, &mut rng);
        let mut m = Matcha::default();
        let plan = m.plan(&fix.view(), &mut rng);
        plan.validate(20).unwrap();
        assert_eq!(plan.active.len(), 20);
        // matchings: in-degree ≤ number of sampled matchings; and each
        // pull is symmetric
        for (k, lst) in plan.pulls_from.iter().enumerate() {
            let i = plan.active[k];
            for &j in lst {
                let kj = plan.active.iter().position(|&x| x == j).unwrap();
                assert!(plan.pulls_from[kj].contains(&i), "asymmetric pair");
            }
        }
    }

    #[test]
    fn frac_zero_means_no_communication() {
        let mut rng = Pcg::seeded(4);
        let fix = Fixture::random(10, &mut rng);
        let mut m = Matcha { frac: 0.0, ..Default::default() };
        let plan = m.plan(&fix.view(), &mut rng);
        assert_eq!(plan.transfers(), 0);
    }

    #[test]
    fn full_frac_uses_sparse_base_graph() {
        let mut rng = Pcg::seeded(5);
        let fix = Fixture::random(12, &mut rng);
        let view = fix.view();
        let mut m = Matcha { frac: 1.0, ..Default::default() };
        let plan = m.plan(&view, &mut rng);
        // sparse base topology: strictly fewer transfers than the full
        // in-range graph would produce, but the graph is non-trivial
        let mut full_count = 0;
        for i in 0..12 {
            for &j in &view.candidates[i] {
                if i < j && view.candidates[j].contains(&i) {
                    full_count += 2;
                }
            }
        }
        assert!(plan.transfers() > 0);
        assert!(
            plan.transfers() <= full_count,
            "{} > {full_count}",
            plan.transfers()
        );
        // degree bound: nobody exchanges with more than ~2×base_degree
        for lst in &plan.pulls_from {
            assert!(lst.len() <= 2 * m.base_degree + 1, "{}", lst.len());
        }
    }
}
