//! Baseline DFL mechanisms (paper §VI-A3), reimplemented over the same
//! substrate so comparisons are apples-to-apples.

mod asydfl;
mod matcha;
mod saadfl;

pub use asydfl::AsyDfl;
pub use matcha::Matcha;
pub use saadfl::SaAdfl;
