//! Phase-aware Topology Construction Algorithm — Alg. 3 of the paper.
//!
//! For each activated worker v_i, PTCA ranks the candidates within
//! communication range (`C_t^i`) by a phase-dependent priority:
//!
//! * **Phase 1** (t ≤ t_thre, Eq. 46): favour neighbors whose label
//!   distribution *differs* (high EMD) and who are physically close —
//!   combined datasets approximate IID (Corollary 3, Fig. 2).
//! * **Phase 2** (t > t_thre, Eq. 47): favour rarely-pulled neighbors
//!   (diversity) with similar staleness (staleness control).
//!
//! Selection is a round-robin over the active workers, one pull per
//! iteration, respecting every worker's bandwidth budget (both the
//! puller's and the source's, Eq. 10) and the in-neighbor cap s, until a
//! full sweep adds no bandwidth (Alg. 3 lines 18–21).

use super::SchedView;
use crate::data::emd;

/// Phase-1 priority p1(v_i, v_j) (Eq. 46). Indices are the view's dense
/// (present-worker) indices; the view remaps to global stores.
pub fn phase1_priority(
    view: &SchedView<'_>,
    i: usize,
    j: usize,
    emd_max: f64,
    dist_max: f64,
) -> f64 {
    let e = emd(view.labels(i), view.labels(j));
    let d = view.dist(i, j);
    e / emd_max.max(1e-9) + (1.0 - d / dist_max.max(1e-9))
}

/// Phase-2 priority p2(v_i, v_j) (Eq. 47).
pub fn phase2_priority(view: &SchedView<'_>, i: usize, j: usize) -> f64 {
    let t = view.round.max(1) as f64;
    let pull_frac = view.pull_count(i, j) as f64 / t;
    let tau_gap = (view.tau[i] as i64 - view.tau[j] as i64).unsigned_abs() as f64;
    (1.0 - pull_frac) * (1.0 / (1.0 + tau_gap))
}

/// Which priority a PTCA instance uses (Fig. 3 ablations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum PhaseMode {
    /// Paper's Alg. 3: p1 before t_thre, p2 after.
    Combined,
    Phase1Only,
    Phase2Only,
}

/// PTCA topology builder.
#[derive(Clone, Debug)]
pub struct Ptca {
    mode: PhaseMode,
}

impl Default for Ptca {
    fn default() -> Self {
        Ptca { mode: PhaseMode::Combined }
    }
}

impl Ptca {
    pub fn phase1_only() -> Self {
        Ptca { mode: PhaseMode::Phase1Only }
    }

    pub fn phase2_only() -> Self {
        Ptca { mode: PhaseMode::Phase2Only }
    }

    fn use_phase1(&self, view: &SchedView<'_>) -> bool {
        match self.mode {
            PhaseMode::Combined => view.round <= view.params.t_thre,
            PhaseMode::Phase1Only => true,
            PhaseMode::Phase2Only => false,
        }
    }

    /// Construct the pull lists for each active worker (aligned with
    /// `active`). Guarantees per-worker bandwidth ≤ budget and in-degree
    /// ≤ s; every active worker gets ≥ 0 pulls (possibly none if starved).
    pub fn construct(
        &self,
        view: &SchedView<'_>,
        active: &[usize],
    ) -> Vec<Vec<usize>> {
        let n = view.n();
        let phase1 = self.use_phase1(view);
        let s_cap = view.params.neighbor_cap;

        // Normalisation constants for p1 over the realised candidates.
        let (emd_max, dist_max) = if phase1 {
            let mut em = 0.0f64;
            let mut dm = 0.0f64;
            for &i in active {
                for &j in &view.candidates[i] {
                    em = em.max(emd(view.labels(i), view.labels(j)));
                    dm = dm.max(view.dist(i, j));
                }
            }
            (em.max(1e-9), dm.max(1e-9))
        } else {
            (1.0, 1.0)
        };

        // Line 2–5: per-active-worker candidate queues sorted descending
        // by priority (a Vec used as a cursor-consumed stack).
        let mut queues: Vec<Vec<usize>> = active
            .iter()
            .map(|&i| {
                // decorate-sort-undecorate: priorities are O(C) to compute
                // (EMD over classes), so evaluate each exactly once rather
                // than inside the sort comparator (§Perf)
                let mut scored: Vec<(f64, usize)> = view.candidates[i]
                    .iter()
                    .copied()
                    .filter(|&j| j != i)
                    .map(|j| {
                        let p = if phase1 {
                            phase1_priority(view, i, j, emd_max, dist_max)
                        } else {
                            phase2_priority(view, i, j)
                        };
                        (p, j)
                    })
                    .collect();
                // ascending: pop() takes from the back = highest priority
                scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                scored.into_iter().map(|(_, j)| j).collect::<Vec<usize>>()
            })
            .collect();

        // Iterative bandwidth-capped selection (lines 6–21).
        let mut used_bw = vec![0.0f64; n]; // B_t^i in model transfers
        let mut result: Vec<Vec<usize>> = vec![Vec::new(); active.len()];
        loop {
            let before: f64 = used_bw.iter().sum();
            for (k, &i) in active.iter().enumerate() {
                if result[k].len() >= s_cap {
                    continue;
                }
                // Line 8: puller must afford one more pull.
                if used_bw[i] + 1.0 > view.budgets[i] {
                    continue;
                }
                // Lines 10–17: take the top-ranked affordable source.
                while let Some(j) = queues[k].pop() {
                    if used_bw[j] + 1.0 > view.budgets[j] {
                        continue; // source saturated — skip (line 11–12)
                    }
                    result[k].push(j);
                    used_bw[i] += 1.0;
                    used_bw[j] += 1.0;
                    break;
                }
            }
            let after: f64 = used_bw.iter().sum();
            if after <= before {
                break; // line 18: no progress in a full sweep
            }
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Fixture;
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg;

    #[test]
    fn respects_neighbor_cap_and_budget() {
        forall(61, |rng| {
            let n = 5 + rng.below_usize(30);
            let mut fix = Fixture::random(n, rng);
            fix.params.neighbor_cap = 1 + rng.below_usize(6);
            let budget = 1.0 + rng.f64() * 8.0;
            fix.budgets = vec![budget; n];
            let n_active = 1 + rng.below_usize(n.min(8));
            let active: Vec<usize> = rng.sample_indices(n, n_active);
            let view = fix.view();
            let ptca = Ptca::default();
            let pulls = ptca.construct(&view, &active);
            assert_eq!(pulls.len(), active.len());
            // accounting
            let mut bw = vec![0.0; n];
            for (k, lst) in pulls.iter().enumerate() {
                assert!(lst.len() <= fix.params.neighbor_cap);
                let mut dedup = lst.clone();
                dedup.sort_unstable();
                dedup.dedup();
                assert_eq!(dedup.len(), lst.len(), "duplicate pulls");
                for &j in lst {
                    assert_ne!(j, active[k]);
                    assert!(
                        view.candidates[active[k]].contains(&j),
                        "pull outside communication range"
                    );
                    bw[active[k]] += 1.0;
                    bw[j] += 1.0;
                }
            }
            for i in 0..n {
                assert!(
                    bw[i] <= view.budgets[i] + 1e-9,
                    "worker {i} bandwidth {} > budget {}",
                    bw[i],
                    view.budgets[i]
                );
            }
        });
    }

    #[test]
    fn phase1_prefers_divergent_close_neighbors() {
        let mut rng = Pcg::seeded(9);
        let mut fix = Fixture::random(4, &mut rng);
        // all same position distances: candidates 1,2,3 for worker 0
        fix.candidates = vec![vec![1, 2, 3], vec![0], vec![0], vec![0]];
        // worker 0 one-hot class 0; worker 1 identical; worker 2 disjoint
        fix.label_dist = vec![
            one_hot(0),
            one_hot(0),
            one_hot(1),
            one_hot(0),
        ];
        fix.net.positions = vec![
            crate::network::Pos { x: 0.0, y: 0.0 },
            crate::network::Pos { x: 10.0, y: 0.0 },
            crate::network::Pos { x: 10.0, y: 0.0 },
            crate::network::Pos { x: 10.0, y: 0.0 },
        ];
        fix.params.neighbor_cap = 1;
        fix.round = 1; // phase 1
        let ptca = Ptca::default();
        let pulls = ptca.construct(&fix.view(), &[0]);
        assert_eq!(pulls[0], vec![2], "should pick the divergent neighbor");
    }

    #[test]
    fn phase2_prefers_rarely_pulled_similar_staleness() {
        let mut rng = Pcg::seeded(10);
        let mut fix = Fixture::random(4, &mut rng);
        fix.candidates = vec![vec![1, 2, 3], vec![0], vec![0], vec![0]];
        fix.round = 100;
        fix.params.t_thre = 50; // phase 2
        fix.tau = vec![2, 2, 2, 9]; // worker 3 has big staleness gap
        for _ in 0..90 {
            fix.pulls.record(0, 1); // worker 1 pulled a lot
        }
        fix.params.neighbor_cap = 1;
        let ptca = Ptca::default();
        let pulls = ptca.construct(&fix.view(), &[0]);
        // worker 2: never pulled, same staleness → top priority
        assert_eq!(pulls[0], vec![2]);
    }

    #[test]
    fn ablation_modes_differ_when_phases_disagree() {
        let mut rng = Pcg::seeded(11);
        let fix = Fixture::random(20, &mut rng);
        let view = fix.view();
        let active: Vec<usize> = (0..5).collect();
        let p1 = Ptca::phase1_only().construct(&view, &active);
        let p2 = Ptca::phase2_only().construct(&view, &active);
        // not a hard guarantee for every seed, but for this fixed seed
        // the orderings disagree — guards against the phases collapsing
        assert_ne!(p1, p2);
    }

    #[test]
    fn zero_budget_yields_no_pulls() {
        let mut rng = Pcg::seeded(12);
        let mut fix = Fixture::random(6, &mut rng);
        fix.budgets = vec![0.0; 6];
        let pulls = Ptca::default().construct(&fix.view(), &[0, 1]);
        assert!(pulls.iter().all(|l| l.is_empty()));
    }

    fn one_hot(k: usize) -> Vec<f64> {
        let mut v = vec![0.0; 10];
        v[k] = 1.0;
        v
    }
}
