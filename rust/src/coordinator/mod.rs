//! The coordinator — the paper's system contribution.
//!
//! Every mechanism (DySTop and the three baselines) is a [`Scheduler`]
//! that, given the per-round [`SchedView`] snapshot, produces a
//! [`RoundPlan`]: which workers activate (`A_t`, Alg. 2) and which
//! in-neighbors each of them pulls from (`G_t`, Alg. 3).

pub mod baselines;
mod lyapunov;
mod ptca;
mod waa;

pub use lyapunov::{drift_plus_penalty, staleness_after, update_queues};
pub use ptca::{phase1_priority, phase2_priority, Ptca};
pub use waa::waa_select;

use crate::config::ExperimentConfig;
use crate::network::EdgeNetwork;
use crate::util::rng::Pcg;
use std::fmt;

/// DySTop-specific knobs carried into the schedulers.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerParams {
    /// τ_bound of constraint (12c).
    pub tau_bound: u64,
    /// Lyapunov trade-off V of Eq. (34).
    pub v: f64,
    /// In-neighbor cap s (Fig. 17/18).
    pub neighbor_cap: usize,
    /// PTCA phase switch round t_thre.
    pub t_thre: usize,
}

impl From<&ExperimentConfig> for SchedulerParams {
    fn from(e: &ExperimentConfig) -> Self {
        SchedulerParams {
            tau_bound: e.tau_bound,
            v: e.v,
            neighbor_cap: e.neighbor_cap,
            t_thre: e.t_thre,
        }
    }
}

/// Run-long pull history `pulls[i][j]` = times `i` pulled from `j`
/// (Eq. 47), **global-indexed**.
///
/// `Dense` keeps n×n counters — cache-friendly and what the dense
/// engine and the threaded testbed use at small N. `Sparse` keeps only
/// the touched edges in a hash map: at N=1M the dense form would be
/// 8 TB, but only O(rounds × pull edges) entries are ever nonzero. The
/// two variants are observationally identical through
/// [`count`](Self::count)/[`record`](Self::record), so engine results
/// don't depend on the representation.
#[derive(Clone, Debug)]
pub enum PullLedger {
    Dense(Vec<Vec<u64>>),
    Sparse(std::collections::HashMap<(u32, u32), u64>),
}

impl PullLedger {
    /// All-zero dense ledger for `n` workers.
    pub fn dense(n: usize) -> Self {
        PullLedger::Dense(vec![vec![0; n]; n])
    }

    /// Empty sparse ledger (any worker-id range).
    pub fn sparse() -> Self {
        PullLedger::Sparse(std::collections::HashMap::new())
    }

    /// Times `i` pulled from `j`.
    pub fn count(&self, i: usize, j: usize) -> u64 {
        match self {
            PullLedger::Dense(m) => m[i][j],
            PullLedger::Sparse(m) => {
                m.get(&(i as u32, j as u32)).copied().unwrap_or(0)
            }
        }
    }

    /// Record one `i ← j` pull.
    pub fn record(&mut self, i: usize, j: usize) {
        match self {
            PullLedger::Dense(m) => m[i][j] += 1,
            PullLedger::Sparse(m) => {
                *m.entry((i as u32, j as u32)).or_insert(0) += 1
            }
        }
    }

    /// Forget all history involving `w` — a `Join` recycles the slot of
    /// a departed worker, and the newcomer starts with a clean ledger.
    pub fn reset_worker(&mut self, w: usize) {
        match self {
            PullLedger::Dense(m) => {
                for row in m.iter_mut() {
                    row[w] = 0;
                }
                for c in m[w].iter_mut() {
                    *c = 0;
                }
            }
            PullLedger::Sparse(m) => {
                let w = w as u32;
                m.retain(|&(i, j), _| i != w && j != w);
            }
        }
    }
}

/// Read-only per-round snapshot handed to schedulers.
///
/// # Indexing under dynamic populations
///
/// The view is *compacted over present workers*: every dense slice
/// (`tau`, `queues`, `h_cmp`, `h_est`, `data_sizes`, `candidates`,
/// `budgets`) has one entry per **present** worker, and `candidates`
/// contains these dense indices too. Schedulers therefore plan over a
/// shrinking/growing population without any membership logic of their
/// own; the engine remaps the returned [`RoundPlan`] back to global
/// worker ids through [`ids`](Self::ids).
///
/// The run-long stores (`label_dist`, `pulls`, `net`) stay indexed by
/// global id — access them through [`labels`](Self::labels),
/// [`pull_count`](Self::pull_count) and [`dist`](Self::dist), which
/// remap internally. With everyone present `ids` is the identity and
/// the view is exactly the pre-scenario one.
pub struct SchedView<'a> {
    /// Round index t (1-based like the paper).
    pub round: usize,
    /// Staleness τ_t^i per present worker.
    pub tau: &'a [u64],
    /// Lyapunov queues q_t^i per present worker.
    pub queues: &'a [f64],
    /// Residual compute h_t^{i,cmp} (Eq. 7) per present worker, seconds.
    pub h_cmp: &'a [f64],
    /// Estimated per-worker round cost H_t^i (Eq. 8), seconds.
    pub h_est: &'a [f64],
    /// Data sizes D_i.
    pub data_sizes: &'a [usize],
    /// Dense→global worker-id map (identity when everyone is present).
    pub ids: &'a [usize],
    /// Per-worker label distributions (PTCA phase 1 / EMD).
    /// **Global-indexed** — use [`labels`](Self::labels).
    pub label_dist: &'a [Vec<f64>],
    /// Candidate in-range workers C_t^i (Alg. 3 input), per present
    /// worker, as dense indices.
    pub candidates: &'a [Vec<usize>],
    /// Per-worker bandwidth budgets \hat B_t^i, in model transfers.
    pub budgets: &'a [f64],
    /// Pull history (Eq. 47). **Global-indexed** — use
    /// [`pull_count`](Self::pull_count).
    pub pulls: &'a PullLedger,
    /// The physical network. **Global-indexed** — use
    /// [`dist`](Self::dist) for distances.
    pub net: &'a EdgeNetwork,
    pub params: SchedulerParams,
}

impl<'a> SchedView<'a> {
    /// Number of present workers (the dense dimension).
    pub fn n(&self) -> usize {
        self.tau.len()
    }

    /// Label distribution of dense worker `k`.
    pub fn labels(&self, k: usize) -> &[f64] {
        &self.label_dist[self.ids[k]]
    }

    /// Physical distance between dense workers `a` and `b`.
    pub fn dist(&self, a: usize, b: usize) -> f64 {
        self.net.distance(self.ids[a], self.ids[b])
    }

    /// Times dense worker `a` pulled from dense worker `b` (Eq. 47).
    pub fn pull_count(&self, a: usize, b: usize) -> u64 {
        self.pulls.count(self.ids[a], self.ids[b])
    }
}

/// Output of a scheduler for one round.
#[derive(Clone, Debug, Default)]
pub struct RoundPlan {
    /// Activated workers A_t.
    pub active: Vec<usize>,
    /// Pull topology: `pulls_from[k]` lists the in-neighbors of
    /// `active[k]` (excluding itself; self-aggregation is implicit).
    pub pulls_from: Vec<Vec<usize>>,
    /// Push edges `(from, to)`: `from` sends its *updated* model to `to`,
    /// which merges it immediately (used by SA-ADFL's push-to-all).
    pub pushes: Vec<(usize, usize)>,
}

/// Every way a [`RoundPlan`] can violate the engines' invariants.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlanError {
    /// `active` and `pulls_from` have different lengths.
    LengthMismatch { active: usize, pulls_from: usize },
    /// An activation or pull source references a worker id ≥ n.
    OutOfRange { worker: usize, n: usize },
    /// A worker appears twice in `active`.
    DuplicateActivation { worker: usize },
    /// A worker pulls from itself (self-aggregation is implicit).
    SelfPull { worker: usize },
    /// The same pull edge appears twice for one activation.
    DuplicatePull { worker: usize, source: usize },
    /// A push edge is out of range or a self-push.
    BadPushEdge { from: usize, to: usize },
    /// A push originates from a worker that is not activated.
    NonActivatedPush { from: usize, to: usize },
    /// The same push edge appears twice.
    DuplicatePush { from: usize, to: usize },
    /// The plan references a worker that is absent this round
    /// (departed/crashed — scenario layer).
    AbsentWorker { worker: usize },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            PlanError::LengthMismatch { active, pulls_from } => write!(
                f,
                "active/pulls_from length mismatch ({active} vs {pulls_from})"
            ),
            PlanError::OutOfRange { worker, n } => {
                write!(f, "worker {worker} out of range (n={n})")
            }
            PlanError::DuplicateActivation { worker } => {
                write!(f, "worker {worker} activated twice")
            }
            PlanError::SelfPull { worker } => {
                write!(f, "worker {worker} pulls from itself")
            }
            PlanError::DuplicatePull { worker, source } => {
                write!(f, "duplicate pull {worker}←{source}")
            }
            PlanError::BadPushEdge { from, to } => {
                write!(f, "bad push edge ({from},{to})")
            }
            PlanError::NonActivatedPush { from, to } => write!(
                f,
                "push ({from},{to}) originates from non-activated worker {from}"
            ),
            PlanError::DuplicatePush { from, to } => {
                write!(f, "duplicate push edge ({from},{to})")
            }
            PlanError::AbsentWorker { worker } => {
                write!(f, "plan references absent worker {worker}")
            }
        }
    }
}

impl std::error::Error for PlanError {}

impl RoundPlan {
    /// Total model transfers this round (each pull + each push moves one
    /// model — Eq. 10's accounting).
    pub fn transfers(&self) -> usize {
        self.pulls_from.iter().map(|v| v.len()).sum::<usize>() + self.pushes.len()
    }

    /// Sanity: every plan invariant the sim relies on, ignoring
    /// membership (all `n` workers assumed present).
    pub fn validate(&self, n: usize) -> Result<(), PlanError> {
        self.validate_members(n, None)
    }

    /// Like [`validate`](Self::validate), but additionally rejects any
    /// reference to an absent worker (`present[i] == false`).
    pub fn validate_present(&self, present: &[bool]) -> Result<(), PlanError> {
        self.validate_members(present.len(), Some(present))
    }

    fn validate_members(
        &self,
        n: usize,
        present: Option<&[bool]>,
    ) -> Result<(), PlanError> {
        let check_member = |w: usize| -> Result<(), PlanError> {
            if w >= n {
                return Err(PlanError::OutOfRange { worker: w, n });
            }
            if let Some(p) = present {
                if !p[w] {
                    return Err(PlanError::AbsentWorker { worker: w });
                }
            }
            Ok(())
        };
        if self.active.len() != self.pulls_from.len() {
            return Err(PlanError::LengthMismatch {
                active: self.active.len(),
                pulls_from: self.pulls_from.len(),
            });
        }
        let mut seen = vec![false; n];
        for &a in &self.active {
            check_member(a)?;
            if seen[a] {
                return Err(PlanError::DuplicateActivation { worker: a });
            }
            seen[a] = true;
        }
        for (k, pulls) in self.pulls_from.iter().enumerate() {
            let owner = self.active[k];
            let mut dedup = std::collections::BTreeSet::new();
            for &j in pulls {
                check_member(j)?;
                if j == owner {
                    return Err(PlanError::SelfPull { worker: owner });
                }
                if !dedup.insert(j) {
                    return Err(PlanError::DuplicatePull { worker: owner, source: j });
                }
            }
        }
        let mut push_seen = std::collections::BTreeSet::new();
        for &(f, t) in &self.pushes {
            if f >= n || t >= n || f == t {
                return Err(PlanError::BadPushEdge { from: f, to: t });
            }
            check_member(f)?;
            check_member(t)?;
            if !seen[f] {
                return Err(PlanError::NonActivatedPush { from: f, to: t });
            }
            if !push_seen.insert((f, t)) {
                return Err(PlanError::DuplicatePush { from: f, to: t });
            }
        }
        Ok(())
    }
}

/// A scheduling mechanism (DySTop or a baseline).
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Plan round `view.round`.
    fn plan(&mut self, view: &SchedView<'_>, rng: &mut Pcg) -> RoundPlan;

    /// Accept a wall-clock telemetry handle for phase self-profiling.
    /// Default: ignore it — baselines stay untouched; only schedulers
    /// with internal phases worth attributing implement this.
    fn attach_telemetry(&mut self, tel: crate::telemetry::Telemetry) {
        let _ = tel;
    }
}

/// DySTop: WAA for activation + PTCA for topology.
pub struct DySTopScheduler {
    ptca: Ptca,
    tel: crate::telemetry::Telemetry,
}

impl DySTopScheduler {
    pub fn new() -> Self {
        DySTopScheduler {
            ptca: Ptca::default(),
            tel: crate::telemetry::Telemetry::disabled(),
        }
    }

    /// Ablations for Fig. 3.
    pub fn phase1_only() -> Self {
        DySTopScheduler {
            ptca: Ptca::phase1_only(),
            tel: crate::telemetry::Telemetry::disabled(),
        }
    }

    pub fn phase2_only() -> Self {
        DySTopScheduler {
            ptca: Ptca::phase2_only(),
            tel: crate::telemetry::Telemetry::disabled(),
        }
    }
}

impl Default for DySTopScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for DySTopScheduler {
    fn name(&self) -> &'static str {
        "dystop"
    }

    fn plan(&mut self, view: &SchedView<'_>, _rng: &mut Pcg) -> RoundPlan {
        let t = self.tel.tick();
        let active = waa_select(view);
        self.tel.tock(crate::telemetry::Phase::Waa, t);
        let t = self.tel.tick();
        let pulls_from = self.ptca.construct(view, &active);
        self.tel.tock(crate::telemetry::Phase::Ptca, t);
        RoundPlan { active, pulls_from, pushes: Vec::new() }
    }

    fn attach_telemetry(&mut self, tel: crate::telemetry::Telemetry) {
        self.tel = tel;
    }
}

/// Factory from config.
pub fn make_scheduler(
    kind: crate::config::SchedulerKind,
) -> Box<dyn Scheduler> {
    use crate::config::SchedulerKind as K;
    match kind {
        K::DySTop => Box::new(DySTopScheduler::new()),
        K::DySTopPhase1Only => Box::new(DySTopScheduler::phase1_only()),
        K::DySTopPhase2Only => Box::new(DySTopScheduler::phase2_only()),
        K::SaAdfl => Box::new(baselines::SaAdfl::default()),
        K::AsyDfl => Box::new(baselines::AsyDfl::default()),
        K::Matcha => Box::new(baselines::Matcha::default()),
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Shared fixture: build a consistent SchedView over a random network.

    use super::*;
    use crate::config::NetworkConfig;

    pub struct Fixture {
        pub net: EdgeNetwork,
        pub tau: Vec<u64>,
        pub queues: Vec<f64>,
        pub h_cmp: Vec<f64>,
        pub h_est: Vec<f64>,
        pub data_sizes: Vec<usize>,
        pub ids: Vec<usize>,
        pub label_dist: Vec<Vec<f64>>,
        pub candidates: Vec<Vec<usize>>,
        pub budgets: Vec<f64>,
        pub pulls: PullLedger,
        pub params: SchedulerParams,
        pub round: usize,
    }

    impl Fixture {
        pub fn random(n: usize, rng: &mut Pcg) -> Self {
            let mut cfg = NetworkConfig::default();
            cfg.comm_range_m = 70.0; // dense enough for small n
            let net = EdgeNetwork::new(n, cfg, rng);
            let candidates: Vec<Vec<usize>> =
                (0..n).map(|i| net.in_range(i)).collect();
            let label_dist: Vec<Vec<f64>> =
                (0..n).map(|_| rng.dirichlet(0.5, 10)).collect();
            Fixture {
                tau: (0..n).map(|_| rng.below(6)).collect(),
                queues: (0..n).map(|_| rng.f64() * 3.0).collect(),
                h_cmp: (0..n).map(|_| rng.f64() * 2.0).collect(),
                h_est: (0..n).map(|_| 0.5 + rng.f64() * 3.0).collect(),
                data_sizes: (0..n).map(|_| 64 + rng.below_usize(128)).collect(),
                ids: (0..n).collect(), // everyone present
                label_dist,
                candidates,
                budgets: vec![8.0; n],
                pulls: PullLedger::dense(n),
                params: SchedulerParams {
                    tau_bound: 5,
                    v: 10.0,
                    neighbor_cap: 4,
                    t_thre: 50,
                },
                round: 1,
                net,
            }
        }

        pub fn view(&self) -> SchedView<'_> {
            SchedView {
                round: self.round,
                tau: &self.tau,
                queues: &self.queues,
                h_cmp: &self.h_cmp,
                h_est: &self.h_est,
                data_sizes: &self.data_sizes,
                ids: &self.ids,
                label_dist: &self.label_dist,
                candidates: &self.candidates,
                budgets: &self.budgets,
                pulls: &self.pulls,
                net: &self.net,
                params: self.params,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::Fixture;
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn roundplan_validation_catches_errors() {
        let mut p = RoundPlan {
            active: vec![0, 1],
            pulls_from: vec![vec![1], vec![0, 2]],
            pushes: vec![],
        };
        assert!(p.validate(3).is_ok());
        p.pulls_from[0] = vec![0]; // self-pull
        assert_eq!(p.validate(3), Err(PlanError::SelfPull { worker: 0 }));
        p.pulls_from[0] = vec![1, 1]; // duplicate
        assert_eq!(
            p.validate(3),
            Err(PlanError::DuplicatePull { worker: 0, source: 1 })
        );
        p.pulls_from[0] = vec![5]; // out of range
        assert_eq!(
            p.validate(3),
            Err(PlanError::OutOfRange { worker: 5, n: 3 })
        );
        let q = RoundPlan { active: vec![0, 0], pulls_from: vec![vec![], vec![]], pushes: vec![] };
        assert_eq!(
            q.validate(3),
            Err(PlanError::DuplicateActivation { worker: 0 })
        );
        let r = RoundPlan { active: vec![0], pulls_from: vec![], pushes: vec![] };
        assert!(matches!(
            r.validate(3),
            Err(PlanError::LengthMismatch { .. })
        ));

        // push-edge invariants
        let base = RoundPlan {
            active: vec![0],
            pulls_from: vec![vec![]],
            pushes: vec![(0, 1), (0, 2)],
        };
        assert!(base.validate(3).is_ok());
        let mut bad = base.clone();
        bad.pushes = vec![(0, 1), (0, 1)]; // duplicate edge
        let err = bad.validate(3).unwrap_err();
        assert_eq!(err, PlanError::DuplicatePush { from: 0, to: 1 });
        assert!(err.to_string().contains("duplicate push"), "{err}");
        let mut bad = base.clone();
        bad.pushes = vec![(1, 2)]; // sender not activated
        let err = bad.validate(3).unwrap_err();
        assert_eq!(err, PlanError::NonActivatedPush { from: 1, to: 2 });
        assert!(err.to_string().contains("non-activated"), "{err}");
        let mut bad = base.clone();
        bad.pushes = vec![(0, 0)]; // self-push
        assert_eq!(
            bad.validate(3),
            Err(PlanError::BadPushEdge { from: 0, to: 0 })
        );
        let mut bad = base;
        bad.pushes = vec![(0, 7)]; // out of range
        assert_eq!(
            bad.validate(3),
            Err(PlanError::BadPushEdge { from: 0, to: 7 })
        );
    }

    #[test]
    fn plan_error_is_std_error_with_messages() {
        let e: Box<dyn std::error::Error> =
            Box::new(PlanError::AbsentWorker { worker: 4 });
        assert!(e.to_string().contains("absent worker 4"), "{e}");
    }

    #[test]
    fn validate_present_rejects_absent_references() {
        let plan = RoundPlan {
            active: vec![0, 2],
            pulls_from: vec![vec![2], vec![1]],
            pushes: vec![(0, 1)],
        };
        let all = vec![true; 3];
        assert!(plan.validate_present(&all).is_ok());
        // absent activation
        assert_eq!(
            plan.validate_present(&[true, true, false]),
            Err(PlanError::AbsentWorker { worker: 2 })
        );
        // absent pull source / push target
        assert_eq!(
            plan.validate_present(&[true, false, true]),
            Err(PlanError::AbsentWorker { worker: 1 })
        );
    }

    #[test]
    fn pull_ledger_variants_agree() {
        forall(43, |rng| {
            let n = 3 + rng.below_usize(12);
            let mut dense = PullLedger::dense(n);
            let mut sparse = PullLedger::sparse();
            for _ in 0..60 {
                let i = rng.below_usize(n);
                let j = rng.below_usize(n);
                dense.record(i, j);
                sparse.record(i, j);
            }
            let w = rng.below_usize(n);
            dense.reset_worker(w);
            sparse.reset_worker(w);
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(
                        dense.count(i, j),
                        sparse.count(i, j),
                        "({i},{j}) after reset_worker({w})"
                    );
                }
            }
        });
    }

    #[test]
    fn all_schedulers_emit_valid_plans() {
        forall(41, |rng| {
            let n = 5 + rng.below_usize(25);
            let fix = Fixture::random(n, rng);
            for kind in [
                crate::config::SchedulerKind::DySTop,
                crate::config::SchedulerKind::DySTopPhase1Only,
                crate::config::SchedulerKind::DySTopPhase2Only,
                crate::config::SchedulerKind::SaAdfl,
                crate::config::SchedulerKind::AsyDfl,
                crate::config::SchedulerKind::Matcha,
            ] {
                let mut s = make_scheduler(kind);
                let plan = s.plan(&fix.view(), rng);
                plan.validate(n).unwrap_or_else(|e| {
                    panic!("{}: invalid plan: {e}", s.name())
                });
                assert!(
                    !plan.active.is_empty(),
                    "{}: empty active set",
                    s.name()
                );
            }
        });
    }
}
