//! Lyapunov machinery (paper §V-B).
//!
//! Problem **P1** couples rounds through the staleness constraint (12c);
//! Theorem 2 decouples it into per-round subproblems **P2** by the
//! drift-plus-penalty method over the virtual queues
//!
//! ```text
//! q_{t+1}^i = max{ q_t^i + τ_t^i − τ_bound, 0 }              (Eq. 33)
//! P2: min_{a_t, c_t}  Σ_i q_t^i (τ_t^i − τ_bound) + V · H_t  (Eq. 34)
//! ```
//!
//! WAA evaluates Eq. (34) over candidate active sets with the staleness
//! *pre-updated* (Alg. 2 line 5), so the drift term sees the effect of
//! the activation decision.

/// Staleness of worker `i` after the round if `active` (Eq. 6).
pub fn staleness_after(tau: u64, active: bool) -> u64 {
    if active {
        0
    } else {
        tau + 1
    }
}

/// Drift-plus-penalty value of Eq. (34) for one candidate active set.
///
/// * `queues` — q_t^i for all workers
/// * `tau_next` — pre-updated staleness τ given the candidate A_t
/// * `tau_bound` — constraint (12c)
/// * `v` — trade-off weight V
/// * `h_round` — the candidate round duration H_t (Eq. 9)
pub fn drift_plus_penalty(
    queues: &[f64],
    tau_next: &[u64],
    tau_bound: u64,
    v: f64,
    h_round: f64,
) -> f64 {
    debug_assert_eq!(queues.len(), tau_next.len());
    let drift: f64 = queues
        .iter()
        .zip(tau_next)
        .map(|(&q, &t)| q * (t as f64 - tau_bound as f64))
        .sum();
    drift + v * h_round
}

/// Queue update (Eq. 33) over a whole staleness vector.
pub fn update_queues(queues: &mut [f64], tau: &[u64], tau_bound: u64) {
    for (q, &t) in queues.iter_mut().zip(tau) {
        *q = (*q + t as f64 - tau_bound as f64).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staleness_update_matches_eq6() {
        assert_eq!(staleness_after(4, true), 0);
        assert_eq!(staleness_after(4, false), 5);
        assert_eq!(staleness_after(0, false), 1);
    }

    #[test]
    fn queues_never_negative() {
        let mut q = vec![0.0, 1.0, 5.0];
        update_queues(&mut q, &[0, 0, 10], 3);
        assert_eq!(q, vec![0.0, 0.0, 12.0]);
    }

    #[test]
    fn queue_stability_under_bounded_staleness() {
        // if τ stays ≤ τ_bound forever, queues stay at 0 (Theorem 2's
        // stability precondition)
        let mut q = vec![0.0; 4];
        for t in 0..100u64 {
            let tau = [t % 3, t % 2, 0, (t % 4).min(3)];
            update_queues(&mut q, &tau, 3);
        }
        assert!(q.iter().all(|&x| x == 0.0), "{q:?}");
    }

    #[test]
    fn penalty_trades_off_with_v() {
        let queues = [2.0, 0.0];
        let tau_next = [6, 0];
        // drift = 2·(6−5) = 2
        let low_v = drift_plus_penalty(&queues, &tau_next, 5, 1.0, 3.0);
        let high_v = drift_plus_penalty(&queues, &tau_next, 5, 100.0, 3.0);
        assert!((low_v - (2.0 + 3.0)).abs() < 1e-12);
        assert!((high_v - (2.0 + 300.0)).abs() < 1e-12);
    }

    #[test]
    fn activating_stale_worker_reduces_objective() {
        // a worker far over bound with a hot queue should make activation
        // (τ→0) strictly better than skipping (τ+1)
        let queues = [10.0];
        let skip = drift_plus_penalty(&queues, &[8], 5, 1.0, 1.0);
        let act = drift_plus_penalty(&queues, &[0], 5, 1.0, 2.0);
        assert!(act < skip);
    }
}
