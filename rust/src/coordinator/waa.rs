//! Worker Activation Algorithm — Alg. 2 of the paper.
//!
//! Workers are sorted ascending by their estimated round cost H_t^i
//! (Eq. 8); prefixes of the sorted order are candidate active sets. For
//! each prefix the staleness vector is pre-updated (Eq. 6) and the
//! drift-plus-penalty objective (Eq. 34) evaluated; the minimising prefix
//! wins. Because the prefix is sorted by H_t^i, the candidate round
//! duration H_t is just the cost of the last added worker (Eq. 9), which
//! keeps the scan O(N log N + N·cost(Eq.34)) — and an incremental drift
//! update makes the whole scan O(N log N).

use super::lyapunov;
use super::SchedView;

/// Select the active set A_t (returns sorted worker ids).
pub fn waa_select(view: &SchedView<'_>) -> Vec<usize> {
    let n = view.n();
    debug_assert!(n > 0);
    let p = view.params;

    // O(N) fast path for the cold-queue regime (τ_bound loose enough
    // that no queue ever charges): every drift term is q_i·(…) = ±0.0,
    // summing to exactly +0.0, so the objective over sorted prefixes is
    // v·H_t — non-decreasing in k for v ≥ 0 — and the strict `<` scan
    // below would keep k = 1 with the stable sort's first minimum of
    // H_t^i. A strict `<` argmin reproduces that worker bit-exactly
    // without the O(N log N) sort.
    if p.v >= 0.0 && view.queues.iter().all(|&q| q == 0.0) {
        let mut best = 0;
        for i in 1..n {
            if view.h_est[i] < view.h_est[best] {
                best = i;
            }
        }
        return vec![best];
    }

    // Line 2: sort workers ascending by H_t^i.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| view.h_est[a].partial_cmp(&view.h_est[b]).unwrap());

    // Base drift: nobody activated — every worker's staleness pre-updates
    // to τ+1 (Eq. 6).
    let mut drift: f64 = (0..n)
        .map(|i| {
            view.queues[i]
                * (lyapunov::staleness_after(view.tau[i], false) as f64
                    - p.tau_bound as f64)
        })
        .sum();

    // Lines 3–8: grow the prefix, tracking the incremental drift.
    // Moving worker i from "skipped" to "active" changes its pre-updated
    // staleness from τ_i+1 to 0, i.e. drift −= q_i·(τ_i+1).
    let mut best_k = 1;
    let mut best_s = f64::INFINITY;
    for (k, &i) in order.iter().enumerate() {
        drift -= view.queues[i] * (view.tau[i] as f64 + 1.0);
        let h_round = view.h_est[i]; // sorted ⇒ max over prefix (Eq. 9)
        let s = drift + p.v * h_round;
        if s < best_s {
            best_s = s;
            best_k = k + 1;
        }
    }

    let mut active: Vec<usize> = order[..best_k].to_vec();
    active.sort_unstable();
    active
}

#[cfg(test)]
mod tests {
    use super::super::testutil::Fixture;
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg;

    /// Reference O(N²) implementation straight off Alg. 2 (no incremental
    /// drift) — the optimised scan must match it exactly.
    fn waa_reference(view: &SchedView<'_>) -> Vec<usize> {
        let n = view.n();
        let p = view.params;
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| view.h_est[a].partial_cmp(&view.h_est[b]).unwrap());
        let mut best: (f64, usize) = (f64::INFINITY, 1);
        for k in 1..=n {
            let active: std::collections::BTreeSet<usize> =
                order[..k].iter().copied().collect();
            let tau_next: Vec<u64> = (0..n)
                .map(|i| lyapunov::staleness_after(view.tau[i], active.contains(&i)))
                .collect();
            let h_round = order[..k]
                .iter()
                .map(|&i| view.h_est[i])
                .fold(0.0f64, f64::max);
            let s = lyapunov::drift_plus_penalty(
                view.queues,
                &tau_next,
                p.tau_bound,
                p.v,
                h_round,
            );
            if s < best.0 {
                best = (s, k);
            }
        }
        let mut active: Vec<usize> = order[..best.1].to_vec();
        active.sort_unstable();
        active
    }

    #[test]
    fn matches_reference_implementation() {
        forall(51, |rng| {
            let n = 2 + rng.below_usize(40);
            let fix = Fixture::random(n, rng);
            let view = fix.view();
            assert_eq!(waa_select(&view), waa_reference(&view));
        });
    }

    #[test]
    fn always_nonempty_and_in_range() {
        forall(52, |rng| {
            let n = 1 + rng.below_usize(50);
            let fix = Fixture::random(n, rng);
            let a = waa_select(&fix.view());
            assert!(!a.is_empty());
            assert!(a.iter().all(|&i| i < n));
            let mut d = a.clone();
            d.dedup();
            assert_eq!(d.len(), a.len());
        });
    }

    #[test]
    fn hot_queues_force_large_active_sets() {
        // when every queue is hot, activating everyone minimises drift
        let mut rng = Pcg::seeded(5);
        let mut fix = Fixture::random(12, &mut rng);
        fix.queues = vec![1000.0; 12];
        fix.tau = vec![10; 12];
        fix.params.v = 0.001;
        let a = waa_select(&fix.view());
        assert_eq!(a.len(), 12, "{a:?}");
    }

    #[test]
    fn huge_v_prefers_single_fast_worker() {
        // V → ∞ makes round duration dominate: pick exactly the fastest
        let mut rng = Pcg::seeded(6);
        let mut fix = Fixture::random(12, &mut rng);
        fix.queues = vec![0.01; 12];
        fix.params.v = 1e9;
        let a = waa_select(&fix.view());
        assert_eq!(a.len(), 1);
        let fastest = (0..12)
            .min_by(|&x, &y| fix.h_est[x].partial_cmp(&fix.h_est[y]).unwrap())
            .unwrap();
        assert_eq!(a[0], fastest);
    }

    #[test]
    fn cold_queues_still_activate_fastest() {
        // all queues zero ⇒ drift is 0 everywhere; smallest H wins
        let mut rng = Pcg::seeded(7);
        let mut fix = Fixture::random(8, &mut rng);
        fix.queues = vec![0.0; 8];
        let a = waa_select(&fix.view());
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn cold_queue_fast_path_matches_reference() {
        // the O(N) all-zero-queue shortcut must agree with the full
        // Alg. 2 scan, including h_est ties (stable-sort first minimum)
        forall(53, |rng| {
            let n = 2 + rng.below_usize(40);
            let mut fix = Fixture::random(n, rng);
            fix.queues = vec![0.0; n];
            if n >= 4 {
                // force ties to exercise the first-minimum rule
                fix.h_est[n - 1] = fix.h_est[1];
                fix.h_est[n / 2] = fix.h_est[1];
            }
            let view = fix.view();
            assert_eq!(waa_select(&view), waa_reference(&view));
        });
    }
}
