//! Testbed runtime — the §VII analog; legacy facade.
//!
//! **Deprecated:** the thread-per-worker runtime now lives in
//! [`crate::experiment`] as
//! [`ThreadedBackend`](crate::experiment::ThreadedBackend), consuming the
//! same shared setup ([`Experiment::builder`]) as the simulator instead
//! of duplicating it. [`run_testbed`] is kept as a thin wrapper with the
//! old panic-on-error semantics.
//!
//! ```no_run
//! // old: run_testbed(cfg, opts)
//! // new: Experiment::builder(cfg)
//! //          .backend_impl(Box::new(ThreadedBackend::with_options(opts)))
//! //          .run()?
//! ```

use crate::config::ExperimentConfig;
use crate::experiment::{Experiment, ThreadedBackend};
use crate::metrics::RunResult;

pub use crate::experiment::TestbedOptions;

/// Run a full testbed experiment; returns metrics like the simulator
/// (times are wall-clock seconds of the compressed run).
///
/// Deprecated: panics on invalid configs and backend failures — use
/// `Experiment::builder(cfg).backend_impl(...).run()` for a `Result`.
/// Behaviour change vs. the pre-builder implementation: configs asking
/// for a non-native trainer now panic here (the old code silently
/// trained with the native trainer regardless of `cfg.trainer`).
pub fn run_testbed(cfg: ExperimentConfig, opts: TestbedOptions) -> RunResult {
    Experiment::builder(cfg)
        .backend_impl(Box::new(ThreadedBackend::with_options(opts)))
        .run()
        .expect("testbed run failed")
}
