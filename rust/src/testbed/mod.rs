//! Testbed runtime — the §VII analog.
//!
//! Unlike the virtual-clock simulator, this mode actually runs one
//! OS thread per worker with real message passing and wall-clock delays:
//!
//! * each worker owns an **updating thread** (Alg. 1 lines 3–7) that
//!   reacts to EXECUTE messages: pull neighbor models, aggregate (Eq. 4),
//!   emulate heterogeneous compute (scaled sleep), train for real, publish
//!   the new model;
//! * the **pushing thread** role (lines 8–10) is played by a shared
//!   `Mutex<Published>` snapshot per worker — a pull locks the source's
//!   snapshot exactly like the paper's pushing thread serves the latest
//!   `w_{t−τ}^i`;
//! * the coordinator thread runs the same [`Scheduler`] implementations
//!   as the simulator and advances rounds on completions.
//!
//! Delays are the paper's §VI-A1 channel/compute model compressed by
//! `time_scale` (default 1000× — a 1 s training job sleeps 1 ms) so a
//! full run finishes in seconds while preserving relative asynchrony.

use crate::config::ExperimentConfig;
use crate::coordinator::{make_scheduler, SchedView, SchedulerParams};
use crate::data::{dirichlet_partition, make_corpus, Dataset, SyntheticSpec};
use crate::metrics::{EvalRecord, RoundRecord, RunResult};
use crate::network::EdgeNetwork;
use crate::util::rng::Pcg;
use crate::worker::{data_size_weights, NativeTrainer, Trainer};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Latest published model of one worker (what pulls observe).
struct Published {
    params: Vec<f32>,
    data_size: usize,
}

/// Coordinator → worker message.
enum Execute {
    /// Pull from these neighbors, then aggregate + train.
    Round { neighbors: Vec<usize>, pull_delays_ms: Vec<u64> },
    Shutdown,
}

/// Worker → coordinator completion report.
struct Done {
    id: usize,
    loss: f64,
}

/// Extra knobs for the testbed runtime.
#[derive(Clone, Copy, Debug)]
pub struct TestbedOptions {
    /// Virtual-seconds → real-milliseconds compression factor.
    pub time_scale: f64,
    /// Optional explicit per-worker speed multipliers (Table II profile);
    /// `None` draws from the config's normal jitter.
    pub profile: bool,
}

impl Default for TestbedOptions {
    fn default() -> Self {
        TestbedOptions { time_scale: 1000.0, profile: true }
    }
}

/// Run a full testbed experiment; returns metrics like the simulator
/// (times are wall-clock seconds of the compressed run).
pub fn run_testbed(cfg: ExperimentConfig, opts: TestbedOptions) -> RunResult {
    cfg.validate().expect("invalid config");
    let n = cfg.workers;
    let mut rng = Pcg::new(cfg.seed, 0x7E57);

    // --- data + network substrate (same as the simulator) ---
    let spec = SyntheticSpec {
        dim: cfg.feature_dim,
        num_classes: cfg.num_classes,
        train_samples: cfg.train_per_worker * n,
        test_samples: cfg.test_samples,
        class_sep: cfg.class_sep,
        seed: cfg.seed,
    };
    let (train, test) = make_corpus(&spec);
    let min_per = cfg.batch.max(cfg.train_per_worker / 4);
    let (shards, stats) = dirichlet_partition(&train, n, cfg.phi, min_per, &mut rng);
    let mut net = EdgeNetwork::new(n, cfg.network.clone(), &mut rng);

    // heterogeneous compute: explicit Table II profile or sampled
    let speeds: Vec<f64> = if opts.profile && n == 15 {
        crate::figures::testbed_profile_speeds()
    } else {
        (0..n)
            .map(|_| rng.normal_ms(0.0, cfg.compute_jitter).exp().recip())
            .collect()
    };
    let h_train: Vec<f64> =
        speeds.iter().map(|s| cfg.compute_mean_s / s).collect();

    // --- shared published models ---
    let trainer0 = NativeTrainer::new(cfg.feature_dim, cfg.num_classes);
    let published: Vec<Arc<Mutex<Published>>> = (0..n)
        .map(|i| {
            Arc::new(Mutex::new(Published {
                params: trainer0.init(cfg.seed.wrapping_add(i as u64)),
                data_size: shards[i].len(),
            }))
        })
        .collect();

    // --- spawn workers ---
    let (done_tx, done_rx) = mpsc::channel::<Done>();
    let mut exec_txs = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for (i, shard) in shards.into_iter().enumerate() {
        let (tx, rx) = mpsc::channel::<Execute>();
        exec_txs.push(tx);
        let done = done_tx.clone();
        let pubs: Vec<Arc<Mutex<Published>>> = published.clone();
        let my_h = h_train[i];
        let scale = opts.time_scale;
        let wcfg = cfg.clone();
        handles.push(thread::spawn(move || {
            worker_loop(i, shard, my_h, scale, &wcfg, pubs, rx, done)
        }));
    }
    drop(done_tx);

    // --- coordinator loop ---
    let mut scheduler = make_scheduler(cfg.scheduler);
    let mut eval_trainer = NativeTrainer::new(cfg.feature_dim, cfg.num_classes);
    let model_bits = if cfg.network.payload_bits > 0.0 {
        cfg.network.payload_bits
    } else {
        trainer0.param_count() as f64 * 32.0
    };
    let mut result = RunResult {
        label: format!("testbed-{}", scheduler.name()),
        model_bits,
        ..Default::default()
    };
    let mut tau = vec![0u64; n];
    let mut queues = vec![0.0f64; n];
    let mut residual = h_train.clone();
    let mut pulls = vec![vec![0u64; n]; n];
    let start = Instant::now();
    let mut cum_transfers = 0usize;

    for round in 1..=cfg.rounds {
        net.step(&mut rng);
        let candidates: Vec<Vec<usize>> = (0..n).map(|i| net.in_range(i)).collect();
        let h_est: Vec<f64> = (0..n)
            .map(|i| {
                let worst = candidates[i]
                    .iter()
                    .take(cfg.neighbor_cap)
                    .map(|&j| net.expected_transfer_time_s(j, i, model_bits))
                    .fold(0.0f64, f64::max);
                residual[i] + worst
            })
            .collect();
        let data_sizes: Vec<usize> =
            published.iter().map(|p| p.lock().unwrap().data_size).collect();
        let plan = {
            let view = SchedView {
                round,
                tau: &tau,
                queues: &queues,
                h_cmp: &residual,
                h_est: &h_est,
                data_sizes: &data_sizes,
                label_dist: &stats.label_distributions,
                candidates: &candidates,
                budgets: &net.budgets,
                pulls: &pulls,
                net: &net,
                params: SchedulerParams::from(&cfg),
            };
            scheduler.plan(&view, &mut rng)
        };
        debug_assert!(plan.validate(n).is_ok());

        // dispatch EXECUTE to the active workers with realised delays
        let round_t0 = Instant::now();
        for (k, &i) in plan.active.iter().enumerate() {
            let delays: Vec<u64> = plan.pulls_from[k]
                .iter()
                .map(|&j| {
                    let t = net.transfer_time_s(j, i, model_bits, &mut rng);
                    (t * opts.time_scale) as u64
                })
                .collect();
            for &j in &plan.pulls_from[k] {
                pulls[i][j] += 1;
            }
            exec_txs[i]
                .send(Execute::Round {
                    neighbors: plan.pulls_from[k].clone(),
                    pull_delays_ms: delays,
                })
                .expect("worker hung up");
        }

        // wait for completions (the synchronization point is per-plan,
        // matching the round abstraction of Alg. 1)
        let mut losses = Vec::with_capacity(plan.active.len());
        for _ in &plan.active {
            let d = done_rx.recv().expect("worker died");
            debug_assert!(plan.active.contains(&d.id));
            losses.push(d.loss);
        }
        let h_round = round_t0.elapsed().as_secs_f64();

        // staleness + queues + residual bookkeeping (Eqs. 6/33/7)
        let mut active_mask = vec![false; n];
        for &i in &plan.active {
            active_mask[i] = true;
        }
        let h_virtual = h_round / opts.time_scale * 1000.0; // ms→virtual s
        for i in 0..n {
            residual[i] = (residual[i] - h_virtual).max(0.0);
            if active_mask[i] {
                tau[i] = 0;
                residual[i] = h_train[i];
            } else {
                tau[i] += 1;
            }
            queues[i] = (queues[i] + tau[i] as f64 - cfg.tau_bound as f64).max(0.0);
        }

        let transfers = plan.transfers();
        cum_transfers += transfers;
        result.rounds.push(RoundRecord {
            round,
            time_s: start.elapsed().as_secs_f64(),
            duration_s: h_round,
            active: plan.active.len(),
            transfers,
            avg_staleness: tau.iter().sum::<u64>() as f64 / n as f64,
            max_staleness: tau.iter().copied().max().unwrap_or(0),
            train_loss: losses.iter().sum::<f64>() / losses.len().max(1) as f64,
        });

        if round % cfg.eval_every.max(1) == 0 || round == cfg.rounds {
            let mut acc_sum = 0.0;
            let mut loss_sum = 0.0;
            for p in &published {
                let params = p.lock().unwrap().params.clone();
                let (l, a) = eval_trainer.evaluate(&params, &test);
                acc_sum += a;
                loss_sum += l;
            }
            result.evals.push(EvalRecord {
                round,
                time_s: start.elapsed().as_secs_f64(),
                avg_accuracy: acc_sum / n as f64,
                avg_loss: loss_sum / n as f64,
                cum_transfers,
            });
        }
    }

    for tx in &exec_txs {
        let _ = tx.send(Execute::Shutdown);
    }
    for h in handles {
        let _ = h.join();
    }
    result
}

/// The per-worker updating thread (Alg. 1 lines 3–7).
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    id: usize,
    shard: Dataset,
    h_train_s: f64,
    time_scale: f64,
    cfg: &ExperimentConfig,
    published: Vec<Arc<Mutex<Published>>>,
    rx: mpsc::Receiver<Execute>,
    done: mpsc::Sender<Done>,
) {
    let mut trainer = NativeTrainer::new(cfg.feature_dim, cfg.num_classes);
    let mut rng = Pcg::new(cfg.seed ^ 0xBEEF, id as u64);
    while let Ok(msg) = rx.recv() {
        match msg {
            Execute::Shutdown => break,
            Execute::Round { neighbors, pull_delays_ms } => {
                // PULL: read each neighbor's published snapshot (the
                // "pushing thread" contract), paying the channel delay
                let mut models: Vec<Vec<f32>> = Vec::with_capacity(neighbors.len() + 1);
                let mut sizes: Vec<usize> = Vec::with_capacity(neighbors.len() + 1);
                {
                    let own = published[id].lock().unwrap();
                    models.push(own.params.clone());
                    sizes.push(own.data_size);
                }
                let worst_delay = pull_delays_ms.iter().copied().max().unwrap_or(0);
                for &j in &neighbors {
                    let p = published[j].lock().unwrap();
                    models.push(p.params.clone());
                    sizes.push(p.data_size);
                }
                // pulls happen in parallel → pay only the slowest link
                thread::sleep(Duration::from_millis(worst_delay));

                // aggregate (Eq. 4) + emulated heterogeneous compute
                let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
                let weights = data_size_weights(&sizes);
                let agg = trainer.aggregate(&refs, &weights);
                thread::sleep(Duration::from_millis(
                    (h_train_s * time_scale) as u64,
                ));
                // real local training (Eq. 5)
                let (new_params, loss) = trainer.train(
                    &agg,
                    &shard,
                    cfg.local_steps,
                    cfg.batch,
                    cfg.lr,
                    &mut rng,
                );
                published[id].lock().unwrap().params = new_params;
                let _ = done.send(Done { id, loss });
            }
        }
    }
}
