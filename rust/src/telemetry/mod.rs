//! Live telemetry: a zero-dependency metric registry on the wall-clock
//! plane.
//!
//! Everything here measures *host* time and host byte counts — never
//! virtual time. The simulation's event/byte ledger (RoundRecord,
//! DeliveryTally, clock_s) is the experiment's result; telemetry is how
//! much wall-clock the machinery spent producing it. The two planes
//! must not mix: no telemetry read ever feeds back into scheduling,
//! RNG, or payload bytes, which is what makes the `bits_eq`
//! telemetry-on == telemetry-off witnesses in `tests/telemetry.rs`
//! possible.
//!
//! The handle is `Option<Arc<Inner>>` under the hood: the disabled
//! default is a `None` check per call site — no clock reads, no atomic
//! traffic — so instrumentation can stay unconditionally inline in the
//! hot paths. All mutation is relaxed atomics, so one registry can be
//! shared across the threaded backend's workers and the HTTP scrape
//! thread without locks on the hot path.

pub mod hist;
pub mod server;
pub mod snapshot;

pub use hist::Hist;
pub use snapshot::TelemetrySink;

use hist::AtomicHist;
use server::ServerGuard;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Monotonic counters — one per event kind worth counting, across every
/// subsystem. Names render as `dystop_<name>_total`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(usize)]
pub enum Counter {
    /// SchedView rebuilt from scratch (dense rebuild path).
    SchedViewRebuilds,
    /// SchedView carried over / patched instead of rebuilt.
    SchedViewPatches,
    /// Codec encode calls (one per source worker per round).
    CodecEncodes,
    /// Codec decode calls.
    CodecDecodes,
    /// Encoded payload bytes produced by the codec.
    CodecBytes,
    /// Messages resolved by the delivery layer (any outcome).
    DeliveryMsgs,
    /// Retransmissions performed by the ack/retry layer.
    DeliveryRetries,
    /// Messages abandoned after exhausting the retry budget.
    DeliveryDeadLetters,
    /// Messages delivered with detected corruption.
    DeliveryCorrupt,
    /// Events drained from the discrete-event queue.
    EventsDrained,
    /// Rounds completed.
    Rounds,
    /// Worker activations executed.
    Activations,
    /// Training samples consumed (activations × per-worker batch).
    TrainSamples,
    /// Socket wire frames sent by the coordinator.
    WireFramesSent,
    /// Socket wire frames received by the coordinator.
    WireFramesRecv,
    /// Socket payload bytes sent by the coordinator.
    WireBytesSent,
    /// Socket payload bytes received by the coordinator.
    WireBytesRecv,
}

impl Counter {
    pub const ALL: [Counter; 17] = [
        Counter::SchedViewRebuilds,
        Counter::SchedViewPatches,
        Counter::CodecEncodes,
        Counter::CodecDecodes,
        Counter::CodecBytes,
        Counter::DeliveryMsgs,
        Counter::DeliveryRetries,
        Counter::DeliveryDeadLetters,
        Counter::DeliveryCorrupt,
        Counter::EventsDrained,
        Counter::Rounds,
        Counter::Activations,
        Counter::TrainSamples,
        Counter::WireFramesSent,
        Counter::WireFramesRecv,
        Counter::WireBytesSent,
        Counter::WireBytesRecv,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::SchedViewRebuilds => "sched_view_rebuilds",
            Counter::SchedViewPatches => "sched_view_patches",
            Counter::CodecEncodes => "codec_encodes",
            Counter::CodecDecodes => "codec_decodes",
            Counter::CodecBytes => "codec_bytes",
            Counter::DeliveryMsgs => "delivery_msgs",
            Counter::DeliveryRetries => "delivery_retries",
            Counter::DeliveryDeadLetters => "delivery_dead_letters",
            Counter::DeliveryCorrupt => "delivery_corrupt",
            Counter::EventsDrained => "events_drained",
            Counter::Rounds => "rounds",
            Counter::Activations => "activations",
            Counter::TrainSamples => "train_samples",
            Counter::WireFramesSent => "wire_frames_sent",
            Counter::WireFramesRecv => "wire_frames_recv",
            Counter::WireBytesSent => "wire_bytes_sent",
            Counter::WireBytesRecv => "wire_bytes_recv",
        }
    }

    pub fn help(self) -> &'static str {
        match self {
            Counter::SchedViewRebuilds => "SchedView full rebuilds",
            Counter::SchedViewPatches => "SchedView incremental patches (rebuild skipped)",
            Counter::CodecEncodes => "codec encode calls",
            Counter::CodecDecodes => "codec decode calls",
            Counter::CodecBytes => "encoded payload bytes produced",
            Counter::DeliveryMsgs => "messages resolved by the delivery layer",
            Counter::DeliveryRetries => "retransmissions by the ack/retry layer",
            Counter::DeliveryDeadLetters => "messages dead-lettered after retry budget",
            Counter::DeliveryCorrupt => "messages delivered corrupt",
            Counter::EventsDrained => "events drained from the discrete-event queue",
            Counter::Rounds => "rounds completed",
            Counter::Activations => "worker activations executed",
            Counter::TrainSamples => "training samples consumed",
            Counter::WireFramesSent => "socket frames sent by the coordinator",
            Counter::WireFramesRecv => "socket frames received by the coordinator",
            Counter::WireBytesSent => "socket payload bytes sent",
            Counter::WireBytesRecv => "socket payload bytes received",
        }
    }
}

/// Instantaneous gauges. Names render as `dystop_<name>`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(usize)]
pub enum Gauge {
    /// Discrete-event queue depth at the last drain.
    EventQueueDepth,
    /// Event-queue drain rate at the last drain (events/s wall).
    EventDrainRate,
    /// Training throughput over the last round (samples/s wall).
    TrainThroughput,
    /// Current worker population.
    Population,
    /// Virtual clock of the run (seconds) — exported for correlation
    /// only; never read back.
    ClockVirtualS,
}

impl Gauge {
    pub const ALL: [Gauge; 5] = [
        Gauge::EventQueueDepth,
        Gauge::EventDrainRate,
        Gauge::TrainThroughput,
        Gauge::Population,
        Gauge::ClockVirtualS,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Gauge::EventQueueDepth => "event_queue_depth",
            Gauge::EventDrainRate => "event_drain_rate",
            Gauge::TrainThroughput => "train_throughput",
            Gauge::Population => "population",
            Gauge::ClockVirtualS => "clock_virtual_s",
        }
    }

    pub fn help(self) -> &'static str {
        match self {
            Gauge::EventQueueDepth => "discrete-event queue depth at last drain",
            Gauge::EventDrainRate => "event drain rate at last drain (events/s)",
            Gauge::TrainThroughput => "train throughput last round (samples/s)",
            Gauge::Population => "current worker population",
            Gauge::ClockVirtualS => "virtual clock of the run (s)",
        }
    }
}

/// Wall-clock phase timings, one log-linear histogram each (values in
/// nanoseconds). Rendered as one Prometheus histogram family
/// `dystop_phase_ns{phase="<name>"}`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(usize)]
pub enum Phase {
    /// WAA worker-activation selection inside the scheduler.
    Waa,
    /// PTCA topology construction inside the scheduler.
    Ptca,
    /// SchedView full rebuild.
    ViewRebuild,
    /// SchedView patch/carry-over path.
    ViewPatch,
    /// Codec encode of one worker payload.
    CodecEncode,
    /// Codec decode of one worker payload.
    CodecDecode,
    /// One aggregation call (rule set via run-info label).
    Aggregate,
    /// One local training call.
    Train,
    /// One full round, coordinator-side.
    Round,
    /// One event-queue drain.
    EventDrain,
    /// Socket EXECUTE→DONE round trip per activation.
    WireRtt,
}

impl Phase {
    pub const ALL: [Phase; 11] = [
        Phase::Waa,
        Phase::Ptca,
        Phase::ViewRebuild,
        Phase::ViewPatch,
        Phase::CodecEncode,
        Phase::CodecDecode,
        Phase::Aggregate,
        Phase::Train,
        Phase::Round,
        Phase::EventDrain,
        Phase::WireRtt,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Waa => "waa",
            Phase::Ptca => "ptca",
            Phase::ViewRebuild => "view_rebuild",
            Phase::ViewPatch => "view_patch",
            Phase::CodecEncode => "codec_encode",
            Phase::CodecDecode => "codec_decode",
            Phase::Aggregate => "aggregate",
            Phase::Train => "train",
            Phase::Round => "round",
            Phase::EventDrain => "event_drain",
            Phase::WireRtt => "wire_rtt",
        }
    }
}

struct Inner {
    counters: Vec<AtomicU64>,
    gauges: Vec<AtomicU64>, // f64 bit patterns
    hists: Vec<AtomicHist>,
    /// Static run labels (scheduler, aggregator, backend, …) exported
    /// as `dystop_run_info{...} 1`.
    info: Mutex<Vec<(String, String)>>,
    /// Keeps the /metrics server alive for the registry's lifetime.
    server: Mutex<Option<ServerGuard>>,
    started: Instant,
}

impl Inner {
    fn new() -> Self {
        Inner {
            counters: (0..Counter::ALL.len()).map(|_| AtomicU64::new(0)).collect(),
            gauges: (0..Gauge::ALL.len()).map(|_| AtomicU64::new(0)).collect(),
            hists: (0..Phase::ALL.len()).map(|_| AtomicHist::default()).collect(),
            info: Mutex::new(Vec::new()),
            server: Mutex::new(None),
            started: Instant::now(),
        }
    }
}

/// An opaque wall-clock timestamp from [`Telemetry::tick`]. Carries
/// `None` when telemetry is disabled so the hot path never reads the
/// clock it won't use.
#[derive(Clone, Copy)]
pub struct Tick(Option<Instant>);

/// The telemetry handle threaded through the builder into every
/// backend. Cheap to clone (one `Option<Arc>`); `disabled()` makes
/// every method a branch-and-return.
#[derive(Clone, Default)]
pub struct Telemetry {
    inner: Option<Arc<Inner>>,
}

impl Telemetry {
    /// The inert default: every call is a `None` check.
    pub fn disabled() -> Self {
        Telemetry { inner: None }
    }

    /// A live registry.
    pub fn enabled() -> Self {
        Telemetry { inner: Some(Arc::new(Inner::new())) }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    #[inline]
    pub fn add(&self, c: Counter, n: u64) {
        if let Some(i) = &self.inner {
            i.counters[c as usize].fetch_add(n, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn inc(&self, c: Counter) {
        self.add(c, 1);
    }

    #[inline]
    pub fn set_gauge(&self, g: Gauge, v: f64) {
        if let Some(i) = &self.inner {
            i.gauges[g as usize].store(v.to_bits(), Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn observe_ns(&self, p: Phase, ns: u64) {
        if let Some(i) = &self.inner {
            i.hists[p as usize].observe(ns);
        }
    }

    /// Start a wall-clock measurement. No-op (no clock read) when
    /// disabled.
    #[inline]
    pub fn tick(&self) -> Tick {
        Tick(if self.inner.is_some() { Some(Instant::now()) } else { None })
    }

    /// Record the elapsed time since `t` into phase `p`.
    #[inline]
    pub fn tock(&self, p: Phase, t: Tick) {
        if let (Some(i), Some(t0)) = (&self.inner, t.0) {
            let ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
            i.hists[p as usize].observe(ns);
        }
    }

    /// Seconds since `t`, for derived rates (0.0 when disabled — always
    /// guard the division).
    #[inline]
    pub fn elapsed_s(&self, t: Tick) -> f64 {
        t.0.map(|t0| t0.elapsed().as_secs_f64()).unwrap_or(0.0)
    }

    /// Attach a static run label for `dystop_run_info`.
    pub fn set_info(&self, key: &str, value: &str) {
        if let Some(i) = &self.inner {
            let mut info = i.info.lock().unwrap();
            if let Some(slot) = info.iter_mut().find(|(k, _)| k == key) {
                slot.1 = value.to_string();
            } else {
                info.push((key.to_string(), value.to_string()));
            }
        }
    }

    // ---- reads ----

    pub fn counter(&self, c: Counter) -> u64 {
        self.inner
            .as_ref()
            .map(|i| i.counters[c as usize].load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn gauge(&self, g: Gauge) -> f64 {
        self.inner
            .as_ref()
            .map(|i| f64::from_bits(i.gauges[g as usize].load(Ordering::Relaxed)))
            .unwrap_or(0.0)
    }

    pub fn hist(&self, p: Phase) -> Hist {
        self.inner
            .as_ref()
            .map(|i| i.hists[p as usize].snapshot())
            .unwrap_or_default()
    }

    pub fn uptime_s(&self) -> f64 {
        self.inner
            .as_ref()
            .map(|i| i.started.elapsed().as_secs_f64())
            .unwrap_or(0.0)
    }

    /// Start the /metrics HTTP server on `addr` (host:port, port 0 for
    /// ephemeral). Returns the bound address. The server lives until
    /// the last handle to this registry drops.
    pub fn serve(&self, addr: &str) -> Result<SocketAddr, String> {
        let inner = self
            .inner
            .as_ref()
            .ok_or_else(|| "telemetry.addr set but telemetry is disabled".to_string())?;
        let guard = ServerGuard::spawn(addr, Arc::downgrade(inner))?;
        let bound = guard.addr();
        *inner.server.lock().unwrap() = Some(guard);
        Ok(bound)
    }

    /// The bound /metrics address, if a server is running.
    pub fn server_addr(&self) -> Option<SocketAddr> {
        self.inner
            .as_ref()
            .and_then(|i| i.server.lock().unwrap().as_ref().map(|g| g.addr()))
    }

    // ---- exposition ----

    /// Prometheus text exposition (version 0.0.4) of the whole
    /// registry. Histogram families are down-sampled to octave (`le` =
    /// power-of-two) boundaries — cumulative counts at those edges are
    /// exact because the full bucket edges subdivide them.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::with_capacity(8192);
        let info = self
            .inner
            .as_ref()
            .map(|i| i.info.lock().unwrap().clone())
            .unwrap_or_default();
        out.push_str("# HELP dystop_run_info static run labels\n");
        out.push_str("# TYPE dystop_run_info gauge\n");
        out.push_str("dystop_run_info{");
        for (k, (key, val)) in info.iter().enumerate() {
            if k > 0 {
                out.push(',');
            }
            out.push_str(key);
            out.push_str("=\"");
            out.push_str(&val.replace('\\', "\\\\").replace('"', "\\\""));
            out.push('"');
        }
        out.push_str("} 1\n");

        for c in Counter::ALL {
            let name = c.name();
            out.push_str(&format!(
                "# HELP dystop_{name}_total {}\n# TYPE dystop_{name}_total counter\ndystop_{name}_total {}\n",
                c.help(),
                self.counter(c)
            ));
        }
        for g in Gauge::ALL {
            let name = g.name();
            out.push_str(&format!(
                "# HELP dystop_{name} {}\n# TYPE dystop_{name} gauge\ndystop_{name} {}\n",
                g.help(),
                fmt_f64(self.gauge(g))
            ));
        }

        out.push_str("# HELP dystop_phase_ns wall-clock phase timings (ns)\n");
        out.push_str("# TYPE dystop_phase_ns histogram\n");
        for p in Phase::ALL {
            let h = self.hist(p);
            let phase = p.name();
            // Down-sample to `le = 2^k - 1` edges up to the highest
            // occupied bucket. Values are integers and every octave
            // starts at a power of two, so the cumulative count of
            // values <= 2^k - 1 is exactly the sum of all buckets below
            // the 2^k boundary — no approximation in the exposition.
            let highest = h
                .buckets
                .iter()
                .rposition(|&c| c > 0)
                .map(hist::bucket_upper)
                .unwrap_or(0);
            let mut cum = 0u64;
            let mut next_pow = 1u64;
            for (i, &c) in h.buckets.iter().enumerate() {
                let lower = hist::bucket_lower(i);
                if lower >= highest {
                    break;
                }
                while next_pow <= lower {
                    out.push_str(&format!(
                        "dystop_phase_ns_bucket{{phase=\"{phase}\",le=\"{}\"}} {cum}\n",
                        next_pow - 1
                    ));
                    next_pow = next_pow.saturating_mul(2);
                }
                cum += c;
            }
            out.push_str(&format!(
                "dystop_phase_ns_bucket{{phase=\"{phase}\",le=\"+Inf\"}} {}\n",
                h.count
            ));
            out.push_str(&format!(
                "dystop_phase_ns_sum{{phase=\"{phase}\"}} {}\n",
                h.sum
            ));
            out.push_str(&format!(
                "dystop_phase_ns_count{{phase=\"{phase}\"}} {}\n",
                h.count
            ));
        }
        out.push_str(&format!(
            "# HELP dystop_uptime_seconds wall-clock since registry creation\n# TYPE dystop_uptime_seconds gauge\ndystop_uptime_seconds {}\n",
            fmt_f64(self.uptime_s())
        ));
        out
    }

    /// One JSONL snapshot line: counters and gauges verbatim, each
    /// phase histogram summarised to count/sum/p50/p90/p99/max.
    pub fn snapshot_json(&self, round: usize) -> String {
        let mut s = String::with_capacity(2048);
        s.push_str(&format!(
            "{{\"kind\":\"telemetry\",\"round\":{round},\"wall_s\":{}",
            fmt_f64(self.uptime_s())
        ));
        s.push_str(",\"counters\":{");
        for (k, c) in Counter::ALL.iter().enumerate() {
            if k > 0 {
                s.push(',');
            }
            s.push_str(&format!("\"{}\":{}", c.name(), self.counter(*c)));
        }
        s.push_str("},\"gauges\":{");
        for (k, g) in Gauge::ALL.iter().enumerate() {
            if k > 0 {
                s.push(',');
            }
            s.push_str(&format!("\"{}\":{}", g.name(), fmt_f64(self.gauge(*g))));
        }
        s.push_str("},\"phases\":{");
        for (k, p) in Phase::ALL.iter().enumerate() {
            if k > 0 {
                s.push(',');
            }
            let h = self.hist(*p);
            s.push_str(&format!(
                "\"{}\":{{\"count\":{},\"sum\":{},\"p50\":{},\"p90\":{},\"p99\":{},\"max\":{}}}",
                p.name(),
                h.count,
                h.sum,
                h.quantile(0.50).unwrap_or(0),
                h.quantile(0.90).unwrap_or(0),
                h.quantile(0.99).unwrap_or(0),
                h.quantile(1.0).unwrap_or(0),
            ));
        }
        s.push_str("}}");
        s
    }
}

/// JSON/Prometheus-safe float formatting: finite values print plainly,
/// non-finite degrade to 0 (snapshots must stay parseable).
fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_handle_is_inert() {
        let t = Telemetry::disabled();
        t.inc(Counter::Rounds);
        t.set_gauge(Gauge::Population, 42.0);
        t.observe_ns(Phase::Round, 100);
        let tick = t.tick();
        t.tock(Phase::Round, tick);
        assert_eq!(t.counter(Counter::Rounds), 0);
        assert_eq!(t.gauge(Gauge::Population), 0.0);
        assert!(t.hist(Phase::Round).is_empty());
    }

    #[test]
    fn counters_gauges_hists_round_trip() {
        let t = Telemetry::enabled();
        t.add(Counter::CodecBytes, 128);
        t.inc(Counter::CodecEncodes);
        t.set_gauge(Gauge::TrainThroughput, 123.5);
        t.observe_ns(Phase::Train, 1_000);
        t.observe_ns(Phase::Train, 2_000);
        assert_eq!(t.counter(Counter::CodecBytes), 128);
        assert_eq!(t.counter(Counter::CodecEncodes), 1);
        assert_eq!(t.gauge(Gauge::TrainThroughput), 123.5);
        assert_eq!(t.hist(Phase::Train).count, 2);
    }

    #[test]
    fn prometheus_exposition_has_every_family() {
        let t = Telemetry::enabled();
        t.set_info("scheduler", "dystop");
        t.inc(Counter::Rounds);
        t.observe_ns(Phase::Waa, 5_000);
        let text = t.render_prometheus();
        assert!(text.contains("dystop_run_info{scheduler=\"dystop\"} 1"));
        for c in Counter::ALL {
            assert!(
                text.contains(&format!("dystop_{}_total", c.name())),
                "missing counter {}",
                c.name()
            );
        }
        for g in Gauge::ALL {
            assert!(text.contains(&format!("dystop_{}", g.name())));
        }
        for p in Phase::ALL {
            assert!(
                text.contains(&format!("dystop_phase_ns_count{{phase=\"{}\"}}", p.name())),
                "missing phase {}",
                p.name()
            );
        }
        // histogram invariants on the populated family
        assert!(text.contains("dystop_phase_ns_bucket{phase=\"waa\",le=\"+Inf\"} 1"));
        assert!(text.contains("dystop_phase_ns_sum{phase=\"waa\"} 5000"));
    }

    #[test]
    fn snapshot_json_parses() {
        let t = Telemetry::enabled();
        t.inc(Counter::Activations);
        t.observe_ns(Phase::Round, 7_777);
        let line = t.snapshot_json(3);
        let j = crate::util::json::Json::parse(&line).expect("snapshot line must parse");
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("telemetry"));
        assert_eq!(j.get("round").and_then(|v| v.as_f64()), Some(3.0));
        let counters = j.get("counters").expect("counters");
        assert_eq!(
            counters.get("activations").and_then(|v| v.as_f64()),
            Some(1.0)
        );
        let phases = j.get("phases").expect("phases");
        let round = phases.get("round").expect("round phase");
        assert_eq!(round.get("count").and_then(|v| v.as_f64()), Some(1.0));
    }
}
