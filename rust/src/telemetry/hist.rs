//! Log-linear histograms: fixed mergeable buckets, quantiles without
//! samples.
//!
//! The bucket layout is HdrHistogram-shaped: 8 linear sub-buckets per
//! power-of-two octave. Values below 16 land in exact unit buckets;
//! above that, bucket width is `2^(msb-3)` — at most 1/8 of the value —
//! so any quantile read off the bucket edges is within one bucket width
//! (≤ 12.5% relative error) of the exact order statistic. The layout is
//! a pure function of the value, so two histograms (from two threads,
//! two runs, two snapshots) merge by element-wise bucket addition, and
//! merging is associative and commutative by construction.
//!
//! Two forms share the layout:
//!
//! * [`AtomicHist`] — the live registry storage: relaxed atomic
//!   fetch-adds, safe to hammer from worker threads;
//! * [`Hist`] — a plain snapshot for math (merge, quantiles, JSON).

use std::sync::atomic::{AtomicU64, Ordering};

/// log2 of the sub-buckets per octave.
const SUB_BITS: u32 = 3;
/// Linear sub-buckets per octave (8).
pub const SUBS: usize = 1 << SUB_BITS;
/// Total buckets: 2·SUBS exact unit buckets for values < 2^(SUB_BITS+1),
/// then SUBS per octave for msb in SUB_BITS+1 ..= 63.
pub const BUCKETS: usize = 2 * SUBS + (63 - SUB_BITS as usize) * SUBS;

/// Bucket index of a value — monotone non-decreasing in `v`, total over
/// all of `u64`.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < (2 * SUBS) as u64 {
        // exact unit buckets: bucket i holds exactly {i}
        v as usize
    } else {
        let msb = 63 - v.leading_zeros(); // >= SUB_BITS + 1
        let shift = msb - SUB_BITS;
        let sub = ((v >> shift) as usize) & (SUBS - 1);
        // octave msb contributes SUBS buckets starting at msb * SUBS
        (msb as usize - SUB_BITS as usize + 1) * SUBS + sub
    }
}

/// Inclusive lower edge of bucket `i` (the smallest value that maps to
/// it).
#[inline]
pub fn bucket_lower(i: usize) -> u64 {
    if i < 2 * SUBS {
        i as u64
    } else {
        let g = i / SUBS; // >= 2
        let sub = (i % SUBS) as u64;
        let msb = (g - 1) as u32 + SUB_BITS;
        (1u64 << msb) + (sub << (msb - SUB_BITS))
    }
}

/// Exclusive upper edge of bucket `i` (`bucket_lower(i+1)` for every
/// non-terminal bucket; the last bucket saturates at `u64::MAX`).
#[inline]
pub fn bucket_upper(i: usize) -> u64 {
    if i + 1 >= BUCKETS {
        u64::MAX
    } else {
        bucket_lower(i + 1)
    }
}

/// A plain (non-atomic) histogram snapshot: mergeable buckets plus the
/// exact count and sum.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Hist {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist { buckets: vec![0; BUCKETS], count: 0, sum: 0 }
    }
}

impl Hist {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }

    /// Element-wise bucket addition — associative and commutative, so
    /// per-thread or per-shard histograms fold in any order.
    pub fn merge(&mut self, other: &Hist) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean of the recorded values (exact — from the running sum).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// The q-quantile (q in [0,1]) read off the bucket edges: the upper
    /// edge of the bucket holding the order statistic of rank
    /// `ceil(q·count)`. Within one bucket width of the exact sample
    /// quantile by construction. `None` on an empty histogram.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let rank = ((q * self.count as f64).ceil() as u64)
            .clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // report the last value the bucket can hold (upper edge
                // is exclusive), saturating on the terminal bucket
                return Some(bucket_upper(i).saturating_sub(1).max(bucket_lower(i)));
            }
        }
        None // unreachable when count > 0
    }
}

/// The live, thread-safe form: relaxed atomics throughout. Telemetry is
/// monotone counting — no read-modify-write invariants — so `Relaxed`
/// is sufficient and keeps the hot path to one `lock xadd` per field.
pub struct AtomicHist {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for AtomicHist {
    fn default() -> Self {
        AtomicHist {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl AtomicHist {
    #[inline]
    pub fn observe(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Hist {
        Hist {
            buckets: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_buckets_are_exact() {
        for v in 0..(2 * SUBS as u64) {
            let i = bucket_index(v);
            assert_eq!(bucket_lower(i), v);
            assert_eq!(bucket_upper(i), v + 1);
        }
    }

    #[test]
    fn edges_tile_the_line() {
        // lower edges strictly increase and each bucket's upper edge is
        // the next bucket's lower edge — no gaps, no overlaps
        for i in 0..BUCKETS - 1 {
            assert!(bucket_lower(i) < bucket_lower(i + 1), "bucket {i}");
            assert_eq!(bucket_upper(i), bucket_lower(i + 1), "bucket {i}");
        }
    }

    #[test]
    fn index_inverts_edges() {
        for i in 0..BUCKETS {
            assert_eq!(bucket_index(bucket_lower(i)), i);
            let last = bucket_upper(i).saturating_sub(1);
            assert_eq!(bucket_index(last), i, "upper-1 of bucket {i}");
        }
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn relative_width_is_bounded() {
        // beyond the unit buckets, width ≤ lower/8
        for i in 2 * SUBS..BUCKETS - 1 {
            let lo = bucket_lower(i);
            let w = bucket_upper(i) - lo;
            assert!(w * 8 <= lo, "bucket {i}: width {w} lower {lo}");
        }
    }

    #[test]
    fn atomic_and_plain_agree() {
        let a = AtomicHist::default();
        let mut h = Hist::new();
        for v in [0, 1, 7, 8, 100, 1_000_000, u64::MAX] {
            a.observe(v);
            h.record(v);
        }
        assert_eq!(a.snapshot(), h);
    }
}
