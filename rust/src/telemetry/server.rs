//! The /metrics HTTP endpoint: std-`TcpListener` only, same zero-dep
//! discipline as `experiment/socket.rs`.
//!
//! One background thread, nonblocking accept with a 5 ms poll. The
//! thread holds only a `Weak` to the registry — the registry owns the
//! guard, so a strong reference here would be a cycle and the server
//! (and registry) would never shut down. Each scrape upgrades the Weak
//! for the duration of one render; once the last real handle drops the
//! upgrade fails and the thread exits on the stop flag set by
//! [`ServerGuard::drop`].

use super::{Inner, Telemetry};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Weak};
use std::thread::JoinHandle;
use std::time::Duration;

pub struct ServerGuard {
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
    addr: SocketAddr,
}

impl ServerGuard {
    pub(super) fn spawn(addr: &str, registry: Weak<Inner>) -> Result<ServerGuard, String> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| format!("telemetry.addr {addr}: bind failed: {e}"))?;
        let bound = listener
            .local_addr()
            .map_err(|e| format!("telemetry.addr {addr}: no local addr: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("telemetry.addr {addr}: nonblocking failed: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let join = std::thread::Builder::new()
            .name("dystop-metrics".to_string())
            .spawn(move || serve_loop(listener, registry, stop2))
            .map_err(|e| format!("telemetry server thread: {e}"))?;
        Ok(ServerGuard { stop, join: Some(join), addr: bound })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for ServerGuard {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn serve_loop(listener: TcpListener, registry: Weak<Inner>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                // scrape errors must never take the run down
                let _ = handle(stream, &registry);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
        // a dead registry means the run is gone — no reason to linger
        if registry.strong_count() == 0 {
            break;
        }
    }
}

fn handle(mut stream: TcpStream, registry: &Weak<Inner>) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_millis(500)))?;
    // read the request head (just enough for the request line)
    let mut buf = [0u8; 2048];
    let mut used = 0;
    loop {
        let n = stream.read(&mut buf[used..])?;
        if n == 0 {
            break;
        }
        used += n;
        if buf[..used].windows(4).any(|w| w == b"\r\n\r\n") || used == buf.len() {
            break;
        }
    }
    let head = String::from_utf8_lossy(&buf[..used]);
    let path = head
        .lines()
        .next()
        .and_then(|line| line.split_whitespace().nth(1))
        .unwrap_or("/");

    match path {
        "/metrics" => {
            let body = match registry.upgrade() {
                Some(arc) => Telemetry { inner: Some(arc) }.render_prometheus(),
                None => String::new(),
            };
            write_response(
                &mut stream,
                "200 OK",
                "text/plain; version=0.0.4; charset=utf-8",
                body.as_bytes(),
            )
        }
        "/" => write_response(
            &mut stream,
            "200 OK",
            "text/plain; charset=utf-8",
            b"dystop telemetry: scrape /metrics\n",
        ),
        _ => write_response(&mut stream, "404 Not Found", "text/plain", b"not found\n"),
    }
}

fn write_response(
    stream: &mut TcpStream,
    status: &str,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{Counter, Telemetry};

    fn get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).expect("connect");
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).expect("read");
        let split = out.find("\r\n\r\n").expect("header/body split");
        (out[..split].to_string(), out[split + 4..].to_string())
    }

    #[test]
    fn serves_metrics_and_404s() {
        let tel = Telemetry::enabled();
        tel.inc(Counter::Rounds);
        let addr = tel.serve("127.0.0.1:0").expect("serve");
        let (head, body) = get(addr, "/metrics");
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert!(head.contains("text/plain; version=0.0.4"));
        assert!(body.contains("dystop_rounds_total 1"), "{body}");
        let (head, _) = get(addr, "/nope");
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");
    }

    #[test]
    fn server_shuts_down_with_registry() {
        let tel = Telemetry::enabled();
        let addr = tel.serve("127.0.0.1:0").expect("serve");
        drop(tel);
        // the guard's Drop joined the thread; a fresh connect may still
        // succeed (OS backlog) but a scrape can't produce a registry
        std::thread::sleep(Duration::from_millis(20));
        if let Ok(mut s) = TcpStream::connect(addr) {
            let _ = write!(s, "GET /metrics HTTP/1.1\r\n\r\n");
            let mut out = String::new();
            let _ = s.read_to_string(&mut out);
            assert!(!out.contains("dystop_rounds_total 1"));
        }
    }
}
