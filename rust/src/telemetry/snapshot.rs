//! Periodic JSONL telemetry snapshots (`telemetry.out`,
//! `telemetry.snapshot_every`): a [`RoundObserver`] riding the same
//! sink machinery as the metrics streams. One line per snapshot (see
//! [`Telemetry::snapshot_json`]); the final line at run end is
//! unconditional so `dystop report` always has a complete summary to
//! render.

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;

use super::Telemetry;
use crate::experiment::RoundObserver;
use crate::metrics::RoundRecord;

pub struct TelemetrySink {
    tel: Telemetry,
    out: BufWriter<File>,
    every: usize,
    err: Option<io::Error>,
}

impl TelemetrySink {
    pub fn create(tel: Telemetry, path: &Path, every: usize) -> io::Result<Self> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let out = BufWriter::new(File::create(path)?);
        Ok(TelemetrySink { tel, out, every, err: None })
    }

    fn write_line(&mut self, round: usize) {
        if self.err.is_some() {
            return;
        }
        let line = self.tel.snapshot_json(round);
        // snapshots are rare (every N rounds) — flush each one so the
        // live artifact stays current for mid-run scrapes/uploads
        let r = writeln!(self.out, "{line}").and_then(|_| self.out.flush());
        if let Err(e) = r {
            self.err = Some(e);
        }
    }
}

impl RoundObserver for TelemetrySink {
    fn on_round_end(&mut self, rec: &RoundRecord) {
        if self.every > 0 && rec.round % self.every == 0 {
            self.write_line(rec.round);
        }
    }

    fn on_run_end(&mut self) -> Result<(), String> {
        let final_round = self.tel.counter(super::Counter::Rounds) as usize;
        self.write_line(final_round);
        match self.err.take() {
            Some(e) => Err(format!("telemetry sink: {e}")),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Counter;

    fn round_rec(round: usize) -> RoundRecord {
        RoundRecord {
            round,
            time_s: round as f64,
            duration_s: 1.0,
            active: 2,
            population: 4,
            adversaries: 0,
            transfers: 3,
            bytes_sent: 24.0,
            avg_staleness: 0.5,
            max_staleness: 1,
            train_loss: 0.9,
            retransmissions: 0,
            dropped_msgs: 0,
            corrupt_detected: 0,
        }
    }

    #[test]
    fn snapshots_every_n_rounds_plus_final() {
        let dir = std::env::temp_dir().join("dystop_tel_snapshot_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("tel.jsonl");
        let tel = Telemetry::enabled();
        let mut sink = TelemetrySink::create(tel.clone(), &path, 2).unwrap();
        for t in 1..=5 {
            tel.inc(Counter::Rounds);
            sink.on_round_end(&round_rec(t));
        }
        sink.on_run_end().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // rounds 2 and 4 snapshot, plus the unconditional final line
        assert_eq!(lines.len(), 3, "{text}");
        for l in &lines {
            let j = crate::util::json::Json::parse(l).expect("parseable");
            assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("telemetry"));
        }
        assert!(lines[2].contains("\"round\":5"), "{}", lines[2]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn write_errors_surface_at_run_end() {
        // /dev/full accepts the open but fails every flush with ENOSPC
        let tel = Telemetry::enabled();
        let mut sink =
            TelemetrySink::create(tel, Path::new("/dev/full"), 1).unwrap();
        sink.on_round_end(&round_rec(1));
        let err = sink.on_run_end().expect_err("ENOSPC must surface");
        assert!(err.contains("telemetry sink"), "{err}");
    }
}
