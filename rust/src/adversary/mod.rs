//! Adversary subsystem: Byzantine worker policies + robust aggregation.
//!
//! DySTop's convergence story assumes every neighbor serves an honest
//! model; ADFL's peer-to-peer aggregation is exactly where poisoned or
//! stale-bombed models do the most damage. This module supplies the two
//! halves of the robustness axis:
//!
//! * **Attack policies** ([`AdversaryPolicy`]) — a per-worker behavior
//!   assigned deterministically from the `adversary.*` knobs (a
//!   `⌊frac·n⌋`-sized cast drawn on a dedicated RNG stream, so the
//!   assignment never perturbs substrate construction) or scripted via
//!   `ExperimentBuilder::adversary`. Attacks apply at the
//!   **model-exchange boundary**: the coordinator routes every outgoing
//!   payload through [`Adversary::transmit`] before it is encoded by the
//!   transport codec, so schedulers, codecs, byte accounting and
//!   scenario events all see poisoned payloads with no special-casing.
//!   The one exception is `labelflip`, which poisons the attacker's
//!   *shard* at build time and then trains honestly — the poison flows
//!   through the ordinary training path in both backends.
//! * **Robust aggregators** ([`Aggregator`]) — the coordinator-side
//!   aggregation rule (`adversary.aggregator`): `mean` is the current
//!   bit-identical `Trainer::aggregate` path; `trimmed-mean`,
//!   `median` and `krum` are the classic Byzantine-robust rules,
//!   composable with every codec's per-sender reconstruction slices and
//!   every `workload.model` (they operate on flattened parameter
//!   vectors only).
//!
//! The default (`adversary.frac=0` × `aggregator=mean`) is inert:
//! [`Adversary::is_active`] is `false`, both engines skip every
//! adversary branch, and runs stay bit-identical to the pre-adversary
//! engine.

use crate::config::{AdversaryConfig, AggregatorKind, AttackKind};
use crate::util::rng::Pcg;
use crate::worker::{Params, Trainer};
use std::collections::VecDeque;

/// Per-worker adversary behavior. `Honest` is the overwhelming default;
/// the attack variants mirror [`AttackKind`] one-to-one.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AdversaryPolicy {
    /// Follows the protocol faithfully.
    #[default]
    Honest,
    /// Transmits `-θ` instead of `θ` (gradient poisoning).
    SignFlip,
    /// Transmits `scale·θ` (gradient poisoning, `adversary.scale`).
    Scale,
    /// Trains on a label-flipped shard (`y → C-1-y`); transmits its
    /// honestly-trained-on-poison model unchanged.
    LabelFlip,
    /// Replays its own parameters from `adversary.stale_tau` rounds ago.
    StaleBomb,
    /// Transmits its frozen initial parameters forever (never
    /// contributes training work).
    FreeRide,
}

impl AdversaryPolicy {
    /// The policy a worker assigned `attack` mounts.
    pub fn from_attack(attack: AttackKind) -> Self {
        match attack {
            AttackKind::None => Self::Honest,
            AttackKind::SignFlip => Self::SignFlip,
            AttackKind::Scale => Self::Scale,
            AttackKind::LabelFlip => Self::LabelFlip,
            AttackKind::StaleBomb => Self::StaleBomb,
            AttackKind::FreeRide => Self::FreeRide,
        }
    }

    pub fn is_honest(self) -> bool {
        self == Self::Honest
    }

    /// Whether the policy rewrites the payload at the exchange boundary
    /// (`labelflip` poisons training data instead, so its wire payload
    /// is its own — honestly computed — model).
    pub fn mutates_exchange(self) -> bool {
        matches!(
            self,
            Self::SignFlip | Self::Scale | Self::StaleBomb | Self::FreeRide
        )
    }

    /// The [`crate::metrics::EventRecord`] kind logged on the policy's
    /// first transmission.
    pub fn event_kind(self) -> &'static str {
        match self {
            Self::Honest => "honest",
            Self::SignFlip => "attack-signflip",
            Self::Scale => "attack-scale",
            Self::LabelFlip => "attack-labelflip",
            Self::StaleBomb => "attack-stalebomb",
            Self::FreeRide => "attack-freeride",
        }
    }
}

/// The per-run adversary state: one policy per worker plus the buffers
/// the stateful attacks need (frozen init params, τ-deep parameter
/// history) and the per-worker wire buffers holding this round's
/// poisoned payloads.
///
/// All mutation ([`transmit`](Self::transmit),
/// [`record_round_end`](Self::record_round_end)) happens on the
/// coordinator in a fixed order; round tasks only read
/// ([`exchange_view`](Self::exchange_view)), so thread count never
/// changes results.
pub struct Adversary {
    policies: Vec<AdversaryPolicy>,
    scale: f32,
    stale_tau: usize,
    /// Frozen initial parameters (filled for `FreeRide` workers only).
    init: Vec<Params>,
    /// Own-parameter history, oldest first (filled for `StaleBomb`
    /// workers only; capped at `stale_tau` entries).
    hist: Vec<VecDeque<Params>>,
    /// This round's outgoing payloads (exchange-mutating workers only).
    wire: Vec<Params>,
    /// First-transmission latch per worker (attack-activation events).
    fired: Vec<bool>,
    /// (worker, kind) pairs fired since the last drain, transmit order.
    newly_fired: Vec<(usize, &'static str)>,
    active: bool,
    stale_bombers: bool,
}

impl Adversary {
    /// Assign policies from the config knobs: `⌊frac·workers⌋` workers
    /// drawn on a dedicated RNG stream (never perturbs the substrate
    /// streams) mount `cfg.attack`; everyone else is honest.
    pub fn from_config(
        cfg: &AdversaryConfig,
        workers: usize,
        seed: u64,
    ) -> Self {
        let mut policies = vec![AdversaryPolicy::Honest; workers];
        let k = (cfg.frac * workers as f64).floor() as usize;
        if k > 0 && cfg.attack != AttackKind::None {
            let mut rng = Pcg::new(seed ^ 0xADF1_B52A_17AC_0002, 0xADF);
            for w in rng.sample_indices(workers, k) {
                policies[w] = AdversaryPolicy::from_attack(cfg.attack);
            }
        }
        Self::assemble(policies, cfg)
    }

    /// Hand-scripted per-worker policies (one entry per worker slot),
    /// for targeted tests and the `ExperimentBuilder::adversary` hook.
    pub fn scripted(
        policies: Vec<AdversaryPolicy>,
        cfg: &AdversaryConfig,
    ) -> Self {
        Self::assemble(policies, cfg)
    }

    /// The benign no-op adversary (every worker honest).
    pub fn inactive(workers: usize) -> Self {
        Self::assemble(
            vec![AdversaryPolicy::Honest; workers],
            &AdversaryConfig::default(),
        )
    }

    fn assemble(
        policies: Vec<AdversaryPolicy>,
        cfg: &AdversaryConfig,
    ) -> Self {
        let n = policies.len();
        let active = policies.iter().any(|p| !p.is_honest());
        let stale_bombers =
            policies.iter().any(|&p| p == AdversaryPolicy::StaleBomb);
        Adversary {
            policies,
            scale: cfg.scale as f32,
            stale_tau: cfg.stale_tau.max(1),
            init: vec![Params::new(); n],
            hist: vec![VecDeque::new(); n],
            wire: vec![Params::new(); n],
            fired: vec![false; n],
            newly_fired: Vec::new(),
            active,
            stale_bombers,
        }
    }

    /// `true` when any worker is non-honest. Both engines gate every
    /// adversary branch on this, so the benign default costs nothing.
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// `true` when any worker replays stale parameters (gates the
    /// per-round history recording).
    pub fn has_stale_bombers(&self) -> bool {
        self.stale_bombers
    }

    pub fn policy(&self, w: usize) -> AdversaryPolicy {
        self.policies[w]
    }

    pub fn is_attacker(&self, w: usize) -> bool {
        !self.policies[w].is_honest()
    }

    /// Total assigned attackers (present or not).
    pub fn attacker_count(&self) -> usize {
        self.policies.iter().filter(|p| !p.is_honest()).count()
    }

    /// Attackers among the given (present) worker ids — the per-round
    /// `RoundRecord::adversaries` count.
    pub fn count_present(&self, ids: &[usize]) -> usize {
        ids.iter().filter(|&&i| self.is_attacker(i)).count()
    }

    /// Builder hook: snapshot worker `w`'s initial parameters (seeds
    /// the `FreeRide` frozen payload and the `StaleBomb` history).
    pub fn observe_init(&mut self, w: usize, params: &[f32]) {
        match self.policies[w] {
            AdversaryPolicy::FreeRide => {
                self.init[w].clear();
                self.init[w].extend_from_slice(params);
            }
            AdversaryPolicy::StaleBomb => {
                self.hist[w].push_back(params.to_vec());
            }
            _ => {}
        }
    }

    /// Coordinator-side exchange boundary: worker `w` is about to
    /// transmit `params`. Returns the payload that actually crosses the
    /// wire — the codec encodes *this*, so byte accounting and TopK/Int8
    /// reconstruction operate on the attacked parameters. Also latches
    /// the policy's first activation for the event log.
    ///
    /// Must be called in a fixed order (ascending pull sources, then
    /// plan-order push sources) on the coordinator only.
    pub fn transmit<'a>(
        &'a mut self,
        w: usize,
        params: &'a [f32],
    ) -> &'a [f32] {
        let pol = self.policies[w];
        if !pol.is_honest() && !self.fired[w] {
            self.fired[w] = true;
            self.newly_fired.push((w, pol.event_kind()));
        }
        let wire = &mut self.wire[w];
        match pol {
            AdversaryPolicy::Honest | AdversaryPolicy::LabelFlip => {
                return params;
            }
            AdversaryPolicy::SignFlip => {
                wire.clear();
                wire.extend(params.iter().map(|&x| -x));
            }
            AdversaryPolicy::Scale => {
                let s = self.scale;
                wire.clear();
                wire.extend(params.iter().map(|&x| s * x));
            }
            AdversaryPolicy::StaleBomb => {
                wire.clear();
                // oldest retained snapshot: the worker's params from (up
                // to) stale_tau rounds ago; init before the history warms
                match self.hist[w].front() {
                    Some(old) => wire.extend_from_slice(old),
                    None => wire.extend_from_slice(params),
                }
            }
            AdversaryPolicy::FreeRide => {
                wire.clear();
                wire.extend_from_slice(&self.init[w]);
            }
        }
        &self.wire[w]
    }

    /// Read-only view of sender `w`'s exchange payload for round tasks.
    /// `codec_view` is what the transport layer reconstructs: under a
    /// non-dense codec it is already the (lossy) decode of the attacked
    /// payload, so it passes through; under the dense codec it is the
    /// sender's raw parameters, so exchange-mutating policies substitute
    /// the wire buffer populated by [`transmit`](Self::transmit).
    pub fn exchange_view<'a>(
        &'a self,
        w: usize,
        codec_view: &'a [f32],
        dense: bool,
    ) -> &'a [f32] {
        if dense && self.policies[w].mutates_exchange() {
            debug_assert_eq!(
                self.wire[w].len(),
                codec_view.len(),
                "transmit({w}) must run before exchange_view"
            );
            &self.wire[w]
        } else {
            codec_view
        }
    }

    /// End-of-round hook: append worker `w`'s current parameters to its
    /// replay history (no-op for non-`StaleBomb` workers). Coordinator
    /// only, after the round's exchanges complete.
    pub fn record_round_end(&mut self, w: usize, params: &[f32]) {
        if self.policies[w] != AdversaryPolicy::StaleBomb {
            return;
        }
        let h = &mut self.hist[w];
        let mut buf = if h.len() >= self.stale_tau {
            h.pop_front().unwrap()
        } else {
            Params::new()
        };
        buf.clear();
        buf.extend_from_slice(params);
        h.push_back(buf);
    }

    /// Drain the attack activations latched since the last call, in
    /// transmit order — the engines turn these into `EventRecord`s.
    pub fn drain_activations(&mut self) -> Vec<(usize, &'static str)> {
        std::mem::take(&mut self.newly_fired)
    }
}

/// Coordinator-side aggregation rule (`adversary.aggregator`): replaces
/// the single `Trainer::aggregate` call site in both engines. `Mean`
/// delegates to the trainer (bit-identical to the pre-adversary path,
/// preserving trainer-specific fast paths like the Pallas PJRT kernel);
/// the robust rules are standard Byzantine-resilient estimators over
/// the flattened parameter vectors.
///
/// The robust rules are **unweighted** — data-size weights are
/// self-reported and therefore attacker-controlled, so robust
/// aggregation deliberately ignores them (the classic formulations are
/// unweighted for the same reason).
#[derive(Clone, Debug)]
pub struct Aggregator {
    kind: AggregatorKind,
    trim_frac: f64,
    krum_f: usize,
    /// Per-coordinate scratch column (trimmed-mean / median).
    col: Vec<f32>,
    /// Pairwise squared-distance matrix scratch (krum).
    d2: Vec<f64>,
    /// Row scratch for the k-nearest sum (krum).
    row: Vec<f64>,
}

impl Aggregator {
    pub fn from_config(cfg: &AdversaryConfig) -> Self {
        Aggregator {
            kind: cfg.aggregator,
            trim_frac: cfg.trim_frac,
            krum_f: cfg.krum_f,
            col: Vec::new(),
            d2: Vec::new(),
            row: Vec::new(),
        }
    }

    pub fn kind(&self) -> AggregatorKind {
        self.kind
    }

    /// Aggregate `models` (aligned with `weights`) into `out`.
    pub fn aggregate_into(
        &mut self,
        trainer: &mut dyn Trainer,
        models: &[&[f32]],
        weights: &[f32],
        out: &mut Params,
    ) {
        assert!(!models.is_empty(), "aggregate of zero models");
        match self.kind {
            AggregatorKind::Mean => {
                trainer.aggregate_into(models, weights, out);
            }
            AggregatorKind::TrimmedMean => self.trimmed_into(models, out),
            AggregatorKind::CoordinateMedian => self.median_into(models, out),
            AggregatorKind::Krum => {
                self.krum_into(trainer, models, weights, out)
            }
        }
    }

    /// Coordinate-wise trimmed mean: drop `t = ⌊trim_frac·n⌋` extremes
    /// on each side (clamped so something survives), average the rest.
    fn trimmed_into(&mut self, models: &[&[f32]], out: &mut Params) {
        let n = models.len();
        let t = ((self.trim_frac * n as f64).floor() as usize)
            .min((n - 1) / 2);
        self.sorted_columns_into(models, out, |col| {
            let kept = &col[t..col.len() - t];
            kept.iter().sum::<f32>() / kept.len() as f32
        });
    }

    /// Coordinate-wise median (even counts average the middle two).
    fn median_into(&mut self, models: &[&[f32]], out: &mut Params) {
        self.sorted_columns_into(models, out, |col| {
            let n = col.len();
            if n % 2 == 1 {
                col[n / 2]
            } else {
                (col[n / 2 - 1] + col[n / 2]) / 2.0
            }
        });
    }

    fn sorted_columns_into(
        &mut self,
        models: &[&[f32]],
        out: &mut Params,
        reduce: impl Fn(&[f32]) -> f32,
    ) {
        let p = models[0].len();
        for m in models {
            assert_eq!(m.len(), p, "model length mismatch");
        }
        out.clear();
        out.reserve(p);
        for c in 0..p {
            self.col.clear();
            self.col.extend(models.iter().map(|m| m[c]));
            self.col.sort_unstable_by(f32::total_cmp);
            out.push(reduce(&self.col));
        }
    }

    /// Krum (Blanchard et al. 2017): return the single model minimizing
    /// the summed squared distance to its `n - f - 2` nearest peers.
    /// `f` clamps to `n-3` (the score needs ≥ 1 neighbor); with fewer
    /// than 3 models the score is undefined and the rule falls back to
    /// the weighted mean.
    fn krum_into(
        &mut self,
        trainer: &mut dyn Trainer,
        models: &[&[f32]],
        weights: &[f32],
        out: &mut Params,
    ) {
        let n = models.len();
        if n < 3 {
            trainer.aggregate_into(models, weights, out);
            return;
        }
        let f = self.krum_f.min(n - 3);
        let k = n - f - 2;
        self.d2.clear();
        self.d2.resize(n * n, 0.0);
        for i in 0..n {
            for j in (i + 1)..n {
                let d: f64 = models[i]
                    .iter()
                    .zip(models[j])
                    .map(|(&a, &b)| {
                        let e = (a - b) as f64;
                        e * e
                    })
                    .sum();
                self.d2[i * n + j] = d;
                self.d2[j * n + i] = d;
            }
        }
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        for i in 0..n {
            self.row.clear();
            self.row.extend(
                (0..n).filter(|&j| j != i).map(|j| self.d2[i * n + j]),
            );
            self.row.sort_unstable_by(f64::total_cmp);
            let score: f64 = self.row[..k].iter().sum();
            // strict < keeps the lowest index on ties: deterministic
            if score < best_score {
                best = i;
                best_score = score;
            }
        }
        out.clear();
        out.extend_from_slice(models[best]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::worker::NativeTrainer;

    fn cfg() -> AdversaryConfig {
        AdversaryConfig::default()
    }

    fn trainer() -> NativeTrainer {
        NativeTrainer::new(2, 2)
    }

    #[test]
    fn assignment_is_deterministic_and_sized() {
        let c = AdversaryConfig {
            frac: 0.3,
            attack: AttackKind::SignFlip,
            ..cfg()
        };
        let a = Adversary::from_config(&c, 20, 7);
        let b = Adversary::from_config(&c, 20, 7);
        assert_eq!(a.attacker_count(), 6); // ⌊0.3·20⌋
        assert!(a.is_active());
        for w in 0..20 {
            assert_eq!(a.policy(w), b.policy(w));
        }
        // different seed → (almost surely) different cast
        let d = Adversary::from_config(&c, 20, 8);
        assert_eq!(d.attacker_count(), 6);
        assert!(
            (0..20).any(|w| a.policy(w) != d.policy(w)),
            "seed must select the cast"
        );
    }

    #[test]
    fn default_knobs_are_inert() {
        let a = Adversary::from_config(&cfg(), 10, 1);
        assert!(!a.is_active());
        assert_eq!(a.attacker_count(), 0);
        // frac without an attack is also inert
        let c = AdversaryConfig { frac: 0.5, ..cfg() };
        assert!(!Adversary::from_config(&c, 10, 1).is_active());
    }

    #[test]
    fn signflip_and_scale_rewrite_payloads() {
        let c = AdversaryConfig { scale: 3.0, ..cfg() };
        let mut a = Adversary::scripted(
            vec![
                AdversaryPolicy::Honest,
                AdversaryPolicy::SignFlip,
                AdversaryPolicy::Scale,
            ],
            &c,
        );
        let p = vec![1.0f32, -2.0];
        assert_eq!(a.transmit(0, &p), &[1.0, -2.0]);
        assert_eq!(a.transmit(1, &p), &[-1.0, 2.0]);
        assert_eq!(a.transmit(2, &p), &[3.0, -6.0]);
        // dense exchange views read the wire buffers
        assert_eq!(a.exchange_view(1, &p, true), &[-1.0, 2.0]);
        assert_eq!(a.exchange_view(0, &p, true), &[1.0, -2.0]);
        // codec views pass through (already attacked at encode)
        assert_eq!(a.exchange_view(1, &p, false), &[1.0, -2.0]);
    }

    #[test]
    fn stalebomb_replays_and_freeride_freezes() {
        let c = AdversaryConfig { stale_tau: 2, ..cfg() };
        let mut a = Adversary::scripted(
            vec![AdversaryPolicy::StaleBomb, AdversaryPolicy::FreeRide],
            &c,
        );
        a.observe_init(0, &[0.0]);
        a.observe_init(1, &[9.0]);
        // round 1: both replay their init-era state
        assert_eq!(a.transmit(0, &[1.0]), &[0.0]);
        assert_eq!(a.transmit(1, &[1.0]), &[9.0]);
        a.record_round_end(0, &[1.0]);
        a.record_round_end(1, &[1.0]); // no-op: not a bomber
        // round 2: history holds [init, r1] — front is still init
        assert_eq!(a.transmit(0, &[2.0]), &[0.0]);
        a.record_round_end(0, &[2.0]);
        // round 3: τ=2 window slid — front is now round 1's params
        assert_eq!(a.transmit(0, &[3.0]), &[1.0]);
        // free-rider never moves
        assert_eq!(a.transmit(1, &[55.0]), &[9.0]);
    }

    #[test]
    fn first_transmit_latches_one_activation_event() {
        let mut a = Adversary::scripted(
            vec![AdversaryPolicy::SignFlip, AdversaryPolicy::Honest],
            &cfg(),
        );
        a.transmit(1, &[1.0]);
        assert!(a.drain_activations().is_empty(), "honest never fires");
        a.transmit(0, &[1.0]);
        a.transmit(0, &[2.0]);
        assert_eq!(a.drain_activations(), vec![(0, "attack-signflip")]);
        a.transmit(0, &[3.0]);
        assert!(a.drain_activations().is_empty(), "fires exactly once");
    }

    #[test]
    fn mean_aggregator_matches_trainer_bitwise() {
        let mut t = trainer();
        let mut g = Aggregator::from_config(&cfg());
        let a = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = vec![0.5f32, -1.0, 2.5, 0.0, 1.0, -3.0];
        let w = [0.25f32, 0.75];
        let models: Vec<&[f32]> = vec![&a, &b];
        let mut out = Params::new();
        g.aggregate_into(&mut t, &models, &w, &mut out);
        let expect = crate::worker::aggregate_native(&models, &w);
        assert_eq!(
            out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            expect.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn trimmed_mean_drops_the_outlier() {
        let c = AdversaryConfig {
            aggregator: AggregatorKind::TrimmedMean,
            trim_frac: 0.34,
            ..cfg()
        };
        let mut g = Aggregator::from_config(&c);
        let honest1 = vec![1.0f32, 1.0];
        let honest2 = vec![2.0f32, 2.0];
        let outlier = vec![1000.0f32, -1000.0];
        let mut out = Params::new();
        // t = ⌊0.34·3⌋ = 1: extremes trimmed on both sides per coordinate
        g.aggregate_into(
            &mut trainer(),
            &[&honest1, &honest2, &outlier],
            &[1.0 / 3.0; 3],
            &mut out,
        );
        assert_eq!(out, vec![2.0, 1.0]);
    }

    #[test]
    fn median_handles_even_and_odd_counts() {
        let c = AdversaryConfig {
            aggregator: AggregatorKind::CoordinateMedian,
            ..cfg()
        };
        let mut g = Aggregator::from_config(&c);
        let mut out = Params::new();
        let (a, b, z) =
            (vec![1.0f32], vec![3.0f32], vec![100.0f32]);
        g.aggregate_into(
            &mut trainer(),
            &[&a, &b, &z],
            &[1.0 / 3.0; 3],
            &mut out,
        );
        assert_eq!(out, vec![3.0]);
        g.aggregate_into(&mut trainer(), &[&a, &b], &[0.5; 2], &mut out);
        assert_eq!(out, vec![2.0]);
    }

    #[test]
    fn krum_selects_a_cluster_member_and_falls_back_when_tiny() {
        let c = AdversaryConfig {
            aggregator: AggregatorKind::Krum,
            krum_f: 1,
            ..cfg()
        };
        let mut g = Aggregator::from_config(&c);
        // 4 clustered honest models + 1 gross outlier (n=5 ≥ 2f+3)
        let ms: Vec<Vec<f32>> = vec![
            vec![1.0, 1.0],
            vec![1.1, 0.9],
            vec![0.9, 1.1],
            vec![1.05, 1.0],
            vec![-500.0, 500.0],
        ];
        let refs: Vec<&[f32]> = ms.iter().map(|m| m.as_slice()).collect();
        let mut out = Params::new();
        g.aggregate_into(&mut trainer(), &refs, &[0.2f32; 5], &mut out);
        assert!(
            ms[..4].iter().any(|m| m == &out),
            "krum must return an honest member verbatim, got {out:?}"
        );
        // n < 3: weighted-mean fallback (bit-identical to the trainer)
        let a = vec![2.0f32, 4.0];
        let b = vec![4.0f32, 8.0];
        g.aggregate_into(&mut trainer(), &[&a, &b], &[0.5; 2], &mut out);
        assert_eq!(out, vec![3.0, 6.0]);
    }
}
