//! Micro-benchmark kit (no `criterion` offline): warmup + timed
//! iterations with mean/stddev/percentile reporting, plus a
//! machine-readable `BENCH_*.json` report writer so the perf trajectory
//! is tracked across PRs.

use crate::util::json::Json;
use crate::util::stats::{mean, percentile, stddev};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    /// One JSON object per case, keyed like the printed columns.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("mean_ns".to_string(), Json::Num(self.mean_ns));
        m.insert("stddev_ns".to_string(), Json::Num(self.stddev_ns));
        m.insert("p50_ns".to_string(), Json::Num(self.p50_ns));
        m.insert("p99_ns".to_string(), Json::Num(self.p99_ns));
        Json::Obj(m)
    }

    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}  ±{:>10}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.stddev_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Write a machine-readable bench report: top-level metadata keys plus a
/// `"results"` array with one entry per case. The output round-trips
/// through [`Json::parse`], so downstream tooling (CI artifacts,
/// cross-PR perf tracking) needs no bespoke parser.
pub fn write_json_report(
    path: &Path,
    meta: Vec<(String, Json)>,
    results: &[BenchResult],
) -> std::io::Result<()> {
    let mut root = BTreeMap::new();
    for (k, v) in meta {
        root.insert(k, v);
    }
    root.insert(
        "results".to_string(),
        Json::Arr(results.iter().map(|r| r.to_json()).collect()),
    );
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", Json::Obj(root))
}

/// Outcome of diffing a fresh bench report against a checked-in
/// baseline (the CI `bench-regression` gate).
#[derive(Debug)]
pub struct BenchDiff {
    /// Relative p50 slowdown allowed before a row counts as regressed.
    pub tolerance: f64,
    /// Human-readable per-row report lines, baseline order.
    pub lines: Vec<String>,
    /// Rows whose fresh p50 exceeds `baseline × (1 + tolerance)`.
    pub regressions: Vec<String>,
    /// Baseline rows absent from the fresh report (coverage rot).
    pub missing: Vec<String>,
    /// Baseline rows with `p50_ns ≤ 0` — placeholders that gate nothing
    /// until the baseline is refreshed from a real run.
    pub unpinned: usize,
    /// Rows actually compared against a pinned baseline value.
    pub compared: usize,
}

impl BenchDiff {
    /// The CI gate: fail on any regression or missing row. Unpinned
    /// baseline rows pass (with a notice) so a placeholder baseline
    /// doesn't block PRs before the first refresh.
    pub fn gate(&self) -> Result<(), String> {
        if self.regressions.is_empty() && self.missing.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "bench regression gate failed (tolerance {:.0}%): {} regressed [{}], {} missing [{}]",
                self.tolerance * 100.0,
                self.regressions.len(),
                self.regressions.join(", "),
                self.missing.len(),
                self.missing.join(", "),
            ))
        }
    }

    /// True when the baseline is still the zeroed placeholder shipped
    /// with the repo: every matched row is unpinned, so the diff gated
    /// nothing beyond row coverage.
    pub fn baseline_is_placeholder(&self) -> bool {
        self.unpinned > 0 && self.compared == 0
    }
}

/// `(name, p50_ns)` per row of a bench report's `results[]`.
fn report_rows(j: &Json) -> Result<Vec<(String, f64)>, String> {
    let arr = j
        .get("results")
        .and_then(|r| r.as_arr())
        .ok_or("bench report missing a results[] array")?;
    let mut out = Vec::new();
    for row in arr {
        let name = row
            .get("name")
            .and_then(|n| n.as_str())
            .ok_or("bench result row missing a name")?;
        let p50 = row
            .get("p50_ns")
            .and_then(|p| p.as_f64())
            .ok_or_else(|| format!("bench row {name:?} missing p50_ns"))?;
        out.push((name.to_string(), p50));
    }
    Ok(out)
}

/// Diff two bench reports row by row on median latency: a fresh row more
/// than `tolerance` slower than its baseline is a regression; a baseline
/// row missing from the fresh report is coverage rot. Baseline rows with
/// `p50_ns ≤ 0` are placeholders — reported but never gating. Fresh-only
/// rows are new coverage, reported as a notice.
pub fn diff_reports(
    baseline: &Json,
    fresh: &Json,
    tolerance: f64,
) -> Result<BenchDiff, String> {
    let base = report_rows(baseline)?;
    let fresh_rows: BTreeMap<String, f64> =
        report_rows(fresh)?.into_iter().collect();
    let mut d = BenchDiff {
        tolerance,
        lines: Vec::new(),
        regressions: Vec::new(),
        missing: Vec::new(),
        unpinned: 0,
        compared: 0,
    };
    for (name, bp50) in &base {
        match fresh_rows.get(name) {
            // coverage rot fails the gate whether or not the baseline
            // value is pinned — the row set is part of the contract
            None => {
                d.lines.push(format!("  MISSING   {name}"));
                d.missing.push(name.clone());
            }
            Some(_) if *bp50 <= 0.0 => {
                d.unpinned += 1;
                d.lines.push(format!(
                    "  unpinned  {name} (baseline p50=0 — refresh BENCH_baseline.json from a real run)"
                ));
            }
            Some(&fp50) => {
                d.compared += 1;
                let pct = (fp50 / bp50 - 1.0) * 100.0;
                if fp50 > bp50 * (1.0 + tolerance) {
                    d.lines.push(format!(
                        "  REGRESSED {name}: p50 {} → {} ({pct:+.1}%)",
                        fmt_ns(*bp50),
                        fmt_ns(fp50)
                    ));
                    d.regressions.push(format!("{name} ({pct:+.1}%)"));
                } else {
                    d.lines.push(format!(
                        "  ok        {name}: p50 {} → {} ({pct:+.1}%)",
                        fmt_ns(*bp50),
                        fmt_ns(fp50)
                    ));
                }
            }
        }
    }
    for name in fresh_rows.keys() {
        if !base.iter().any(|(b, _)| b == name) {
            d.lines.push(format!(
                "  new       {name} (not in baseline yet)"
            ));
        }
    }
    Ok(d)
}

/// Benchmark `f`, auto-scaling iteration count to the target duration.
pub fn bench(name: &str, mut f: impl FnMut()) -> BenchResult {
    bench_with(name, 3, 0.5, &mut f)
}

/// Benchmark with explicit warmup iterations and measure budget (s).
pub fn bench_with(
    name: &str,
    warmup: usize,
    budget_s: f64,
    f: &mut dyn FnMut(),
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    // calibrate: how long is one call?
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / one) as usize).clamp(5, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean(&samples),
        stddev_ns: stddev(&samples),
        p50_ns: percentile(&samples, 0.5),
        p99_ns: percentile(&samples, 0.99),
    };
    r.print();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench_with("noop-ish", 1, 0.02, &mut || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns);
    }

    #[test]
    fn formats_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }

    fn report(rows: &[(&str, f64)]) -> Json {
        let text = format!(
            "{{\"results\":[{}]}}",
            rows.iter()
                .map(|(n, p)| format!(
                    "{{\"name\":\"{n}\",\"iters\":10,\"mean_ns\":{p},\"stddev_ns\":1,\"p50_ns\":{p},\"p99_ns\":{p}}}"
                ))
                .collect::<Vec<_>>()
                .join(",")
        );
        Json::parse(&text).unwrap()
    }

    #[test]
    fn diff_passes_within_tolerance() {
        let base = report(&[("a", 1000.0), ("b", 2000.0)]);
        let fresh = report(&[("a", 1100.0), ("b", 1900.0)]);
        let d = diff_reports(&base, &fresh, 0.15).unwrap();
        assert_eq!(d.compared, 2);
        assert!(d.regressions.is_empty() && d.missing.is_empty());
        d.gate().unwrap();
    }

    #[test]
    fn diff_fails_on_injected_slowdown() {
        // the acceptance check: a >15% p50 slowdown must fail the gate
        let base = report(&[("sim_round N=200 dystop", 1000.0)]);
        let fresh = report(&[("sim_round N=200 dystop", 1200.0)]); // +20%
        let d = diff_reports(&base, &fresh, 0.15).unwrap();
        assert_eq!(d.regressions.len(), 1);
        let err = d.gate().unwrap_err();
        assert!(err.contains("sim_round N=200 dystop"), "{err}");
        assert!(err.contains("+20.0%"), "{err}");
        // just inside the tolerance band: not a regression
        let at = report(&[("sim_round N=200 dystop", 1140.0)]);
        diff_reports(&base, &at, 0.15).unwrap().gate().unwrap();
    }

    #[test]
    fn diff_fails_on_missing_row() {
        let base = report(&[("a", 1000.0), ("b", 2000.0)]);
        let fresh = report(&[("a", 1000.0)]);
        let d = diff_reports(&base, &fresh, 0.15).unwrap();
        assert_eq!(d.missing, vec!["b".to_string()]);
        assert!(d.gate().is_err());
    }

    #[test]
    fn diff_placeholder_baseline_rows_never_gate() {
        // a zeroed baseline (pre-refresh placeholder) must not block PRs
        let base = report(&[("a", 0.0), ("b", 0.0)]);
        let fresh = report(&[("a", 99999.0), ("b", 1.0), ("c", 1.0)]);
        let d = diff_reports(&base, &fresh, 0.15).unwrap();
        assert_eq!(d.unpinned, 2);
        assert_eq!(d.compared, 0);
        d.gate().unwrap();
        // fresh-only rows are reported as new coverage
        assert!(d.lines.iter().any(|l| l.contains("new") && l.contains('c')));
        // but row coverage is enforced even for placeholder rows
        let gone = report(&[("a", 99999.0)]);
        assert!(diff_reports(&base, &gone, 0.15).unwrap().gate().is_err());
    }

    #[test]
    fn placeholder_detection_requires_every_row_unpinned() {
        // all-zero baseline → placeholder (bench-diff warns)
        let base = report(&[("a", 0.0), ("b", 0.0)]);
        let fresh = report(&[("a", 1.0), ("b", 1.0)]);
        assert!(diff_reports(&base, &fresh, 0.15)
            .unwrap()
            .baseline_is_placeholder());
        // one pinned row → a real (if partial) baseline, no warning
        let partial = report(&[("a", 0.0), ("b", 1000.0)]);
        assert!(!diff_reports(&partial, &fresh, 0.15)
            .unwrap()
            .baseline_is_placeholder());
        // fully pinned → no warning
        let pinned = report(&[("a", 1000.0), ("b", 1000.0)]);
        assert!(!diff_reports(&pinned, &fresh, 0.15)
            .unwrap()
            .baseline_is_placeholder());
    }

    #[test]
    fn diff_rejects_malformed_reports() {
        let good = report(&[("a", 1.0)]);
        let bad = Json::parse("{\"results\": 3}").unwrap();
        assert!(diff_reports(&bad, &good, 0.15).is_err());
        let noname =
            Json::parse("{\"results\":[{\"p50_ns\": 1}]}").unwrap();
        assert!(diff_reports(&noname, &good, 0.15).is_err());
        let nop50 = Json::parse("{\"results\":[{\"name\":\"x\"}]}").unwrap();
        assert!(diff_reports(&good, &nop50, 0.15).is_err());
    }

    #[test]
    fn json_report_round_trips() {
        let r = BenchResult {
            name: "case".into(),
            iters: 10,
            mean_ns: 1500.0,
            stddev_ns: 10.0,
            p50_ns: 1490.0,
            p99_ns: 1600.0,
        };
        // pid-suffixed so concurrent test runs on one machine don't race
        let path = std::env::temp_dir().join(format!(
            "dystop_bench_report_test_{}.json",
            std::process::id()
        ));
        write_json_report(
            &path,
            vec![("quick".to_string(), Json::Bool(true))],
            &[r],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("quick"), Some(&Json::Bool(true)));
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(
            results[0].get("name").unwrap().as_str(),
            Some("case")
        );
        assert_eq!(results[0].get("mean_ns").unwrap().as_f64(), Some(1500.0));
        let _ = std::fs::remove_file(&path);
    }
}
