//! Micro-benchmark kit (no `criterion` offline): warmup + timed
//! iterations with mean/stddev/percentile reporting, plus a
//! machine-readable `BENCH_*.json` report writer so the perf trajectory
//! is tracked across PRs.

use crate::util::json::Json;
use crate::util::stats::{mean, percentile, stddev};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    /// One JSON object per case, keyed like the printed columns.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("mean_ns".to_string(), Json::Num(self.mean_ns));
        m.insert("stddev_ns".to_string(), Json::Num(self.stddev_ns));
        m.insert("p50_ns".to_string(), Json::Num(self.p50_ns));
        m.insert("p99_ns".to_string(), Json::Num(self.p99_ns));
        Json::Obj(m)
    }

    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}  ±{:>10}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.stddev_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Write a machine-readable bench report: top-level metadata keys plus a
/// `"results"` array with one entry per case. The output round-trips
/// through [`Json::parse`], so downstream tooling (CI artifacts,
/// cross-PR perf tracking) needs no bespoke parser.
pub fn write_json_report(
    path: &Path,
    meta: Vec<(String, Json)>,
    results: &[BenchResult],
) -> std::io::Result<()> {
    let mut root = BTreeMap::new();
    for (k, v) in meta {
        root.insert(k, v);
    }
    root.insert(
        "results".to_string(),
        Json::Arr(results.iter().map(|r| r.to_json()).collect()),
    );
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", Json::Obj(root))
}

/// Benchmark `f`, auto-scaling iteration count to the target duration.
pub fn bench(name: &str, mut f: impl FnMut()) -> BenchResult {
    bench_with(name, 3, 0.5, &mut f)
}

/// Benchmark with explicit warmup iterations and measure budget (s).
pub fn bench_with(
    name: &str,
    warmup: usize,
    budget_s: f64,
    f: &mut dyn FnMut(),
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    // calibrate: how long is one call?
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / one) as usize).clamp(5, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean(&samples),
        stddev_ns: stddev(&samples),
        p50_ns: percentile(&samples, 0.5),
        p99_ns: percentile(&samples, 0.99),
    };
    r.print();
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench_with("noop-ish", 1, 0.02, &mut || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p99_ns >= r.p50_ns);
    }

    #[test]
    fn formats_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }

    #[test]
    fn json_report_round_trips() {
        let r = BenchResult {
            name: "case".into(),
            iters: 10,
            mean_ns: 1500.0,
            stddev_ns: 10.0,
            p50_ns: 1490.0,
            p99_ns: 1600.0,
        };
        // pid-suffixed so concurrent test runs on one machine don't race
        let path = std::env::temp_dir().join(format!(
            "dystop_bench_report_test_{}.json",
            std::process::id()
        ));
        write_json_report(
            &path,
            vec![("quick".to_string(), Json::Bool(true))],
            &[r],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("quick"), Some(&Json::Bool(true)));
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(
            results[0].get("name").unwrap().as_str(),
            Some("case")
        );
        assert_eq!(results[0].get("mean_ns").unwrap().as_f64(), Some(1500.0));
        let _ = std::fs::remove_file(&path);
    }
}
