//! Virtual-clock simulation engine (paper §VI).
//!
//! Drives Alg. 1 end to end over the edge-network substrate: each round
//! the engine snapshots worker state into a [`SchedView`], asks the
//! configured [`Scheduler`] for a [`RoundPlan`], executes the plan
//! (pull-aggregate-train per Eqs. 3–5, *real* training through the
//! configured [`Trainer`]), advances the virtual clock by the realised
//! round duration H_t (Eqs. 7–9), and updates staleness (Eq. 6) and the
//! Lyapunov queues (Eq. 33).

use crate::config::{ExperimentConfig, TrainerKind};
use crate::coordinator::{
    make_scheduler, RoundPlan, SchedView, Scheduler, SchedulerParams,
};
use crate::data::{dirichlet_partition, make_corpus, Dataset, SyntheticSpec};
use crate::metrics::{EvalRecord, RoundRecord, RunResult};
use crate::network::EdgeNetwork;
use crate::util::rng::Pcg;
use crate::worker::{data_size_weights, NativeTrainer, Trainer, WorkerState};

/// The assembled simulation.
pub struct SimEngine {
    pub cfg: ExperimentConfig,
    pub net: EdgeNetwork,
    pub workers: Vec<WorkerState>,
    pub test: Dataset,
    trainer: Box<dyn Trainer>,
    scheduler: Box<dyn Scheduler>,
    /// pulls\[i\]\[j\]: times worker i pulled from j (Eq. 47's history).
    pulls: Vec<Vec<u64>>,
    /// Pushed-model inboxes: models received via PUSH wait here until the
    /// receiver's next activation (SA-ADFL semantics — receivers don't
    /// interrupt training to merge).
    inbox: Vec<Vec<(usize, Vec<f32>)>>,
    clock_s: f64,
    round: usize,
    cum_transfers: usize,
    rng: Pcg,
    result: RunResult,
    /// Precomputed label distributions per worker (static shards).
    label_dist: Vec<Vec<f64>>,
    model_bits: f64,
}

impl SimEngine {
    /// Build a simulation with the native trainer (no artifacts needed).
    pub fn new(cfg: ExperimentConfig) -> Self {
        let trainer: Box<dyn Trainer> = match cfg.trainer {
            TrainerKind::Native => Box::new(NativeTrainer::new(
                cfg.feature_dim,
                cfg.num_classes,
            )),
            TrainerKind::Pjrt => {
                panic!("use SimEngine::with_trainer for PJRT backends")
            }
        };
        Self::with_trainer(cfg, trainer)
    }

    /// Build with an explicit trainer backend (PJRT path).
    pub fn with_trainer(cfg: ExperimentConfig, trainer: Box<dyn Trainer>) -> Self {
        cfg.validate().expect("invalid experiment config");
        let mut rng = Pcg::new(cfg.seed, 0x51B);
        let spec = SyntheticSpec {
            dim: cfg.feature_dim,
            num_classes: cfg.num_classes,
            train_samples: cfg.train_per_worker * cfg.workers,
            test_samples: cfg.test_samples,
            class_sep: cfg.class_sep,
            seed: cfg.seed,
        };
        let (train, test) = make_corpus(&spec);
        let min_per = cfg.batch.max(cfg.train_per_worker / 4);
        let (shards, stats) =
            dirichlet_partition(&train, cfg.workers, cfg.phi, min_per, &mut rng);

        let net = EdgeNetwork::new(cfg.workers, cfg.network.clone(), &mut rng);

        // heterogeneous compute: h_i = mean × lognormal(0, jitter).
        // Edge-device speeds are heavy-tailed (the paper's Table II spans
        // ~10× between Jetson Nano and Orin) — the lognormal gives the
        // straggler regime the synchronous baselines suffer in (§VI-B1).
        let workers: Vec<WorkerState> = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                let coeff = rng.normal_ms(0.0, cfg.compute_jitter).exp();
                let h = cfg.compute_mean_s * coeff;
                let params = trainer.init(cfg.seed.wrapping_add(i as u64));
                WorkerState::new(i, params, shard, h)
            })
            .collect();

        let scheduler = make_scheduler(cfg.scheduler);
        let model_bits = if cfg.network.payload_bits > 0.0 {
            cfg.network.payload_bits
        } else {
            trainer.param_count() as f64 * 32.0
        };
        let label_dist = stats.label_distributions;
        let n = cfg.workers;
        SimEngine {
            result: RunResult {
                label: scheduler.name().to_string(),
                model_bits,
                ..Default::default()
            },
            cfg,
            net,
            workers,
            test,
            trainer,
            scheduler,
            pulls: vec![vec![0; n]; n],
            inbox: vec![Vec::new(); n],
            clock_s: 0.0,
            round: 0,
            cum_transfers: 0,
            rng,
            label_dist,
            model_bits,
        }
    }

    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }

    /// Estimated per-worker round cost H_t^i (Eq. 8): residual compute
    /// plus the worst expected pull transfer over its (≤ s nearest)
    /// candidates.
    fn estimate_h(&self, candidates: &[Vec<usize>]) -> Vec<f64> {
        let s = self.cfg.neighbor_cap;
        (0..self.workers.len())
            .map(|i| {
                // PTCA will pick ≤ s in-neighbors; estimate with the s
                // *nearest* candidates (best case the coordinator can
                // predict without knowing the realised priorities).
                let mut near: Vec<usize> = candidates[i].clone();
                near.sort_by(|&a, &b| {
                    self.net
                        .distance(i, a)
                        .partial_cmp(&self.net.distance(i, b))
                        .unwrap()
                });
                let worst = near
                    .iter()
                    .take(s)
                    .map(|&j| {
                        self.net
                            .expected_transfer_time_s(j, i, self.model_bits)
                    })
                    .fold(0.0f64, f64::max);
                self.workers[i].residual_s + worst
            })
            .collect()
    }

    /// Run one round of Alg. 1; returns the realised plan.
    pub fn step(&mut self) -> RoundPlan {
        self.round += 1;
        self.net.step(&mut self.rng);

        let candidates: Vec<Vec<usize>> = (0..self.workers.len())
            .map(|i| self.net.in_range(i))
            .collect();
        let h_cmp: Vec<f64> =
            self.workers.iter().map(|w| w.residual_s).collect();
        let h_est = self.estimate_h(&candidates);
        let tau: Vec<u64> = self.workers.iter().map(|w| w.staleness).collect();
        let queues: Vec<f64> = self.workers.iter().map(|w| w.queue).collect();
        let data_sizes: Vec<usize> =
            self.workers.iter().map(|w| w.data_size()).collect();

        let plan = {
            let view = SchedView {
                round: self.round,
                tau: &tau,
                queues: &queues,
                h_cmp: &h_cmp,
                h_est: &h_est,
                data_sizes: &data_sizes,
                label_dist: &self.label_dist,
                candidates: &candidates,
                budgets: &self.net.budgets,
                pulls: &self.pulls,
                net: &self.net,
                params: SchedulerParams::from(&self.cfg),
            };
            self.scheduler.plan(&view, &mut self.rng)
        };
        debug_assert!(plan.validate(self.workers.len()).is_ok());

        self.execute(&plan);
        plan
    }

    /// Execute a round plan: aggregate + train the active workers,
    /// advance the clock, update staleness/queues/ledgers.
    fn execute(&mut self, plan: &RoundPlan) {
        let n = self.workers.len();
        // --- realised round duration (Eqs. 7–9) ---
        let mut h_round = 0.0f64;
        let mut durations = Vec::with_capacity(plan.active.len());
        let channels = self.cfg.network.channels.max(1);
        for (k, &i) in plan.active.iter().enumerate() {
            // pulls beyond the radio's orthogonal channels serialize:
            // K transfers take ⌈K/channels⌉ slots of the worst link time
            let worst_pull = plan.pulls_from[k]
                .iter()
                .map(|&j| {
                    self.net
                        .transfer_time_s(j, i, self.model_bits, &mut self.rng)
                })
                .fold(0.0f64, f64::max);
            let pull_slots = plan.pulls_from[k].len().div_ceil(channels);
            // pushes originating at i (SA-ADFL's send-to-all) also occupy
            // its radio, serialized the same way
            let push_times: Vec<f64> = plan
                .pushes
                .iter()
                .filter(|&&(from, _)| from == i)
                .map(|&(_, to)| {
                    self.net
                        .transfer_time_s(i, to, self.model_bits, &mut self.rng)
                })
                .collect();
            let worst_push = push_times.iter().cloned().fold(0.0f64, f64::max);
            let push_slots = push_times.len().div_ceil(channels);
            let d = self.workers[i].residual_s
                + worst_pull * pull_slots as f64
                + worst_push * push_slots as f64;
            durations.push(d);
            h_round = h_round.max(d);
        }
        if plan.active.is_empty() {
            h_round = 0.01; // avoid stalling the clock
        }

        // --- aggregate + train (Eqs. 4–5), pull-count ledger ---
        // snapshot models first so intra-round pulls see pre-round state
        let mut losses = Vec::with_capacity(plan.active.len());
        let mut new_models: Vec<(usize, Vec<f32>, f64)> = Vec::new();
        for (k, &i) in plan.active.iter().enumerate() {
            let mut srcs: Vec<usize> = vec![i];
            srcs.extend(plan.pulls_from[k].iter().copied());
            let mut models: Vec<&[f32]> = srcs
                .iter()
                .map(|&j| self.workers[j].params.as_slice())
                .collect();
            let mut sizes: Vec<usize> =
                srcs.iter().map(|&j| self.workers[j].data_size()).collect();
            // pushed models waiting in the inbox join the aggregation
            // (skipping senders we just pulled fresh models from)
            for (from, params) in &self.inbox[i] {
                if !srcs.contains(from) {
                    models.push(params.as_slice());
                    sizes.push(self.workers[*from].data_size());
                }
            }
            let weights = data_size_weights(&sizes);
            let agg = self.trainer.aggregate(&models, &weights);
            let (trained, loss) = self.trainer.train(
                &agg,
                &self.workers[i].shard,
                self.cfg.local_steps,
                self.cfg.batch,
                self.cfg.lr,
                &mut self.rng,
            );
            new_models.push((i, trained, loss));
            losses.push(loss);
            for &j in &plan.pulls_from[k] {
                self.pulls[i][j] += 1;
            }
        }
        for (i, params, loss) in new_models {
            self.workers[i].params = params;
            self.workers[i].last_loss = loss;
            self.inbox[i].clear(); // consumed by this aggregation
        }

        // --- pushes (SA-ADFL): the updated model lands in each
        // receiver's inbox for *their* next aggregation (latest wins)
        for &(from, to) in &plan.pushes {
            let pushed = self.workers[from].params.clone();
            self.inbox[to].retain(|(f, _)| *f != from);
            self.inbox[to].push((from, pushed));
        }

        // --- clock + staleness + queues (Eqs. 6, 33) ---
        self.clock_s += h_round;
        let active_set: Vec<bool> = {
            let mut v = vec![false; n];
            for &i in &plan.active {
                v[i] = true;
            }
            v
        };
        for (i, w) in self.workers.iter_mut().enumerate() {
            w.advance(h_round);
            if active_set[i] {
                w.on_activated();
            } else {
                w.on_skipped();
            }
            w.update_queue(self.cfg.tau_bound);
        }

        // --- metrics ---
        let transfers = plan.transfers();
        self.cum_transfers += transfers;
        let avg_tau = self
            .workers
            .iter()
            .map(|w| w.staleness as f64)
            .sum::<f64>()
            / n as f64;
        let max_tau = self.workers.iter().map(|w| w.staleness).max().unwrap_or(0);
        let train_loss = if losses.is_empty() {
            f64::NAN
        } else {
            losses.iter().sum::<f64>() / losses.len() as f64
        };
        self.result.rounds.push(RoundRecord {
            round: self.round,
            time_s: self.clock_s,
            duration_s: h_round,
            active: plan.active.len(),
            transfers,
            avg_staleness: avg_tau,
            max_staleness: max_tau,
            train_loss,
        });
    }

    /// Evaluate the average of all (or a sampled fraction of) workers'
    /// local models on the test set and record a snapshot.
    pub fn evaluate(&mut self) -> EvalRecord {
        let n = self.workers.len();
        let count = ((n as f64 * self.cfg.eval_worker_frac).round() as usize)
            .clamp(1, n);
        let ids: Vec<usize> = if count == n {
            (0..n).collect()
        } else {
            self.rng.sample_indices(n, count)
        };
        let mut acc_sum = 0.0;
        let mut loss_sum = 0.0;
        for &i in &ids {
            let (loss, acc) =
                self.trainer.evaluate(&self.workers[i].params, &self.test);
            acc_sum += acc;
            loss_sum += loss;
        }
        let rec = EvalRecord {
            round: self.round,
            time_s: self.clock_s,
            avg_accuracy: acc_sum / ids.len() as f64,
            avg_loss: loss_sum / ids.len() as f64,
            cum_transfers: self.cum_transfers,
        };
        self.result.evals.push(rec.clone());
        rec
    }

    /// Run the configured number of rounds (with periodic evaluation);
    /// stops early once `target_accuracy` is reached *and* at least one
    /// later snapshot confirms it.
    pub fn run(mut self) -> RunResult {
        let rounds = self.cfg.rounds;
        let every = self.cfg.eval_every.max(1);
        let mut hits = 0;
        for t in 1..=rounds {
            self.step();
            if t % every == 0 || t == rounds {
                let rec = self.evaluate();
                if rec.avg_accuracy >= self.cfg.target_accuracy {
                    hits += 1;
                    if hits >= 2 {
                        break;
                    }
                }
            }
        }
        self.result
    }

    /// Like [`run`] but without early stopping (full curves for figures).
    pub fn run_full(mut self) -> RunResult {
        let rounds = self.cfg.rounds;
        let every = self.cfg.eval_every.max(1);
        for t in 1..=rounds {
            self.step();
            if t % every == 0 || t == rounds {
                self.evaluate();
            }
        }
        self.result
    }

    /// Immutable access to collected metrics (tests).
    pub fn result(&self) -> &RunResult {
        &self.result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulerKind;

    fn small_cfg(scheduler: SchedulerKind) -> ExperimentConfig {
        ExperimentConfig {
            workers: 12,
            rounds: 60,
            train_per_worker: 64,
            test_samples: 200,
            eval_every: 10,
            scheduler,
            target_accuracy: 2.0, // never early-stop
            ..Default::default()
        }
    }

    #[test]
    fn dystop_sim_trains() {
        let sim = SimEngine::new(small_cfg(SchedulerKind::DySTop));
        let res = sim.run_full();
        assert_eq!(res.rounds.len(), 60);
        assert!(!res.evals.is_empty());
        let first = res.evals.first().unwrap().avg_accuracy;
        let best = res.best_accuracy();
        assert!(best > first, "no learning: {first} → {best}");
        assert!(best > 0.5, "best acc {best}");
    }

    #[test]
    fn staleness_stays_bounded_under_dystop() {
        let mut cfg = small_cfg(SchedulerKind::DySTop);
        cfg.rounds = 80;
        cfg.tau_bound = 4;
        let sim = SimEngine::new(cfg);
        let res = sim.run_full();
        // after warmup, staleness must hover near the bound
        let late: Vec<&RoundRecord> =
            res.rounds.iter().skip(30).collect();
        let avg = late.iter().map(|r| r.avg_staleness).sum::<f64>()
            / late.len() as f64;
        assert!(avg < 8.0, "avg staleness {avg} too high for bound 4");
    }

    #[test]
    fn all_schedulers_run_and_learn() {
        for k in [
            SchedulerKind::DySTop,
            SchedulerKind::SaAdfl,
            SchedulerKind::AsyDfl,
            SchedulerKind::Matcha,
        ] {
            let sim = SimEngine::new(small_cfg(k));
            let res = sim.run_full();
            assert!(
                res.best_accuracy() > 0.4,
                "{}: best acc {}",
                res.label,
                res.best_accuracy()
            );
        }
    }

    #[test]
    fn clock_monotone_and_positive() {
        let sim = SimEngine::new(small_cfg(SchedulerKind::DySTop));
        let res = sim.run_full();
        let mut prev = 0.0;
        for r in &res.rounds {
            assert!(r.time_s > prev);
            assert!(r.duration_s > 0.0);
            prev = r.time_s;
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SimEngine::new(small_cfg(SchedulerKind::DySTop)).run_full();
        let b = SimEngine::new(small_cfg(SchedulerKind::DySTop)).run_full();
        assert_eq!(a.rounds.len(), b.rounds.len());
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.time_s, y.time_s);
            assert_eq!(x.transfers, y.transfers);
        }
        for (x, y) in a.evals.iter().zip(&b.evals) {
            assert_eq!(x.avg_accuracy, y.avg_accuracy);
        }
    }

    #[test]
    fn matcha_is_synchronous_straggler_bound() {
        let res_m = SimEngine::new(small_cfg(SchedulerKind::Matcha)).run_full();
        let res_d = SimEngine::new(small_cfg(SchedulerKind::DySTop)).run_full();
        // per-round duration of MATCHA ≈ slowest worker; DySTop's mean
        // round must be meaningfully shorter
        let mean = |r: &RunResult| {
            r.rounds.iter().map(|x| x.duration_s).sum::<f64>()
                / r.rounds.len() as f64
        };
        assert!(
            mean(&res_d) < mean(&res_m),
            "dystop {} vs matcha {}",
            mean(&res_d),
            mean(&res_m)
        );
    }

    #[test]
    fn sa_adfl_uses_more_comm_per_round_than_dystop() {
        let res_s = SimEngine::new(small_cfg(SchedulerKind::SaAdfl)).run_full();
        let res_d = SimEngine::new(small_cfg(SchedulerKind::DySTop)).run_full();
        let per_active = |r: &RunResult| {
            r.rounds.iter().map(|x| x.transfers).sum::<usize>() as f64
                / r.rounds.iter().map(|x| x.active).sum::<usize>() as f64
        };
        assert!(
            per_active(&res_s) > per_active(&res_d),
            "sa-adfl {} vs dystop {}",
            per_active(&res_s),
            per_active(&res_d)
        );
    }
}
