//! Virtual-clock simulation engine (paper §VI) — legacy facade.
//!
//! **Deprecated:** the engine now lives in [`crate::experiment`]
//! ([`VirtualClockEngine`] driven by
//! [`VirtualClockBackend`](crate::experiment::VirtualClockBackend));
//! construct runs through [`Experiment::builder`]. [`SimEngine`] is kept
//! as a thin wrapper so existing callers (benches, examples, tests)
//! continue to work, with the old panic-on-error construction semantics.
//!
//! ```no_run
//! // old:                              // new:
//! // SimEngine::new(cfg).run()         Experiment::builder(cfg).run()?
//! ```

use crate::config::ExperimentConfig;
use crate::coordinator::RoundPlan;
use crate::experiment::{Experiment, VirtualClockEngine};
use crate::metrics::{EvalRecord, RunResult};
use crate::worker::Trainer;

pub use crate::experiment::VirtualClockBackend;

/// The assembled simulation (legacy facade over [`VirtualClockEngine`]).
pub struct SimEngine {
    engine: VirtualClockEngine,
}

impl SimEngine {
    /// Build a simulation with the config's default trainer.
    ///
    /// Deprecated: panics on invalid configs and on trainer kinds without
    /// a default constructor — use
    /// `Experiment::builder(cfg).build()` for a `Result` instead.
    pub fn new(cfg: ExperimentConfig) -> Self {
        let exp = Experiment::builder(cfg)
            .build()
            .expect("invalid experiment config");
        SimEngine { engine: VirtualClockEngine::new(exp) }
    }

    /// Build with an explicit trainer backend (PJRT path).
    ///
    /// Deprecated: panics on invalid configs — use
    /// `Experiment::builder(cfg).trainer(t).build()` instead.
    pub fn with_trainer(
        cfg: ExperimentConfig,
        trainer: Box<dyn Trainer>,
    ) -> Self {
        let exp = Experiment::builder(cfg)
            .trainer(trainer)
            .build()
            .expect("invalid experiment config");
        SimEngine { engine: VirtualClockEngine::new(exp) }
    }

    pub fn clock_s(&self) -> f64 {
        self.engine.clock_s()
    }

    /// Run one round of Alg. 1; returns the realised plan.
    pub fn step(&mut self) -> RoundPlan {
        self.engine.step()
    }

    /// Evaluate and record a snapshot.
    pub fn evaluate(&mut self) -> EvalRecord {
        self.engine.evaluate()
    }

    /// Run the configured number of rounds (with periodic evaluation);
    /// stops early once `target_accuracy` is reached *and* at least one
    /// later snapshot confirms it.
    pub fn run(self) -> RunResult {
        self.engine.run(true)
    }

    /// Like [`run`](Self::run) but without early stopping (full curves
    /// for figures).
    pub fn run_full(self) -> RunResult {
        self.engine.run(false)
    }

    /// Immutable access to collected metrics (tests).
    pub fn result(&self) -> &RunResult {
        self.engine.result()
    }

    /// The underlying engine (workers, network, clock) for callers that
    /// poked at the old public fields.
    pub fn engine(&self) -> &VirtualClockEngine {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut VirtualClockEngine {
        &mut self.engine
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulerKind;
    use crate::metrics::RoundRecord;

    fn small_cfg(scheduler: SchedulerKind) -> ExperimentConfig {
        ExperimentConfig {
            workers: 12,
            rounds: 60,
            train_per_worker: 64,
            test_samples: 200,
            eval_every: 10,
            scheduler,
            target_accuracy: 2.0, // never early-stop
            ..Default::default()
        }
    }

    #[test]
    fn dystop_sim_trains() {
        let sim = SimEngine::new(small_cfg(SchedulerKind::DySTop));
        let res = sim.run_full();
        assert_eq!(res.rounds.len(), 60);
        assert!(!res.evals.is_empty());
        let first = res.evals.first().unwrap().avg_accuracy;
        let best = res.best_accuracy();
        assert!(best > first, "no learning: {first} → {best}");
        assert!(best > 0.5, "best acc {best}");
    }

    #[test]
    fn staleness_stays_bounded_under_dystop() {
        let mut cfg = small_cfg(SchedulerKind::DySTop);
        cfg.rounds = 80;
        cfg.tau_bound = 4;
        let sim = SimEngine::new(cfg);
        let res = sim.run_full();
        // after warmup, staleness must hover near the bound
        let late: Vec<&RoundRecord> =
            res.rounds.iter().skip(30).collect();
        let avg = late.iter().map(|r| r.avg_staleness).sum::<f64>()
            / late.len() as f64;
        assert!(avg < 8.0, "avg staleness {avg} too high for bound 4");
    }

    #[test]
    fn all_schedulers_run_and_learn() {
        for k in [
            SchedulerKind::DySTop,
            SchedulerKind::SaAdfl,
            SchedulerKind::AsyDfl,
            SchedulerKind::Matcha,
        ] {
            let sim = SimEngine::new(small_cfg(k));
            let res = sim.run_full();
            assert!(
                res.best_accuracy() > 0.4,
                "{}: best acc {}",
                res.label,
                res.best_accuracy()
            );
        }
    }

    #[test]
    fn clock_monotone_and_positive() {
        let sim = SimEngine::new(small_cfg(SchedulerKind::DySTop));
        let res = sim.run_full();
        let mut prev = 0.0;
        for r in &res.rounds {
            assert!(r.time_s > prev);
            assert!(r.duration_s > 0.0);
            prev = r.time_s;
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SimEngine::new(small_cfg(SchedulerKind::DySTop)).run_full();
        let b = SimEngine::new(small_cfg(SchedulerKind::DySTop)).run_full();
        assert_eq!(a.rounds.len(), b.rounds.len());
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.time_s, y.time_s);
            assert_eq!(x.transfers, y.transfers);
        }
        for (x, y) in a.evals.iter().zip(&b.evals) {
            assert_eq!(x.avg_accuracy, y.avg_accuracy);
        }
    }

    #[test]
    fn matcha_is_synchronous_straggler_bound() {
        let res_m = SimEngine::new(small_cfg(SchedulerKind::Matcha)).run_full();
        let res_d = SimEngine::new(small_cfg(SchedulerKind::DySTop)).run_full();
        // per-round duration of MATCHA ≈ slowest worker; DySTop's mean
        // round must be meaningfully shorter
        let mean = |r: &RunResult| {
            r.rounds.iter().map(|x| x.duration_s).sum::<f64>()
                / r.rounds.len() as f64
        };
        assert!(
            mean(&res_d) < mean(&res_m),
            "dystop {} vs matcha {}",
            mean(&res_d),
            mean(&res_m)
        );
    }

    #[test]
    fn sa_adfl_uses_more_comm_per_round_than_dystop() {
        let res_s = SimEngine::new(small_cfg(SchedulerKind::SaAdfl)).run_full();
        let res_d = SimEngine::new(small_cfg(SchedulerKind::DySTop)).run_full();
        let per_active = |r: &RunResult| {
            r.rounds.iter().map(|x| x.transfers).sum::<usize>() as f64
                / r.rounds.iter().map(|x| x.active).sum::<usize>() as f64
        };
        assert!(
            per_active(&res_s) > per_active(&res_d),
            "sa-adfl {} vs dystop {}",
            per_active(&res_s),
            per_active(&res_d)
        );
    }
}
