//! Dirichlet non-IID partitioner (paper §VI-A2, ref. \[39\]).
//!
//! For each class `k`, a Dirichlet(φ·1_N) draw splits the class's samples
//! across the N workers. Smaller φ ⇒ more skew; the paper sweeps
//! φ ∈ {1.0, 0.7, 0.4} in simulation and {1.0, 0.5} on the testbed.
//! Every worker is guaranteed at least `min_per_worker` samples
//! (re-balanced from the largest shards) so local training is well-posed.

use super::Dataset;
use crate::util::rng::Pcg;

/// Summary of a partition, used by tests and by PTCA (phase-1 priorities
/// need per-worker label distributions).
#[derive(Clone, Debug)]
pub struct PartitionStats {
    pub sizes: Vec<usize>,
    pub label_distributions: Vec<Vec<f64>>,
}

/// Split `train` into `n` worker shards with Dirichlet(φ) class skew.
pub fn dirichlet_partition(
    train: &Dataset,
    n: usize,
    phi: f64,
    min_per_worker: usize,
    rng: &mut Pcg,
) -> (Vec<Dataset>, PartitionStats) {
    assert!(n > 0 && phi > 0.0);
    // class → sample indices
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); train.num_classes];
    for (i, &y) in train.labels.iter().enumerate() {
        by_class[y as usize].push(i);
    }

    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n];
    for idxs in by_class.iter_mut() {
        rng.shuffle(idxs);
        let props = rng.dirichlet(phi, n);
        // proportional allocation with remainder to the largest share
        let total = idxs.len();
        let mut counts: Vec<usize> =
            props.iter().map(|p| (p * total as f64).floor() as usize).collect();
        let assigned: usize = counts.iter().sum();
        let mut rem = total - assigned;
        // distribute remainder by largest fractional part
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            let fa = props[a] * total as f64 - counts[a] as f64;
            let fb = props[b] * total as f64 - counts[b] as f64;
            fb.partial_cmp(&fa).unwrap()
        });
        for &w in order.iter().cycle().take(rem.min(n * 2)) {
            if rem == 0 {
                break;
            }
            counts[w] += 1;
            rem -= 1;
        }
        let mut cursor = 0;
        for (w, &c) in counts.iter().enumerate() {
            shards[w].extend_from_slice(&idxs[cursor..cursor + c]);
            cursor += c;
        }
    }

    // rebalance: top up starved workers from the largest shards
    loop {
        let (min_w, min_len) = shards
            .iter()
            .enumerate()
            .map(|(w, s)| (w, s.len()))
            .min_by_key(|&(_, l)| l)
            .unwrap();
        if min_len >= min_per_worker {
            break;
        }
        let (max_w, max_len) = shards
            .iter()
            .enumerate()
            .map(|(w, s)| (w, s.len()))
            .max_by_key(|&(_, l)| l)
            .unwrap();
        if max_len <= min_per_worker {
            break; // nothing left to take
        }
        let take = ((min_per_worker - min_len).min(max_len - min_per_worker)).max(1);
        let moved: Vec<usize> =
            shards[max_w].drain(max_len - take..).collect();
        shards[min_w].extend(moved);
    }

    let datasets: Vec<Dataset> = shards.iter().map(|s| train.subset(s)).collect();
    let stats = PartitionStats {
        sizes: datasets.iter().map(|d| d.len()).collect(),
        label_distributions: datasets.iter().map(|d| d.label_distribution()).collect(),
    };
    (datasets, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{emd, make_corpus, SyntheticSpec};
    use crate::util::prop::forall;

    fn corpus(n: usize) -> Dataset {
        make_corpus(&SyntheticSpec { train_samples: n, test_samples: 10, ..Default::default() }).0
    }

    #[test]
    fn partition_conserves_samples() {
        let train = corpus(2000);
        let mut rng = Pcg::seeded(3);
        let (shards, stats) = dirichlet_partition(&train, 20, 0.4, 16, &mut rng);
        assert_eq!(shards.len(), 20);
        assert_eq!(stats.sizes.iter().sum::<usize>(), 2000);
        // class totals conserved
        let mut total = vec![0usize; train.num_classes];
        for s in &shards {
            for (k, c) in s.label_histogram().into_iter().enumerate() {
                total[k] += c;
            }
        }
        assert_eq!(total, train.label_histogram());
    }

    #[test]
    fn min_per_worker_enforced() {
        let train = corpus(2000);
        let mut rng = Pcg::seeded(5);
        let (_, stats) = dirichlet_partition(&train, 50, 0.1, 16, &mut rng);
        assert!(
            stats.sizes.iter().all(|&s| s >= 16),
            "sizes {:?}",
            stats.sizes
        );
    }

    #[test]
    fn lower_phi_is_more_skewed() {
        // average pairwise EMD should grow as φ shrinks
        let train = corpus(4000);
        let avg_emd = |phi: f64| {
            let mut rng = Pcg::seeded(7);
            let (_, stats) = dirichlet_partition(&train, 20, phi, 8, &mut rng);
            let mut sum = 0.0;
            let mut cnt = 0;
            for i in 0..20 {
                for j in (i + 1)..20 {
                    sum += emd(
                        &stats.label_distributions[i],
                        &stats.label_distributions[j],
                    );
                    cnt += 1;
                }
            }
            sum / cnt as f64
        };
        let skew_04 = avg_emd(0.4);
        let skew_10 = avg_emd(1.0);
        let skew_100 = avg_emd(100.0);
        assert!(skew_04 > skew_10, "0.4:{skew_04} 1.0:{skew_10}");
        assert!(skew_10 > skew_100, "1.0:{skew_10} 100:{skew_100}");
    }

    #[test]
    fn property_partition_invariants() {
        let train = corpus(1000);
        forall(11, |rng| {
            let n = 2 + rng.below_usize(30);
            let phi = 0.1 + rng.f64() * 2.0;
            let (shards, stats) = dirichlet_partition(&train, n, phi, 4, rng);
            assert_eq!(shards.len(), n);
            assert_eq!(stats.sizes.iter().sum::<usize>(), train.len());
            for d in &stats.label_distributions {
                let s: f64 = d.iter().sum();
                assert!((s - 1.0).abs() < 1e-9 || s == 0.0);
            }
        });
    }
}
