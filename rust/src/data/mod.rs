//! Data substrate: synthetic classification corpus, Dirichlet non-IID
//! partitioning, and label-distribution measures (EMD, Eq. 45).
//!
//! The paper trains on FMNIST/CIFAR-10 (simulation) and SVHN/CIFAR-100
//! (testbed). Those are unavailable offline; we substitute a deterministic
//! Gaussian-mixture corpus that exercises the identical code paths — see
//! DESIGN.md §2. Class structure is what matters to DySTop: per-class
//! histograms feed the Dirichlet partitioner, EMD, and PTCA phase 1.

mod partition;
mod synthetic;

pub use partition::{dirichlet_partition, PartitionStats};
pub use synthetic::{SyntheticSpec, make_corpus};

/// A labelled dataset: row-major features `[n, dim]` + integer labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub dim: usize,
    pub num_classes: usize,
    pub features: Vec<f32>,
    pub labels: Vec<u32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn feature_row(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }

    /// Per-class sample counts (`D_i^k` of Eq. 45).
    pub fn label_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.num_classes];
        for &y in &self.labels {
            h[y as usize] += 1;
        }
        h
    }

    /// Normalised label distribution.
    pub fn label_distribution(&self) -> Vec<f64> {
        let h = self.label_histogram();
        let n = self.len().max(1) as f64;
        h.into_iter().map(|c| c as f64 / n).collect()
    }

    /// Select rows by index into a new dataset.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut features = Vec::with_capacity(idx.len() * self.dim);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            features.extend_from_slice(self.feature_row(i));
            labels.push(self.labels[i]);
        }
        Dataset { dim: self.dim, num_classes: self.num_classes, features, labels }
    }
}

/// Earth Mover's Distance between label distributions (Eq. 45).
///
/// The paper uses the per-class L1 form
/// `EMD(D_i, D_j) = Σ_k |D_i^k/D_i − D_j^k/D_j|`, bounded by \[0, 2\].
pub fn emd(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len(), "EMD over mismatched class counts");
    p.iter().zip(q).map(|(a, b)| (a - b).abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset {
            dim: 2,
            num_classes: 3,
            features: vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0],
            labels: vec![0, 1, 1, 2],
        }
    }

    #[test]
    fn histogram_counts() {
        assert_eq!(toy().label_histogram(), vec![1, 2, 1]);
    }

    #[test]
    fn distribution_sums_to_one() {
        let d = toy().label_distribution();
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(d, vec![0.25, 0.5, 0.25]);
    }

    #[test]
    fn subset_picks_rows() {
        let s = toy().subset(&[2, 0]);
        assert_eq!(s.labels, vec![1, 0]);
        assert_eq!(s.feature_row(0), &[4.0, 5.0]);
        assert_eq!(s.feature_row(1), &[0.0, 1.0]);
    }

    #[test]
    fn emd_properties() {
        let p = [0.5, 0.5, 0.0];
        let q = [0.0, 0.5, 0.5];
        // symmetric
        assert_eq!(emd(&p, &q), emd(&q, &p));
        // identity of indiscernibles
        assert_eq!(emd(&p, &p), 0.0);
        // disjoint one-hot distributions hit the max of 2
        assert_eq!(emd(&[1.0, 0.0], &[0.0, 1.0]), 2.0);
        // triangle inequality on this triple
        let r = [0.25, 0.25, 0.5];
        assert!(emd(&p, &q) <= emd(&p, &r) + emd(&r, &q) + 1e-12);
    }
}
