//! Deterministic Gaussian-mixture classification corpus.
//!
//! Each class `c` gets a unit-ish mean vector μ_c drawn once from the
//! corpus seed; samples are μ_c + ε with isotropic noise. `class_sep`
//! controls difficulty (separation / noise ratio). The corpus is split
//! into train and test partitions with matching class balance.

use super::Dataset;
use crate::util::rng::Pcg;

/// Parameters of the synthetic corpus.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub dim: usize,
    pub num_classes: usize,
    pub train_samples: usize,
    pub test_samples: usize,
    /// Separation of class means relative to unit noise.
    pub class_sep: f64,
    pub seed: u64,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec {
            dim: 32,
            num_classes: 10,
            train_samples: 12800,
            test_samples: 512,
            class_sep: 2.0,
            seed: 7,
        }
    }
}

/// Generate (train, test) datasets.
pub fn make_corpus(spec: &SyntheticSpec) -> (Dataset, Dataset) {
    let mut rng = Pcg::new(spec.seed, 0xDA7A);
    // class means
    let means: Vec<Vec<f32>> = (0..spec.num_classes)
        .map(|_| {
            let v = rng.normal_vec(spec.dim, 0.0, 1.0);
            let norm = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt().max(1e-9);
            v.iter()
                .map(|x| (*x as f64 / norm * spec.class_sep) as f32)
                .collect()
        })
        .collect();

    let gen = |n: usize, rng: &mut Pcg| -> Dataset {
        let mut features = Vec::with_capacity(n * spec.dim);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            // stratified labels: cycle classes then shuffle via index perm
            let y = (i % spec.num_classes) as u32;
            labels.push(y);
            let mu = &means[y as usize];
            for d in 0..spec.dim {
                features.push(mu[d] + rng.normal() as f32);
            }
        }
        let mut ds = Dataset {
            dim: spec.dim,
            num_classes: spec.num_classes,
            features,
            labels,
        };
        // shuffle rows
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        ds = ds.subset(&idx);
        ds
    };

    let train = gen(spec.train_samples, &mut rng);
    let test = gen(spec.test_samples, &mut rng);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let spec = SyntheticSpec { train_samples: 100, test_samples: 50, ..Default::default() };
        let (a, _) = make_corpus(&spec);
        let (b, _) = make_corpus(&spec);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn shapes_and_balance() {
        let spec = SyntheticSpec {
            train_samples: 1000,
            test_samples: 200,
            num_classes: 10,
            ..Default::default()
        };
        let (train, test) = make_corpus(&spec);
        assert_eq!(train.len(), 1000);
        assert_eq!(test.len(), 200);
        assert_eq!(train.features.len(), 1000 * spec.dim);
        // stratified: exactly equal class counts
        assert!(train.label_histogram().iter().all(|&c| c == 100));
        assert!(test.label_histogram().iter().all(|&c| c == 20));
    }

    #[test]
    fn classes_are_separated() {
        // nearest-class-mean classifier should beat chance comfortably
        let spec = SyntheticSpec {
            train_samples: 500,
            test_samples: 500,
            class_sep: 3.0,
            ..Default::default()
        };
        let (train, test) = make_corpus(&spec);
        // estimate class means from train
        let mut means = vec![vec![0.0f64; spec.dim]; spec.num_classes];
        let hist = train.label_histogram();
        for i in 0..train.len() {
            let y = train.labels[i] as usize;
            for d in 0..spec.dim {
                means[y][d] += train.feature_row(i)[d] as f64;
            }
        }
        for (c, m) in means.iter_mut().enumerate() {
            for v in m.iter_mut() {
                *v /= hist[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let row = test.feature_row(i);
            let pred = (0..spec.num_classes)
                .min_by(|&a, &b| {
                    let da: f64 = row
                        .iter()
                        .zip(&means[a])
                        .map(|(x, m)| (*x as f64 - m).powi(2))
                        .sum();
                    let db: f64 = row
                        .iter()
                        .zip(&means[b])
                        .map(|(x, m)| (*x as f64 - m).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred as u32 == test.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.6, "nearest-mean acc {acc}");
    }

    #[test]
    fn different_seeds_differ() {
        let a = make_corpus(&SyntheticSpec { seed: 1, train_samples: 64, ..Default::default() }).0;
        let b = make_corpus(&SyntheticSpec { seed: 2, train_samples: 64, ..Default::default() }).0;
        assert_ne!(a.features, b.features);
    }
}
