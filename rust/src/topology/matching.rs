//! Matching decomposition for the MATCHA baseline (paper \[9\]).
//!
//! MATCHA decomposes the base (undirected) communication graph into
//! disjoint matchings — subgraphs where every worker talks to at most one
//! peer — and activates a random subset of matchings each round. A greedy
//! edge-coloring (Misra–Gries flavoured, but greedy suffices for the
//! baseline: at most 2Δ−1 matchings) reproduces the mechanism.

use crate::util::rng::Pcg;

/// One matching: a set of disjoint undirected pairs.
#[derive(Clone, Debug, Default)]
pub struct Matching {
    pub pairs: Vec<(usize, usize)>,
}

impl Matching {
    /// No vertex may appear twice.
    pub fn is_valid(&self) -> bool {
        let mut seen = std::collections::BTreeSet::new();
        for &(a, b) in &self.pairs {
            if a == b || !seen.insert(a) || !seen.insert(b) {
                return false;
            }
        }
        true
    }
}

/// Greedily decompose undirected edges into disjoint matchings.
pub fn greedy_matching_decomposition(
    n: usize,
    edges: &[(usize, usize)],
) -> Vec<Matching> {
    let mut matchings: Vec<Matching> = Vec::new();
    let mut used: Vec<Vec<bool>> = Vec::new(); // used[m][v]
    for &(a, b) in edges {
        assert!(a < n && b < n && a != b, "bad edge ({a},{b})");
        let slot = (0..matchings.len())
            .find(|&m| !used[m][a] && !used[m][b])
            .unwrap_or_else(|| {
                matchings.push(Matching::default());
                used.push(vec![false; n]);
                matchings.len() - 1
            });
        matchings[slot].pairs.push((a, b));
        used[slot][a] = true;
        used[slot][b] = true;
    }
    matchings
}

/// Sample a subset of matchings (MATCHA's per-round activation with
/// communication budget `frac` ∈ (0, 1]).
pub fn sample_matchings<'a>(
    matchings: &'a [Matching],
    frac: f64,
    rng: &mut Pcg,
) -> Vec<&'a Matching> {
    matchings.iter().filter(|_| rng.f64() < frac).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn decomposition_covers_all_edges() {
        let edges = vec![(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)];
        let ms = greedy_matching_decomposition(4, &edges);
        let total: usize = ms.iter().map(|m| m.pairs.len()).sum();
        assert_eq!(total, edges.len());
        for m in &ms {
            assert!(m.is_valid(), "{m:?}");
        }
    }

    #[test]
    fn star_graph_needs_degree_matchings() {
        // star: center 0 to 5 leaves — every edge shares vertex 0
        let edges: Vec<_> = (1..=5).map(|i| (0, i)).collect();
        let ms = greedy_matching_decomposition(6, &edges);
        assert_eq!(ms.len(), 5);
        for m in &ms {
            assert_eq!(m.pairs.len(), 1);
        }
    }

    #[test]
    fn property_matchings_always_disjoint() {
        forall(31, |rng| {
            let n = 4 + rng.below_usize(30);
            let mut edges = Vec::new();
            for i in 0..n {
                for j in (i + 1)..n {
                    if rng.f64() < 0.3 {
                        edges.push((i, j));
                    }
                }
            }
            let ms = greedy_matching_decomposition(n, &edges);
            assert_eq!(
                ms.iter().map(|m| m.pairs.len()).sum::<usize>(),
                edges.len()
            );
            for m in &ms {
                assert!(m.is_valid());
            }
            // greedy bound: at most 2Δ − 1 colors
            let mut deg = vec![0usize; n];
            for &(a, b) in &edges {
                deg[a] += 1;
                deg[b] += 1;
            }
            let delta = deg.into_iter().max().unwrap_or(0);
            if delta > 0 {
                assert!(ms.len() <= 2 * delta - 1, "{} > 2*{delta}-1", ms.len());
            }
        });
    }

    #[test]
    fn sampling_respects_frac_extremes() {
        let edges = vec![(0, 1), (1, 2), (2, 3)];
        let ms = greedy_matching_decomposition(4, &edges);
        let mut rng = Pcg::seeded(5);
        assert_eq!(sample_matchings(&ms, 1.0, &mut rng).len(), ms.len());
        assert!(sample_matchings(&ms, 0.0, &mut rng).is_empty());
    }
}
