//! Topology substrate: the per-round directed communication graph
//! `G_t = (V_t, E_t)` (paper §III-A), plus the matching decomposition the
//! MATCHA baseline needs.

mod matching;

pub use matching::{greedy_matching_decomposition, sample_matchings, Matching};

use std::collections::BTreeSet;

/// Directed graph over `n` workers; edge `(i → j)` means `i` transmits to
/// `j` (so `i ∈ N_t^j`, the in-neighbor set of `j`).
#[derive(Clone, Debug, Default)]
pub struct Topology {
    n: usize,
    /// in_neighbors[j] = sorted set of i with edge i→j (excluding j).
    in_neighbors: Vec<BTreeSet<usize>>,
    /// out_neighbors[i] = sorted set of j with edge i→j (excluding i).
    out_neighbors: Vec<BTreeSet<usize>>,
}

impl Topology {
    pub fn new(n: usize) -> Self {
        Topology {
            n,
            in_neighbors: vec![BTreeSet::new(); n],
            out_neighbors: vec![BTreeSet::new(); n],
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Add directed edge `from → to`. Self-loops are implicit (every
    /// worker aggregates its own model, §III-A) and rejected here.
    pub fn add_edge(&mut self, from: usize, to: usize) {
        assert!(from < self.n && to < self.n, "edge out of range");
        assert_ne!(from, to, "self-loops are implicit");
        self.in_neighbors[to].insert(from);
        self.out_neighbors[from].insert(to);
    }

    pub fn has_edge(&self, from: usize, to: usize) -> bool {
        from < self.n && self.out_neighbors[from].contains(&to)
    }

    /// In-neighbors of `j` *excluding* j itself (the explicit pulls).
    pub fn in_neighbors(&self, j: usize) -> impl Iterator<Item = usize> + '_ {
        self.in_neighbors[j].iter().copied()
    }

    pub fn out_neighbors(&self, i: usize) -> impl Iterator<Item = usize> + '_ {
        self.out_neighbors[i].iter().copied()
    }

    pub fn in_degree(&self, j: usize) -> usize {
        self.in_neighbors[j].len()
    }

    pub fn out_degree(&self, i: usize) -> usize {
        self.out_neighbors[i].len()
    }

    pub fn edge_count(&self) -> usize {
        self.out_neighbors.iter().map(|s| s.len()).sum()
    }

    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut v = Vec::with_capacity(self.edge_count());
        for (i, outs) in self.out_neighbors.iter().enumerate() {
            for &j in outs {
                v.push((i, j));
            }
        }
        v
    }

    /// Undirected connectivity check over the union of edge directions
    /// (used by tests: a topology that fragments the network forever
    /// cannot mix models).
    pub fn weakly_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = stack.pop() {
            for &v in self.out_neighbors[u].iter().chain(self.in_neighbors[u].iter()) {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count == self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_query_edges() {
        let mut t = Topology::new(4);
        t.add_edge(0, 1);
        t.add_edge(2, 1);
        t.add_edge(1, 3);
        assert!(t.has_edge(0, 1));
        assert!(!t.has_edge(1, 0));
        assert_eq!(t.in_neighbors(1).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(t.out_neighbors(1).collect::<Vec<_>>(), vec![3]);
        assert_eq!(t.in_degree(1), 2);
        assert_eq!(t.out_degree(1), 1);
        assert_eq!(t.edge_count(), 3);
    }

    #[test]
    #[should_panic(expected = "self-loops")]
    fn self_loop_rejected() {
        Topology::new(2).add_edge(1, 1);
    }

    #[test]
    fn duplicate_edges_idempotent() {
        let mut t = Topology::new(3);
        t.add_edge(0, 1);
        t.add_edge(0, 1);
        assert_eq!(t.edge_count(), 1);
    }

    #[test]
    fn connectivity() {
        let mut t = Topology::new(4);
        t.add_edge(0, 1);
        t.add_edge(1, 2);
        assert!(!t.weakly_connected());
        t.add_edge(3, 2);
        assert!(t.weakly_connected());
    }

    #[test]
    fn empty_graph_connected() {
        assert!(Topology::new(0).weakly_connected());
        assert!(Topology::new(1).weakly_connected());
        assert!(!Topology::new(2).weakly_connected());
    }
}
