//! Worker substrate: per-worker state (model, staleness, data shard) and
//! the training backends.
//!
//! Two [`Trainer`] implementations exist:
//!
//! * [`NativeTrainer`] — pure-Rust SGD over the native model zoo
//!   ([`crate::workload`]: `workload.model=linear|mlp|cnn-s`). A fast,
//!   dependency-free substrate used by the large-scale simulations,
//!   property tests and benches (the paper's mechanisms are
//!   model-agnostic).
//! * `PjrtTrainer` (in [`crate::runtime`]) — the real L2/L1 model
//!   executed from the AOT HLO artifacts, used by the end-to-end examples
//!   and the testbed.

mod native;
mod state;

pub use native::NativeTrainer;
pub use state::WorkerState;

use crate::config::{ExperimentConfig, TrainerKind};
use crate::data::Dataset;
use crate::util::rng::Pcg;

/// Default trainer factory for a config: `Some` when the configured
/// [`TrainerKind`] can be constructed without external inputs (the
/// native trainer over the configured `workload.model`), `None` when
/// the caller must supply one (PJRT trainers need an artifact directory
/// — pass them via `ExperimentBuilder::trainer`).
pub fn default_trainer(cfg: &ExperimentConfig) -> Option<Box<dyn Trainer>> {
    match cfg.trainer {
        TrainerKind::Native => {
            Some(Box::new(NativeTrainer::from_config(cfg)))
        }
        TrainerKind::Pjrt => None,
    }
}

/// A flattened model parameter vector (layout per artifacts/manifest.json
/// for PJRT models; `[dim·C + C]` for the native trainer).
pub type Params = Vec<f32>;

/// Training backend interface. All methods are deterministic given `rng`.
pub trait Trainer {
    /// Length of the flattened parameter vector.
    fn param_count(&self) -> usize;

    /// Fresh initial parameters.
    fn init(&self, seed: u64) -> Params;

    /// Run `steps` minibatch-SGD steps (Eq. 5) on `shard`; returns the new
    /// parameters and the mean minibatch loss.
    fn train(
        &mut self,
        params: &[f32],
        shard: &Dataset,
        steps: usize,
        batch: usize,
        lr: f32,
        rng: &mut Pcg,
    ) -> (Params, f64);

    /// Evaluate on `data`: (mean loss, accuracy).
    fn evaluate(&mut self, params: &[f32], data: &Dataset) -> (f64, f64);

    /// Weighted aggregation (Eq. 4). Weights must sum to 1.
    fn aggregate(&mut self, models: &[&[f32]], weights: &[f32]) -> Params {
        aggregate_native(models, weights)
    }

    /// Weighted aggregation (Eq. 4) into a reusable buffer (`out` is
    /// overwritten). The engines call this on the round hot path so the
    /// per-activation aggregate allocates nothing; the default routes
    /// through [`aggregate`](Self::aggregate) so trainers that override
    /// only that (e.g. the Pallas-kernel PJRT aggregate) keep their fast
    /// path.
    fn aggregate_into(
        &mut self,
        models: &[&[f32]],
        weights: &[f32],
        out: &mut Params,
    ) {
        let r = self.aggregate(models, weights);
        out.clear();
        out.extend_from_slice(&r);
    }

    /// Clone this trainer for one slot of the parallel round executor
    /// (each pool thread owns its clone, keeping scratch thread-local).
    /// `None` — the default — keeps round execution sequential; correct
    /// for trainers whose state cannot cross threads (PJRT executables).
    fn clone_box(&self) -> Option<Box<dyn Trainer + Send>> {
        None
    }
}

/// Reference CPU aggregation: `Σ_j σ_j · w_j` over flattened models.
pub fn aggregate_native(models: &[&[f32]], weights: &[f32]) -> Params {
    let mut out = Params::new();
    aggregate_native_into(models, weights, &mut out);
    out
}

/// [`aggregate_native`] into a reusable buffer (no allocation once `out`
/// has the right capacity).
pub fn aggregate_native_into(
    models: &[&[f32]],
    weights: &[f32],
    out: &mut Params,
) {
    assert_eq!(models.len(), weights.len());
    assert!(!models.is_empty(), "aggregate of zero models");
    let p = models[0].len();
    let wsum: f32 = weights.iter().sum();
    debug_assert!(
        (wsum - 1.0).abs() < 1e-3,
        "aggregation weights must sum to 1 (got {wsum})"
    );
    out.clear();
    out.resize(p, 0.0);
    for (m, &w) in models.iter().zip(weights) {
        assert_eq!(m.len(), p, "model length mismatch");
        for (o, &x) in out.iter_mut().zip(m.iter()) {
            *o += w * x;
        }
    }
}

/// Aggregation weights σ_t^{i,j} = D_j / Σ D_{j'} over the in-neighbor
/// set (paper Eq. 4); `sizes` aligned with `models`.
pub fn data_size_weights(sizes: &[usize]) -> Vec<f32> {
    let mut out = Vec::new();
    data_size_weights_into(sizes, &mut out);
    out
}

/// [`data_size_weights`] into a reusable buffer.
pub fn data_size_weights_into(sizes: &[usize], out: &mut Vec<f32>) {
    let total: usize = sizes.iter().sum();
    assert!(total > 0, "aggregation over empty datasets");
    out.clear();
    out.extend(sizes.iter().map(|&s| s as f32 / total as f32));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_sum_to_one() {
        let w = data_size_weights(&[10, 30, 60]);
        assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!((w[2] - 0.6).abs() < 1e-6);
    }

    #[test]
    fn aggregate_mean_of_two() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 6.0];
        let out = aggregate_native(&[&a, &b], &[0.5, 0.5]);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn aggregate_identity_single() {
        let a = vec![1.5f32, -2.0, 0.25];
        assert_eq!(aggregate_native(&[&a], &[1.0]), a);
    }

    #[test]
    #[should_panic(expected = "zero models")]
    fn aggregate_empty_panics() {
        aggregate_native(&[], &[]);
    }

    #[test]
    fn aggregate_into_reuses_buffer_and_matches() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 6.0];
        let mut out = vec![9.0f32; 7]; // stale content must be overwritten
        aggregate_native_into(&[&a, &b], &[0.5, 0.5], &mut out);
        assert_eq!(out, aggregate_native(&[&a, &b], &[0.5, 0.5]));
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    fn weights_into_matches_allocating_variant() {
        let sizes = [10usize, 30, 60];
        let mut out = vec![0.5f32; 1];
        data_size_weights_into(&sizes, &mut out);
        assert_eq!(out, data_size_weights(&sizes));
    }
}
