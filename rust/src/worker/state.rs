//! Per-worker runtime state tracked by the simulator and the testbed.

use super::Params;
use crate::data::Dataset;

/// State of one worker `v_i` (paper §III-A/B).
#[derive(Clone, Debug)]
pub struct WorkerState {
    pub id: usize,
    /// Current local model `w_t^i` — last updated at its latest
    /// activation, so pulling from this worker naturally yields the stale
    /// `w_{t−τ}^i` of Eq. (3).
    pub params: Params,
    /// Staleness τ_t^i (Eq. 6).
    pub staleness: u64,
    /// Lyapunov virtual queue q_t^i (Eq. 33).
    pub queue: f64,
    /// Local training shard D_i.
    pub shard: Dataset,
    /// Latent full local-training time h_i in seconds (heterogeneous).
    pub h_train_s: f64,
    /// Residual compute h_t^{i,cmp} (Eq. 7): seconds of the current local
    /// training job still outstanding.
    pub residual_s: f64,
    /// Last recorded local training loss.
    pub last_loss: f64,
    /// Activation count (→ activating frequency ψ_i of Theorem 1).
    pub activations: u64,
}

impl WorkerState {
    pub fn new(id: usize, params: Params, shard: Dataset, h_train_s: f64) -> Self {
        WorkerState {
            id,
            params,
            staleness: 0,
            queue: 0.0,
            shard,
            h_train_s,
            residual_s: h_train_s,
            last_loss: f64::NAN,
            activations: 0,
        }
    }

    pub fn data_size(&self) -> usize {
        self.shard.len()
    }

    /// Advance this worker's background local training by `dt` seconds.
    pub fn advance(&mut self, dt: f64) {
        self.residual_s = (self.residual_s - dt).max(0.0);
    }

    /// Called when the coordinator activates this worker: staleness
    /// resets (Eq. 6) and a fresh local-training job starts.
    pub fn on_activated(&mut self) {
        self.staleness = 0;
        self.residual_s = self.h_train_s;
        self.activations += 1;
    }

    /// Called each round for non-activated workers (Eq. 6).
    pub fn on_skipped(&mut self) {
        self.staleness += 1;
    }

    /// Lyapunov queue update (Eq. 33).
    pub fn update_queue(&mut self, tau_bound: u64) {
        self.queue =
            (self.queue + self.staleness as f64 - tau_bound as f64).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker() -> WorkerState {
        let shard = Dataset {
            dim: 1,
            num_classes: 2,
            features: vec![0.0, 1.0],
            labels: vec![0, 1],
        };
        WorkerState::new(0, vec![0.0; 4], shard, 2.0)
    }

    #[test]
    fn staleness_cycle() {
        let mut w = worker();
        w.on_skipped();
        w.on_skipped();
        assert_eq!(w.staleness, 2);
        w.on_activated();
        assert_eq!(w.staleness, 0);
        assert_eq!(w.activations, 1);
        assert_eq!(w.residual_s, 2.0);
    }

    #[test]
    fn residual_depletes_not_below_zero() {
        let mut w = worker();
        w.advance(1.5);
        assert!((w.residual_s - 0.5).abs() < 1e-12);
        w.advance(10.0);
        assert_eq!(w.residual_s, 0.0);
    }

    #[test]
    fn queue_tracks_excess_staleness() {
        let mut w = worker();
        // τ below bound: queue stays at 0
        w.staleness = 1;
        w.update_queue(3);
        assert_eq!(w.queue, 0.0);
        // τ above bound: queue grows by τ − bound
        w.staleness = 5;
        w.update_queue(3);
        assert_eq!(w.queue, 2.0);
        w.update_queue(3);
        assert_eq!(w.queue, 4.0);
        // recovers once staleness drops
        w.staleness = 0;
        w.update_queue(3);
        assert_eq!(w.queue, 1.0);
    }
}
