//! Pure-Rust softmax-regression trainer.
//!
//! Parameter layout: `[W (dim × C) row-major, b (C)]`, matching the
//! flat-vector contract of the PJRT trainers so all coordinator code is
//! backend-agnostic.

use super::{Params, Trainer};
use crate::data::Dataset;
use crate::util::rng::Pcg;

#[derive(Clone, Debug)]
pub struct NativeTrainer {
    pub dim: usize,
    pub num_classes: usize,
    /// Scratch: per-class logits/probabilities.
    scratch: Vec<f64>,
}

impl NativeTrainer {
    pub fn new(dim: usize, num_classes: usize) -> Self {
        NativeTrainer { dim, num_classes, scratch: vec![0.0; num_classes] }
    }

    fn logits(&mut self, params: &[f32], x: &[f32]) {
        let c = self.num_classes;
        let d = self.dim;
        let bias = &params[d * c..];
        for k in 0..c {
            self.scratch[k] = bias[k] as f64;
        }
        // W row-major [d][c]: logit_k += x_j * W[j][k]
        for (j, &xj) in x.iter().enumerate() {
            if xj == 0.0 {
                continue;
            }
            let row = &params[j * c..(j + 1) * c];
            for k in 0..c {
                self.scratch[k] += xj as f64 * row[k] as f64;
            }
        }
    }

    /// In-place softmax over scratch; returns log-sum-exp.
    fn softmax(&mut self) -> f64 {
        let m = self.scratch.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut sum = 0.0;
        for v in &mut self.scratch {
            *v = (*v - m).exp();
            sum += *v;
        }
        for v in &mut self.scratch {
            *v /= sum;
        }
        m + sum.ln()
    }
}

impl Trainer for NativeTrainer {
    fn param_count(&self) -> usize {
        self.dim * self.num_classes + self.num_classes
    }

    fn init(&self, seed: u64) -> Params {
        let mut rng = Pcg::new(seed, 0x1217);
        let std = (2.0 / self.dim as f64).sqrt() * 0.5;
        let mut p = rng.normal_vec(self.dim * self.num_classes, 0.0, std);
        p.extend(std::iter::repeat(0.0f32).take(self.num_classes));
        p
    }

    fn train(
        &mut self,
        params: &[f32],
        shard: &Dataset,
        steps: usize,
        batch: usize,
        lr: f32,
        rng: &mut Pcg,
    ) -> (Params, f64) {
        assert_eq!(params.len(), self.param_count());
        assert_eq!(shard.dim, self.dim);
        assert!(!shard.is_empty(), "training on empty shard");
        let c = self.num_classes;
        let d = self.dim;
        let mut p = params.to_vec();
        let mut loss_acc = 0.0;
        let batch = batch.min(shard.len());
        for _ in 0..steps {
            let idx = rng.sample_indices(shard.len(), batch);
            // grad accumulators
            let mut gw = vec![0.0f64; d * c];
            let mut gb = vec![0.0f64; c];
            let mut loss = 0.0f64;
            for &i in &idx {
                let x = shard.feature_row(i);
                let y = shard.labels[i] as usize;
                self.logits(&p, x);
                let gold = self.scratch[y];
                let lse = self.softmax();
                loss += lse - gold;
                // dlogit_k = p_k - 1[k==y]
                for k in 0..c {
                    let dk = self.scratch[k] - if k == y { 1.0 } else { 0.0 };
                    gb[k] += dk;
                    for (j, &xj) in x.iter().enumerate() {
                        if xj != 0.0 {
                            gw[j * c + k] += dk * xj as f64;
                        }
                    }
                }
            }
            let scale = lr as f64 / batch as f64;
            for (w, g) in p[..d * c].iter_mut().zip(&gw) {
                *w -= (scale * g) as f32;
            }
            for (b, g) in p[d * c..].iter_mut().zip(&gb) {
                *b -= (scale * g) as f32;
            }
            loss_acc += loss / batch as f64;
        }
        (p, loss_acc / steps.max(1) as f64)
    }

    fn evaluate(&mut self, params: &[f32], data: &Dataset) -> (f64, f64) {
        assert!(!data.is_empty());
        let mut loss = 0.0;
        let mut correct = 0usize;
        for i in 0..data.len() {
            let x = data.feature_row(i);
            let y = data.labels[i] as usize;
            self.logits(params, x);
            let gold = self.scratch[y];
            let lse = self.softmax();
            loss += lse - gold;
            let pred = self
                .scratch
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == y {
                correct += 1;
            }
        }
        (loss / data.len() as f64, correct as f64 / data.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{make_corpus, SyntheticSpec};

    fn setup() -> (NativeTrainer, Dataset, Dataset) {
        let spec = SyntheticSpec {
            train_samples: 600,
            test_samples: 300,
            class_sep: 2.5,
            ..Default::default()
        };
        let (train, test) = make_corpus(&spec);
        (NativeTrainer::new(spec.dim, spec.num_classes), train, test)
    }

    #[test]
    fn param_count_layout() {
        let t = NativeTrainer::new(32, 10);
        assert_eq!(t.param_count(), 32 * 10 + 10);
        assert_eq!(t.init(1).len(), t.param_count());
    }

    #[test]
    fn loss_decreases_and_accuracy_rises() {
        let (mut t, train, test) = setup();
        let mut rng = Pcg::seeded(1);
        let p0 = t.init(0);
        let (l0, a0) = t.evaluate(&p0, &test);
        let (p1, _) = t.train(&p0, &train, 60, 32, 0.2, &mut rng);
        let (l1, a1) = t.evaluate(&p1, &test);
        assert!(l1 < l0 * 0.8, "loss {l0} → {l1}");
        assert!(a1 > a0 + 0.2, "acc {a0} → {a1}");
        assert!(a1 > 0.6, "final acc {a1}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (mut t, train, _) = setup();
        let p0 = t.init(0);
        let (a, la) = t.train(&p0, &train, 5, 16, 0.1, &mut Pcg::seeded(3));
        let (b, lb) = t.train(&p0, &train, 5, 16, 0.1, &mut Pcg::seeded(3));
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn eval_of_zero_params_is_chance() {
        let (mut t, _, test) = setup();
        let zeros = vec![0.0f32; t.param_count()];
        let (loss, acc) = t.evaluate(&zeros, &test);
        assert!((loss - (10f64).ln()).abs() < 1e-6);
        assert!(acc < 0.35);
    }

    #[test]
    fn batch_larger_than_shard_clamps() {
        let (mut t, train, _) = setup();
        let small = train.subset(&[0, 1, 2]);
        let p0 = t.init(0);
        let (_p, loss) = t.train(&p0, &small, 2, 999, 0.1, &mut Pcg::seeded(5));
        assert!(loss.is_finite());
    }
}
