//! Pure-Rust SGD trainer over the native model zoo.
//!
//! `NativeTrainer` is the minibatch-SGD *driver*: batch sampling, the
//! gradient accumulator, the parameter update, and aggregation. The
//! architecture — parameter layout, initialisation, per-sample
//! forward/backward — lives behind the [`Model`] contract
//! ([`crate::workload`]), so init, the gradient buffer and the layout
//! assertions are all derived from one `Model::layout()` description
//! and cannot drift apart.
//!
//! The train/eval hot path is allocation-free after construction: the
//! batch-index sample and the flat gradient accumulator live in
//! reusable scratch owned by the trainer, and each model keeps its own
//! forward/backward scratch (fused feature-major passes, f32
//! arithmetic with f64 reserved for the loss accumulator).
//!
//! `NativeTrainer::new(dim, classes)` builds the historical softmax
//! regression ([`LinearModel`]) — bit-compatible, op for op and draw
//! for draw, with the pre-workload trainer.

use super::{aggregate_native_into, Params, Trainer};
use crate::config::ExperimentConfig;
use crate::data::Dataset;
use crate::util::rng::Pcg;
use crate::workload::{build_model, LinearModel, Model, ParamLayout};
use std::fmt;

pub struct NativeTrainer {
    model: Box<dyn Model>,
    /// Scratch: flat minibatch gradient accumulator, sized by
    /// `Model::layout()`.
    grad: Vec<f32>,
    /// Scratch: minibatch index sample.
    idx: Vec<usize>,
}

impl Clone for NativeTrainer {
    fn clone(&self) -> Self {
        NativeTrainer {
            model: self.model.clone_model(),
            grad: vec![0.0; self.grad.len()],
            idx: Vec::new(),
        }
    }
}

impl fmt::Debug for NativeTrainer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("NativeTrainer")
            .field("model", &self.model.name())
            .field("params", &self.model.param_count())
            .finish()
    }
}

impl NativeTrainer {
    /// The historical default: linear softmax regression over `dim`
    /// features — bit-compatible with the pre-workload trainer.
    pub fn new(dim: usize, num_classes: usize) -> Self {
        Self::with_model(Box::new(LinearModel::new(dim, num_classes)))
    }

    /// Drive an explicit model instance.
    pub fn with_model(model: Box<dyn Model>) -> Self {
        let grad = vec![0.0; model.param_count()];
        NativeTrainer { model, grad, idx: Vec::new() }
    }

    /// Build the configured `workload.model` over the config's feature
    /// dim / class count. Infallible once the config has validated.
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        Self::with_model(build_model(
            &cfg.workload,
            cfg.feature_dim,
            cfg.num_classes,
        ))
    }

    /// The driven model's registry name.
    pub fn model_name(&self) -> &'static str {
        self.model.name()
    }

    /// The driven model's parameter layout.
    pub fn layout(&self) -> &ParamLayout {
        self.model.layout()
    }
}

impl Trainer for NativeTrainer {
    fn param_count(&self) -> usize {
        self.model.param_count()
    }

    fn init(&self, seed: u64) -> Params {
        self.model.init(seed)
    }

    fn train(
        &mut self,
        params: &[f32],
        shard: &Dataset,
        steps: usize,
        batch: usize,
        lr: f32,
        rng: &mut Pcg,
    ) -> (Params, f64) {
        assert_eq!(
            params.len(),
            self.model.param_count(),
            "param vector does not match the {} layout",
            self.model.name()
        );
        assert_eq!(shard.dim, self.model.input_dim());
        assert!(!shard.is_empty(), "training on empty shard");
        let mut p = params.to_vec();
        let mut loss_acc = 0.0;
        let batch = batch.min(shard.len());
        for _ in 0..steps {
            rng.sample_indices_into(shard.len(), batch, &mut self.idx);
            self.grad.fill(0.0);
            let mut loss = 0.0f64;
            // lift the index buffer out so iterating it doesn't hold a
            // borrow of self across grad_sample (restored below)
            let idx = std::mem::take(&mut self.idx);
            for &i in &idx {
                let x = shard.feature_row(i);
                let y = shard.labels[i] as usize;
                loss += self.model.grad_sample(&p, x, y, &mut self.grad);
            }
            self.idx = idx;
            let scale = lr / batch as f32;
            for (w, &g) in p.iter_mut().zip(&self.grad) {
                *w -= scale * g;
            }
            loss_acc += loss / batch as f64;
        }
        (p, loss_acc / steps.max(1) as f64)
    }

    fn evaluate(&mut self, params: &[f32], data: &Dataset) -> (f64, f64) {
        assert!(!data.is_empty());
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for i in 0..data.len() {
            let x = data.feature_row(i);
            let y = data.labels[i] as usize;
            let (l, pred) = self.model.predict(params, x, y);
            loss += l;
            if pred == y {
                correct += 1;
            }
        }
        (loss / data.len() as f64, correct as f64 / data.len() as f64)
    }

    fn aggregate_into(
        &mut self,
        models: &[&[f32]],
        weights: &[f32],
        out: &mut Params,
    ) {
        aggregate_native_into(models, weights, out);
    }

    fn clone_box(&self) -> Option<Box<dyn Trainer + Send>> {
        Some(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelArch;
    use crate::data::{make_corpus, SyntheticSpec};
    use crate::workload::MODELS;

    fn setup() -> (NativeTrainer, Dataset, Dataset) {
        let spec = SyntheticSpec {
            train_samples: 600,
            test_samples: 300,
            class_sep: 2.5,
            ..Default::default()
        };
        let (train, test) = make_corpus(&spec);
        (NativeTrainer::new(spec.dim, spec.num_classes), train, test)
    }

    fn trainer_for(arch: ModelArch) -> NativeTrainer {
        let cfg = ExperimentConfig {
            workload: crate::config::WorkloadConfig {
                model: arch,
                ..Default::default()
            },
            ..Default::default()
        };
        NativeTrainer::from_config(&cfg)
    }

    #[test]
    fn param_count_layout() {
        let t = NativeTrainer::new(32, 10);
        assert_eq!(t.param_count(), 32 * 10 + 10);
        assert_eq!(t.init(1).len(), t.param_count());
        assert_eq!(t.model_name(), "linear");
    }

    #[test]
    fn every_registered_model_derives_sizes_from_its_layout() {
        // init length, gradient buffer and param_count all come from
        // Model::layout() — the three spots the old trainer hardcoded
        for arch in MODELS {
            let t = trainer_for(arch);
            assert_eq!(t.param_count(), t.layout().total(), "{arch:?}");
            assert_eq!(t.init(2).len(), t.layout().total(), "{arch:?}");
            assert_eq!(t.grad.len(), t.layout().total(), "{arch:?}");
        }
    }

    #[test]
    fn loss_decreases_and_accuracy_rises() {
        let (mut t, train, test) = setup();
        let mut rng = Pcg::seeded(1);
        let p0 = t.init(0);
        let (l0, a0) = t.evaluate(&p0, &test);
        let (p1, _) = t.train(&p0, &train, 60, 32, 0.2, &mut rng);
        let (l1, a1) = t.evaluate(&p1, &test);
        assert!(l1 < l0 * 0.8, "loss {l0} → {l1}");
        assert!(a1 > a0 + 0.2, "acc {a0} → {a1}");
        assert!(a1 > 0.6, "final acc {a1}");
    }

    #[test]
    fn every_registered_model_learns() {
        let spec = SyntheticSpec {
            train_samples: 600,
            test_samples: 300,
            class_sep: 2.5,
            ..Default::default()
        };
        let (train, test) = make_corpus(&spec);
        for arch in MODELS {
            let mut t = trainer_for(arch);
            let mut rng = Pcg::seeded(1);
            let p0 = t.init(0);
            let (_, a0) = t.evaluate(&p0, &test);
            let (p1, _) = t.train(&p0, &train, 80, 32, 0.2, &mut rng);
            let (_, a1) = t.evaluate(&p1, &test);
            assert!(
                a1 > a0 + 0.15 && a1 > 0.5,
                "{arch:?}: acc {a0} → {a1}"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (mut t, train, _) = setup();
        let p0 = t.init(0);
        let (a, la) = t.train(&p0, &train, 5, 16, 0.1, &mut Pcg::seeded(3));
        let (b, lb) = t.train(&p0, &train, 5, 16, 0.1, &mut Pcg::seeded(3));
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn clone_box_trains_identically_to_the_original() {
        // the parallel engine hands each pool thread a clone — cloned
        // scratch must not change results, for any registered model
        let spec = SyntheticSpec {
            train_samples: 300,
            test_samples: 50,
            ..Default::default()
        };
        let (train, _) = make_corpus(&spec);
        for arch in MODELS {
            let mut t = trainer_for(arch);
            let p0 = t.init(0);
            let mut c = t.clone_box().expect("native trainer is cloneable");
            let (a, la) =
                t.train(&p0, &train, 3, 16, 0.1, &mut Pcg::seeded(3));
            let (b, lb) =
                c.train(&p0, &train, 3, 16, 0.1, &mut Pcg::seeded(3));
            assert_eq!(a, b, "{arch:?}");
            assert_eq!(la, lb, "{arch:?}");
        }
    }

    #[test]
    fn eval_of_zero_params_is_chance() {
        let (mut t, _, test) = setup();
        let zeros = vec![0.0f32; t.param_count()];
        let (loss, acc) = t.evaluate(&zeros, &test);
        assert!((loss - (10f64).ln()).abs() < 1e-6);
        assert!(acc < 0.35);
    }

    #[test]
    fn evaluate_with_nan_params_does_not_panic() {
        // regression: the old argmax used partial_cmp().unwrap(), which
        // panicked as soon as a hot LR produced NaN parameters
        let (mut t, _, test) = setup();
        let p = vec![f32::NAN; t.param_count()];
        let (loss, acc) = t.evaluate(&p, &test);
        assert!(loss.is_nan());
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn batch_larger_than_shard_clamps() {
        let (mut t, train, _) = setup();
        let small = train.subset(&[0, 1, 2]);
        let p0 = t.init(0);
        let (_p, loss) = t.train(&p0, &small, 2, 999, 0.1, &mut Pcg::seeded(5));
        assert!(loss.is_finite());
    }
}
