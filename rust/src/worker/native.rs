//! Pure-Rust softmax-regression trainer.
//!
//! Parameter layout: `[W (dim × C) row-major, b (C)]`, matching the
//! flat-vector contract of the PJRT trainers so all coordinator code is
//! backend-agnostic.
//!
//! The train/eval hot path is allocation-free after construction: batch
//! indices, logits and gradient accumulators live in reusable scratch
//! owned by the trainer, and the gradient update is one fused
//! feature-major pass per sample (contiguous `gw` row writes) in f32
//! arithmetic — only the loss accumulates in f64.

use super::{aggregate_native_into, Params, Trainer};
use crate::data::Dataset;
use crate::util::rng::Pcg;

#[derive(Clone, Debug)]
pub struct NativeTrainer {
    pub dim: usize,
    pub num_classes: usize,
    /// Scratch: per-class logits, softmaxed in place to probabilities.
    logits: Vec<f32>,
    /// Scratch: per-class logit gradient δ_k = p_k − 1[k==y].
    delta: Vec<f32>,
    /// Scratch: minibatch gradient accumulators for W and b.
    gw: Vec<f32>,
    gb: Vec<f32>,
    /// Scratch: minibatch index sample.
    idx: Vec<usize>,
}

impl NativeTrainer {
    pub fn new(dim: usize, num_classes: usize) -> Self {
        NativeTrainer {
            dim,
            num_classes,
            logits: vec![0.0; num_classes],
            delta: vec![0.0; num_classes],
            gw: vec![0.0; dim * num_classes],
            gb: vec![0.0; num_classes],
            idx: Vec::new(),
        }
    }

    fn compute_logits(&mut self, params: &[f32], x: &[f32]) {
        let c = self.num_classes;
        let d = self.dim;
        self.logits.copy_from_slice(&params[d * c..]);
        // W row-major [d][c]: logit_k += x_j * W[j][k]
        for (j, &xj) in x.iter().enumerate() {
            if xj == 0.0 {
                continue;
            }
            let row = &params[j * c..(j + 1) * c];
            for (l, &w) in self.logits.iter_mut().zip(row) {
                *l += xj * w;
            }
        }
    }

    /// In-place softmax over the logits scratch; returns log-sum-exp.
    fn softmax(&mut self) -> f32 {
        let m = self.logits.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut sum = 0.0f32;
        for v in &mut self.logits {
            *v = (*v - m).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in &mut self.logits {
            *v *= inv;
        }
        m + sum.ln()
    }
}

impl Trainer for NativeTrainer {
    fn param_count(&self) -> usize {
        self.dim * self.num_classes + self.num_classes
    }

    fn init(&self, seed: u64) -> Params {
        let mut rng = Pcg::new(seed, 0x1217);
        let std = (2.0 / self.dim as f64).sqrt() * 0.5;
        let mut p = rng.normal_vec(self.dim * self.num_classes, 0.0, std);
        p.extend(std::iter::repeat(0.0f32).take(self.num_classes));
        p
    }

    fn train(
        &mut self,
        params: &[f32],
        shard: &Dataset,
        steps: usize,
        batch: usize,
        lr: f32,
        rng: &mut Pcg,
    ) -> (Params, f64) {
        assert_eq!(params.len(), self.param_count());
        assert_eq!(shard.dim, self.dim);
        assert!(!shard.is_empty(), "training on empty shard");
        let c = self.num_classes;
        let d = self.dim;
        let mut p = params.to_vec();
        let mut loss_acc = 0.0;
        let batch = batch.min(shard.len());
        for _ in 0..steps {
            rng.sample_indices_into(shard.len(), batch, &mut self.idx);
            self.gw.fill(0.0);
            self.gb.fill(0.0);
            let mut loss = 0.0f64;
            // lift the index buffer out so iterating it doesn't hold a
            // borrow of self across compute_logits (restored below)
            let idx = std::mem::take(&mut self.idx);
            for &i in &idx {
                let x = shard.feature_row(i);
                let y = shard.labels[i] as usize;
                self.compute_logits(&p, x);
                let gold = self.logits[y];
                let lse = self.softmax();
                loss += (lse - gold) as f64;
                // δ_k = p_k − 1[k==y]
                for (k, (dv, gv)) in self
                    .delta
                    .iter_mut()
                    .zip(self.gb.iter_mut())
                    .enumerate()
                {
                    let dk =
                        self.logits[k] - if k == y { 1.0 } else { 0.0 };
                    *dv = dk;
                    *gv += dk;
                }
                // fused feature-major pass: each nonzero x_j touches one
                // contiguous gw row, instead of C strided feature sweeps
                for (j, &xj) in x.iter().enumerate() {
                    if xj == 0.0 {
                        continue;
                    }
                    let row = &mut self.gw[j * c..(j + 1) * c];
                    for (g, &dk) in row.iter_mut().zip(&self.delta) {
                        *g += dk * xj;
                    }
                }
            }
            self.idx = idx;
            let scale = lr / batch as f32;
            for (w, &g) in p[..d * c].iter_mut().zip(&self.gw) {
                *w -= scale * g;
            }
            for (b, &g) in p[d * c..].iter_mut().zip(&self.gb) {
                *b -= scale * g;
            }
            loss_acc += loss / batch as f64;
        }
        (p, loss_acc / steps.max(1) as f64)
    }

    fn evaluate(&mut self, params: &[f32], data: &Dataset) -> (f64, f64) {
        assert!(!data.is_empty());
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for i in 0..data.len() {
            let x = data.feature_row(i);
            let y = data.labels[i] as usize;
            self.compute_logits(params, x);
            let gold = self.logits[y];
            let lse = self.softmax();
            loss += (lse - gold) as f64;
            // total-order argmax: NaN probabilities (reachable with a hot
            // LR blowing up the params) never win and never panic
            let mut pred = 0usize;
            let mut best = f32::NEG_INFINITY;
            for (k, &v) in self.logits.iter().enumerate() {
                if v > best {
                    best = v;
                    pred = k;
                }
            }
            if pred == y {
                correct += 1;
            }
        }
        (loss / data.len() as f64, correct as f64 / data.len() as f64)
    }

    fn aggregate_into(
        &mut self,
        models: &[&[f32]],
        weights: &[f32],
        out: &mut Params,
    ) {
        aggregate_native_into(models, weights, out);
    }

    fn clone_box(&self) -> Option<Box<dyn Trainer + Send>> {
        Some(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{make_corpus, SyntheticSpec};

    fn setup() -> (NativeTrainer, Dataset, Dataset) {
        let spec = SyntheticSpec {
            train_samples: 600,
            test_samples: 300,
            class_sep: 2.5,
            ..Default::default()
        };
        let (train, test) = make_corpus(&spec);
        (NativeTrainer::new(spec.dim, spec.num_classes), train, test)
    }

    #[test]
    fn param_count_layout() {
        let t = NativeTrainer::new(32, 10);
        assert_eq!(t.param_count(), 32 * 10 + 10);
        assert_eq!(t.init(1).len(), t.param_count());
    }

    #[test]
    fn loss_decreases_and_accuracy_rises() {
        let (mut t, train, test) = setup();
        let mut rng = Pcg::seeded(1);
        let p0 = t.init(0);
        let (l0, a0) = t.evaluate(&p0, &test);
        let (p1, _) = t.train(&p0, &train, 60, 32, 0.2, &mut rng);
        let (l1, a1) = t.evaluate(&p1, &test);
        assert!(l1 < l0 * 0.8, "loss {l0} → {l1}");
        assert!(a1 > a0 + 0.2, "acc {a0} → {a1}");
        assert!(a1 > 0.6, "final acc {a1}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (mut t, train, _) = setup();
        let p0 = t.init(0);
        let (a, la) = t.train(&p0, &train, 5, 16, 0.1, &mut Pcg::seeded(3));
        let (b, lb) = t.train(&p0, &train, 5, 16, 0.1, &mut Pcg::seeded(3));
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn clone_box_trains_identically_to_the_original() {
        // the parallel engine hands each pool thread a clone — cloned
        // scratch must not change results
        let (mut t, train, _) = setup();
        let p0 = t.init(0);
        let mut c = t.clone_box().expect("native trainer is cloneable");
        let (a, la) = t.train(&p0, &train, 3, 16, 0.1, &mut Pcg::seeded(3));
        let (b, lb) = c.train(&p0, &train, 3, 16, 0.1, &mut Pcg::seeded(3));
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn eval_of_zero_params_is_chance() {
        let (mut t, _, test) = setup();
        let zeros = vec![0.0f32; t.param_count()];
        let (loss, acc) = t.evaluate(&zeros, &test);
        assert!((loss - (10f64).ln()).abs() < 1e-6);
        assert!(acc < 0.35);
    }

    #[test]
    fn evaluate_with_nan_params_does_not_panic() {
        // regression: the old argmax used partial_cmp().unwrap(), which
        // panicked as soon as a hot LR produced NaN parameters
        let (mut t, _, test) = setup();
        let p = vec![f32::NAN; t.param_count()];
        let (loss, acc) = t.evaluate(&p, &test);
        assert!(loss.is_nan());
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn batch_larger_than_shard_clamps() {
        let (mut t, train, _) = setup();
        let small = train.subset(&[0, 1, 2]);
        let p0 = t.init(0);
        let (_p, loss) = t.train(&p0, &small, 2, 999, 0.1, &mut Pcg::seeded(5));
        assert!(loss.is_finite());
    }
}
