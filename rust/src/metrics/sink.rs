//! Streaming metrics sinks (`metrics.sink=csv|jsonl`): [`RoundObserver`]
//! variants that write each record to disk the moment the engine commits
//! it, so a run's full history never has to fit in memory. Combined with
//! a bounded `metrics.window` on the in-memory recorder this makes the
//! resident footprint of an N=1M run independent of round count.
//!
//! The CSV sink produces byte-identical rows to the post-hoc
//! [`RunResult`](super::RunResult) CSV writers (same format strings), so
//! downstream tooling cannot tell whether a file was streamed or dumped.

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;

use crate::config::{MetricsConfig, SinkKind};
use crate::coordinator::RoundPlan;
use crate::experiment::RoundObserver;
use crate::metrics::{EvalRecord, EventRecord, RoundRecord};

/// Remember the first I/O error a sink hits; later writes are skipped
/// cheaply and [`RoundObserver::on_run_end`] surfaces the stored error
/// instead of letting the run end "successfully" with a truncated file.
fn note(err: &mut Option<io::Error>, r: io::Result<()>) {
    if err.is_none() {
        if let Err(e) = r {
            *err = Some(e);
        }
    }
}

fn create_buffered(path: &Path) -> io::Result<BufWriter<File>> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    Ok(BufWriter::new(File::create(path)?))
}

/// Build the configured streaming sink (`None` for `sink=memory`).
pub fn make_sink(
    cfg: &MetricsConfig,
) -> io::Result<Option<Box<dyn RoundObserver>>> {
    match cfg.sink {
        SinkKind::Memory => Ok(None),
        SinkKind::Csv => {
            Ok(Some(Box::new(CsvSink::create(Path::new(&cfg.out))?)))
        }
        SinkKind::Jsonl => {
            Ok(Some(Box::new(JsonlSink::create(Path::new(&cfg.out))?)))
        }
    }
}

/// Streams rounds/evals/events to three CSV files named by appending
/// `_rounds.csv` / `_evals.csv` / `_events.csv` to the `metrics.out`
/// prefix. Row formats match [`RunResult::write_rounds_csv`] /
/// `write_eval_csv` / `write_events_csv` exactly.
pub struct CsvSink {
    rounds: BufWriter<File>,
    evals: BufWriter<File>,
    events: BufWriter<File>,
    err: Option<io::Error>,
}

fn with_suffix(prefix: &Path, suffix: &str) -> std::path::PathBuf {
    let mut s = prefix.as_os_str().to_os_string();
    s.push(suffix);
    std::path::PathBuf::from(s)
}

impl CsvSink {
    pub fn create(prefix: &Path) -> io::Result<Self> {
        let mut rounds = create_buffered(&with_suffix(prefix, "_rounds.csv"))?;
        let mut evals = create_buffered(&with_suffix(prefix, "_evals.csv"))?;
        let mut events = create_buffered(&with_suffix(prefix, "_events.csv"))?;
        writeln!(
            rounds,
            "round,time_s,duration_s,active,population,adversaries,transfers,bytes_sent,avg_staleness,max_staleness,train_loss,retransmissions,dropped_msgs,corrupt_detected"
        )?;
        writeln!(evals, "round,time_s,accuracy,loss,comm_gb")?;
        writeln!(events, "round,kind,worker,population")?;
        Ok(CsvSink { rounds, evals, events, err: None })
    }
}

impl Drop for CsvSink {
    fn drop(&mut self) {
        // best-effort: an aborting run (panic, early return) must not
        // lose buffered tail rows. Errors here have nowhere to go —
        // on_run_end is the reporting path on the normal exit.
        let _ = self.rounds.flush();
        let _ = self.evals.flush();
        let _ = self.events.flush();
    }
}

impl RoundObserver for CsvSink {
    fn on_scenario_event(&mut self, rec: &EventRecord) {
        let r = writeln!(
            self.events,
            "{},{},{},{}",
            rec.round,
            rec.kind,
            rec.worker.map(|w| w.to_string()).unwrap_or_default(),
            rec.population,
        );
        note(&mut self.err, r);
    }

    fn on_round_end(&mut self, rec: &RoundRecord) {
        let r = writeln!(
            self.rounds,
            "{},{:.4},{:.4},{},{},{},{},{:.0},{:.4},{},{:.6},{},{},{}",
            rec.round,
            rec.time_s,
            rec.duration_s,
            rec.active,
            rec.population,
            rec.adversaries,
            rec.transfers,
            rec.bytes_sent,
            rec.avg_staleness,
            rec.max_staleness,
            rec.train_loss,
            rec.retransmissions,
            rec.dropped_msgs,
            rec.corrupt_detected,
        );
        note(&mut self.err, r);
    }

    fn on_eval(&mut self, rec: &EvalRecord) {
        let r = writeln!(
            self.evals,
            "{},{:.4},{:.6},{:.6},{:.6}",
            rec.round,
            rec.time_s,
            rec.avg_accuracy,
            rec.avg_loss,
            rec.cum_bytes / 1e9,
        );
        note(&mut self.err, r);
        // evals are rare — flush so long runs keep fresh artifacts even
        // if the process is killed (CI smoke uploads mid-run state)
        let r = self.evals.flush();
        note(&mut self.err, r);
        let r = self.rounds.flush();
        note(&mut self.err, r);
        let r = self.events.flush();
        note(&mut self.err, r);
    }

    fn on_run_end(&mut self) -> Result<(), String> {
        let r = self.rounds.flush();
        note(&mut self.err, r);
        let r = self.evals.flush();
        note(&mut self.err, r);
        let r = self.events.flush();
        note(&mut self.err, r);
        match self.err.take() {
            Some(e) => Err(format!("csv sink: {e}")),
            None => Ok(()),
        }
    }
}

/// JSON number: `f64`'s `Display` is valid JSON for finite values;
/// NaN/inf (train_loss on empty rounds) become `null`.
fn jnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".into()
    }
}

/// Streams every record to one JSON-lines file (`metrics.out`), one
/// type-tagged object per line — `{"type":"round",...}`,
/// `{"type":"eval",...}`, `{"type":"event",...}`, plus a
/// `{"type":"plan",...}` line per scheduled round (round + active-set
/// size only, so lines stay O(1)).
pub struct JsonlSink {
    out: BufWriter<File>,
    err: Option<io::Error>,
}

impl JsonlSink {
    pub fn create(path: &Path) -> io::Result<Self> {
        Ok(JsonlSink { out: create_buffered(path)?, err: None })
    }
}

impl Drop for JsonlSink {
    fn drop(&mut self) {
        // best-effort tail flush for aborting runs; see CsvSink::drop
        let _ = self.out.flush();
    }
}

impl RoundObserver for JsonlSink {
    fn on_scenario_event(&mut self, rec: &EventRecord) {
        let worker = rec
            .worker
            .map(|w| w.to_string())
            .unwrap_or_else(|| "null".into());
        let r = writeln!(
            self.out,
            "{{\"type\":\"event\",\"round\":{},\"kind\":\"{}\",\"worker\":{},\"population\":{}}}",
            rec.round, rec.kind, worker, rec.population,
        );
        note(&mut self.err, r);
    }

    fn on_plan(&mut self, round: usize, plan: &RoundPlan) {
        let r = writeln!(
            self.out,
            "{{\"type\":\"plan\",\"round\":{},\"active\":{}}}",
            round,
            plan.active.len(),
        );
        note(&mut self.err, r);
    }

    fn on_round_end(&mut self, rec: &RoundRecord) {
        let r = writeln!(
            self.out,
            "{{\"type\":\"round\",\"round\":{},\"time_s\":{},\"duration_s\":{},\"active\":{},\"population\":{},\"adversaries\":{},\"transfers\":{},\"bytes_sent\":{},\"avg_staleness\":{},\"max_staleness\":{},\"train_loss\":{},\"retransmissions\":{},\"dropped_msgs\":{},\"corrupt_detected\":{}}}",
            rec.round,
            jnum(rec.time_s),
            jnum(rec.duration_s),
            rec.active,
            rec.population,
            rec.adversaries,
            rec.transfers,
            jnum(rec.bytes_sent),
            jnum(rec.avg_staleness),
            rec.max_staleness,
            jnum(rec.train_loss),
            rec.retransmissions,
            rec.dropped_msgs,
            rec.corrupt_detected,
        );
        note(&mut self.err, r);
    }

    fn on_eval(&mut self, rec: &EvalRecord) {
        let r = writeln!(
            self.out,
            "{{\"type\":\"eval\",\"round\":{},\"time_s\":{},\"accuracy\":{},\"loss\":{},\"cum_transfers\":{},\"cum_bytes\":{}}}",
            rec.round,
            jnum(rec.time_s),
            jnum(rec.avg_accuracy),
            jnum(rec.avg_loss),
            rec.cum_transfers,
            jnum(rec.cum_bytes),
        );
        note(&mut self.err, r);
        let r = self.out.flush();
        note(&mut self.err, r);
    }

    fn on_run_end(&mut self) -> Result<(), String> {
        let r = self.out.flush();
        note(&mut self.err, r);
        match self.err.take() {
            Some(e) => Err(format!("jsonl sink: {e}")),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RunResult;

    fn round_rec(round: usize) -> RoundRecord {
        RoundRecord {
            round,
            time_s: round as f64 + 0.125,
            duration_s: 1.0,
            active: 2,
            population: 4,
            adversaries: 0,
            transfers: 3,
            bytes_sent: 24.0,
            avg_staleness: 0.5,
            max_staleness: 1,
            train_loss: if round == 2 { f64::NAN } else { 0.9 },
            retransmissions: 0,
            dropped_msgs: 0,
            corrupt_detected: 0,
        }
    }

    fn eval_rec() -> EvalRecord {
        EvalRecord {
            round: 2,
            time_s: 2.125,
            avg_accuracy: 0.75,
            avg_loss: 0.5,
            cum_transfers: 6,
            cum_bytes: 48.0,
        }
    }

    fn event_rec() -> EventRecord {
        EventRecord { round: 1, kind: "leave", worker: Some(3), population: 3 }
    }

    #[test]
    fn csv_sink_matches_post_hoc_writers_byte_for_byte() {
        let dir = std::env::temp_dir().join("dystop_sink_csv_test");
        let _ = std::fs::remove_dir_all(&dir);
        let prefix = dir.join("run");
        {
            let mut sink = CsvSink::create(&prefix).unwrap();
            sink.on_scenario_event(&event_rec());
            for t in 1..=2 {
                sink.on_round_end(&round_rec(t));
            }
            sink.on_eval(&eval_rec());
        } // drop flushes
        // the same records through the in-memory result + batch writers
        let result = RunResult {
            label: "x".into(),
            model_bits: 64.0,
            rounds: vec![round_rec(1), round_rec(2)],
            evals: vec![eval_rec()],
            events: vec![event_rec()],
        };
        result.write_rounds_csv(&dir.join("batch_rounds.csv")).unwrap();
        result.write_eval_csv(&dir.join("batch_evals.csv")).unwrap();
        result.write_events_csv(&dir.join("batch_events.csv")).unwrap();
        for (streamed, batch) in [
            ("run_rounds.csv", "batch_rounds.csv"),
            ("run_evals.csv", "batch_evals.csv"),
            ("run_events.csv", "batch_events.csv"),
        ] {
            let s = std::fs::read_to_string(dir.join(streamed)).unwrap();
            let b = std::fs::read_to_string(dir.join(batch)).unwrap();
            assert_eq!(s, b, "{streamed} diverged from {batch}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn jsonl_sink_emits_tagged_lines_with_null_for_nan() {
        let dir = std::env::temp_dir().join("dystop_sink_jsonl_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("run.jsonl");
        {
            let mut sink = JsonlSink::create(&path).unwrap();
            sink.on_scenario_event(&EventRecord {
                round: 1,
                kind: "bandwidth-shift",
                worker: None,
                population: 4,
            });
            sink.on_plan(1, &RoundPlan::default());
            sink.on_round_end(&round_rec(2)); // NaN train_loss
            sink.on_eval(&eval_rec());
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("{\"type\":\"event\""));
        assert!(lines[0].contains("\"worker\":null"), "{}", lines[0]);
        assert!(lines[1].starts_with("{\"type\":\"plan\""));
        assert!(lines[2].contains("\"train_loss\":null"), "{}", lines[2]);
        assert!(lines[3].starts_with("{\"type\":\"eval\""));
        assert!(lines[3].contains("\"accuracy\":0.75"), "{}", lines[3]);
        // every line is a braces-balanced object (cheap well-formedness)
        for l in &lines {
            assert!(l.starts_with('{') && l.ends_with('}'), "{l}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn aborted_run_loses_no_tail_rows() {
        // a run that dies mid-round never reaches on_eval (the only
        // pre-existing flush point) — dropping the sink must still land
        // every buffered row on disk. Enough rows to overflow nothing:
        // the point is that rows past the last flush survive.
        let dir = std::env::temp_dir().join("dystop_sink_truncation_test");
        let _ = std::fs::remove_dir_all(&dir);
        let rounds = 200;
        {
            let mut sink = JsonlSink::create(&dir.join("run.jsonl")).unwrap();
            for t in 1..=rounds {
                sink.on_round_end(&round_rec(t));
            }
        } // dropped without on_eval/on_run_end — simulated abort
        let text = std::fs::read_to_string(dir.join("run.jsonl")).unwrap();
        assert_eq!(text.lines().count(), rounds, "jsonl rows truncated");
        {
            let mut sink = CsvSink::create(&dir.join("run")).unwrap();
            for t in 1..=rounds {
                sink.on_round_end(&round_rec(t));
            }
        }
        let text =
            std::fs::read_to_string(dir.join("run_rounds.csv")).unwrap();
        assert_eq!(text.lines().count(), rounds + 1, "csv rows truncated");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn io_errors_surface_at_run_end() {
        // /dev/full: opens fine, every flush fails with ENOSPC. The old
        // sinks swallowed this (`let _ =`) and the run "succeeded" with
        // a truncated artifact.
        let mut sink = JsonlSink::create(Path::new("/dev/full")).unwrap();
        for t in 1..=2000 {
            sink.on_round_end(&round_rec(t));
        }
        let err = sink.on_run_end().expect_err("ENOSPC must surface");
        assert!(err.contains("jsonl sink"), "{err}");
    }

    #[test]
    fn make_sink_respects_the_knob() {
        let dir = std::env::temp_dir().join("dystop_sink_make_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mem = MetricsConfig::default();
        assert!(make_sink(&mem).unwrap().is_none());
        let jsonl = MetricsConfig {
            sink: SinkKind::Jsonl,
            out: dir.join("a.jsonl").to_string_lossy().into_owned(),
            window: 0,
        };
        assert!(make_sink(&jsonl).unwrap().is_some());
        let csv = MetricsConfig {
            sink: SinkKind::Csv,
            out: dir.join("b").to_string_lossy().into_owned(),
            window: 0,
        };
        assert!(make_sink(&csv).unwrap().is_some());
        assert!(dir.join("b_rounds.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
