//! Perfetto trace export: a [`RoundObserver`] that renders a run as
//! Chrome Trace Event JSON (`{"traceEvents": [...]}`), loadable in
//! `ui.perfetto.dev` or `chrome://tracing`.
//!
//! # Track/span contract (DESIGN.md §Tracing)
//!
//! - `pid` is always 1; `tid 0` is the coordinator track, worker `w`
//!   renders on `tid w + 1` (named `worker w` via `thread_name`
//!   metadata).
//! - Each activated worker emits complete (`ph:"X"`) spans in order:
//!   `train` (residual compute), `transfer` (base network time),
//!   `retry` (delivery retransmission overhead, omitted when zero) and
//!   `stale-wait` (idle until the round barrier, omitted when zero).
//! - The coordinator track carries one `round N` span per round and
//!   `ph:"i"` instants for scenario/dead-letter events (on the
//!   affected worker's track when the event names one).
//!
//! Timestamps are the backend's *virtual* clock converted to µs, so
//! traces from the simulator and the socket backend line up span for
//! span. Events buffer in memory and flush on [`TraceSink::finish`]
//! (or best-effort on drop), so observers stay cheap inside the round
//! loop.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{self, Write};
use std::path::PathBuf;

use crate::experiment::RoundObserver;
use crate::metrics::{ActivationRecord, EventRecord, RoundRecord};
use crate::util::json::Json;

/// Buffers Trace Event JSON for one run and writes it as a single
/// `{"traceEvents": [...]}` document.
pub struct TraceSink {
    path: PathBuf,
    file: Option<File>,
    events: Vec<Json>,
    named_tids: Vec<u64>,
    /// Virtual clock (µs) at the last round boundary — instants fired
    /// before a round's execution land here.
    clock_us: f64,
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in fields {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

impl TraceSink {
    /// Open `path` for writing now (so a bad path fails at build time,
    /// not after the run) and buffer events until [`Self::finish`].
    pub fn to_path(path: impl Into<PathBuf>) -> io::Result<Self> {
        let path = path.into();
        let file = File::create(&path)?;
        let mut sink = TraceSink {
            path,
            file: Some(file),
            events: Vec::new(),
            named_tids: Vec::new(),
            clock_us: 0.0,
        };
        sink.events.push(obj(vec![
            ("ph", Json::Str("M".into())),
            ("name", Json::Str("process_name".into())),
            ("pid", Json::Num(1.0)),
            ("args", obj(vec![("name", Json::Str("dystop".into()))])),
        ]));
        sink.name_tid(0);
        Ok(sink)
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    /// Emit `thread_name` metadata the first time a track appears.
    fn name_tid(&mut self, tid: u64) {
        if self.named_tids.contains(&tid) {
            return;
        }
        self.named_tids.push(tid);
        let name = if tid == 0 {
            "coordinator".to_string()
        } else {
            format!("worker {}", tid - 1)
        };
        self.events.push(obj(vec![
            ("ph", Json::Str("M".into())),
            ("name", Json::Str("thread_name".into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid as f64)),
            ("args", obj(vec![("name", Json::Str(name))])),
        ]));
    }

    fn span(
        &mut self,
        name: String,
        cat: &str,
        tid: u64,
        ts_us: f64,
        dur_us: f64,
        round: usize,
    ) {
        self.name_tid(tid);
        self.events.push(obj(vec![
            ("ph", Json::Str("X".into())),
            ("name", Json::Str(name)),
            ("cat", Json::Str(cat.into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid as f64)),
            ("ts", Json::Num(ts_us)),
            ("dur", Json::Num(dur_us)),
            ("args", obj(vec![("round", Json::Num(round as f64))])),
        ]));
    }

    /// Write the buffered document. Idempotent: the file handle is
    /// consumed, so a second call (or the drop hook after an explicit
    /// finish) is a no-op.
    pub fn finish(&mut self) -> io::Result<()> {
        let Some(mut file) = self.file.take() else {
            return Ok(());
        };
        let doc = obj(vec![(
            "traceEvents",
            Json::Arr(std::mem::take(&mut self.events)),
        )]);
        write!(file, "{doc}")?;
        file.flush()
    }
}

impl Drop for TraceSink {
    fn drop(&mut self) {
        // Runs own their observers, so the natural flush point is the
        // end of the run; I/O errors here have nowhere to surface.
        let _ = self.finish();
    }
}

impl RoundObserver for TraceSink {
    fn on_scenario_event(&mut self, rec: &EventRecord) {
        let tid = rec.worker.map(|w| w as u64 + 1).unwrap_or(0);
        self.name_tid(tid);
        self.events.push(obj(vec![
            ("ph", Json::Str("i".into())),
            ("name", Json::Str(rec.kind.to_string())),
            ("cat", Json::Str("scenario".into())),
            ("s", Json::Str("g".into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid as f64)),
            ("ts", Json::Num(self.clock_us)),
            ("args", obj(vec![
                ("round", Json::Num(rec.round as f64)),
                ("population", Json::Num(rec.population as f64)),
            ])),
        ]));
    }

    fn on_activation(&mut self, rec: &ActivationRecord) {
        let tid = rec.worker as u64 + 1;
        let mut ts = rec.start_s * 1e6;
        self.span(
            "train".into(),
            "phase",
            tid,
            ts,
            rec.compute_s * 1e6,
            rec.round,
        );
        ts += rec.compute_s * 1e6;
        self.span(
            "transfer".into(),
            "phase",
            tid,
            ts,
            rec.transfer_s * 1e6,
            rec.round,
        );
        ts += rec.transfer_s * 1e6;
        if rec.retry_s > 0.0 {
            self.span(
                "retry".into(),
                "phase",
                tid,
                ts,
                rec.retry_s * 1e6,
                rec.round,
            );
            ts += rec.retry_s * 1e6;
        }
        if rec.wait_s > 0.0 {
            self.span(
                "stale-wait".into(),
                "phase",
                tid,
                ts,
                rec.wait_s * 1e6,
                rec.round,
            );
        }
    }

    fn on_round_end(&mut self, rec: &RoundRecord) {
        let start_us = self.clock_us;
        self.span(
            format!("round {}", rec.round),
            "round",
            0,
            start_us,
            rec.duration_s * 1e6,
            rec.round,
        );
        self.clock_us = rec.time_s * 1e6;
    }

    fn on_run_end(&mut self) -> Result<(), String> {
        // explicit flush point: unlike the drop hook, write failures
        // here surface as a backend error instead of vanishing
        self.finish().map_err(|e| format!("trace sink: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("dystop-trace-{}-{name}.json", std::process::id()))
    }

    fn activation(worker: usize) -> ActivationRecord {
        ActivationRecord {
            round: 1,
            worker,
            start_s: 0.0,
            compute_s: 2.0,
            transfer_s: 0.5,
            retry_s: 0.25,
            wait_s: 1.0,
        }
    }

    fn round_rec(round: usize, time_s: f64) -> RoundRecord {
        RoundRecord {
            round,
            time_s,
            duration_s: 3.75,
            active: 1,
            population: 4,
            adversaries: 0,
            transfers: 2,
            bytes_sent: 16.0,
            avg_staleness: 0.0,
            max_staleness: 0,
            train_loss: 1.0,
            retransmissions: 1,
            dropped_msgs: 0,
            corrupt_detected: 0,
        }
    }

    fn spans_named<'a>(doc: &'a Json, name: &str) -> Vec<&'a Json> {
        doc.get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| {
                e.get("ph").unwrap().as_str() == Some("X")
                    && e.get("name").unwrap().as_str() == Some(name)
            })
            .collect()
    }

    #[test]
    fn emits_valid_trace_event_json() {
        let path = tmp("basic");
        {
            let mut sink = TraceSink::to_path(&path).unwrap();
            sink.on_activation(&activation(2));
            sink.on_round_end(&round_rec(1, 3.75));
            sink.finish().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text).unwrap();
        let train = spans_named(&doc, "train");
        assert_eq!(train.len(), 1);
        assert_eq!(train[0].get("tid").unwrap().as_usize(), Some(3));
        assert_eq!(train[0].get("dur").unwrap().as_f64(), Some(2.0e6));
        // transfer starts where train ends
        let transfer = spans_named(&doc, "transfer");
        assert_eq!(transfer[0].get("ts").unwrap().as_f64(), Some(2.0e6));
        assert_eq!(spans_named(&doc, "retry").len(), 1);
        assert_eq!(spans_named(&doc, "stale-wait").len(), 1);
        // coordinator round span on tid 0
        let round = spans_named(&doc, "round 1");
        assert_eq!(round[0].get("tid").unwrap().as_usize(), Some(0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn zero_width_phases_are_omitted() {
        let path = tmp("zero");
        {
            let mut sink = TraceSink::to_path(&path).unwrap();
            sink.on_activation(&ActivationRecord {
                retry_s: 0.0,
                wait_s: 0.0,
                ..activation(0)
            });
            sink.finish().unwrap();
        }
        let doc =
            Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(spans_named(&doc, "retry").is_empty());
        assert!(spans_named(&doc, "stale-wait").is_empty());
        assert_eq!(spans_named(&doc, "train").len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn instants_land_at_the_round_boundary_clock() {
        let path = tmp("instants");
        {
            let mut sink = TraceSink::to_path(&path).unwrap();
            sink.on_round_end(&round_rec(1, 3.75));
            sink.on_scenario_event(&EventRecord {
                round: 2,
                kind: "crash",
                worker: Some(1),
                population: 3,
            });
            sink.finish().unwrap();
        }
        let doc =
            Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let inst: Vec<_> = doc
            .get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("i"))
            .collect();
        assert_eq!(inst.len(), 1);
        assert_eq!(inst[0].get("name").unwrap().as_str(), Some("crash"));
        assert_eq!(inst[0].get("ts").unwrap().as_f64(), Some(3.75e6));
        assert_eq!(inst[0].get("tid").unwrap().as_usize(), Some(2));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn thread_names_emitted_once_per_track() {
        let path = tmp("names");
        {
            let mut sink = TraceSink::to_path(&path).unwrap();
            sink.on_activation(&activation(5));
            sink.on_activation(&activation(5));
            sink.finish().unwrap();
        }
        let doc =
            Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let names: Vec<_> = doc
            .get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| {
                e.get("name").unwrap().as_str() == Some("thread_name")
            })
            .collect();
        // coordinator + worker 5, despite two activations
        assert_eq!(names.len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn drop_flushes_unfinished_sink() {
        let path = tmp("drop");
        {
            let mut sink = TraceSink::to_path(&path).unwrap();
            sink.on_round_end(&round_rec(1, 1.0));
        }
        let doc =
            Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(spans_named(&doc, "round 1").len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
