//! Metrics: per-round records, evaluation snapshots, communication
//! ledger, and the derived quantities the paper reports (completion time
//! to a target accuracy, communication overhead to a target accuracy).

use std::io::Write;
use std::path::Path;

pub mod sink;
pub mod trace;

/// One worker activation, decomposed into the phases the trace sink
/// renders as spans: local training, model transfer (base transfer
/// time × channel slots), retry overhead added by the delivery layer,
/// and the stale-wait until the round barrier. All times are virtual
/// seconds; `start_s + compute_s + transfer_s + retry_s + wait_s` is
/// the round-end clock for every activation of the round (exactly
/// under the clean fault profile, up to FP rounding under lossy ones).
#[derive(Clone, Debug)]
pub struct ActivationRecord {
    /// Round this activation ran in (1-based, like [`RoundRecord`]).
    pub round: usize,
    /// Activated worker (global id).
    pub worker: usize,
    /// Virtual clock at round start (s).
    pub start_s: f64,
    /// Local-training time (the worker's residual at activation).
    pub compute_s: f64,
    /// Fault-free transfer time: worst pull × pull slots + worst push
    /// × push slots.
    pub transfer_s: f64,
    /// Extra transfer time from delivery-layer retries/backoff.
    pub retry_s: f64,
    /// Idle wait until the slowest activation finishes the round.
    pub wait_s: f64,
}

/// One scheduler round.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Virtual time at the *end* of the round (s).
    pub time_s: f64,
    /// Duration H_t of this round (Eq. 9).
    pub duration_s: f64,
    pub active: usize,
    /// Present workers this round (scenario layer — constant and equal
    /// to `sim.workers` under `scenario.preset=stable`).
    pub population: usize,
    /// Present workers currently running a Byzantine attack policy
    /// (adversary layer — 0 under the default `adversary.frac=0`).
    pub adversaries: usize,
    /// Model transfers this round (pulls + pushes), in models.
    pub transfers: usize,
    /// Bytes actually put on the wire this round: one *encoded* message
    /// per transfer edge (transport layer). Under the dense codec this
    /// is exactly `transfers × model_bits / 8` — the pre-transport
    /// ledger.
    pub bytes_sent: f64,
    /// Mean staleness over *present* workers after the round.
    pub avg_staleness: f64,
    pub max_staleness: u64,
    /// Mean training loss over the workers that trained this round.
    pub train_loss: f64,
    /// Frames retransmitted by the delivery layer this round (delivery
    /// layer — 0 under the default `faults.profile=clean`). Each one is
    /// charged real measured bytes in `bytes_sent`.
    pub retransmissions: usize,
    /// Messages that never reached an aggregation this round: frames
    /// lost in transit plus in-flight models dropped by scenario
    /// `Crash` events (routed through the delivery ledger).
    pub dropped_msgs: usize,
    /// Frames that arrived corrupted and were rejected by the CRC32
    /// check this round (then retried like a loss).
    pub corrupt_detected: usize,
}

/// One applied scenario event (population or environment change). Only
/// events that actually changed state are recorded, so replaying the log
/// accounts for every population change of the run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EventRecord {
    /// Round at whose start the event applied (1-based).
    pub round: usize,
    /// Event tag: `leave`, `crash`, `join`, `rejoin`, `bandwidth-shift`,
    /// `mobility-burst`, `region-partition`, plus the delivery layer's
    /// `dead-letter` (a pull edge exhausted its retry budget; `worker`
    /// is the receiver that degraded gracefully).
    pub kind: &'static str,
    /// Affected worker (global id) for population events; `None` for
    /// environment-wide events.
    pub worker: Option<usize>,
    /// Present-worker count immediately after the event applied.
    pub population: usize,
}

/// One evaluation snapshot (average over workers' local models).
#[derive(Clone, Debug)]
pub struct EvalRecord {
    pub round: usize,
    pub time_s: f64,
    pub avg_accuracy: f64,
    pub avg_loss: f64,
    /// Cumulative communication in model transfers at snapshot time.
    pub cum_transfers: usize,
    /// Cumulative measured wire bytes at snapshot time (transport
    /// layer). Equals `cum_transfers × model_bits / 8` bit-exactly under
    /// the dense codec.
    pub cum_bytes: f64,
}

/// Full run output.
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub label: String,
    pub rounds: Vec<RoundRecord>,
    pub evals: Vec<EvalRecord>,
    /// Applied scenario events, in application order (empty under
    /// `scenario.preset=stable`).
    pub events: Vec<EventRecord>,
    /// Bits of one model transfer (P × 32 for f32).
    pub model_bits: f64,
}

impl RunResult {
    /// Empty result with identity fields set (what a recorder starts from).
    pub fn new(label: impl Into<String>, model_bits: f64) -> Self {
        RunResult { label: label.into(), model_bits, ..Default::default() }
    }

    pub fn total_transfers(&self) -> usize {
        self.rounds.iter().map(|r| r.transfers).sum()
    }

    /// Total measured wire bytes over the run (transport layer). Under
    /// the dense codec this reproduces the pre-transport
    /// `transfers × model_bits / 8` accounting bit-exactly.
    pub fn cum_bytes(&self) -> f64 {
        self.rounds.iter().map(|r| r.bytes_sent).sum()
    }

    /// Total communication in GB (paper's communication-overhead
    /// metric), from measured wire bytes.
    pub fn total_comm_gb(&self) -> f64 {
        self.cum_bytes() / 1e9
    }

    pub fn final_time_s(&self) -> f64 {
        self.rounds.last().map(|r| r.time_s).unwrap_or(0.0)
    }

    pub fn best_accuracy(&self) -> f64 {
        self.evals.iter().map(|e| e.avg_accuracy).fold(0.0, f64::max)
    }

    /// Completion time: first snapshot time with accuracy ≥ target.
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.evals
            .iter()
            .find(|e| e.avg_accuracy >= target)
            .map(|e| e.time_s)
    }

    /// Communication (GB) consumed to first reach the target accuracy,
    /// from measured wire bytes. The old `model_bits` accounting is the
    /// dense-codec special case: there `cum_bytes` *is*
    /// `cum_transfers × model_bits / 8`, bit-exactly.
    pub fn comm_to_accuracy(&self, target: f64) -> Option<f64> {
        self.evals
            .iter()
            .find(|e| e.avg_accuracy >= target)
            .map(|e| e.cum_bytes / 1e9)
    }

    /// Bit-exact equality over every recorded field (floats compared by
    /// `to_bits`, so NaN == NaN and -0.0 != 0.0). The single definition
    /// of "bit-identical run" — used by the `experiment_api` parity and
    /// thread-count-determinism tests and by the bench determinism
    /// witness recorded in `BENCH_sim.json`.
    pub fn bits_eq(&self, other: &RunResult) -> bool {
        self.label == other.label
            && self.model_bits.to_bits() == other.model_bits.to_bits()
            && self.rounds.len() == other.rounds.len()
            && self.evals.len() == other.evals.len()
            && self.events == other.events
            && self.rounds.iter().zip(&other.rounds).all(|(x, y)| {
                x.round == y.round
                    && x.time_s.to_bits() == y.time_s.to_bits()
                    && x.duration_s.to_bits() == y.duration_s.to_bits()
                    && x.active == y.active
                    && x.population == y.population
                    && x.adversaries == y.adversaries
                    && x.transfers == y.transfers
                    && x.bytes_sent.to_bits() == y.bytes_sent.to_bits()
                    && x.avg_staleness.to_bits() == y.avg_staleness.to_bits()
                    && x.max_staleness == y.max_staleness
                    && x.train_loss.to_bits() == y.train_loss.to_bits()
                    && x.retransmissions == y.retransmissions
                    && x.dropped_msgs == y.dropped_msgs
                    && x.corrupt_detected == y.corrupt_detected
            })
            && self.evals.iter().zip(&other.evals).all(|(x, y)| {
                x.round == y.round
                    && x.time_s.to_bits() == y.time_s.to_bits()
                    && x.avg_accuracy.to_bits() == y.avg_accuracy.to_bits()
                    && x.avg_loss.to_bits() == y.avg_loss.to_bits()
                    && x.cum_transfers == y.cum_transfers
                    && x.cum_bytes.to_bits() == y.cum_bytes.to_bits()
            })
    }

    /// Smallest / largest present-worker count over the run (population
    /// range under churn; `(n, n)` when stable, `(0, 0)` when empty).
    pub fn population_range(&self) -> (usize, usize) {
        let mut lo = usize::MAX;
        let mut hi = 0usize;
        for r in &self.rounds {
            lo = lo.min(r.population);
            hi = hi.max(r.population);
        }
        if lo == usize::MAX {
            (0, 0)
        } else {
            (lo, hi)
        }
    }

    /// Mean staleness across all rounds (Fig. 14 metric).
    pub fn mean_staleness(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.avg_staleness).sum::<f64>()
            / self.rounds.len() as f64
    }

    /// Write the evaluation curve as CSV (`round,time_s,acc,loss,comm_gb`).
    pub fn write_eval_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "round,time_s,accuracy,loss,comm_gb")?;
        for e in &self.evals {
            writeln!(
                f,
                "{},{:.4},{:.6},{:.6},{:.6}",
                e.round,
                e.time_s,
                e.avg_accuracy,
                e.avg_loss,
                e.cum_bytes / 1e9,
            )?;
        }
        Ok(())
    }

    /// Write per-round records as CSV.
    pub fn write_rounds_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(
            f,
            "round,time_s,duration_s,active,population,adversaries,transfers,bytes_sent,avg_staleness,max_staleness,train_loss,retransmissions,dropped_msgs,corrupt_detected"
        )?;
        for r in &self.rounds {
            writeln!(
                f,
                "{},{:.4},{:.4},{},{},{},{},{:.0},{:.4},{},{:.6},{},{},{}",
                r.round,
                r.time_s,
                r.duration_s,
                r.active,
                r.population,
                r.adversaries,
                r.transfers,
                r.bytes_sent,
                r.avg_staleness,
                r.max_staleness,
                r.train_loss,
                r.retransmissions,
                r.dropped_msgs,
                r.corrupt_detected,
            )?;
        }
        Ok(())
    }

    /// Write the applied scenario-event log as CSV
    /// (`round,kind,worker,population`).
    pub fn write_events_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "round,kind,worker,population")?;
        for e in &self.events {
            writeln!(
                f,
                "{},{},{},{}",
                e.round,
                e.kind,
                e.worker.map(|w| w.to_string()).unwrap_or_default(),
                e.population,
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunResult {
        RunResult {
            label: "test".into(),
            model_bits: 32.0 * 1000.0,
            rounds: (0..4)
                .map(|t| RoundRecord {
                    round: t,
                    time_s: (t + 1) as f64,
                    duration_s: 1.0,
                    active: 1,
                    population: 8 - t,
                    adversaries: 0,
                    transfers: 10,
                    // dense accounting: transfers × model_bits / 8
                    bytes_sent: 10.0 * 32.0 * 1000.0 / 8.0,
                    avg_staleness: t as f64,
                    max_staleness: t as u64,
                    train_loss: 1.0 / (t + 1) as f64,
                    retransmissions: 0,
                    dropped_msgs: 0,
                    corrupt_detected: 0,
                })
                .collect(),
            evals: vec![
                EvalRecord { round: 1, time_s: 2.0, avg_accuracy: 0.5, avg_loss: 1.0, cum_transfers: 20, cum_bytes: 20.0 * 32.0 * 1000.0 / 8.0 },
                EvalRecord { round: 3, time_s: 4.0, avg_accuracy: 0.85, avg_loss: 0.4, cum_transfers: 40, cum_bytes: 40.0 * 32.0 * 1000.0 / 8.0 },
            ],
            events: vec![EventRecord {
                round: 2,
                kind: "leave",
                worker: Some(3),
                population: 7,
            }],
        }
    }

    #[test]
    fn totals() {
        let r = sample();
        assert_eq!(r.total_transfers(), 40);
        // dense: measured bytes reproduce the model_bits ledger exactly
        assert_eq!(
            r.cum_bytes().to_bits(),
            (40.0 * 32000.0 / 8.0f64).to_bits()
        );
        assert!((r.total_comm_gb() - 40.0 * 32000.0 / 8.0 / 1e9).abs() < 1e-12);
        assert_eq!(r.final_time_s(), 4.0);
        assert_eq!(r.best_accuracy(), 0.85);
    }

    #[test]
    fn target_extraction() {
        let r = sample();
        assert_eq!(r.time_to_accuracy(0.8), Some(4.0));
        assert_eq!(r.time_to_accuracy(0.4), Some(2.0));
        assert_eq!(r.time_to_accuracy(0.99), None);
        assert!(r.comm_to_accuracy(0.8).unwrap() > r.comm_to_accuracy(0.4).unwrap());
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("dystop_metrics_test");
        let path = dir.join("eval.csv");
        sample().write_eval_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("round,time_s"));
        assert_eq!(text.lines().count(), 3);
        sample().write_rounds_csv(&dir.join("rounds.csv")).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mean_staleness() {
        assert!((sample().mean_staleness() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn population_range_and_events_csv() {
        let r = sample();
        assert_eq!(r.population_range(), (5, 8));
        assert_eq!(RunResult::default().population_range(), (0, 0));
        let dir = std::env::temp_dir().join("dystop_metrics_events_test");
        let path = dir.join("events.csv");
        r.write_events_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("round,kind,worker,population"));
        assert!(text.contains("2,leave,3,7"), "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bits_eq_detects_population_and_event_divergence() {
        let a = sample();
        let mut b = sample();
        assert!(a.bits_eq(&b));
        b.rounds[0].population += 1;
        assert!(!a.bits_eq(&b));
        let mut c = sample();
        c.events.clear();
        assert!(!a.bits_eq(&c));
        // byte accounting is part of the bit-identity contract
        let mut d = sample();
        d.rounds[0].bytes_sent += 1.0;
        assert!(!a.bits_eq(&d));
        let mut e = sample();
        e.evals[0].cum_bytes += 1.0;
        assert!(!a.bits_eq(&e));
        // so is the per-round adversary census
        let mut g = sample();
        g.rounds[0].adversaries = 1;
        assert!(!a.bits_eq(&g));
        // and the delivery ledger columns
        let mut h = sample();
        h.rounds[0].retransmissions = 1;
        assert!(!a.bits_eq(&h));
        let mut i = sample();
        i.rounds[0].dropped_msgs = 1;
        assert!(!a.bits_eq(&i));
        let mut j = sample();
        j.rounds[0].corrupt_detected = 1;
        assert!(!a.bits_eq(&j));
    }

    #[test]
    fn rounds_csv_carries_the_delivery_columns() {
        let mut r = sample();
        r.rounds[1].retransmissions = 4;
        r.rounds[1].dropped_msgs = 2;
        r.rounds[1].corrupt_detected = 1;
        let dir = std::env::temp_dir().join("dystop_metrics_delivery_test");
        let path = dir.join("rounds.csv");
        r.write_rounds_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text
            .lines()
            .next()
            .unwrap()
            .ends_with("retransmissions,dropped_msgs,corrupt_detected"));
        assert!(
            text.lines().nth(2).unwrap().ends_with(",4,2,1"),
            "{text}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
