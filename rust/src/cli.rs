//! Command-line interface (hand-rolled: no `clap` offline).
//!
//! ```text
//! dystop train   [--config FILE] [--set key=value ...] [--out DIR]
//! dystop figures --fig ID [--out DIR] [--workers N] [--rounds R] [--seed S]
//! dystop testbed [--config FILE] [--set key=value ...] [--out DIR]
//! dystop sweep   --key K --values a,b,c [--config FILE] [--out DIR]
//! dystop config  [--list | KEY]
//! dystop inspect [--artifacts DIR]
//! ```
//!
//! Every `--set` key is validated against the typed knob registry
//! ([`crate::config::registry`]); unknown keys error with a
//! nearest-key suggestion, and `dystop config --list` prints the full
//! table (type, default, doc) instead of a drift-prone usage dump.

use crate::config::{BackendKind, Config, ExperimentConfig};
use crate::experiment::Experiment;
use crate::figures::{self, FigScale};
use crate::metrics::RunResult;
use crate::util::json::Json;
use std::path::PathBuf;

/// Parsed flag map: `--key value` pairs + repeated `--set k=v`.
#[derive(Debug, Default)]
pub struct Flags {
    pub values: Vec<(String, String)>,
    pub sets: Vec<(String, String)>,
}

impl Flags {
    pub fn parse(args: &[String]) -> Result<Flags, String> {
        let mut f = Flags::default();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got {a:?}"))?;
            let val = args
                .get(i + 1)
                .ok_or_else(|| format!("--{key} needs a value"))?
                .clone();
            if key == "set" {
                let (k, v) = val
                    .split_once('=')
                    .ok_or_else(|| format!("--set expects key=value, got {val:?}"))?;
                f.sets.push((k.to_string(), v.to_string()));
            } else {
                f.values.push((key.to_string(), val));
            }
            i += 2;
        }
        Ok(f)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, String> {
        self.get(key)
            .map(|v| v.parse().map_err(|_| format!("--{key}: bad integer {v:?}")))
            .transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, String> {
        self.get(key)
            .map(|v| v.parse().map_err(|_| format!("--{key}: bad float {v:?}")))
            .transpose()
    }
}

/// Build the experiment config from `--config` + `--set` overrides.
fn load_config(flags: &Flags) -> Result<ExperimentConfig, String> {
    let mut cfg = match flags.get("config") {
        Some(path) => Config::from_file(&PathBuf::from(path))?,
        None => Config::new(),
    };
    for (k, v) in &flags.sets {
        cfg.set(k, v);
    }
    ExperimentConfig::from_config(&cfg)
}

fn report(res: &RunResult, out: &PathBuf) -> Result<(), String> {
    std::fs::create_dir_all(out).map_err(|e| e.to_string())?;
    res.write_eval_csv(&out.join(format!("{}_eval.csv", res.label)))
        .map_err(|e| e.to_string())?;
    res.write_rounds_csv(&out.join(format!("{}_rounds.csv", res.label)))
        .map_err(|e| e.to_string())?;
    if !res.events.is_empty() {
        res.write_events_csv(&out.join(format!("{}_events.csv", res.label)))
            .map_err(|e| e.to_string())?;
    }
    println!(
        "{}: {} rounds, final t={:.1}s, best acc={:.3}, comm={:.4} GB, mean τ={:.2}",
        res.label,
        res.rounds.len(),
        res.final_time_s(),
        res.best_accuracy(),
        res.total_comm_gb(),
        res.mean_staleness()
    );
    if !res.events.is_empty() {
        let (lo, hi) = res.population_range();
        println!(
            "scenario: {} events applied, population ranged {lo}–{hi}",
            res.events.len()
        );
    }
    println!("wrote CSVs under {}", out.display());
    Ok(())
}

pub fn main_with_args(args: &[String]) -> Result<(), String> {
    let Some(cmd) = args.first() else {
        return Err(usage());
    };
    // `config` takes a bare `--list` / KEY operand, which the strict
    // `--flag value` parser would reject — dispatch it first
    if cmd == "config" {
        return run_config(&args[1..]);
    }
    let flags = Flags::parse(&args[1..])?;
    let out = PathBuf::from(flags.get("out").unwrap_or("results"));
    match cmd.as_str() {
        "train" => {
            let cfg = load_config(&flags)?;
            let threads = if cfg.threads == 0 {
                "auto".to_string()
            } else {
                cfg.threads.to_string()
            };
            println!(
                "train: scheduler={} backend={} threads={} workers={} rounds={} φ={} scenario={} model={} dataset={}",
                cfg.scheduler.name(),
                cfg.backend.name(),
                threads,
                cfg.workers,
                cfg.rounds,
                cfg.phi,
                cfg.scenario.preset.name(),
                cfg.workload.model.name(),
                cfg.workload.dataset.name()
            );
            let backend = cfg.backend;
            let res = Experiment::builder(cfg).backend(backend).run()?;
            report(&res, &out)
        }
        "figures" => {
            let fig = flags.get("fig").unwrap_or("all").to_string();
            let mut scale = FigScale::default();
            if let Some(w) = flags.get_usize("workers")? {
                scale.workers = w;
            }
            if let Some(r) = flags.get_usize("rounds")? {
                scale.rounds = r;
            }
            if let Some(s) = flags.get_usize("seed")? {
                scale.seed = s as u64;
            }
            figures::run_figure(&fig, &out, scale)
        }
        "testbed" => {
            let cfg = load_config(&flags)?;
            let res = Experiment::builder(cfg)
                .backend(BackendKind::Testbed)
                .run()?;
            report(&res, &out)
        }
        "sweep" => {
            let key = flags.get("key").ok_or("--key required")?.to_string();
            let values: Vec<String> = flags
                .get("values")
                .ok_or("--values required (comma separated)")?
                .split(',')
                .map(|s| s.trim().to_string())
                .collect();
            for v in values {
                let mut cfg_raw = match flags.get("config") {
                    Some(p) => Config::from_file(&PathBuf::from(p))?,
                    None => Config::new(),
                };
                for (k, val) in &flags.sets {
                    cfg_raw.set(k, val);
                }
                cfg_raw.set(&key, &v);
                let cfg = ExperimentConfig::from_config(&cfg_raw)?;
                // run() dispatches on cfg.backend (run.backend knob)
                let mut res = Experiment::builder(cfg).run()?;
                res.label = format!("{}_{}{}", res.label, key.replace('.', "_"), v);
                report(&res, &out)?;
            }
            Ok(())
        }
        "bench-diff" => {
            // the CI bench-regression gate: diff a fresh BENCH_sim.json
            // against the checked-in baseline on per-row median latency
            let baseline_p =
                flags.get("baseline").unwrap_or("BENCH_baseline.json");
            let fresh_p = flags.get("fresh").unwrap_or("BENCH_sim.json");
            let tol = flags.get_f64("tolerance")?.unwrap_or(0.15);
            if !(0.0..10.0).contains(&tol) {
                return Err(format!("--tolerance {tol} out of range [0,10)"));
            }
            let load = |p: &str| -> Result<Json, String> {
                let text = std::fs::read_to_string(p)
                    .map_err(|e| format!("read {p}: {e}"))?;
                Json::parse(&text).map_err(|e| format!("{p}: {e}"))
            };
            let diff = crate::bench::diff_reports(
                &load(baseline_p)?,
                &load(fresh_p)?,
                tol,
            )?;
            println!(
                "bench-diff: {fresh_p} vs baseline {baseline_p} (p50 tolerance {:.0}%)",
                tol * 100.0
            );
            for l in &diff.lines {
                println!("{l}");
            }
            println!(
                "{} compared, {} unpinned, {} regressed, {} missing",
                diff.compared,
                diff.unpinned,
                diff.regressions.len(),
                diff.missing.len()
            );
            if diff.baseline_is_placeholder() {
                println!(
                    "warning: {baseline_p} is the zeroed placeholder (all p50=0) — latency gated nothing; refresh it from a real run and commit"
                );
            }
            diff.gate()
        }
        "report" => {
            let path = flags.get("telemetry").unwrap_or("telemetry.jsonl");
            run_report(path)
        }
        "inspect" => {
            let dir = PathBuf::from(flags.get("artifacts").unwrap_or("artifacts"));
            let m = crate::runtime::Manifest::load(&dir)?;
            for (name, mm) in &m.models {
                println!(
                    "{name}: P={} D={} C={} B={}/{} K_max={}",
                    mm.param_count,
                    mm.input_dim,
                    mm.num_classes,
                    mm.train_batch,
                    mm.eval_batch,
                    mm.k_max
                );
                for e in &mm.layout {
                    println!("  {:>6} @{:<6} {:?}", e.name, e.offset, e.shape);
                }
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n{}", usage())),
    }
}

/// `dystop report --telemetry FILE`: render the end-of-run summary
/// from a telemetry JSONL snapshot stream (`telemetry.out`). The last
/// line is the final snapshot written at run end; earlier lines are
/// the periodic `telemetry.snapshot_every` samples.
fn run_report(path: &str) -> Result<(), String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read {path}: {e}"))?;
    let last = text
        .lines()
        .rev()
        .find(|l| !l.trim().is_empty())
        .ok_or_else(|| format!("{path}: empty telemetry stream"))?;
    let snap = Json::parse(last).map_err(|e| format!("{path}: {e}"))?;
    if snap.get("kind").and_then(|k| k.as_str()) != Some("telemetry") {
        return Err(format!(
            "{path}: last line is not a telemetry snapshot \
             (expected \"kind\":\"telemetry\")"
        ));
    }
    let round = snap.get("round").and_then(|v| v.as_usize()).unwrap_or(0);
    let wall_s = snap.get("wall_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
    println!("telemetry report: {path}");
    println!("  round {round}, wall clock {wall_s:.3}s");
    let num = |v: &Json| v.as_f64().unwrap_or(0.0);
    if let Some(counters) = snap.get("counters").and_then(|c| c.as_obj()) {
        println!("counters:");
        for (k, v) in counters {
            let n = num(v);
            if n != 0.0 {
                println!("  {k:<28} {n:>14.0}");
            }
        }
    }
    if let Some(gauges) = snap.get("gauges").and_then(|g| g.as_obj()) {
        println!("gauges:");
        for (k, v) in gauges {
            println!("  {k:<28} {:>14.3}", num(v));
        }
    }
    if let Some(phases) = snap.get("phases").and_then(|p| p.as_obj()) {
        println!(
            "phases (wall ns):  {:>10} {:>12} {:>12} {:>12} {:>12}",
            "count", "p50", "p90", "p99", "max"
        );
        for (name, ph) in phases {
            let f = |key: &str| {
                ph.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0)
            };
            if f("count") == 0.0 {
                continue;
            }
            println!(
                "  {name:<16} {:>10.0} {:>12.0} {:>12.0} {:>12.0} {:>12.0}",
                f("count"),
                f("p50"),
                f("p90"),
                f("p99"),
                f("max")
            );
        }
    }
    Ok(())
}

/// `dystop config [--list | KEY]`: the knob registry as a reference.
fn run_config(rest: &[String]) -> Result<(), String> {
    use crate::config::registry;
    // a bare key operand prints one knob; `--list` / nothing, the table
    if let Some(key) = rest.iter().find(|a| !a.starts_with("--")) {
        let k = registry::find(key).ok_or_else(|| {
            match registry::suggest(key) {
                Some(s) => {
                    format!("unknown config key {key:?} (did you mean {s:?}?)")
                }
                None => format!("unknown config key {key:?}"),
            }
        })?;
        println!("{}", knob_line(k));
        return Ok(());
    }
    let mut section = "";
    for k in registry::knobs() {
        let sec = k.key.split('.').next().unwrap_or("");
        if sec != section {
            if !section.is_empty() {
                println!();
            }
            println!("[{sec}]");
            section = sec;
        }
        println!("{}", knob_line(k));
    }
    Ok(())
}

fn knob_line(k: &crate::config::registry::KnobDef) -> String {
    let default = if k.default.is_empty() { "\"\"" } else { k.default };
    format!(
        "  {:<28} {:<20} default {:<10} {}",
        k.key, k.ty, default, k.doc
    )
}

fn usage() -> String {
    "usage: dystop <train|figures|testbed|sweep|config|report|bench-diff|inspect|help> [flags]\n\
     \n\
     train   --config FILE --set KEY=VALUE ... --out results/\n\
     \x20       runs the configured experiment; every KEY is validated against\n\
     \x20       the knob registry (typo ⇒ error with a nearest-key suggestion)\n\
     \x20       --set run.backend=sim|testbed|socket  execution backend:\n\
     \x20       deterministic virtual-clock sim, thread-per-worker testbed, or\n\
     \x20       socket deployment (workers behind real TCP/UDS connections with\n\
     \x20       the sim's event/byte ledger preserved bit-for-bit)\n\
     \x20       --set socket.transport=uds|tcp --set socket.addr=HOST:PORT\n\
     \x20       --set socket.time_scale=1000  socket-backend wall-clock scale\n\
     \x20       --set trace.out=trace.json  write a Perfetto-loadable Trace\n\
     \x20       Event JSON timeline (per-worker tracks; works on any backend)\n\
     \x20       --set telemetry.enabled=true  wall-clock self-profiling registry\n\
     \x20       --set telemetry.addr=127.0.0.1:9184  live Prometheus /metrics\n\
     \x20       --set telemetry.out=telemetry.jsonl --set telemetry.snapshot_every=N\n\
     \x20       periodic JSONL snapshots + a final one at run end (any backend)\n\
     figures --fig <3|4..18|20..25|26|churn|27|codec|28|workload|29|adversary|30|lossy|31|scale|all> --out results/ [--workers N --rounds R]\n\
     testbed --set sim.workers=15 --out results/\n\
     sweep   --key dystop.tau_bound --values 2,5,8 --out results/\n\
     config  [--list | KEY]  print the full knob table (type, default, doc)\n\
     \x20       or one knob's entry — the authoritative list of --set keys\n\
     report  --telemetry telemetry.jsonl  end-of-run summary (counters,\n\
     \x20       gauges, per-phase wall-clock p50/p90/p99) from the snapshots\n\
     bench-diff --baseline BENCH_baseline.json --fresh BENCH_sim.json --tolerance 0.15\n\
     inspect --artifacts artifacts/"
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_sets() {
        let f = Flags::parse(&s(&[
            "--fig", "14", "--set", "sim.workers=10", "--set", "dystop.v=5",
        ]))
        .unwrap();
        assert_eq!(f.get("fig"), Some("14"));
        assert_eq!(f.sets.len(), 2);
        assert_eq!(f.sets[1], ("dystop.v".into(), "5".into()));
    }

    #[test]
    fn rejects_bad_flags() {
        assert!(Flags::parse(&s(&["fig"])).is_err());
        assert!(Flags::parse(&s(&["--fig"])).is_err());
        assert!(Flags::parse(&s(&["--set", "noequals"])).is_err());
    }

    #[test]
    fn unknown_command_errors() {
        assert!(main_with_args(&s(&["bogus"])).is_err());
        assert!(main_with_args(&[]).is_err());
    }

    #[test]
    fn report_renders_the_last_snapshot_and_errors_cleanly() {
        let path = std::env::temp_dir().join(format!(
            "dystop-cli-report-{}.jsonl",
            std::process::id()
        ));
        std::fs::write(
            &path,
            concat!(
                "{\"kind\":\"telemetry\",\"round\":1,\"wall_s\":0.1,\
                 \"counters\":{\"rounds\":1},\"gauges\":{},\"phases\":{}}\n",
                "{\"kind\":\"telemetry\",\"round\":5,\"wall_s\":0.5,\
                 \"counters\":{\"rounds\":5,\"activations\":20},\
                 \"gauges\":{\"population\":6},\
                 \"phases\":{\"round\":{\"count\":5,\"sum\":100,\
                 \"p50\":20,\"p90\":30,\"p99\":30,\"max\":31}}}\n"
            ),
        )
        .unwrap();
        let p = path.to_str().unwrap();
        main_with_args(&s(&["report", "--telemetry", p])).unwrap();
        let _ = std::fs::remove_file(&path);
        // missing file and non-telemetry content are clean errors
        assert!(main_with_args(&s(&["report", "--telemetry", p])).is_err());
        std::fs::write(&path, "{\"kind\":\"round\"}\n").unwrap();
        assert!(main_with_args(&s(&["report", "--telemetry", p])).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn pjrt_without_artifacts_is_clean_error() {
        // construction-path failures surface as Err, never a panic/abort
        let err = main_with_args(&s(&[
            "train",
            "--set", "sim.trainer=pjrt",
            "--set", "sim.workers=4",
            "--set", "sim.rounds=2",
        ]))
        .unwrap_err();
        assert!(err.contains("trainer required"), "{err}");
    }

    #[test]
    fn bad_backend_knob_is_clean_error() {
        let err = main_with_args(&s(&[
            "train",
            "--set", "run.backend=quantum",
        ]))
        .unwrap_err();
        assert!(err.contains("unknown backend"), "{err}");
    }

    #[test]
    fn train_with_churn_scenario_writes_event_log() {
        let dir = std::env::temp_dir().join("dystop_cli_churn_test");
        let _ = std::fs::remove_dir_all(&dir);
        main_with_args(&s(&[
            "train",
            "--set", "sim.workers=10",
            "--set", "sim.rounds=20",
            "--set", "data.train_per_worker=48",
            "--set", "eval.every=10",
            "--set", "scenario.preset=diurnal",
            "--out", dir.to_str().unwrap(),
        ]))
        .unwrap();
        let events = dir.join("dystop_events.csv");
        assert!(events.exists(), "diurnal run must log scenario events");
        let text = std::fs::read_to_string(&events).unwrap();
        assert!(text.lines().count() > 1, "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_scenario_preset_is_clean_error() {
        let err = main_with_args(&s(&[
            "train",
            "--set", "scenario.preset=apocalypse",
        ]))
        .unwrap_err();
        assert!(err.contains("unknown scenario preset"), "{err}");
    }

    #[test]
    fn bench_diff_gates_on_files() {
        let dir = std::env::temp_dir()
            .join(format!("dystop_cli_benchdiff_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("base.json");
        let fresh = dir.join("fresh.json");
        let row = |p50: f64| {
            format!(
                "{{\"results\":[{{\"name\":\"sim_round N=60 dystop\",\"iters\":9,\"mean_ns\":{p50},\"stddev_ns\":1,\"p50_ns\":{p50},\"p99_ns\":{p50}}}]}}"
            )
        };
        std::fs::write(&base, row(1000.0)).unwrap();
        // within tolerance: passes
        std::fs::write(&fresh, row(1100.0)).unwrap();
        main_with_args(&s(&[
            "bench-diff",
            "--baseline", base.to_str().unwrap(),
            "--fresh", fresh.to_str().unwrap(),
        ]))
        .unwrap();
        // injected >15% slowdown: the gate must fail
        std::fs::write(&fresh, row(1300.0)).unwrap();
        let err = main_with_args(&s(&[
            "bench-diff",
            "--baseline", base.to_str().unwrap(),
            "--fresh", fresh.to_str().unwrap(),
        ]))
        .unwrap_err();
        assert!(err.contains("regression gate failed"), "{err}");
        // a looser explicit tolerance admits the same slowdown
        main_with_args(&s(&[
            "bench-diff",
            "--baseline", base.to_str().unwrap(),
            "--fresh", fresh.to_str().unwrap(),
            "--tolerance", "0.5",
        ]))
        .unwrap();
        // missing files are clean errors
        let err = main_with_args(&s(&[
            "bench-diff",
            "--baseline", dir.join("nope.json").to_str().unwrap(),
            "--fresh", fresh.to_str().unwrap(),
        ]))
        .unwrap_err();
        assert!(err.contains("read"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn train_tiny_end_to_end() {
        let dir = std::env::temp_dir().join("dystop_cli_test");
        let _ = std::fs::remove_dir_all(&dir);
        main_with_args(&s(&[
            "train",
            "--set", "sim.workers=6",
            "--set", "sim.rounds=10",
            "--set", "data.train_per_worker=48",
            "--set", "eval.every=5",
            "--out", dir.to_str().unwrap(),
        ]))
        .unwrap();
        assert!(dir.join("dystop_eval.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn config_subcommand_lists_and_looks_up() {
        main_with_args(&s(&["config"])).unwrap();
        main_with_args(&s(&["config", "--list"])).unwrap();
        main_with_args(&s(&["config", "sim.workers"])).unwrap();
        let err = main_with_args(&s(&["config", "sim.wrokers"])).unwrap_err();
        assert!(err.contains("did you mean"), "{err}");
        assert!(err.contains("sim.workers"), "{err}");
    }

    #[test]
    fn typoed_set_key_suggests_nearest() {
        let err = main_with_args(&s(&[
            "train",
            "--set", "dystop.tau_bond=5",
        ]))
        .unwrap_err();
        assert!(err.contains("unknown config key"), "{err}");
        assert!(err.contains("dystop.tau_bound"), "{err}");
    }

    #[cfg(unix)]
    #[test]
    fn train_socket_backend_with_trace_end_to_end() {
        let dir = std::env::temp_dir().join(format!(
            "dystop_cli_socket_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("trace.json");
        main_with_args(&s(&[
            "train",
            "--set", "run.backend=socket",
            "--set", "socket.time_scale=0.001",
            "--set", "sim.workers=6",
            "--set", "sim.rounds=4",
            "--set", "data.train_per_worker=48",
            "--set", "data.test_samples=64",
            "--set", "eval.every=2",
            "--set", &format!("trace.out={}", trace.display()),
            "--out", dir.to_str().unwrap(),
        ]))
        .unwrap();
        let text = std::fs::read_to_string(&trace).unwrap();
        let json = Json::parse(&text).unwrap();
        let events = json.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert!(!events.is_empty(), "trace must contain events");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
