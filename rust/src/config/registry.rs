//! Central knob registry: every `--set`/config-file key the experiment
//! surface understands, with its type, default, and a one-line doc.
//!
//! [`ExperimentConfig::from_config`](super::ExperimentConfig::from_config)
//! validates incoming keys against this table, so a typo'd knob errors
//! with a nearest-key suggestion instead of being silently ignored —
//! and `dystop config --list` prints the table, replacing the
//! drift-prone knob dumps that used to live in the CLI usage text.

/// One registered knob.
#[derive(Clone, Copy, Debug)]
pub struct KnobDef {
    /// Flattened `section.key` name (`--set key=value`).
    pub key: &'static str,
    /// Human-readable value type (`int`, `float`, `bool`, `string`, or
    /// a `a|b|c` enum list).
    pub ty: &'static str,
    /// Default value, rendered as the string a user would pass.
    pub default: &'static str,
    /// One-line description.
    pub doc: &'static str,
}

/// The full knob table, grouped by section. Keep this in sync with
/// `ExperimentConfig::from_config` — the registry tests pin that every
/// default listed here round-trips through it.
#[rustfmt::skip]
static KNOBS: &[KnobDef] = &[
    // --- sim ---
    KnobDef { key: "sim.seed", ty: "int", default: "1", doc: "master RNG seed; every backend derives its streams from it" },
    KnobDef { key: "sim.workers", ty: "int", default: "100", doc: "population size N" },
    KnobDef { key: "sim.rounds", ty: "int", default: "300", doc: "training rounds to run" },
    KnobDef { key: "sim.phi", ty: "float", default: "1.0", doc: "Dirichlet non-IID level phi (1.0 ~ IID, 0.4 highly skewed)" },
    KnobDef { key: "sim.scheduler", ty: "dystop|dystop-phase1|dystop-phase2|sa-adfl|asydfl|matcha", default: "dystop", doc: "topology scheduler under test" },
    KnobDef { key: "sim.model", ty: "mlp|cnn", default: "mlp", doc: "legacy model selector (prefer workload.model)" },
    KnobDef { key: "sim.trainer", ty: "native|pjrt", default: "native", doc: "local-step trainer: pure-Rust native or PJRT artifacts" },
    // --- run ---
    KnobDef { key: "run.backend", ty: "sim|testbed|socket", default: "sim", doc: "execution backend: virtual-clock sim, thread testbed, or socket deployment" },
    KnobDef { key: "run.engine", ty: "dense|event", default: "dense", doc: "sim round core: dense O(N) sweep or discrete-event queue (bit-identical)" },
    KnobDef { key: "run.threads", ty: "int", default: "0", doc: "round-execution worker pool (0 = all cores; bit-identical for any value)" },
    // --- metrics ---
    KnobDef { key: "metrics.sink", ty: "memory|csv|jsonl", default: "memory", doc: "where round/eval/event records stream" },
    KnobDef { key: "metrics.out", ty: "string", default: "", doc: "sink output path (JSONL file or CSV prefix); required when sink != memory" },
    KnobDef { key: "metrics.window", ty: "int", default: "0", doc: "in-memory retention: keep only the last K round records (0 = all)" },
    // --- dystop ---
    KnobDef { key: "dystop.tau_bound", ty: "int", default: "5", doc: "staleness bound tau_bound (Eq. 12c)" },
    KnobDef { key: "dystop.v", ty: "float", default: "10.0", doc: "Lyapunov trade-off V (Eq. 34)" },
    KnobDef { key: "dystop.neighbor_cap", ty: "int", default: "7", doc: "in-neighbor sample cap s" },
    KnobDef { key: "dystop.t_thre", ty: "int", default: "60", doc: "PTCA phase-switch round t_thre (Alg. 3)" },
    // --- data ---
    KnobDef { key: "data.classes", ty: "int", default: "10", doc: "synthetic corpus class count" },
    KnobDef { key: "data.dim", ty: "int", default: "32", doc: "synthetic corpus feature dimension" },
    KnobDef { key: "data.train_per_worker", ty: "int", default: "128", doc: "training samples per worker" },
    KnobDef { key: "data.test_samples", ty: "int", default: "512", doc: "shared test-set size" },
    KnobDef { key: "data.class_sep", ty: "float", default: "2.0", doc: "class separation of the synthetic mixture (higher = easier)" },
    // --- train ---
    KnobDef { key: "train.lr", ty: "float", default: "0.1", doc: "SGD learning rate" },
    KnobDef { key: "train.batch", ty: "int", default: "32", doc: "minibatch size" },
    KnobDef { key: "train.local_steps", ty: "int", default: "2", doc: "local SGD steps per activation" },
    // --- compute ---
    KnobDef { key: "compute.mean_s", ty: "float", default: "1.0", doc: "median local-training time h_i in seconds" },
    KnobDef { key: "compute.jitter", ty: "float", default: "0.8", doc: "sigma of the lognormal per-worker speed coefficient" },
    // --- eval ---
    KnobDef { key: "eval.every", ty: "int", default: "10", doc: "evaluate every K rounds" },
    KnobDef { key: "eval.worker_frac", ty: "float", default: "1.0", doc: "fraction of workers whose local model is evaluated" },
    KnobDef { key: "eval.target_accuracy", ty: "float", default: "0.8", doc: "time-to-accuracy target for the eval summary" },
    // --- net ---
    KnobDef { key: "net.region_m", ty: "float", default: "100.0", doc: "deployment region side length in meters" },
    KnobDef { key: "net.bandwidth_hz", ty: "float", default: "1e6", doc: "per-link bandwidth in Hz" },
    KnobDef { key: "net.g0_db", ty: "float", default: "-43.0", doc: "path-loss constant at 1 m" },
    KnobDef { key: "net.noise_w", ty: "float", default: "1e-13", doc: "noise power in W" },
    KnobDef { key: "net.tx_dbm_min", ty: "float", default: "10.0", doc: "minimum transmit power in dBm" },
    KnobDef { key: "net.tx_dbm_max", ty: "float", default: "20.0", doc: "maximum transmit power in dBm" },
    KnobDef { key: "net.comm_range_m", ty: "float", default: "45.0", doc: "communication range in meters" },
    KnobDef { key: "net.budget_jitter", ty: "float", default: "0.15", doc: "std-dev of per-round multiplicative bandwidth-budget jitter" },
    KnobDef { key: "net.budget_models", ty: "float", default: "16.0", doc: "per-round per-worker bandwidth budget in model-transfer units" },
    KnobDef { key: "net.link_drop_prob", ty: "float", default: "0.02", doc: "probability a link drops for a round" },
    KnobDef { key: "net.mobility_m", ty: "float", default: "1.0", doc: "per-round worker movement std-dev in meters" },
    KnobDef { key: "net.payload_bits", ty: "float", default: "2e6", doc: "simulated model payload on the wire in bits (0 = actual model size)" },
    KnobDef { key: "net.channels", ty: "int", default: "4", doc: "orthogonal sub-channels per worker radio" },
    // --- scenario ---
    KnobDef { key: "scenario.preset", ty: "stable|diurnal|flash-crowd|degraded", default: "stable", doc: "population-dynamics preset" },
    KnobDef { key: "scenario.churn_rate", ty: "float", default: "0.0", doc: "per-round per-worker leave probability" },
    KnobDef { key: "scenario.mean_downtime_rounds", ty: "float", default: "10.0", doc: "mean rounds a departed worker stays away" },
    KnobDef { key: "scenario.crash_frac", ty: "float", default: "0.0", doc: "fraction of departures that are crashes (state loss)" },
    // --- transport ---
    KnobDef { key: "transport.codec", ty: "dense|topk|int8", default: "dense", doc: "model-exchange compression codec" },
    KnobDef { key: "transport.topk_frac", ty: "float", default: "0.1", doc: "top-k codec: fraction of coordinates kept" },
    KnobDef { key: "transport.int8_clip", ty: "float", default: "1.0", doc: "int8 codec: symmetric clip range" },
    // --- workload ---
    KnobDef { key: "workload.model", ty: "linear|mlp|cnn-s", default: "linear", doc: "native model architecture" },
    KnobDef { key: "workload.dataset", ty: "synthetic|clusters|drift|file", default: "synthetic", doc: "corpus generator" },
    KnobDef { key: "workload.hidden", ty: "int", default: "32", doc: "MLP hidden width" },
    KnobDef { key: "workload.conv_filters", ty: "int", default: "16", doc: "cnn-s filter count" },
    KnobDef { key: "workload.conv_kernel", ty: "int", default: "11", doc: "cnn-s kernel size" },
    KnobDef { key: "workload.conv_stride", ty: "int", default: "2", doc: "cnn-s stride" },
    KnobDef { key: "workload.cluster_skew", ty: "float", default: "0.6", doc: "clusters dataset: per-worker cluster concentration" },
    KnobDef { key: "workload.drift_deg", ty: "float", default: "40.0", doc: "drift dataset: per-round rotation in degrees" },
    KnobDef { key: "workload.path", ty: "string", default: "", doc: "file dataset: features.idx,labels.idx pair" },
    // --- adversary ---
    KnobDef { key: "adversary.frac", ty: "float", default: "0.0", doc: "fraction of workers that are Byzantine" },
    KnobDef { key: "adversary.attack", ty: "none|signflip|scale|labelflip|stalebomb|freeride", default: "none", doc: "Byzantine attack policy" },
    KnobDef { key: "adversary.scale", ty: "float", default: "10.0", doc: "scale attack: blow-up factor" },
    KnobDef { key: "adversary.stale_tau", ty: "int", default: "5", doc: "stale-bomb attack: rounds a bomber withholds updates" },
    KnobDef { key: "adversary.aggregator", ty: "mean|trimmed-mean|median|krum", default: "mean", doc: "robust aggregation rule" },
    KnobDef { key: "adversary.trim_frac", ty: "float", default: "0.2", doc: "trimmed-mean: fraction trimmed per tail" },
    KnobDef { key: "adversary.krum_f", ty: "int", default: "1", doc: "krum: assumed Byzantine count f" },
    // --- faults ---
    KnobDef { key: "faults.profile", ty: "clean|wifi|cellular|hostile", default: "clean", doc: "lossy-link fault preset" },
    KnobDef { key: "faults.loss", ty: "float", default: "0.0", doc: "per-frame loss probability" },
    KnobDef { key: "faults.dup", ty: "float", default: "0.0", doc: "per-frame duplication probability" },
    KnobDef { key: "faults.corrupt", ty: "float", default: "0.0", doc: "per-frame corruption probability" },
    KnobDef { key: "faults.delay_spike", ty: "float", default: "0.0", doc: "per-frame delay-spike probability" },
    KnobDef { key: "faults.delay_spike_factor", ty: "float", default: "4.0", doc: "delay-spike transfer-time multiplier" },
    KnobDef { key: "faults.retries", ty: "int", default: "3", doc: "ack/retry attempts (0 disables the protocol)" },
    KnobDef { key: "faults.backoff_base_s", ty: "float", default: "0.05", doc: "retry backoff base in seconds" },
    KnobDef { key: "faults.backoff_cap_s", ty: "float", default: "2.0", doc: "retry backoff cap in seconds" },
    KnobDef { key: "faults.jitter", ty: "float", default: "0.5", doc: "retry backoff jitter fraction" },
    // --- testbed ---
    KnobDef { key: "testbed.time_scale", ty: "float", default: "1000.0", doc: "testbed backend: virtual-second to wall-millisecond scale" },
    KnobDef { key: "testbed.profile", ty: "bool", default: "true", doc: "testbed backend: profile real thread speeds for the 15-worker demo" },
    // --- socket ---
    KnobDef { key: "socket.transport", ty: "uds|tcp", default: "uds", doc: "socket backend: stream transport (uds is unix-only)" },
    KnobDef { key: "socket.addr", ty: "string", default: "", doc: "socket backend: bind path (uds) or host:port (tcp); empty = auto" },
    KnobDef { key: "socket.time_scale", ty: "float", default: "1000.0", doc: "socket backend: virtual-second to wall-millisecond scale" },
    // --- trace ---
    KnobDef { key: "trace.out", ty: "string", default: "", doc: "Perfetto Trace Event JSON output path (empty = no trace)" },
    // --- telemetry ---
    KnobDef { key: "telemetry.enabled", ty: "bool", default: "false", doc: "force the wall-clock metric registry on (addr/out also enable it)" },
    KnobDef { key: "telemetry.addr", ty: "string", default: "", doc: "live /metrics HTTP bind address, host:port (empty = no server; port 0 = ephemeral)" },
    KnobDef { key: "telemetry.out", ty: "string", default: "", doc: "telemetry JSONL snapshot path (empty = no snapshot file)" },
    KnobDef { key: "telemetry.snapshot_every", ty: "int", default: "0", doc: "snapshot cadence in rounds (0 = end-of-run snapshot only; requires telemetry.out)" },
];

/// Every registered knob, in display order (grouped by section).
pub fn knobs() -> &'static [KnobDef] {
    KNOBS
}

/// Look up a knob by exact key.
pub fn find(key: &str) -> Option<&'static KnobDef> {
    KNOBS.iter().find(|k| k.key == key)
}

/// Nearest registered key by edit distance, if any is close enough to
/// plausibly be a typo.
pub fn suggest(key: &str) -> Option<&'static str> {
    KNOBS
        .iter()
        .map(|k| (edit_distance(key, k.key), k.key))
        .min_by_key(|&(d, _)| d)
        .filter(|&(d, _)| d <= 3)
        .map(|(_, k)| k)
}

/// Reject any key that is not in the registry, with a nearest-key
/// suggestion when one is close.
pub fn validate_keys<'a>(
    keys: impl Iterator<Item = &'a str>,
) -> Result<(), String> {
    for k in keys {
        if find(k).is_none() {
            return Err(match suggest(k) {
                Some(s) => format!(
                    "unknown config key {k:?} (did you mean {s:?}?)"
                ),
                None => format!(
                    "unknown config key {k:?} (see `dystop config --list`)"
                ),
            });
        }
    }
    Ok(())
}

/// Levenshtein distance, small-string flavor (knob keys are short).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, ExperimentConfig};

    #[test]
    fn keys_are_unique_and_sectioned() {
        let mut seen = std::collections::BTreeSet::new();
        for k in knobs() {
            assert!(seen.insert(k.key), "duplicate registry key {}", k.key);
            assert!(
                k.key.contains('.'),
                "key {} must be section.name",
                k.key
            );
            assert!(!k.doc.is_empty(), "key {} needs a doc line", k.key);
        }
    }

    #[test]
    fn every_default_round_trips_through_from_config() {
        // set every knob to its registry default; from_config must
        // accept the full set (pins registry <-> from_config sync in
        // the direction "registry key is actually consumed")
        let mut cfg = Config::new();
        for k in knobs() {
            cfg.set(k.key, k.default);
        }
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.workers, 100);
        assert_eq!(e.socket.time_scale, 1000.0);
    }

    #[test]
    fn unknown_key_suggests_nearest() {
        let mut cfg = Config::new();
        cfg.set("dystop.tau_bond", "5");
        let err = ExperimentConfig::from_config(&cfg).unwrap_err();
        assert!(err.contains("unknown config key"), "{err}");
        assert!(err.contains("did you mean"), "{err}");
        assert!(err.contains("dystop.tau_bound"), "{err}");
    }

    #[test]
    fn distant_garbage_gets_no_suggestion() {
        let err =
            validate_keys(["zzzz.qqqqqqqqqqqq"].into_iter()).unwrap_err();
        assert!(err.contains("dystop config --list"), "{err}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("", ""), 0);
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("abc", "abd"), 1);
        assert_eq!(edit_distance("abc", ""), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }

    #[test]
    fn find_and_suggest() {
        assert!(find("sim.workers").is_some());
        assert!(find("sim.wrokers").is_none());
        assert_eq!(suggest("sim.wrokers"), Some("sim.workers"));
    }
}
