//! Configuration system: a typed key/value store parsed from a
//! TOML-subset text format (sections, scalars, inline lists) plus CLI
//! `--key value` overrides. No `serde`/`toml` offline — the parser is a
//! substrate of this repo.
//!
//! ```text
//! [sim]
//! workers = 100
//! rounds = 300
//! phi = 0.4            # Dirichlet non-IID level (§VI-A2)
//!
//! [dystop]
//! tau_bound = 5
//! v = 10.0
//! neighbor_cap = 7
//! ```

mod experiment;
pub mod registry;

pub use experiment::{
    AdversaryConfig, AggregatorKind, AttackKind, BackendKind, CodecKind,
    DatasetKind, EngineKind, ExperimentConfig, FaultConfig, FaultProfile,
    MetricsConfig, ModelArch, ModelKind, NetworkConfig, ScenarioConfig,
    ScenarioPreset, SchedulerKind, SinkKind, SocketConfig,
    SocketTransportKind, TelemetryConfig, TestbedConfig, TraceConfig,
    TrainerKind, TransportConfig, WorkloadConfig,
};

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// Parsed config: flattened `section.key` → raw string value.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

#[derive(Debug)]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error (line {}): {}", self.line, self.msg)
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse from text. Supports `[section]`, `key = value`, `#`/`;`
    /// comments, quoted strings, and `[a, b, c]` inline lists.
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or(ConfigError {
                    line: lineno + 1,
                    msg: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or(ConfigError {
                line: lineno + 1,
                msg: format!("expected `key = value`, got {line:?}"),
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(ConfigError {
                    line: lineno + 1,
                    msg: "empty key".into(),
                });
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            cfg.values.insert(full, unquote(value.trim()).to_string());
        }
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Config::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Set/override a value (CLI overrides use this).
    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, String> {
        self.typed(key, "float", |s| s.parse::<f64>().ok())
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, String> {
        self.typed(key, "integer", |s| s.parse::<usize>().ok())
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>, String> {
        self.typed(key, "integer", |s| s.parse::<u64>().ok())
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>, String> {
        self.typed(key, "bool", |s| match s {
            "true" | "yes" | "1" => Some(true),
            "false" | "no" | "0" => Some(false),
            _ => None,
        })
    }

    /// Inline list of floats: `[1.0, 0.7, 0.4]`.
    pub fn get_f64_list(&self, key: &str) -> Result<Option<Vec<f64>>, String> {
        self.typed(key, "float list", |s| {
            let inner = s.strip_prefix('[')?.strip_suffix(']')?;
            inner
                .split(',')
                .map(|t| t.trim().parse::<f64>().ok())
                .collect::<Option<Vec<_>>>()
        })
    }

    fn typed<T>(
        &self,
        key: &str,
        ty: &str,
        parse: impl Fn(&str) -> Option<T>,
    ) -> Result<Option<T>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(raw) => parse(raw)
                .map(Some)
                .ok_or_else(|| format!("key {key}: expected {ty}, got {raw:?}")),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // respect quotes when stripping comments
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' | ';' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(s: &str) -> &str {
    if s.len() >= 2 && s.starts_with('"') && s.ends_with('"') {
        &s[1..s.len() - 1]
    } else {
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(
            "top = 1\n[sim]\nworkers = 100 # count\nphi = 0.4\nname = \"run a\"\nlist = [1.0, 0.7, 0.4]\nflag = true\n",
        )
        .unwrap();
        assert_eq!(cfg.get_usize("top").unwrap(), Some(1));
        assert_eq!(cfg.get_usize("sim.workers").unwrap(), Some(100));
        assert_eq!(cfg.get_f64("sim.phi").unwrap(), Some(0.4));
        assert_eq!(cfg.get("sim.name"), Some("run a"));
        assert_eq!(
            cfg.get_f64_list("sim.list").unwrap(),
            Some(vec![1.0, 0.7, 0.4])
        );
        assert_eq!(cfg.get_bool("sim.flag").unwrap(), Some(true));
    }

    #[test]
    fn missing_key_is_none() {
        let cfg = Config::parse("").unwrap();
        assert_eq!(cfg.get_f64("nope").unwrap(), None);
    }

    #[test]
    fn type_error_reports_key() {
        let cfg = Config::parse("x = notanumber").unwrap();
        let err = cfg.get_f64("x").unwrap_err();
        assert!(err.contains("x"), "{err}");
    }

    #[test]
    fn bad_lines_error_with_lineno() {
        let err = Config::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        let err = Config::parse("[open\n").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn override_wins() {
        let mut cfg = Config::parse("[a]\nx = 1").unwrap();
        cfg.set("a.x", "2");
        assert_eq!(cfg.get_usize("a.x").unwrap(), Some(2));
    }

    #[test]
    fn comment_inside_quotes_kept() {
        let cfg = Config::parse("s = \"a # b\"").unwrap();
        assert_eq!(cfg.get("s"), Some("a # b"));
    }
}
