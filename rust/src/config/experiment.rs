//! Typed experiment schema with paper-faithful defaults (§VI-A).

use super::Config;

/// Which mechanism schedules rounds (paper §VI-A3 benchmarks + ablations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    /// DySTop: WAA + PTCA (this paper).
    DySTop,
    /// PTCA ablation: phase-1 priority only (Fig. 3).
    DySTopPhase1Only,
    /// PTCA ablation: phase-2 priority only (Fig. 3).
    DySTopPhase2Only,
    /// SA-ADFL \[15\]: single staleness-aware worker, pushes to all in range.
    SaAdfl,
    /// AsyDFL \[14\]: event-driven async, no staleness control.
    AsyDfl,
    /// MATCHA \[9\]: synchronous matching decomposition.
    Matcha,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "dystop" => Ok(Self::DySTop),
            "dystop-phase1" | "phase1" => Ok(Self::DySTopPhase1Only),
            "dystop-phase2" | "phase2" => Ok(Self::DySTopPhase2Only),
            "sa-adfl" | "saadfl" => Ok(Self::SaAdfl),
            "asydfl" => Ok(Self::AsyDfl),
            "matcha" => Ok(Self::Matcha),
            other => Err(format!(
                "unknown scheduler {other:?} (dystop|dystop-phase1|dystop-phase2|sa-adfl|asydfl|matcha)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::DySTop => "dystop",
            Self::DySTopPhase1Only => "dystop-phase1",
            Self::DySTopPhase2Only => "dystop-phase2",
            Self::SaAdfl => "sa-adfl",
            Self::AsyDfl => "asydfl",
            Self::Matcha => "matcha",
        }
    }
}

/// Which model artifact the workers train.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Mlp,
    Cnn,
}

impl ModelKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "mlp" => Ok(Self::Mlp),
            "cnn" => Ok(Self::Cnn),
            other => Err(format!("unknown model {other:?} (mlp|cnn)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Mlp => "mlp",
            Self::Cnn => "cnn",
        }
    }
}

/// Which native model architecture the workers train (`workload.model`
/// knob — the registry in [`crate::workload`]). Distinct from
/// [`ModelKind`], which names the PJRT *artifact* for the AOT runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ModelArch {
    /// Softmax regression — bit-compatible with the pre-workload
    /// trainer; the default.
    #[default]
    Linear,
    /// One ReLU hidden layer (`workload.hidden` units).
    Mlp,
    /// Small 1-D conv net via im2col (`workload.conv_*` knobs).
    CnnS,
}

impl ModelArch {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "linear" => Ok(Self::Linear),
            "mlp" => Ok(Self::Mlp),
            "cnn-s" | "cnns" | "cnn_s" => Ok(Self::CnnS),
            other => Err(format!(
                "unknown workload model {other:?} (linear|mlp|cnn-s)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Linear => "linear",
            Self::Mlp => "mlp",
            Self::CnnS => "cnn-s",
        }
    }

    /// CI matrix hook: `DYSTOP_WORKLOAD_MODEL` (when set and non-empty)
    /// overrides `default` — workload-parametric tests route their
    /// model choice through this so one test binary covers the whole
    /// registry across CI matrix legs.
    pub fn from_env_or(default: Self) -> Self {
        match std::env::var("DYSTOP_WORKLOAD_MODEL") {
            Ok(v) if !v.is_empty() => Self::parse(&v)
                .expect("DYSTOP_WORKLOAD_MODEL must name a registered model"),
            _ => default,
        }
    }
}

/// Which corpus generator feeds the workers (`workload.dataset` knob —
/// the generators in [`crate::workload`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum DatasetKind {
    /// The base Gaussian-mixture corpus — bit-identical to the
    /// pre-workload data path; the default.
    #[default]
    Synthetic,
    /// Shifted-cluster label-skew: antipodal cluster pairs per class
    /// with mixture weights skewed across classes
    /// (`workload.cluster_skew`) — the workload where the model axis
    /// separates (Fig. 28).
    Clusters,
    /// Rotated/drifting features (`workload.drift_deg`): train rows
    /// drift progressively, the test set sits at the full angle.
    Drift,
    /// On-disk corpus (`workload.path`): an `"features.idx,labels.idx"`
    /// IDX pair or a `label,f1,…` CSV — real MNIST-class data without a
    /// new build.
    File,
}

impl DatasetKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "synthetic" => Ok(Self::Synthetic),
            "clusters" | "shifted-clusters" => Ok(Self::Clusters),
            "drift" | "rotated" => Ok(Self::Drift),
            "file" | "idx" | "csv" => Ok(Self::File),
            other => Err(format!(
                "unknown workload dataset {other:?} \
                 (synthetic|clusters|drift|file)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Synthetic => "synthetic",
            Self::Clusters => "clusters",
            Self::Drift => "drift",
            Self::File => "file",
        }
    }
}

/// Workload-layer knobs (`workload.*` keys): which model architecture
/// and corpus generator the experiment runs over, plus their
/// parameters. The defaults (`linear` × `synthetic`) reproduce
/// pre-workload runs bit-identically.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadConfig {
    pub model: ModelArch,
    pub dataset: DatasetKind,
    /// Hidden-layer width of the `mlp` model (`workload.hidden`).
    pub hidden: usize,
    /// Filter count of the `cnn-s` model (`workload.conv_filters`).
    pub conv_filters: usize,
    /// Kernel length of the `cnn-s` model (`workload.conv_kernel`).
    pub conv_kernel: usize,
    /// Stride of the `cnn-s` model (`workload.conv_stride`).
    pub conv_stride: usize,
    /// Cluster-share skew of the `clusters` dataset
    /// (`workload.cluster_skew`, in [0,1]).
    pub cluster_skew: f64,
    /// Full drift angle of the `drift` dataset in degrees
    /// (`workload.drift_deg`).
    pub drift_deg: f64,
    /// Corpus path for the `file` dataset (`workload.path`):
    /// `"features.idx,labels.idx"` or `data.csv`.
    pub path: String,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            model: ModelArch::Linear,
            dataset: DatasetKind::Synthetic,
            hidden: 32,
            // validated on the clusters workload: a wide-ish receptive
            // field is what lets the shared filters resolve the
            // antipodal waveform structure a linear separator cannot
            conv_filters: 16,
            conv_kernel: 11,
            conv_stride: 2,
            cluster_skew: 0.6,
            drift_deg: 40.0,
            path: String::new(),
        }
    }
}

impl WorkloadConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.hidden == 0 {
            return Err("workload.hidden must be > 0".into());
        }
        if self.conv_filters == 0 {
            return Err("workload.conv_filters must be > 0".into());
        }
        if self.conv_kernel == 0 {
            return Err("workload.conv_kernel must be > 0".into());
        }
        if self.conv_stride == 0 {
            return Err("workload.conv_stride must be > 0".into());
        }
        if !(0.0..=1.0).contains(&self.cluster_skew) {
            return Err("workload.cluster_skew must be in [0,1]".into());
        }
        if !self.drift_deg.is_finite() {
            return Err("workload.drift_deg must be finite".into());
        }
        if self.dataset == DatasetKind::File && self.path.is_empty() {
            return Err(
                "workload.dataset=file requires workload.path".into()
            );
        }
        Ok(())
    }

    /// Shape constraints between the model and the feature dimension.
    /// Checked at config validation (against `data.dim`) and re-checked
    /// by the builder after a `file` corpus defines its own shape.
    pub fn model_fits(&self, feature_dim: usize) -> Result<(), String> {
        if self.model == ModelArch::CnnS && self.conv_kernel > feature_dim {
            return Err(format!(
                "workload.conv_kernel ({}) exceeds the feature dim ({})",
                self.conv_kernel, feature_dim
            ));
        }
        Ok(())
    }
}

/// Which Byzantine attack the adversarial workers mount
/// (`adversary.attack` knob — see [`crate::adversary`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AttackKind {
    /// No attack: every worker honest. The default — bit-identical to
    /// the pre-adversary engine.
    #[default]
    None,
    /// Gradient poisoning: transmit `-θ` instead of `θ`.
    SignFlip,
    /// Gradient poisoning: transmit `adversary.scale · θ`.
    Scale,
    /// Data poisoning: the attacker's shard labels are flipped
    /// (`y → C-1-y`) at build time; its honest-looking training then
    /// pushes anti-gradients.
    LabelFlip,
    /// Stale bomb: replay the attacker's parameters from
    /// `adversary.stale_tau` rounds ago.
    StaleBomb,
    /// Free riding: transmit the frozen initial parameters forever.
    FreeRide,
}

impl AttackKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "honest" => Ok(Self::None),
            "signflip" | "sign-flip" => Ok(Self::SignFlip),
            "scale" => Ok(Self::Scale),
            "labelflip" | "label-flip" => Ok(Self::LabelFlip),
            "stalebomb" | "stale-bomb" => Ok(Self::StaleBomb),
            "freeride" | "free-ride" => Ok(Self::FreeRide),
            other => Err(format!(
                "unknown adversary attack {other:?} \
                 (none|signflip|scale|labelflip|stalebomb|freeride)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::None => "none",
            Self::SignFlip => "signflip",
            Self::Scale => "scale",
            Self::LabelFlip => "labelflip",
            Self::StaleBomb => "stalebomb",
            Self::FreeRide => "freeride",
        }
    }

    /// CI matrix hook: `DYSTOP_ADVERSARY_ATTACK` (when set and
    /// non-empty) overrides `default` — attack-parametric tests route
    /// their choice through this so one test binary covers every attack
    /// across CI matrix legs (mirrors `DYSTOP_WORKLOAD_MODEL`).
    pub fn from_env_or(default: Self) -> Self {
        match std::env::var("DYSTOP_ADVERSARY_ATTACK") {
            Ok(v) if !v.is_empty() => Self::parse(&v)
                .expect("DYSTOP_ADVERSARY_ATTACK must name an attack"),
            _ => default,
        }
    }
}

/// Which coordinator-side aggregation rule combines pulled models
/// (`adversary.aggregator` knob — see [`crate::adversary`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AggregatorKind {
    /// Data-size-weighted mean (paper Eq. 4). The default —
    /// bit-identical to the pre-adversary `Trainer::aggregate` path.
    #[default]
    Mean,
    /// Coordinate-wise trimmed mean: drop the `adversary.trim_frac`
    /// extremes on each side, average the rest (unweighted).
    TrimmedMean,
    /// Coordinate-wise median (even counts average the middle two).
    CoordinateMedian,
    /// Krum: keep the single model minimizing the summed squared
    /// distance to its `n - f - 2` nearest peers (`adversary.krum_f`).
    Krum,
}

impl AggregatorKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "mean" => Ok(Self::Mean),
            "trimmed-mean" | "trimmed_mean" | "trimmedmean" | "trim" => {
                Ok(Self::TrimmedMean)
            }
            "median" | "coordinate-median" | "coordinate_median" => {
                Ok(Self::CoordinateMedian)
            }
            "krum" => Ok(Self::Krum),
            other => Err(format!(
                "unknown aggregator {other:?} (mean|trimmed-mean|median|krum)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Mean => "mean",
            Self::TrimmedMean => "trimmed-mean",
            Self::CoordinateMedian => "median",
            Self::Krum => "krum",
        }
    }
}

/// Adversary-layer knobs (`adversary.*` keys): which attack a seeded
/// fraction of workers mounts and which robust aggregation rule the
/// honest side runs. The defaults (`frac=0` × `aggregator=mean`)
/// reproduce pre-adversary runs bit-identically.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdversaryConfig {
    /// Fraction of workers assigned the attack policy
    /// (`adversary.frac`; attackers = ⌊frac·workers⌋, drawn on a
    /// dedicated RNG stream).
    pub frac: f64,
    /// Attack the adversarial workers mount (`adversary.attack`).
    pub attack: AttackKind,
    /// Multiplier of the `scale` attack (`adversary.scale`).
    pub scale: f64,
    /// Replay age of the `stalebomb` attack, in rounds
    /// (`adversary.stale_tau`).
    pub stale_tau: usize,
    /// Aggregation rule (`adversary.aggregator`).
    pub aggregator: AggregatorKind,
    /// Per-side trim fraction of `trimmed-mean`
    /// (`adversary.trim_frac`, in [0,0.5)).
    pub trim_frac: f64,
    /// Byzantine count Krum assumes among in-neighbors
    /// (`adversary.krum_f`; clamped to `n-3` per aggregation).
    pub krum_f: usize,
}

impl Default for AdversaryConfig {
    fn default() -> Self {
        AdversaryConfig {
            frac: 0.0,
            attack: AttackKind::None,
            scale: 10.0,
            stale_tau: 5,
            aggregator: AggregatorKind::Mean,
            trim_frac: 0.2,
            krum_f: 1,
        }
    }
}

impl AdversaryConfig {
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=1.0).contains(&self.frac) {
            return Err("adversary.frac must be in [0,1]".into());
        }
        if !self.scale.is_finite() {
            return Err("adversary.scale must be finite".into());
        }
        if self.stale_tau == 0 {
            return Err("adversary.stale_tau must be >= 1".into());
        }
        if !(0.0..0.5).contains(&self.trim_frac) {
            return Err("adversary.trim_frac must be in [0,0.5)".into());
        }
        Ok(())
    }
}

/// Which link-fault preset the delivery layer injects
/// (`faults.profile` knob — see [`crate::delivery`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FaultProfile {
    /// Lossless links: every frame arrives intact on the first attempt.
    /// The default — bit-identical to the pre-delivery engine.
    #[default]
    Clean,
    /// Light residential-WiFi impairment: occasional loss, rare
    /// duplication/corruption.
    Wifi,
    /// Congested cellular uplink: noticeable loss, duplication from
    /// handover retries, regular latency spikes.
    Cellular,
    /// Hostile/degraded RF environment: heavy loss, frequent corruption
    /// and latency spikes — the retry budget is routinely exhausted.
    Hostile,
}

impl FaultProfile {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "clean" | "none" => Ok(Self::Clean),
            "wifi" => Ok(Self::Wifi),
            "cellular" | "lte" => Ok(Self::Cellular),
            "hostile" => Ok(Self::Hostile),
            other => Err(format!(
                "unknown faults profile {other:?} \
                 (clean|wifi|cellular|hostile)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Clean => "clean",
            Self::Wifi => "wifi",
            Self::Cellular => "cellular",
            Self::Hostile => "hostile",
        }
    }

    /// CI matrix hook: `DYSTOP_FAULTS_PROFILE` (when set and non-empty)
    /// overrides `default` — fault-parametric tests route their profile
    /// choice through this so one test binary covers every profile
    /// across CI matrix legs (mirrors `DYSTOP_WORKLOAD_MODEL` /
    /// `DYSTOP_ADVERSARY_ATTACK`).
    pub fn from_env_or(default: Self) -> Self {
        match std::env::var("DYSTOP_FAULTS_PROFILE") {
            Ok(v) if !v.is_empty() => Self::parse(&v)
                .expect("DYSTOP_FAULTS_PROFILE must name a fault profile"),
            _ => default,
        }
    }

    /// Preset knob defaults: (loss, dup, corrupt, delay_spike) per-frame
    /// probabilities. Explicit `faults.*` keys override these.
    pub fn default_knobs(self) -> (f64, f64, f64, f64) {
        match self {
            Self::Clean => (0.0, 0.0, 0.0, 0.0),
            Self::Wifi => (0.05, 0.01, 0.005, 0.02),
            Self::Cellular => (0.12, 0.02, 0.01, 0.08),
            Self::Hostile => (0.35, 0.05, 0.05, 0.20),
        }
    }
}

/// Delivery-layer knobs (`faults.*` keys): the deterministic per-link
/// fault model plus the reliable-delivery retry protocol on top. The
/// default (`profile=clean`, all rates zero) is knob-inert:
/// bit-identical to the pre-delivery engine for every backend × codec ×
/// model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultConfig {
    /// Fault preset (`faults.profile`); sets the four rate knobs below.
    pub profile: FaultProfile,
    /// Per-frame-attempt loss probability (`faults.loss`).
    pub loss: f64,
    /// Probability a delivered frame arrives duplicated (`faults.dup`;
    /// the duplicate is detected by sequencing and never
    /// double-aggregated).
    pub dup: f64,
    /// Per-frame-attempt corruption probability (`faults.corrupt`; a
    /// corrupted frame fails its CRC32 check and is treated as lost).
    pub corrupt: f64,
    /// Per-frame-attempt latency-spike probability
    /// (`faults.delay_spike`; a spiked attempt costs
    /// `delay_spike_factor ×` its transfer time).
    pub delay_spike: f64,
    /// Transfer-time multiplier of a latency spike
    /// (`faults.delay_spike_factor`).
    pub delay_spike_factor: f64,
    /// Per-edge retransmission budget per round (`faults.retries`);
    /// attempts = retries + 1, exhaustion dead-letters the edge and the
    /// receiver aggregates without it.
    pub retries: usize,
    /// Initial ack-timeout backoff in seconds (`faults.backoff_base_s`;
    /// doubles per retry up to the cap).
    pub backoff_base_s: f64,
    /// Backoff cap in seconds (`faults.backoff_cap_s`).
    pub backoff_cap_s: f64,
    /// Deterministic jitter fraction in [0,1] applied to each backoff
    /// (`faults.jitter`; drawn from the same per-edge stream as the
    /// fault outcomes).
    pub jitter: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig::preset(FaultProfile::Clean)
    }
}

impl FaultConfig {
    /// A fault config carrying the preset's default rate knobs; the
    /// retry-protocol knobs are profile-independent.
    pub fn preset(profile: FaultProfile) -> Self {
        let (loss, dup, corrupt, delay_spike) = profile.default_knobs();
        FaultConfig {
            profile,
            loss,
            dup,
            corrupt,
            delay_spike,
            delay_spike_factor: 4.0,
            retries: 3,
            backoff_base_s: 0.05,
            backoff_cap_s: 2.0,
            jitter: 0.5,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        for (v, k) in [
            (self.loss, "faults.loss"),
            (self.dup, "faults.dup"),
            (self.corrupt, "faults.corrupt"),
            (self.delay_spike, "faults.delay_spike"),
            (self.jitter, "faults.jitter"),
        ] {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("{k} must be in [0,1]"));
            }
        }
        if self.loss + self.corrupt >= 1.0
            && (self.loss > 0.0 || self.corrupt > 0.0)
        {
            return Err(
                "faults.loss + faults.corrupt must be < 1 (every frame \
                 failing makes delivery impossible)"
                    .into(),
            );
        }
        if self.delay_spike_factor < 1.0 {
            return Err("faults.delay_spike_factor must be >= 1".into());
        }
        if self.backoff_base_s < 0.0 {
            return Err("faults.backoff_base_s must be >= 0".into());
        }
        if self.backoff_cap_s < self.backoff_base_s {
            return Err(
                "faults.backoff_cap_s must be >= faults.backoff_base_s"
                    .into(),
            );
        }
        Ok(())
    }

    /// Whether any fault channel can fire. `false` (the `clean`
    /// default) is the knob-inert contract: the delivery layer draws no
    /// randomness and changes no behavior.
    pub fn is_active(&self) -> bool {
        self.loss > 0.0
            || self.dup > 0.0
            || self.corrupt > 0.0
            || self.delay_spike > 0.0
    }
}

/// Which training backend executes local steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainerKind {
    /// Pure-Rust softmax-regression trainer: fast substrate for
    /// large-scale sims and tests (no artifacts needed).
    Native,
    /// Real model via AOT HLO artifacts on the PJRT CPU client.
    Pjrt,
}

impl TrainerKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(Self::Native),
            "pjrt" => Ok(Self::Pjrt),
            other => Err(format!("unknown trainer {other:?} (native|pjrt)")),
        }
    }
}

/// Which execution backend drives rounds (`run.backend` knob): the
/// virtual-clock simulator (§VI), the thread-per-worker testbed
/// (§VII), or the socket deployment backend (workers behind a real
/// TCP/UDS wire).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Deterministic virtual-clock simulation (`experiment::VirtualClockBackend`).
    #[default]
    Sim,
    /// Thread-per-worker runtime with real message passing
    /// (`experiment::ThreadedBackend`).
    Testbed,
    /// Deployment runtime: worker threads speak the length-prefixed
    /// wire format over real TCP/UDS sockets
    /// (`experiment::SocketBackend`).
    Socket,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "sim" | "virtual" | "virtual-clock" => Ok(Self::Sim),
            "testbed" | "threaded" => Ok(Self::Testbed),
            "socket" | "deploy" => Ok(Self::Socket),
            other => Err(format!(
                "unknown backend {other:?} (sim|testbed|socket)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Sim => "sim",
            Self::Testbed => "testbed",
            Self::Socket => "socket",
        }
    }
}

/// Which simulation core the virtual-clock backend runs (`run.engine`
/// knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Per-round dense engine: rebuilds the full scheduler view
    /// (candidate sets, H estimates, staleness/queue gathers) every
    /// round. Cost O(N) per round regardless of activity. The default.
    #[default]
    Dense,
    /// Discrete-event engine: caches the scheduler view across rounds
    /// and advances worker state lazily, so a round's incremental cost
    /// is proportional to the activated workers and pull edges (plus a
    /// trivial O(present) scan), not to the full candidate/geometry
    /// rebuild. Bit-identical to `dense` for every seeded config — the
    /// cross-engine equivalence suite pins it.
    Event,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "dense" | "round" => Ok(Self::Dense),
            "event" | "discrete-event" => Ok(Self::Event),
            other => Err(format!(
                "unknown engine {other:?} (dense|event)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Dense => "dense",
            Self::Event => "event",
        }
    }
}

/// Where round/eval/event records go (`metrics.sink` knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SinkKind {
    /// Keep every record in the in-memory [`RunResult`]. The default.
    #[default]
    Memory,
    /// Stream records to three CSV files (`metrics.out` prefix +
    /// `_rounds.csv` / `_evals.csv` / `_events.csv`) as they happen —
    /// same formats as the post-hoc CSV writers.
    Csv,
    /// Stream records to one JSON-lines file (`metrics.out`), one
    /// type-tagged object per line.
    Jsonl,
}

impl SinkKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "memory" | "mem" => Ok(Self::Memory),
            "csv" => Ok(Self::Csv),
            "jsonl" | "json-lines" | "ndjson" => Ok(Self::Jsonl),
            other => Err(format!(
                "unknown metrics sink {other:?} (memory|csv|jsonl)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Memory => "memory",
            Self::Csv => "csv",
            Self::Jsonl => "jsonl",
        }
    }
}

/// Metrics-plumbing knobs (`metrics.*` keys): where records stream and
/// how much of the run the in-memory [`RunResult`] retains. The
/// defaults (`sink=memory`, `window=0` = unbounded) reproduce the
/// pre-streaming engine exactly.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct MetricsConfig {
    /// Streaming sink (`metrics.sink=memory|csv|jsonl`).
    pub sink: SinkKind,
    /// Output path (`metrics.out`): the JSONL file, or the CSV file
    /// prefix. Required when `sink != memory`.
    pub out: String,
    /// In-memory retention (`metrics.window`): keep only the last
    /// `window` round/eval/event records in the [`RunResult`]
    /// (0 = keep everything). With a streaming sink the full run is on
    /// disk, so a bounded window makes N=1M runs O(window) resident.
    pub window: usize,
}

impl MetricsConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.sink != SinkKind::Memory && self.out.is_empty() {
            return Err(format!(
                "metrics.sink={} requires metrics.out",
                self.sink.name()
            ));
        }
        Ok(())
    }
}

/// Which scenario preset drives the population/environment timeline
/// (`scenario.preset` knob — see [`crate::scenario`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ScenarioPreset {
    /// Static population, nominal environment: the empty timeline. The
    /// default — bit-identical to the pre-scenario engine.
    #[default]
    Stable,
    /// Day/night population wave: workers leave and rejoin tracking a
    /// sinusoidal target, plus light random churn.
    Diurnal,
    /// Population surge: a reduced initial cast, a mass join wave
    /// mid-run (fresh devices), then mass departure.
    FlashCrowd,
    /// Hostile environment: heavy churn with crashes, a bandwidth
    /// collapse window, a mobility burst, and a region partition.
    Degraded,
}

impl ScenarioPreset {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "stable" => Ok(Self::Stable),
            "diurnal" => Ok(Self::Diurnal),
            "flash-crowd" | "flashcrowd" | "flash_crowd" => Ok(Self::FlashCrowd),
            "degraded" => Ok(Self::Degraded),
            other => Err(format!(
                "unknown scenario preset {other:?} (stable|diurnal|flash-crowd|degraded)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Stable => "stable",
            Self::Diurnal => "diurnal",
            Self::FlashCrowd => "flash-crowd",
            Self::Degraded => "degraded",
        }
    }

    /// Preset knob defaults: (churn_rate, mean_downtime_rounds,
    /// crash_frac). Explicit `scenario.*` keys override these.
    pub fn default_knobs(self) -> (f64, f64, f64) {
        match self {
            Self::Stable => (0.0, 10.0, 0.0),
            Self::Diurnal => (0.02, 12.0, 0.1),
            Self::FlashCrowd => (0.01, 8.0, 0.25),
            Self::Degraded => (0.05, 6.0, 0.5),
        }
    }
}

/// Scenario-layer knobs: which preset timeline to generate and the
/// stochastic-churn generator parameters (`scenario.*` keys).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScenarioConfig {
    pub preset: ScenarioPreset,
    /// Per-present-worker, per-round probability of departing
    /// (`scenario.churn_rate`).
    pub churn_rate: f64,
    /// Mean downtime before a departed worker returns, in rounds
    /// (`scenario.mean_downtime_rounds`; exponential draw, ceiled to a
    /// whole number of rounds, min 1).
    pub mean_downtime_rounds: f64,
    /// Fraction of departures that are crashes (in-flight models
    /// dropped) rather than graceful leaves (`scenario.crash_frac`).
    pub crash_frac: f64,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        Self::preset(ScenarioPreset::Stable)
    }
}

impl ScenarioConfig {
    /// A scenario config carrying the preset's default knob values.
    pub fn preset(preset: ScenarioPreset) -> Self {
        let (churn_rate, mean_downtime_rounds, crash_frac) =
            preset.default_knobs();
        ScenarioConfig { preset, churn_rate, mean_downtime_rounds, crash_frac }
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=1.0).contains(&self.churn_rate) {
            return Err("scenario.churn_rate must be in [0,1]".into());
        }
        if self.mean_downtime_rounds < 1.0 {
            return Err("scenario.mean_downtime_rounds must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.crash_frac) {
            return Err("scenario.crash_frac must be in [0,1]".into());
        }
        Ok(())
    }
}

/// Which compression codec the model transport layer applies to every
/// model exchange (`transport.codec` knob — see [`crate::transport`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum CodecKind {
    /// Identity transport: full dense f32 payload. The default —
    /// bit-identical semantics and byte accounting to the pre-transport
    /// engine.
    #[default]
    Dense,
    /// Top-k delta sparsification with per-worker error-feedback
    /// residuals (`transport.topk_frac` of entries kept).
    TopK,
    /// Uniform 8-bit quantization over `[-clip, clip]`
    /// (`transport.int8_clip`).
    Int8,
}

impl CodecKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "dense" => Ok(Self::Dense),
            "topk" | "top-k" => Ok(Self::TopK),
            "int8" | "q8" => Ok(Self::Int8),
            other => Err(format!(
                "unknown transport codec {other:?} (dense|topk|int8)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Dense => "dense",
            Self::TopK => "topk",
            Self::Int8 => "int8",
        }
    }
}

/// Model-transport knobs (`transport.*` keys): which codec compresses
/// model exchanges and its parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransportConfig {
    pub codec: CodecKind,
    /// Fraction of parameter entries the `topk` codec transmits per
    /// message (`transport.topk_frac`).
    pub topk_frac: f64,
    /// Clipping range of the `int8` codec (`transport.int8_clip`):
    /// values quantize uniformly over `[-clip, clip]`.
    pub int8_clip: f64,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            codec: CodecKind::Dense,
            topk_frac: 0.1,
            int8_clip: 1.0,
        }
    }
}

impl TransportConfig {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.topk_frac > 0.0 && self.topk_frac <= 1.0) {
            return Err("transport.topk_frac must be in (0,1]".into());
        }
        if self.int8_clip <= 0.0 {
            return Err("transport.int8_clip must be > 0".into());
        }
        Ok(())
    }
}

/// Wireless edge-network model constants (paper §VI-A1).
#[derive(Clone, Debug)]
pub struct NetworkConfig {
    /// Region side length in meters (workers uniform in the square).
    pub region_m: f64,
    /// Per-link bandwidth in Hz (paper: 1 MHz).
    pub bandwidth_hz: f64,
    /// Path-loss constant at 1 m (paper: −43 dB).
    pub g0_db: f64,
    /// Noise power in W (paper: 1e-13).
    pub noise_w: f64,
    /// Transmit power range in dBm (paper: 10–20 dBm).
    pub tx_dbm_min: f64,
    pub tx_dbm_max: f64,
    /// Communication range in meters (neighbors must be within range).
    pub comm_range_m: f64,
    /// Std-dev of the per-round multiplicative bandwidth-budget jitter
    /// (edge dynamics: time-varying budgets, Eq. 12d).
    pub budget_jitter: f64,
    /// Per-round per-worker bandwidth budget, in model-transfer units.
    pub budget_models: f64,
    /// Probability a link drops for a round (edge dynamics).
    pub link_drop_prob: f64,
    /// Worker mobility: per-round movement std-dev in meters.
    pub mobility_m: f64,
    /// Orthogonal sub-channels per worker radio: transfers beyond this
    /// concurrency serialize (Eq. 8's max is per-channel; a worker
    /// pulling/pushing more than `channels` models pays extra slots).
    pub channels: usize,
    /// Simulated model payload on the wire, in bits. The compute-side
    /// model is deliberately small (fast CPU sims); the paper's models
    /// (CNN/ResNet-18) are MBs, which is what makes topology efficiency
    /// matter. 0 ⇒ use the actual trained model's size.
    pub payload_bits: f64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        NetworkConfig {
            region_m: 100.0,
            bandwidth_hz: 1e6,
            g0_db: -43.0,
            noise_w: 1e-13,
            tx_dbm_min: 10.0,
            tx_dbm_max: 20.0,
            comm_range_m: 45.0,
            budget_jitter: 0.15,
            budget_models: 16.0,
            link_drop_prob: 0.02,
            mobility_m: 1.0,
            channels: 4,
            // ≈ 250 KB — a small CNN like the paper's FMNIST model; at
            // §VI-A1 rates this is a few-hundred-ms transfer, the regime
            // where communication actually competes with compute.
            payload_bits: 2.0e6,
        }
    }
}

/// Thread-per-worker testbed knobs (`testbed.*` keys). These used to
/// be the programmatic-only `TestbedOptions`; folding them into the
/// config surface gives every backend the same per-backend section.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TestbedConfig {
    /// Virtual-second → wall-millisecond scale for worker sleeps
    /// (`testbed.time_scale`). 1000.0 = real time; smaller is faster.
    pub time_scale: f64,
    /// Profile real thread speeds for the 15-worker heterogeneity
    /// demo instead of the configured lognormal draw
    /// (`testbed.profile`).
    pub profile: bool,
}

impl Default for TestbedConfig {
    fn default() -> Self {
        TestbedConfig { time_scale: 1000.0, profile: true }
    }
}

impl TestbedConfig {
    pub fn validate(&self) -> Result<(), String> {
        if !self.time_scale.is_finite() || self.time_scale <= 0.0 {
            return Err("testbed.time_scale must be > 0".into());
        }
        Ok(())
    }
}

/// Which stream transport the socket backend deploys over
/// (`socket.transport` knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SocketTransportKind {
    /// Unix-domain stream socket (unix targets only). The default:
    /// no ports to collide on, and the path is auto-generated.
    #[default]
    Uds,
    /// TCP over loopback (`127.0.0.1`, ephemeral port by default).
    Tcp,
}

impl SocketTransportKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "uds" | "unix" => Ok(Self::Uds),
            "tcp" => Ok(Self::Tcp),
            other => Err(format!(
                "unknown socket transport {other:?} (uds|tcp)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Uds => "uds",
            Self::Tcp => "tcp",
        }
    }
}

/// Socket deployment backend knobs (`socket.*` keys).
#[derive(Clone, Debug, PartialEq)]
pub struct SocketConfig {
    /// Stream transport (`socket.transport=uds|tcp`).
    pub transport: SocketTransportKind,
    /// Bind address (`socket.addr`): a filesystem path for `uds`, a
    /// `host:port` for `tcp`. Empty (the default) auto-generates a
    /// temp-dir socket path / binds an ephemeral loopback port.
    pub addr: String,
    /// Virtual-second → wall-millisecond scale for worker sleeps
    /// (`socket.time_scale`). The round ledger and records use the
    /// virtual clock, so this only trades realism for wall time.
    pub time_scale: f64,
}

impl Default for SocketConfig {
    fn default() -> Self {
        SocketConfig {
            transport: SocketTransportKind::Uds,
            addr: String::new(),
            time_scale: 1000.0,
        }
    }
}

impl SocketConfig {
    pub fn validate(&self) -> Result<(), String> {
        if !self.time_scale.is_finite() || self.time_scale <= 0.0 {
            return Err("socket.time_scale must be > 0".into());
        }
        Ok(())
    }
}

/// Trace observability knobs (`trace.*` keys).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct TraceConfig {
    /// Perfetto Trace Event JSON output path (`trace.out`). Empty (the
    /// default) disables tracing.
    pub out: String,
}

/// Live telemetry knobs (`telemetry.*` keys). All three exposures ride
/// one registry: any of `enabled`, `addr`, or `out` being set turns the
/// registry on; the all-default config keeps the handle fully inert.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct TelemetryConfig {
    /// Force the registry on without any exposure configured
    /// (`telemetry.enabled`) — counters readable in-process only.
    pub enabled: bool,
    /// `/metrics` HTTP bind address (`telemetry.addr`), `host:port`
    /// (port 0 for ephemeral). Empty (the default) starts no server.
    pub addr: String,
    /// JSONL snapshot path (`telemetry.out`). Empty disables snapshots
    /// except via the end-of-run line when `addr`/`enabled` are set and
    /// `out` is not — no `out`, no file.
    pub out: String,
    /// Snapshot cadence in rounds (`telemetry.snapshot_every`); 0 means
    /// only the unconditional end-of-run snapshot.
    pub snapshot_every: usize,
}

impl TelemetryConfig {
    /// Whether any knob asks for a live registry.
    pub fn active(&self) -> bool {
        self.enabled || !self.addr.is_empty() || !self.out.is_empty()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.snapshot_every > 0 && self.out.is_empty() {
            return Err(
                "telemetry.snapshot_every requires telemetry.out".into()
            );
        }
        Ok(())
    }
}

/// Full experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub seed: u64,
    pub workers: usize,
    pub rounds: usize,
    /// Dirichlet non-IID level φ (paper: 1.0 ≈ IID, 0.4 highly skewed).
    pub phi: f64,
    pub scheduler: SchedulerKind,
    pub model: ModelKind,
    pub trainer: TrainerKind,
    /// Execution backend (`run.backend=sim|testbed`).
    pub backend: BackendKind,
    /// Simulation core (`run.engine=dense|event`). `event` is the
    /// discrete-event core: O(activations + pull edges) incremental
    /// round cost, bit-identical results to `dense` at any seed.
    pub engine: EngineKind,
    /// Metrics streaming + retention (`metrics.*` knobs).
    pub metrics: MetricsConfig,
    /// Worker-pool size for parallel round execution in the
    /// virtual-clock backend (`run.threads`). `0` (the default) means
    /// "use all available parallelism"; `1` forces sequential
    /// execution. Results are bit-identical for every setting — the
    /// engine trains on per-activation RNG streams keyed by
    /// `(seed, round, worker)`, so thread count never reorders draws.
    pub threads: usize,

    // --- DySTop knobs ---
    /// Staleness bound τ_bound (Eq. 12c); Fig. 14/15 sweep.
    pub tau_bound: u64,
    /// Lyapunov trade-off V (Eq. 34); Fig. 16 sweep.
    pub v: f64,
    /// In-neighbor sample cap s (Fig. 17/18 sweep).
    pub neighbor_cap: usize,
    /// PTCA phase switch round t_thre (Alg. 3 line 2).
    pub t_thre: usize,

    // --- data ---
    pub num_classes: usize,
    pub feature_dim: usize,
    pub train_per_worker: usize,
    pub test_samples: usize,
    /// Class-separation of the synthetic mixture (higher = easier).
    pub class_sep: f64,

    // --- training ---
    pub lr: f32,
    pub batch: usize,
    pub local_steps: usize,

    // --- compute heterogeneity (paper: measured batch time × normal coeff) ---
    /// Median local-training time h_i in seconds.
    pub compute_mean_s: f64,
    /// σ of the lognormal per-worker speed coefficient (0.8 ≈ the ~10×
    /// spread of the paper's Table II device mix).
    pub compute_jitter: f64,

    // --- evaluation ---
    pub eval_every: usize,
    /// Fraction of workers whose local model is evaluated (1.0 = all).
    pub eval_worker_frac: f64,
    pub target_accuracy: f64,

    pub network: NetworkConfig,

    /// Population/environment dynamics (`scenario.*` knobs). The default
    /// (`preset=stable`) is the empty timeline: bit-identical to the
    /// pre-scenario engine.
    pub scenario: ScenarioConfig,

    /// Model-transport codec (`transport.*` knobs). The default
    /// (`codec=dense`) is the identity transport: bit-identical to the
    /// pre-transport engine.
    pub transport: TransportConfig,

    /// Workload selection (`workload.*` knobs): model architecture ×
    /// dataset generator. The default (`linear` × `synthetic`)
    /// reproduces pre-workload runs bit-identically.
    pub workload: WorkloadConfig,

    /// Byzantine adversaries + robust aggregation (`adversary.*`
    /// knobs). The default (`frac=0` × `aggregator=mean`) reproduces
    /// pre-adversary runs bit-identically.
    pub adversary: AdversaryConfig,

    /// Lossy-link fault injection + reliable delivery (`faults.*`
    /// knobs). The default (`profile=clean`) is the lossless identity
    /// path: bit-identical to the pre-delivery engine.
    pub faults: FaultConfig,

    /// Thread-per-worker testbed backend section (`testbed.*` knobs).
    pub testbed: TestbedConfig,

    /// Socket deployment backend section (`socket.*` knobs).
    pub socket: SocketConfig,

    /// Perfetto trace observability (`trace.*` knobs). The default
    /// (empty `trace.out`) attaches no trace sink.
    pub trace: TraceConfig,

    /// Live telemetry (`telemetry.*` knobs). The default (everything
    /// off) threads an inert handle — provably bit-identical to runs
    /// without telemetry compiled in at all.
    pub telemetry: TelemetryConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            seed: 1,
            workers: 100,
            rounds: 300,
            phi: 1.0,
            scheduler: SchedulerKind::DySTop,
            model: ModelKind::Mlp,
            trainer: TrainerKind::Native,
            backend: BackendKind::Sim,
            engine: EngineKind::Dense,
            metrics: MetricsConfig::default(),
            threads: 0,
            tau_bound: 5,
            v: 10.0,
            neighbor_cap: 7,
            t_thre: 60,
            num_classes: 10,
            feature_dim: 32,
            train_per_worker: 128,
            test_samples: 512,
            class_sep: 2.0,
            lr: 0.1,
            batch: 32,
            local_steps: 2,
            compute_mean_s: 1.0,
            compute_jitter: 0.8,
            eval_every: 10,
            eval_worker_frac: 1.0,
            target_accuracy: 0.8,
            network: NetworkConfig::default(),
            scenario: ScenarioConfig::default(),
            transport: TransportConfig::default(),
            workload: WorkloadConfig::default(),
            adversary: AdversaryConfig::default(),
            faults: FaultConfig::default(),
            testbed: TestbedConfig::default(),
            socket: SocketConfig::default(),
            trace: TraceConfig::default(),
            telemetry: TelemetryConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Build from a parsed [`Config`], falling back to defaults.
    ///
    /// Every key is checked against the central
    /// [`registry`](crate::config::registry) first, so a typo'd knob
    /// errors with a nearest-key suggestion instead of being silently
    /// ignored.
    pub fn from_config(cfg: &Config) -> Result<Self, String> {
        super::registry::validate_keys(cfg.keys())?;
        let mut e = ExperimentConfig::default();
        macro_rules! opt {
            ($field:expr, $get:ident, $key:expr) => {
                if let Some(v) = cfg.$get($key)? {
                    $field = v;
                }
            };
        }
        opt!(e.seed, get_u64, "sim.seed");
        opt!(e.workers, get_usize, "sim.workers");
        opt!(e.rounds, get_usize, "sim.rounds");
        opt!(e.phi, get_f64, "sim.phi");
        if let Some(s) = cfg.get("sim.scheduler") {
            e.scheduler = SchedulerKind::parse(s)?;
        }
        if let Some(s) = cfg.get("sim.model") {
            e.model = ModelKind::parse(s)?;
        }
        if let Some(s) = cfg.get("sim.trainer") {
            e.trainer = TrainerKind::parse(s)?;
        }
        if let Some(s) = cfg.get("run.backend") {
            e.backend = BackendKind::parse(s)?;
        }
        if let Some(s) = cfg.get("run.engine") {
            e.engine = EngineKind::parse(s)?;
        }
        opt!(e.threads, get_usize, "run.threads");
        if let Some(s) = cfg.get("metrics.sink") {
            e.metrics.sink = SinkKind::parse(s)?;
        }
        if let Some(s) = cfg.get("metrics.out") {
            e.metrics.out = s.to_string();
        }
        opt!(e.metrics.window, get_usize, "metrics.window");
        opt!(e.tau_bound, get_u64, "dystop.tau_bound");
        opt!(e.v, get_f64, "dystop.v");
        opt!(e.neighbor_cap, get_usize, "dystop.neighbor_cap");
        opt!(e.t_thre, get_usize, "dystop.t_thre");
        opt!(e.num_classes, get_usize, "data.classes");
        opt!(e.feature_dim, get_usize, "data.dim");
        opt!(e.train_per_worker, get_usize, "data.train_per_worker");
        opt!(e.test_samples, get_usize, "data.test_samples");
        opt!(e.class_sep, get_f64, "data.class_sep");
        if let Some(v) = cfg.get_f64("train.lr")? {
            e.lr = v as f32;
        }
        opt!(e.batch, get_usize, "train.batch");
        opt!(e.local_steps, get_usize, "train.local_steps");
        opt!(e.compute_mean_s, get_f64, "compute.mean_s");
        opt!(e.compute_jitter, get_f64, "compute.jitter");
        opt!(e.eval_every, get_usize, "eval.every");
        opt!(e.eval_worker_frac, get_f64, "eval.worker_frac");
        opt!(e.target_accuracy, get_f64, "eval.target_accuracy");
        opt!(e.network.region_m, get_f64, "net.region_m");
        opt!(e.network.bandwidth_hz, get_f64, "net.bandwidth_hz");
        opt!(e.network.g0_db, get_f64, "net.g0_db");
        opt!(e.network.noise_w, get_f64, "net.noise_w");
        opt!(e.network.tx_dbm_min, get_f64, "net.tx_dbm_min");
        opt!(e.network.tx_dbm_max, get_f64, "net.tx_dbm_max");
        opt!(e.network.comm_range_m, get_f64, "net.comm_range_m");
        opt!(e.network.budget_jitter, get_f64, "net.budget_jitter");
        opt!(e.network.budget_models, get_f64, "net.budget_models");
        opt!(e.network.link_drop_prob, get_f64, "net.link_drop_prob");
        opt!(e.network.mobility_m, get_f64, "net.mobility_m");
        opt!(e.network.payload_bits, get_f64, "net.payload_bits");
        opt!(e.network.channels, get_usize, "net.channels");
        if let Some(s) = cfg.get("scenario.preset") {
            e.scenario = ScenarioConfig::preset(ScenarioPreset::parse(s)?);
        }
        opt!(e.scenario.churn_rate, get_f64, "scenario.churn_rate");
        opt!(
            e.scenario.mean_downtime_rounds,
            get_f64,
            "scenario.mean_downtime_rounds"
        );
        opt!(e.scenario.crash_frac, get_f64, "scenario.crash_frac");
        if let Some(s) = cfg.get("transport.codec") {
            e.transport.codec = CodecKind::parse(s)?;
        }
        opt!(e.transport.topk_frac, get_f64, "transport.topk_frac");
        opt!(e.transport.int8_clip, get_f64, "transport.int8_clip");
        if let Some(s) = cfg.get("workload.model") {
            e.workload.model = ModelArch::parse(s)?;
        }
        if let Some(s) = cfg.get("workload.dataset") {
            e.workload.dataset = DatasetKind::parse(s)?;
        }
        opt!(e.workload.hidden, get_usize, "workload.hidden");
        opt!(e.workload.conv_filters, get_usize, "workload.conv_filters");
        opt!(e.workload.conv_kernel, get_usize, "workload.conv_kernel");
        opt!(e.workload.conv_stride, get_usize, "workload.conv_stride");
        opt!(e.workload.cluster_skew, get_f64, "workload.cluster_skew");
        opt!(e.workload.drift_deg, get_f64, "workload.drift_deg");
        if let Some(s) = cfg.get("workload.path") {
            e.workload.path = s.to_string();
        }
        opt!(e.adversary.frac, get_f64, "adversary.frac");
        if let Some(s) = cfg.get("adversary.attack") {
            e.adversary.attack = AttackKind::parse(s)?;
        }
        opt!(e.adversary.scale, get_f64, "adversary.scale");
        opt!(e.adversary.stale_tau, get_usize, "adversary.stale_tau");
        if let Some(s) = cfg.get("adversary.aggregator") {
            e.adversary.aggregator = AggregatorKind::parse(s)?;
        }
        opt!(e.adversary.trim_frac, get_f64, "adversary.trim_frac");
        opt!(e.adversary.krum_f, get_usize, "adversary.krum_f");
        if let Some(s) = cfg.get("faults.profile") {
            e.faults = FaultConfig::preset(FaultProfile::parse(s)?);
        }
        opt!(e.faults.loss, get_f64, "faults.loss");
        opt!(e.faults.dup, get_f64, "faults.dup");
        opt!(e.faults.corrupt, get_f64, "faults.corrupt");
        opt!(e.faults.delay_spike, get_f64, "faults.delay_spike");
        opt!(
            e.faults.delay_spike_factor,
            get_f64,
            "faults.delay_spike_factor"
        );
        opt!(e.faults.retries, get_usize, "faults.retries");
        opt!(e.faults.backoff_base_s, get_f64, "faults.backoff_base_s");
        opt!(e.faults.backoff_cap_s, get_f64, "faults.backoff_cap_s");
        opt!(e.faults.jitter, get_f64, "faults.jitter");
        opt!(e.testbed.time_scale, get_f64, "testbed.time_scale");
        opt!(e.testbed.profile, get_bool, "testbed.profile");
        if let Some(s) = cfg.get("socket.transport") {
            e.socket.transport = SocketTransportKind::parse(s)?;
        }
        if let Some(s) = cfg.get("socket.addr") {
            e.socket.addr = s.to_string();
        }
        opt!(e.socket.time_scale, get_f64, "socket.time_scale");
        if let Some(s) = cfg.get("trace.out") {
            e.trace.out = s.to_string();
        }
        opt!(e.telemetry.enabled, get_bool, "telemetry.enabled");
        if let Some(s) = cfg.get("telemetry.addr") {
            e.telemetry.addr = s.to_string();
        }
        if let Some(s) = cfg.get("telemetry.out") {
            e.telemetry.out = s.to_string();
        }
        opt!(
            e.telemetry.snapshot_every,
            get_usize,
            "telemetry.snapshot_every"
        );
        e.validate()?;
        Ok(e)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.workers == 0 {
            return Err("sim.workers must be > 0".into());
        }
        if self.phi <= 0.0 {
            return Err("sim.phi must be > 0 (Dirichlet concentration)".into());
        }
        if !(0.0..=1.0).contains(&self.eval_worker_frac) {
            return Err("eval.worker_frac must be in [0,1]".into());
        }
        if self.neighbor_cap == 0 {
            return Err("dystop.neighbor_cap must be > 0".into());
        }
        if self.batch == 0 || self.batch > self.train_per_worker {
            return Err(format!(
                "train.batch ({}) must be in [1, train_per_worker={}]",
                self.batch, self.train_per_worker
            ));
        }
        if self.network.comm_range_m <= 0.0 {
            return Err("net.comm_range_m must be > 0".into());
        }
        self.metrics.validate()?;
        self.scenario.validate()?;
        self.transport.validate()?;
        self.workload.validate()?;
        self.adversary.validate()?;
        self.faults.validate()?;
        self.testbed.validate()?;
        self.socket.validate()?;
        self.telemetry.validate()?;
        // file corpora define their own feature dim at build time — the
        // builder re-runs model_fits against the adopted shape; checking
        // the placeholder dim here would spuriously reject valid configs
        if self.workload.dataset != DatasetKind::File {
            self.workload.model_fits(self.feature_dim)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn from_config_overrides() {
        let cfg = Config::parse(
            "[sim]\nworkers = 20\nphi = 0.4\nscheduler = matcha\n[dystop]\ntau_bound = 8\n[net]\ncomm_range_m = 60\n",
        )
        .unwrap();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.workers, 20);
        assert_eq!(e.phi, 0.4);
        assert_eq!(e.scheduler, SchedulerKind::Matcha);
        assert_eq!(e.tau_bound, 8);
        assert_eq!(e.network.comm_range_m, 60.0);
        // untouched default
        assert_eq!(e.v, 10.0);
    }

    #[test]
    fn invalid_values_rejected() {
        let cfg = Config::parse("[sim]\nworkers = 0").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[sim]\nscheduler = bogus").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[train]\nbatch = 100000").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn backend_knob_parses() {
        assert_eq!(BackendKind::parse("sim").unwrap(), BackendKind::Sim);
        assert_eq!(
            BackendKind::parse("Testbed").unwrap(),
            BackendKind::Testbed
        );
        assert!(BackendKind::parse("bogus").is_err());
        let cfg = Config::parse("[run]\nbackend = testbed").unwrap();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.backend, BackendKind::Testbed);
        // default stays sim
        assert_eq!(ExperimentConfig::default().backend, BackendKind::Sim);
    }

    #[test]
    fn engine_knob_parses() {
        assert_eq!(EngineKind::parse("dense").unwrap(), EngineKind::Dense);
        assert_eq!(EngineKind::parse("Event").unwrap(), EngineKind::Event);
        assert_eq!(
            EngineKind::parse("discrete-event").unwrap(),
            EngineKind::Event
        );
        assert!(EngineKind::parse("bogus").is_err());
        let cfg = Config::parse("[run]\nengine = event").unwrap();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.engine, EngineKind::Event);
        // default stays dense
        assert_eq!(ExperimentConfig::default().engine, EngineKind::Dense);
        assert_eq!(EngineKind::Event.name(), "event");
    }

    #[test]
    fn metrics_knobs_parse_and_validate() {
        let d = ExperimentConfig::default();
        assert_eq!(d.metrics.sink, SinkKind::Memory);
        assert_eq!(d.metrics.window, 0);
        let cfg = Config::parse(
            "[metrics]\nsink = jsonl\nout = /tmp/run.jsonl\nwindow = 64\n",
        )
        .unwrap();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.metrics.sink, SinkKind::Jsonl);
        assert_eq!(e.metrics.out, "/tmp/run.jsonl");
        assert_eq!(e.metrics.window, 64);
        // a file sink without a path is rejected
        let cfg = Config::parse("[metrics]\nsink = csv\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        // unknown sink rejected
        let cfg = Config::parse("[metrics]\nsink = bogus\nout = x\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        assert_eq!(SinkKind::Csv.name(), "csv");
        assert_eq!(SinkKind::parse("ndjson").unwrap(), SinkKind::Jsonl);
    }

    #[test]
    fn threads_knob_parses_and_defaults_to_auto() {
        assert_eq!(ExperimentConfig::default().threads, 0); // 0 = auto
        let cfg = Config::parse("[run]\nthreads = 4").unwrap();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.threads, 4);
    }

    #[test]
    fn scenario_knobs_parse_with_preset_defaults_and_overrides() {
        // default is stable with zero churn
        let d = ExperimentConfig::default();
        assert_eq!(d.scenario.preset, ScenarioPreset::Stable);
        assert_eq!(d.scenario.churn_rate, 0.0);
        // preset sets knob defaults
        let cfg = Config::parse("[scenario]\npreset = diurnal\n").unwrap();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.scenario.preset, ScenarioPreset::Diurnal);
        assert!(e.scenario.churn_rate > 0.0);
        // explicit knobs override the preset defaults
        let cfg = Config::parse(
            "[scenario]\npreset = degraded\nchurn_rate = 0.11\ncrash_frac = 0.9\n",
        )
        .unwrap();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.scenario.preset, ScenarioPreset::Degraded);
        assert_eq!(e.scenario.churn_rate, 0.11);
        assert_eq!(e.scenario.crash_frac, 0.9);
        // invalid values rejected
        let cfg = Config::parse("[scenario]\nchurn_rate = 1.5\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[scenario]\npreset = bogus\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn scenario_preset_names_roundtrip() {
        for p in [
            ScenarioPreset::Stable,
            ScenarioPreset::Diurnal,
            ScenarioPreset::FlashCrowd,
            ScenarioPreset::Degraded,
        ] {
            assert_eq!(ScenarioPreset::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn transport_knobs_parse_with_defaults_and_overrides() {
        // default is the dense identity transport
        let d = ExperimentConfig::default();
        assert_eq!(d.transport.codec, CodecKind::Dense);
        assert_eq!(d.transport.topk_frac, 0.1);
        assert_eq!(d.transport.int8_clip, 1.0);
        // knobs parse
        let cfg = Config::parse(
            "[transport]\ncodec = topk\ntopk_frac = 0.05\nint8_clip = 2.5\n",
        )
        .unwrap();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.transport.codec, CodecKind::TopK);
        assert_eq!(e.transport.topk_frac, 0.05);
        assert_eq!(e.transport.int8_clip, 2.5);
        // invalid values rejected
        let cfg = Config::parse("[transport]\ncodec = gzip\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[transport]\ntopk_frac = 0\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[transport]\ntopk_frac = 1.5\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[transport]\nint8_clip = -1\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn codec_names_roundtrip() {
        for c in [CodecKind::Dense, CodecKind::TopK, CodecKind::Int8] {
            assert_eq!(CodecKind::parse(c.name()).unwrap(), c);
        }
        assert!(CodecKind::parse("bogus").is_err());
    }

    #[test]
    fn workload_knobs_parse_with_defaults_and_overrides() {
        // default is linear × synthetic (the bit-identity pair)
        let d = ExperimentConfig::default();
        assert_eq!(d.workload.model, ModelArch::Linear);
        assert_eq!(d.workload.dataset, DatasetKind::Synthetic);
        assert_eq!(d.workload.hidden, 32);
        // knobs parse
        let cfg = Config::parse(
            "[workload]\nmodel = mlp\ndataset = clusters\nhidden = 16\n\
             cluster_skew = 0.3\n",
        )
        .unwrap();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.workload.model, ModelArch::Mlp);
        assert_eq!(e.workload.dataset, DatasetKind::Clusters);
        assert_eq!(e.workload.hidden, 16);
        assert_eq!(e.workload.cluster_skew, 0.3);
        // cnn-s spelling variants
        assert_eq!(ModelArch::parse("CNN-S").unwrap(), ModelArch::CnnS);
        assert_eq!(ModelArch::parse("cnn_s").unwrap(), ModelArch::CnnS);
        // invalid values rejected
        let cfg = Config::parse("[workload]\nmodel = resnet\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[workload]\nhidden = 0\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[workload]\ncluster_skew = 1.5\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        // file dataset needs a path
        let cfg = Config::parse("[workload]\ndataset = file\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse(
            "[workload]\ndataset = file\npath = data.csv\n",
        )
        .unwrap();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.workload.dataset, DatasetKind::File);
        assert_eq!(e.workload.path, "data.csv");
        // the cnn kernel must fit the feature dim
        let cfg = Config::parse(
            "[workload]\nmodel = cnn-s\nconv_kernel = 64\n",
        )
        .unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn workload_names_roundtrip() {
        for m in [ModelArch::Linear, ModelArch::Mlp, ModelArch::CnnS] {
            assert_eq!(ModelArch::parse(m.name()).unwrap(), m);
        }
        for d in [
            DatasetKind::Synthetic,
            DatasetKind::Clusters,
            DatasetKind::Drift,
            DatasetKind::File,
        ] {
            assert_eq!(DatasetKind::parse(d.name()).unwrap(), d);
        }
        assert!(ModelArch::parse("bogus").is_err());
        assert!(DatasetKind::parse("bogus").is_err());
    }

    #[test]
    fn adversary_knobs_parse_with_defaults_and_overrides() {
        // default is benign: no attackers, plain weighted mean
        let d = ExperimentConfig::default();
        assert_eq!(d.adversary.frac, 0.0);
        assert_eq!(d.adversary.attack, AttackKind::None);
        assert_eq!(d.adversary.aggregator, AggregatorKind::Mean);
        // knobs parse
        let cfg = Config::parse(
            "[adversary]\nfrac = 0.2\nattack = signflip\n\
             aggregator = krum\nkrum_f = 3\nscale = -4\nstale_tau = 9\n\
             trim_frac = 0.25\n",
        )
        .unwrap();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.adversary.frac, 0.2);
        assert_eq!(e.adversary.attack, AttackKind::SignFlip);
        assert_eq!(e.adversary.aggregator, AggregatorKind::Krum);
        assert_eq!(e.adversary.krum_f, 3);
        assert_eq!(e.adversary.scale, -4.0);
        assert_eq!(e.adversary.stale_tau, 9);
        assert_eq!(e.adversary.trim_frac, 0.25);
        // invalid values rejected
        let cfg = Config::parse("[adversary]\nattack = ddos\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[adversary]\nfrac = 1.5\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[adversary]\ntrim_frac = 0.5\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[adversary]\nstale_tau = 0\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[adversary]\naggregator = sum\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn adversary_names_roundtrip() {
        for a in [
            AttackKind::None,
            AttackKind::SignFlip,
            AttackKind::Scale,
            AttackKind::LabelFlip,
            AttackKind::StaleBomb,
            AttackKind::FreeRide,
        ] {
            assert_eq!(AttackKind::parse(a.name()).unwrap(), a);
        }
        for g in [
            AggregatorKind::Mean,
            AggregatorKind::TrimmedMean,
            AggregatorKind::CoordinateMedian,
            AggregatorKind::Krum,
        ] {
            assert_eq!(AggregatorKind::parse(g.name()).unwrap(), g);
        }
        assert!(AttackKind::parse("bogus").is_err());
        assert!(AggregatorKind::parse("bogus").is_err());
    }

    #[test]
    fn fault_knobs_parse_with_preset_defaults_and_overrides() {
        // default is clean: every rate zero, delivery layer inert
        let d = ExperimentConfig::default();
        assert_eq!(d.faults.profile, FaultProfile::Clean);
        assert_eq!(d.faults.loss, 0.0);
        assert_eq!(d.faults.dup, 0.0);
        assert_eq!(d.faults.corrupt, 0.0);
        assert_eq!(d.faults.delay_spike, 0.0);
        assert!(!d.faults.is_active());
        // preset sets rate defaults
        let cfg = Config::parse("[faults]\nprofile = cellular\n").unwrap();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.faults.profile, FaultProfile::Cellular);
        assert!(e.faults.loss > 0.0);
        assert!(e.faults.is_active());
        // explicit knobs override the preset defaults
        let cfg = Config::parse(
            "[faults]\nprofile = wifi\nloss = 0.3\nretries = 1\n\
             jitter = 0.0\n",
        )
        .unwrap();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.faults.profile, FaultProfile::Wifi);
        assert_eq!(e.faults.loss, 0.3);
        assert_eq!(e.faults.retries, 1);
        assert_eq!(e.faults.jitter, 0.0);
        // invalid values rejected
        let cfg = Config::parse("[faults]\nloss = 1.5\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[faults]\nprofile = bogus\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse(
            "[faults]\nloss = 0.7\ncorrupt = 0.3\n",
        )
        .unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse(
            "[faults]\nbackoff_base_s = 3.0\nbackoff_cap_s = 1.0\n",
        )
        .unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[faults]\ndelay_spike_factor = 0.5\n")
            .unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn fault_profile_names_roundtrip() {
        for p in [
            FaultProfile::Clean,
            FaultProfile::Wifi,
            FaultProfile::Cellular,
            FaultProfile::Hostile,
        ] {
            assert_eq!(FaultProfile::parse(p.name()).unwrap(), p);
        }
        assert!(FaultProfile::parse("bogus").is_err());
    }

    #[test]
    fn fault_profile_env_default_passthrough() {
        // without the env knob set, the default passes through (the
        // set-path is covered by the CI matrix itself — mutating the
        // process environment in a threaded test harness is unsound)
        if std::env::var("DYSTOP_FAULTS_PROFILE").is_err() {
            assert_eq!(
                FaultProfile::from_env_or(FaultProfile::Cellular),
                FaultProfile::Cellular
            );
        }
    }

    #[test]
    fn attack_env_default_passthrough() {
        // without the env knob set, the default passes through (the
        // set-path is covered by the CI matrix itself — mutating the
        // process environment in a threaded test harness is unsound)
        if std::env::var("DYSTOP_ADVERSARY_ATTACK").is_err() {
            assert_eq!(
                AttackKind::from_env_or(AttackKind::SignFlip),
                AttackKind::SignFlip
            );
        }
    }

    #[test]
    fn model_arch_env_default_passthrough() {
        // without the env knob set, the default passes through (the
        // set-path is covered by the CI matrix itself — mutating the
        // process environment in a threaded test harness is unsound)
        if std::env::var("DYSTOP_WORKLOAD_MODEL").is_err() {
            assert_eq!(
                ModelArch::from_env_or(ModelArch::Mlp),
                ModelArch::Mlp
            );
        }
    }

    #[test]
    fn scheduler_names_roundtrip() {
        for k in [
            SchedulerKind::DySTop,
            SchedulerKind::DySTopPhase1Only,
            SchedulerKind::DySTopPhase2Only,
            SchedulerKind::SaAdfl,
            SchedulerKind::AsyDfl,
            SchedulerKind::Matcha,
        ] {
            assert_eq!(SchedulerKind::parse(k.name()).unwrap(), k);
        }
    }

    #[test]
    fn socket_backend_knob_parses() {
        assert_eq!(BackendKind::parse("socket").unwrap(), BackendKind::Socket);
        assert_eq!(BackendKind::parse("deploy").unwrap(), BackendKind::Socket);
        assert_eq!(BackendKind::Socket.name(), "socket");
        let err = BackendKind::parse("bogus").unwrap_err();
        assert!(err.contains("sim|testbed|socket"), "{err}");
    }

    #[test]
    fn socket_and_testbed_sections_parse() {
        let cfg = Config::parse(
            "[socket]\ntransport = tcp\naddr = 127.0.0.1:7070\n\
             time_scale = 10\n[testbed]\ntime_scale = 5\nprofile = false\n\
             [trace]\nout = /tmp/run.trace.json\n",
        )
        .unwrap();
        let e = ExperimentConfig::from_config(&cfg).unwrap();
        assert_eq!(e.socket.transport, SocketTransportKind::Tcp);
        assert_eq!(e.socket.addr, "127.0.0.1:7070");
        assert_eq!(e.socket.time_scale, 10.0);
        assert_eq!(e.testbed.time_scale, 5.0);
        assert!(!e.testbed.profile);
        assert_eq!(e.trace.out, "/tmp/run.trace.json");
        // defaults: uds transport, auto addr, no trace
        let d = ExperimentConfig::default();
        assert_eq!(d.socket.transport, SocketTransportKind::Uds);
        assert!(d.socket.addr.is_empty());
        assert!(d.trace.out.is_empty());
        // invalid values rejected
        let cfg = Config::parse("[socket]\ntransport = carrier-pigeon\n")
            .unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[socket]\ntime_scale = 0\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
        let cfg = Config::parse("[testbed]\ntime_scale = -1\n").unwrap();
        assert!(ExperimentConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn socket_transport_names_roundtrip() {
        for t in [SocketTransportKind::Uds, SocketTransportKind::Tcp] {
            assert_eq!(SocketTransportKind::parse(t.name()).unwrap(), t);
        }
        assert!(SocketTransportKind::parse("bogus").is_err());
    }
}
