//! The PJRT execution surface: loads the AOT HLO-text artifacts and
//! executes them on the request path, wrapped as a backend-agnostic
//! [`Trainer`].
//!
//! Pattern per /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Artifacts are compiled once and cached
//! per model variant (DESIGN.md: one executable per entry point).
//!
//! Compiled only with the `pjrt` feature (on by default). The `xla`
//! names resolve through [`super::xla`] — an API-compatible offline
//! stub whose constructors fail cleanly when no real PJRT binding is
//! available; see that module for how to swap the real crate in.

use super::artifact::{Manifest, ModelManifest};
use super::xla;
use crate::config::ModelKind;
use crate::data::Dataset;
use crate::util::rng::Pcg;
use crate::worker::{aggregate_native, Params, Trainer};
use std::path::Path;

/// Compiled entry points for one model variant.
pub struct PjrtModel {
    pub manifest: ModelManifest,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    agg: xla::PjRtLoadedExecutable,
}

/// Shared PJRT client + compiled models.
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self, String> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu: {e}"))?;
        Ok(PjrtRuntime { client })
    }

    pub fn compile_file(
        &self,
        path: &Path,
    ) -> Result<xla::PjRtLoadedExecutable, String> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| format!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| format!("compile {}: {e}", path.display()))
    }

    /// Load + compile all three entry points of one model.
    pub fn load_model(
        &self,
        manifest: &Manifest,
        kind: ModelKind,
    ) -> Result<PjrtModel, String> {
        let mm = manifest.model(kind.name())?.clone();
        Ok(PjrtModel {
            train: self.compile_file(mm.artifact("train")?)?,
            eval: self.compile_file(mm.artifact("eval")?)?,
            agg: self.compile_file(mm.artifact("agg")?)?,
            manifest: mm,
        })
    }
}

fn run1(
    exe: &xla::PjRtLoadedExecutable,
    args: &[xla::Literal],
) -> Result<Vec<xla::Literal>, String> {
    let out = exe
        .execute::<xla::Literal>(args)
        .map_err(|e| format!("execute: {e}"))?;
    let lit = out[0][0]
        .to_literal_sync()
        .map_err(|e| format!("to_literal: {e}"))?;
    // aot.py lowers with return_tuple=True: single tuple output
    lit.to_tuple().map_err(|e| format!("to_tuple: {e}"))
}

fn f32_lit(xs: &[f32], dims: &[i64]) -> Result<xla::Literal, String> {
    xla::Literal::vec1(xs)
        .reshape(dims)
        .map_err(|e| format!("reshape: {e}"))
}

/// The PJRT-backed [`Trainer`]: real model training through the AOT
/// artifacts (L2 JAX + L1 Pallas lowered to HLO).
pub struct PjrtTrainer {
    model: PjrtModel,
    /// Scratch for batch assembly.
    xbuf: Vec<f32>,
    ybuf: Vec<i32>,
    /// Reusable [K_max × P] staging buffer for aggregation — rebuilding
    /// and re-zeroing it per call dominated the agg hot path (§Perf).
    agg_buf: Vec<f32>,
}

impl PjrtTrainer {
    pub fn new(artifact_dir: &Path, kind: ModelKind) -> Result<Self, String> {
        let rt = PjrtRuntime::cpu()?;
        let manifest = Manifest::load(artifact_dir)?;
        let model = rt.load_model(&manifest, kind)?;
        Ok(PjrtTrainer {
            model,
            xbuf: Vec::new(),
            ybuf: Vec::new(),
            agg_buf: Vec::new(),
        })
    }

    pub fn manifest(&self) -> &ModelManifest {
        &self.model.manifest
    }

    /// One train-step execution on an explicit batch: returns
    /// (new_params, loss). Used directly by benches.
    pub fn train_batch(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Params, f64), String> {
        let mm = &self.model.manifest;
        let b = mm.train_batch as i64;
        let d = mm.input_dim as i64;
        let args = [
            f32_lit(params, &[mm.param_count as i64])?,
            f32_lit(x, &[b, d])?,
            xla::Literal::vec1(y),
            xla::Literal::scalar(lr),
        ];
        let mut out = run1(&self.model.train, &args)?;
        let loss = out
            .pop()
            .ok_or("train: missing loss output")?
            .to_vec::<f32>()
            .map_err(|e| e.to_string())?[0];
        let new_params = out
            .pop()
            .ok_or("train: missing params output")?
            .to_vec::<f32>()
            .map_err(|e| e.to_string())?;
        Ok((new_params, loss as f64))
    }

    fn fill_batch(&mut self, shard: &Dataset, idx: &[usize]) {
        self.xbuf.clear();
        self.ybuf.clear();
        for &i in idx {
            self.xbuf.extend_from_slice(shard.feature_row(i));
            self.ybuf.push(shard.labels[i] as i32);
        }
    }
}

impl Trainer for PjrtTrainer {
    fn param_count(&self) -> usize {
        self.model.manifest.param_count
    }

    fn init(&self, seed: u64) -> Params {
        // He init per layout entry (matches python/compile/model.py's
        // scheme; exact values differ — only the distribution matters).
        let mm = &self.model.manifest;
        let mut rng = Pcg::new(seed, 0x1217);
        let mut out = vec![0.0f32; mm.param_count];
        for entry in &mm.layout {
            if entry.shape.len() <= 1 {
                continue; // biases stay zero
            }
            let std = (2.0 / entry.fan_in() as f64).sqrt() * 0.5;
            let vals = rng.normal_vec(entry.numel(), 0.0, std);
            out[entry.offset..entry.offset + entry.numel()]
                .copy_from_slice(&vals);
        }
        out
    }

    fn train(
        &mut self,
        params: &[f32],
        shard: &Dataset,
        steps: usize,
        _batch: usize,
        lr: f32,
        rng: &mut Pcg,
    ) -> (Params, f64) {
        // the artifact's batch size is baked in at lowering time
        let b = self.model.manifest.train_batch;
        assert!(!shard.is_empty());
        let mut p = params.to_vec();
        let mut loss_acc = 0.0;
        for _ in 0..steps {
            // sample with replacement if the shard is smaller than b
            let idx: Vec<usize> = if shard.len() >= b {
                rng.sample_indices(shard.len(), b)
            } else {
                (0..b).map(|_| rng.below_usize(shard.len())).collect()
            };
            self.fill_batch(shard, &idx);
            let (x, y) = (std::mem::take(&mut self.xbuf), std::mem::take(&mut self.ybuf));
            let (np, loss) = self
                .train_batch(&p, &x, &y, lr)
                .expect("pjrt train_step failed");
            self.xbuf = x;
            self.ybuf = y;
            p = np;
            loss_acc += loss;
        }
        (p, loss_acc / steps.max(1) as f64)
    }

    fn evaluate(&mut self, params: &[f32], data: &Dataset) -> (f64, f64) {
        let (be, pc, idim) = {
            let mm = &self.model.manifest;
            (mm.eval_batch, mm.param_count as i64, mm.input_dim as i64)
        };
        assert!(!data.is_empty());
        // stream fixed-size chunks; the tail wraps around (duplicated
        // samples are averaged like any other — small, documented bias
        // when len % be != 0)
        let chunks = data.len().div_ceil(be);
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for c in 0..chunks {
            let idx: Vec<usize> =
                (0..be).map(|k| (c * be + k) % data.len()).collect();
            self.fill_batch(data, &idx);
            let args = [
                f32_lit(params, &[pc]).unwrap(),
                f32_lit(&self.xbuf, &[be as i64, idim]).unwrap(),
                xla::Literal::vec1(&self.ybuf),
            ];
            let out = run1(&self.model.eval, &args).expect("pjrt eval failed");
            loss_sum += out[0].to_vec::<f32>().unwrap()[0] as f64;
            correct += out[1].to_vec::<f32>().unwrap()[0] as f64;
        }
        let total = (chunks * be) as f64;
        (loss_sum / total, correct / total)
    }

    fn aggregate(&mut self, models: &[&[f32]], weights: &[f32]) -> Params {
        let mm = &self.model.manifest;
        let k_max = mm.k_max;
        if models.len() > k_max {
            // SA-ADFL can pull more neighbors than the artifact's K_max;
            // fall back to the native path (numerically identical).
            return aggregate_native(models, weights);
        }
        // zero-pad to K_max (exactness tested in python/tests); the
        // staging buffer is reused across calls — only rows actually
        // written need zeroing when the caller count shrinks
        let p = mm.param_count;
        self.agg_buf.resize(k_max * p, 0.0);
        let mut w = vec![0.0f32; k_max];
        for (k, (m, &wt)) in models.iter().zip(weights).enumerate() {
            self.agg_buf[k * p..(k + 1) * p].copy_from_slice(m);
            w[k] = wt;
        }
        for row in self.agg_buf[models.len() * p..].chunks_mut(p) {
            row.fill(0.0);
        }
        let args = [
            f32_lit(&self.agg_buf, &[k_max as i64, p as i64]).unwrap(),
            xla::Literal::vec1(&w),
        ];
        let out = run1(&self.model.agg, &args).expect("pjrt aggregate failed");
        out[0].to_vec::<f32>().expect("agg output")
    }

    fn aggregate_into(
        &mut self,
        models: &[&[f32]],
        weights: &[f32],
        out: &mut Params,
    ) {
        // move the kernel result in rather than copying it (the trait
        // default would memcpy the returned Vec into `out`)
        *out = self.aggregate(models, weights);
    }
}
