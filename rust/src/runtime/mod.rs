//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on
//! the request path. This is the only place Rust touches XLA; everything
//! above it sees the backend-agnostic
//! [`Trainer`](crate::worker::Trainer) interface.
//!
//! The artifact manifest layer ([`Manifest`]) is pure Rust and always
//! available (the `dystop inspect` command needs nothing else). The
//! execution surface ([`PjrtTrainer`], [`PjrtRuntime`]) is gated behind
//! the `pjrt` cargo feature (on by default): it compiles against
//! whatever `xla` binding the build provides — here the offline API
//! stub in [`xla`], whose constructors fail cleanly at runtime — and
//! `--no-default-features` drops it entirely. CI builds both ways so
//! the feature gate can't rot.

mod artifact;
#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub mod xla;

pub use artifact::{LayoutEntry, Manifest, ModelManifest};
#[cfg(feature = "pjrt")]
pub use pjrt::{PjrtModel, PjrtRuntime, PjrtTrainer};
