//! Artifact manifest: the shape/layout contract between `python/compile/
//! aot.py` and the Rust runtime (`artifacts/manifest.json`).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One named parameter block in the flat layout.
#[derive(Clone, Debug, PartialEq)]
pub struct LayoutEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl LayoutEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Fan-in for He initialisation (product of all but the last dim;
    /// 1 for bias vectors).
    pub fn fan_in(&self) -> usize {
        if self.shape.len() <= 1 {
            1
        } else {
            self.shape[..self.shape.len() - 1].iter().product()
        }
    }
}

/// Manifest entry for one model variant.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub param_count: usize,
    pub input_dim: usize,
    pub num_classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub k_max: usize,
    pub layout: Vec<LayoutEntry>,
    /// kind ("train"/"eval"/"agg") → absolute artifact path.
    pub artifacts: BTreeMap<String, PathBuf>,
}

impl ModelManifest {
    pub fn artifact(&self, kind: &str) -> Result<&Path, String> {
        self.artifacts
            .get(kind)
            .map(|p| p.as_path())
            .ok_or_else(|| format!("model {}: no {kind} artifact", self.name))
    }
}

/// Parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        if j.get("format").and_then(Json::as_str) != Some("hlo-text") {
            return Err("manifest: expected format=hlo-text".into());
        }
        let models_j = j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or("manifest: missing models")?;
        let mut models = BTreeMap::new();
        for (name, m) in models_j {
            let get = |k: &str| -> Result<usize, String> {
                m.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("model {name}: missing {k}"))
            };
            let layout = m
                .get("layout")
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("model {name}: missing layout"))?
                .iter()
                .map(|l| {
                    Ok(LayoutEntry {
                        name: l
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or("layout name")?
                            .to_string(),
                        offset: l
                            .get("offset")
                            .and_then(Json::as_usize)
                            .ok_or("layout offset")?,
                        shape: l
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or("layout shape")?
                            .iter()
                            .map(|d| d.as_usize().ok_or("shape dim"))
                            .collect::<Result<_, _>>()?,
                    })
                })
                .collect::<Result<Vec<_>, &str>>()
                .map_err(|e| format!("model {name}: bad layout ({e})"))?;
            let artifacts = m
                .get("artifacts")
                .and_then(Json::as_obj)
                .ok_or_else(|| format!("model {name}: missing artifacts"))?
                .iter()
                .map(|(k, v)| {
                    v.as_str()
                        .map(|f| (k.clone(), dir.join(f)))
                        .ok_or_else(|| format!("model {name}: bad artifact {k}"))
                })
                .collect::<Result<BTreeMap<_, _>, _>>()?;
            let mm = ModelManifest {
                name: name.clone(),
                param_count: get("param_count")?,
                input_dim: get("input_dim")?,
                num_classes: get("num_classes")?,
                train_batch: get("train_batch")?,
                eval_batch: get("eval_batch")?,
                k_max: get("k_max")?,
                layout,
                artifacts,
            };
            // layout consistency
            let total: usize = mm.layout.iter().map(LayoutEntry::numel).sum();
            if total != mm.param_count {
                return Err(format!(
                    "model {name}: layout covers {total} ≠ param_count {}",
                    mm.param_count
                ));
            }
            models.insert(name.clone(), mm);
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest, String> {
        self.models
            .get(name)
            .ok_or_else(|| format!("manifest has no model {name:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text", "version": 1,
      "models": {
        "mlp": {
          "param_count": 6,
          "input_dim": 2, "num_classes": 2,
          "train_batch": 4, "eval_batch": 8, "k_max": 3,
          "layout": [
            {"name": "w", "offset": 0, "shape": [2, 2]},
            {"name": "b", "offset": 4, "shape": [2]}
          ],
          "artifacts": {"train": "mlp_train.hlo.txt"}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/x"), SAMPLE).unwrap();
        let mlp = m.model("mlp").unwrap();
        assert_eq!(mlp.param_count, 6);
        assert_eq!(mlp.layout[0].fan_in(), 2);
        assert_eq!(mlp.layout[1].fan_in(), 1);
        assert_eq!(
            mlp.artifact("train").unwrap(),
            Path::new("/x/mlp_train.hlo.txt")
        );
        assert!(mlp.artifact("eval").is_err());
    }

    #[test]
    fn rejects_inconsistent_layout() {
        let bad = SAMPLE.replace("\"param_count\": 6", "\"param_count\": 7");
        let err = Manifest::parse(Path::new("/x"), &bad).unwrap_err();
        assert!(err.contains("layout covers"), "{err}");
    }

    #[test]
    fn real_manifest_loads_if_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let mlp = m.model("mlp").unwrap();
        assert!(mlp.param_count > 1000);
        assert!(mlp.artifact("train").unwrap().exists());
        assert!(mlp.artifact("eval").unwrap().exists());
        assert!(mlp.artifact("agg").unwrap().exists());
    }
}
