//! Offline stand-in for the `xla` PJRT binding.
//!
//! The build environment for this crate carries no Rust `xla` crate, so
//! this module mirrors exactly the slice of its API the runtime uses —
//! same type names, same signatures — with every constructor failing
//! cleanly at runtime. That keeps the whole PJRT surface
//! ([`super::PjrtTrainer`] and friends) compiling, testable for its
//! error paths, and one swap away from the real thing:
//!
//! * add the real `xla` crate to `[dependencies]`,
//! * replace this module's body with `pub use ::xla::*;` (or delete it
//!   and import the crate directly in `runtime/pjrt.rs`).
//!
//! Building with `--no-default-features` drops the PJRT surface (and
//! this stub) entirely — CI builds both configurations so neither can
//! rot.

use std::path::Path;

fn unavailable(op: &str) -> String {
    format!(
        "{op}: PJRT unavailable — built against the offline xla stub \
         (rust/src/runtime/xla.rs); wire the real xla crate in to run \
         AOT artifacts"
    )
}

/// Host-side tensor/literal handle (stub).
#[derive(Clone, Debug)]
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: Copy>(_xs: &[T]) -> Literal {
        Literal
    }

    /// Rank-0 literal.
    pub fn scalar<T: Copy>(_x: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, String> {
        Err(unavailable("reshape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, String> {
        Err(unavailable("to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, String> {
        Err(unavailable("to_tuple"))
    }
}

/// Device buffer returned by an execution (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, String> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, String> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, String> {
        Err(unavailable("execute"))
    }
}

/// PJRT client (stub): construction is the first call every runtime
/// path makes, so the clean failure surfaces immediately.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, String> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, String> {
        Err(unavailable("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_with_a_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.contains("offline xla stub"), "{err}");
        let err = HloModuleProto::from_text_file(Path::new("x.hlo.txt"))
            .unwrap_err();
        assert!(err.contains("PJRT unavailable"), "{err}");
        assert!(Literal::vec1(&[1.0f32]).to_vec::<f32>().is_err());
    }
}
