//! Thread-per-worker execution backend — the §VII testbed analog.
//!
//! Unlike the virtual-clock backend, this mode actually runs one OS
//! thread per worker with real message passing and wall-clock delays:
//!
//! * each worker owns an **updating thread** (Alg. 1 lines 3–7) that
//!   reacts to EXECUTE messages: pull neighbor models, aggregate (Eq. 4),
//!   emulate heterogeneous compute (scaled sleep), train for real, publish
//!   the new model;
//! * the **pushing thread** role (lines 8–10) is played by a shared
//!   `Mutex<Published>` snapshot per worker — a pull locks the source's
//!   snapshot exactly like the paper's pushing thread serves the latest
//!   `w_{t−τ}^i`;
//! * the coordinator thread runs the same
//!   [`Scheduler`](crate::coordinator::Scheduler) implementations as the
//!   simulator and advances rounds on completions.
//!
//! Delays are the paper's §VI-A1 channel/compute model compressed by
//! `time_scale` (default 1000× — a 1 s training job sleeps 1 ms) so a
//! full run finishes in seconds while preserving relative asynchrony.

use super::observer::{ObserverChain, RunRecorder};
use super::{Backend, Experiment, ExperimentError};
use crate::config::{ExperimentConfig, TrainerKind};
use crate::coordinator::{SchedView, SchedulerParams};
use crate::data::Dataset;
use crate::metrics::{EvalRecord, RoundRecord, RunResult};
use crate::worker::{data_size_weights, NativeTrainer, Trainer};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Latest published model of one worker (what pulls observe).
struct Published {
    params: Vec<f32>,
    data_size: usize,
}

/// Coordinator → worker message.
enum Execute {
    /// Pull from these neighbors, then aggregate + train.
    Round { neighbors: Vec<usize>, pull_delays_ms: Vec<u64> },
    Shutdown,
}

/// Worker → coordinator completion report.
struct Done {
    id: usize,
    loss: f64,
}

/// Extra knobs for the threaded (testbed) backend.
#[derive(Clone, Copy, Debug)]
pub struct TestbedOptions {
    /// Virtual-seconds → real-milliseconds compression factor.
    pub time_scale: f64,
    /// Use the explicit Table II per-worker speed profile when the
    /// worker count matches (15); otherwise keep the builder's sampled
    /// lognormal heterogeneity.
    pub profile: bool,
}

impl Default for TestbedOptions {
    fn default() -> Self {
        TestbedOptions { time_scale: 1000.0, profile: true }
    }
}

/// Thread-per-worker [`Backend`] with real message passing and
/// compressed wall-clock delays (§VII).
#[derive(Clone, Copy, Debug, Default)]
pub struct ThreadedBackend {
    opts: TestbedOptions,
}

impl ThreadedBackend {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_options(opts: TestbedOptions) -> Self {
        ThreadedBackend { opts }
    }
}

impl Backend for ThreadedBackend {
    fn name(&self) -> &'static str {
        "testbed"
    }

    fn run(&mut self, exp: Experiment) -> Result<RunResult, ExperimentError> {
        run_threaded(exp, self.opts)
    }
}

fn run_threaded(
    exp: Experiment,
    opts: TestbedOptions,
) -> Result<RunResult, ExperimentError> {
    let Experiment {
        cfg,
        mut net,
        workers,
        test,
        label_dist,
        model_bits,
        mut trainer,
        mut scheduler,
        mut rng,
        observers,
    } = exp;
    if cfg.trainer != TrainerKind::Native {
        return Err(ExperimentError::Unsupported(
            "the threaded backend trains with one NativeTrainer per worker \
             thread; run.backend=sim for PJRT trainers"
                .into(),
        ));
    }
    let n = cfg.workers;
    let recorder =
        RunRecorder::new(format!("testbed-{}", scheduler.name()), model_bits);
    let mut chain = ObserverChain::new(recorder, observers);

    // heterogeneous compute: explicit Table II profile (when the worker
    // count matches the paper's testbed) or the builder's sampled draw
    let h_train: Vec<f64> = if opts.profile && n == 15 {
        crate::figures::testbed_profile_speeds()
            .iter()
            .map(|s| cfg.compute_mean_s / s)
            .collect()
    } else {
        workers.iter().map(|w| w.h_train_s).collect()
    };

    // --- shared published models (initial params from the builder) ---
    let published: Vec<Arc<Mutex<Published>>> = workers
        .iter()
        .map(|w| {
            Arc::new(Mutex::new(Published {
                params: w.params.clone(),
                data_size: w.data_size(),
            }))
        })
        .collect();

    // --- spawn workers ---
    let (done_tx, done_rx) = mpsc::channel::<Done>();
    let mut exec_txs = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for (i, w) in workers.into_iter().enumerate() {
        let (tx, rx) = mpsc::channel::<Execute>();
        exec_txs.push(tx);
        let done = done_tx.clone();
        let pubs: Vec<Arc<Mutex<Published>>> = published.clone();
        let my_h = h_train[i];
        let scale = opts.time_scale;
        let wcfg = cfg.clone();
        let shard = w.shard;
        handles.push(thread::spawn(move || {
            worker_loop(i, shard, my_h, scale, &wcfg, pubs, rx, done)
        }));
    }
    drop(done_tx);

    // --- coordinator loop ---
    let mut tau = vec![0u64; n];
    let mut queues = vec![0.0f64; n];
    let mut residual = h_train.clone();
    let mut pulls = vec![vec![0u64; n]; n];
    let start = Instant::now();
    let mut cum_transfers = 0usize;

    for round in 1..=cfg.rounds {
        net.step(&mut rng);
        let candidates: Vec<Vec<usize>> =
            (0..n).map(|i| net.in_range(i)).collect();
        let h_est: Vec<f64> = (0..n)
            .map(|i| {
                let worst = candidates[i]
                    .iter()
                    .take(cfg.neighbor_cap)
                    .map(|&j| net.expected_transfer_time_s(j, i, model_bits))
                    .fold(0.0f64, f64::max);
                residual[i] + worst
            })
            .collect();
        let data_sizes: Vec<usize> = published
            .iter()
            .map(|p| p.lock().unwrap().data_size)
            .collect();
        let plan = {
            let view = SchedView {
                round,
                tau: &tau,
                queues: &queues,
                h_cmp: &residual,
                h_est: &h_est,
                data_sizes: &data_sizes,
                label_dist: &label_dist,
                candidates: &candidates,
                budgets: &net.budgets,
                pulls: &pulls,
                net: &net,
                params: SchedulerParams::from(&cfg),
            };
            scheduler.plan(&view, &mut rng)
        };
        debug_assert!(plan.validate(n).is_ok());
        chain.plan(round, &plan);

        // dispatch EXECUTE to the active workers with realised delays
        let round_t0 = Instant::now();
        for (k, &i) in plan.active.iter().enumerate() {
            let delays: Vec<u64> = plan.pulls_from[k]
                .iter()
                .map(|&j| {
                    let t = net.transfer_time_s(j, i, model_bits, &mut rng);
                    (t * opts.time_scale) as u64
                })
                .collect();
            for &j in &plan.pulls_from[k] {
                pulls[i][j] += 1;
            }
            exec_txs[i]
                .send(Execute::Round {
                    neighbors: plan.pulls_from[k].clone(),
                    pull_delays_ms: delays,
                })
                .map_err(|_| {
                    ExperimentError::Backend(format!(
                        "worker {i} hung up (thread died?)"
                    ))
                })?;
        }

        // wait for completions (the synchronization point is per-plan,
        // matching the round abstraction of Alg. 1)
        let mut losses = Vec::with_capacity(plan.active.len());
        for _ in &plan.active {
            let d = done_rx.recv().map_err(|_| {
                ExperimentError::Backend(
                    "a worker thread died mid-round".into(),
                )
            })?;
            debug_assert!(plan.active.contains(&d.id));
            losses.push(d.loss);
        }
        let h_round = round_t0.elapsed().as_secs_f64();

        // staleness + queues + residual bookkeeping (Eqs. 6/33/7)
        let mut active_mask = vec![false; n];
        for &i in &plan.active {
            active_mask[i] = true;
        }
        let h_virtual = h_round / opts.time_scale * 1000.0; // ms→virtual s
        for i in 0..n {
            residual[i] = (residual[i] - h_virtual).max(0.0);
            if active_mask[i] {
                tau[i] = 0;
                residual[i] = h_train[i];
            } else {
                tau[i] += 1;
            }
            queues[i] =
                (queues[i] + tau[i] as f64 - cfg.tau_bound as f64).max(0.0);
        }

        let transfers = plan.transfers();
        cum_transfers += transfers;
        chain.round_end(&RoundRecord {
            round,
            time_s: start.elapsed().as_secs_f64(),
            duration_s: h_round,
            active: plan.active.len(),
            transfers,
            avg_staleness: tau.iter().sum::<u64>() as f64 / n as f64,
            max_staleness: tau.iter().copied().max().unwrap_or(0),
            train_loss: losses.iter().sum::<f64>() / losses.len().max(1) as f64,
        });

        if round % cfg.eval_every.max(1) == 0 || round == cfg.rounds {
            let mut acc_sum = 0.0;
            let mut loss_sum = 0.0;
            for p in &published {
                let params = p.lock().unwrap().params.clone();
                let (l, a) = trainer.evaluate(&params, &test);
                acc_sum += a;
                loss_sum += l;
            }
            chain.eval(&EvalRecord {
                round,
                time_s: start.elapsed().as_secs_f64(),
                avg_accuracy: acc_sum / n as f64,
                avg_loss: loss_sum / n as f64,
                cum_transfers,
            });
        }
    }

    for tx in &exec_txs {
        let _ = tx.send(Execute::Shutdown);
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(chain.into_result())
}

/// The per-worker updating thread (Alg. 1 lines 3–7).
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    id: usize,
    shard: Dataset,
    h_train_s: f64,
    time_scale: f64,
    cfg: &ExperimentConfig,
    published: Vec<Arc<Mutex<Published>>>,
    rx: mpsc::Receiver<Execute>,
    done: mpsc::Sender<Done>,
) {
    let mut trainer = NativeTrainer::new(cfg.feature_dim, cfg.num_classes);
    let mut rng = crate::util::rng::Pcg::new(cfg.seed ^ 0xBEEF, id as u64);
    while let Ok(msg) = rx.recv() {
        match msg {
            Execute::Shutdown => break,
            Execute::Round { neighbors, pull_delays_ms } => {
                // PULL: read each neighbor's published snapshot (the
                // "pushing thread" contract), paying the channel delay
                let mut models: Vec<Vec<f32>> =
                    Vec::with_capacity(neighbors.len() + 1);
                let mut sizes: Vec<usize> =
                    Vec::with_capacity(neighbors.len() + 1);
                {
                    let own = published[id].lock().unwrap();
                    models.push(own.params.clone());
                    sizes.push(own.data_size);
                }
                let worst_delay =
                    pull_delays_ms.iter().copied().max().unwrap_or(0);
                for &j in &neighbors {
                    let p = published[j].lock().unwrap();
                    models.push(p.params.clone());
                    sizes.push(p.data_size);
                }
                // pulls happen in parallel → pay only the slowest link
                thread::sleep(Duration::from_millis(worst_delay));

                // aggregate (Eq. 4) + emulated heterogeneous compute
                let refs: Vec<&[f32]> =
                    models.iter().map(|m| m.as_slice()).collect();
                let weights = data_size_weights(&sizes);
                let agg = trainer.aggregate(&refs, &weights);
                thread::sleep(Duration::from_millis(
                    (h_train_s * time_scale) as u64,
                ));
                // real local training (Eq. 5)
                let (new_params, loss) = trainer.train(
                    &agg,
                    &shard,
                    cfg.local_steps,
                    cfg.batch,
                    cfg.lr,
                    &mut rng,
                );
                published[id].lock().unwrap().params = new_params;
                let _ = done.send(Done { id, loss });
            }
        }
    }
}
