//! Thread-per-worker execution backend — the §VII testbed analog.
//!
//! Unlike the virtual-clock backend, this mode actually runs one OS
//! thread per worker with real message passing and wall-clock delays:
//!
//! * each worker owns an **updating thread** (Alg. 1 lines 3–7) that
//!   reacts to EXECUTE messages: pull neighbor models, aggregate (Eq. 4),
//!   emulate heterogeneous compute (scaled sleep), train for real, publish
//!   the new model;
//! * the **pushing thread** role (lines 8–10) is played by a shared
//!   `Mutex<Published>` snapshot per worker — a pull locks the source's
//!   snapshot exactly like the paper's pushing thread serves the latest
//!   `w_{t−τ}^i`;
//! * the coordinator thread runs the same
//!   [`Scheduler`](crate::coordinator::Scheduler) implementations as the
//!   simulator and advances rounds on completions.
//!
//! Delays are the paper's §VI-A1 channel/compute model compressed by
//! `time_scale` (default 1000× — a 1 s training job sleeps 1 ms) so a
//! full run finishes in seconds while preserving relative asynchrony.
//!
//! # Dynamic populations
//!
//! The scenario timeline ([`crate::scenario`]) applies on the
//! coordinator at round boundaries, exactly as in the simulator. A
//! departed worker's thread is *parked*: the coordinator stops
//! dispatching EXECUTE messages to it, so the thread blocks on its
//! channel (OS-parked, zero CPU) until the worker rejoins — its
//! published snapshot stays around, which is precisely the stale model a
//! `Rejoin` resumes from. A `Join` (fresh device on the slot) resets the
//! published snapshot to re-initialised parameters before the thread is
//! unparked by the next EXECUTE. Push edges give `Crash` real teeth
//! here: a sender's post-training model sits in the coordinator-side
//! inbox until the receiver's next activation, and a `Crash` at a round
//! boundary drops every in-flight copy from the crashed worker — each
//! drop ledger'd as `crash_dropped` (surfacing in `dropped_msgs`),
//! exactly as in the virtual-clock engine. A graceful `Leave` only
//! discards models *addressed to* the leaver.
//!
//! # Push edges
//!
//! Plans may carry push edges (SA-ADFL's push-to-all). The sender's
//! *post-training* published model is captured into the receiver's
//! coordinator inbox after the round completes (once-per-sender encode
//! under a non-dense codec or an active adversary, replace-or-push per
//! sender) and rides the receiver's next EXECUTE, skipping senders the
//! receiver freshly pulled that round — the virtual-clock engine's
//! inbox semantics, port for port.
//!
//! # Transport
//!
//! Every pull crosses the wire through the transport layer
//! ([`crate::transport`]): the coordinator encodes each pull source's
//! published model once per round, the EXECUTE message carries the
//! *decoded* reconstruction to the receiver, and the emulated channel
//! delays and the byte ledger both consume the codec's encoded message
//! size. Under the default `dense` codec the layer vanishes: workers
//! read published snapshots directly, exactly as before.
//!
//! # Delivery
//!
//! Faults and ack-timeouts inject on the real channels: the coordinator
//! resolves every pull edge through the reliable delivery layer
//! ([`crate::delivery`]) *before* dispatching EXECUTE — the same pure
//! `(seed, round, from, to)` streams the virtual-clock engine draws, so
//! both backends' delivery/byte ledgers agree for the same seed. A
//! delivered edge's emulated delay stretches by its retries/backoff; a
//! dead-lettered sender is removed from the message (the receiver
//! aggregates without it, gracefully) but its burned retry window is
//! still slept out. Pushed models are charged to the byte ledger via
//! `RoundPlan::transfers` and dropped through `crash_dropped` on a
//! crash, so ledger conservation holds on every backend.

use super::observer::{ObserverChain, RunRecorder};
use super::{Backend, Experiment, ExperimentError};
use crate::adversary::Aggregator;
use crate::config::{ExperimentConfig, TestbedConfig, TrainerKind};
use crate::coordinator::{PullLedger, SchedView, SchedulerParams};
use crate::data::Dataset;
use crate::delivery::DeliveryTally;
use crate::metrics::{
    ActivationRecord, EvalRecord, EventRecord, RoundRecord, RunResult,
};
use crate::scenario::ScenarioEvent;
use crate::worker::{data_size_weights, NativeTrainer, Trainer};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Latest published model of one worker (what pulls observe).
struct Published {
    params: Vec<f32>,
    data_size: usize,
}

/// Coordinator → worker message.
enum Execute {
    /// Pull from these neighbors, then aggregate + train.
    Round {
        /// Pull sources that actually delivered (dead-lettered senders
        /// are already removed by the coordinator's delivery pass).
        neighbors: Vec<usize>,
        pull_delays_ms: Vec<u64>,
        /// Decoded neighbor models (transport layer), aligned with
        /// `neighbors`. `None` under the dense codec — the worker reads
        /// the published snapshots directly, exactly as before the
        /// transport layer existed.
        models: Option<Vec<Vec<f32>>>,
        /// Burned retry window of this round's dead-lettered pull
        /// edges, if any: the receiver waited out the budget before
        /// degrading, so the wait is slept even though nothing arrived.
        dead_wait_ms: u64,
        /// Models pushed to this worker in earlier rounds (sender id +
        /// wire copy), drained from the coordinator inbox, senders
        /// freshly pulled this round already filtered out.
        pushed: Vec<(usize, Vec<f32>)>,
    },
    Shutdown,
}

/// Worker → coordinator completion report.
struct Done {
    id: usize,
    loss: f64,
}

/// Extra knobs for the threaded (testbed) backend.
#[derive(Clone, Copy, Debug)]
pub struct TestbedOptions {
    /// Virtual-seconds → real-milliseconds compression factor.
    pub time_scale: f64,
    /// Use the explicit Table II per-worker speed profile when the
    /// worker count matches (15); otherwise keep the builder's sampled
    /// lognormal heterogeneity.
    pub profile: bool,
}

impl Default for TestbedOptions {
    fn default() -> Self {
        TestbedOptions { time_scale: 1000.0, profile: true }
    }
}

/// Thread-per-worker [`Backend`] with real message passing and
/// compressed wall-clock delays (§VII).
#[derive(Clone, Copy, Debug, Default)]
pub struct ThreadedBackend {
    opts: TestbedOptions,
}

impl ThreadedBackend {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_options(opts: TestbedOptions) -> Self {
        ThreadedBackend { opts }
    }

    /// Build from the `[testbed]` config section.
    pub fn from_config(cfg: &TestbedConfig) -> Self {
        ThreadedBackend {
            opts: TestbedOptions {
                time_scale: cfg.time_scale,
                profile: cfg.profile,
            },
        }
    }
}

impl Backend for ThreadedBackend {
    fn name(&self) -> &'static str {
        "testbed"
    }

    fn run(&mut self, exp: Experiment) -> Result<RunResult, ExperimentError> {
        run_threaded(exp, self.opts)
    }
}

fn run_threaded(
    exp: Experiment,
    opts: TestbedOptions,
) -> Result<RunResult, ExperimentError> {
    let Experiment {
        cfg,
        mut net,
        workers,
        test,
        label_dist,
        model_bits,
        scenario,
        mut transport,
        mut adversary,
        delivery,
        mut trainer,
        mut scheduler,
        mut rng,
        observers,
        telemetry: tel,
    } = exp;
    // every pull crosses the wire encoded: channel costs (the emulated
    // delays) consume the codec's message size, and the byte ledger
    // records it
    let wire_bits = transport.message_bits();
    if cfg.trainer != TrainerKind::Native {
        return Err(ExperimentError::Unsupported(
            "the threaded backend trains with one NativeTrainer per worker \
             thread; run.backend=sim for PJRT trainers"
                .into(),
        ));
    }
    let n = cfg.workers;
    let recorder = RunRecorder::with_window(
        format!("testbed-{}", scheduler.name()),
        model_bits,
        cfg.metrics.window,
    );
    let mut chain = ObserverChain::new(recorder, observers);

    // heterogeneous compute: explicit Table II profile (when the worker
    // count matches the paper's testbed) or the builder's sampled draw
    let h_train: Vec<f64> = if opts.profile && n == 15 {
        crate::figures::testbed_profile_speeds()
            .iter()
            .map(|s| cfg.compute_mean_s / s)
            .collect()
    } else {
        workers.iter().map(|w| w.h_train_s).collect()
    };

    // --- shared published models (initial params from the builder) ---
    let published: Vec<Arc<Mutex<Published>>> = workers
        .iter()
        .map(|w| {
            Arc::new(Mutex::new(Published {
                params: w.params.clone(),
                data_size: w.data_size(),
            }))
        })
        .collect();

    // --- spawn workers ---
    let (done_tx, done_rx) = mpsc::channel::<Done>();
    let mut exec_txs = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for (i, w) in workers.into_iter().enumerate() {
        let (tx, rx) = mpsc::channel::<Execute>();
        exec_txs.push(tx);
        let done = done_tx.clone();
        let pubs: Vec<Arc<Mutex<Published>>> = published.clone();
        let my_h = h_train[i];
        let scale = opts.time_scale;
        let wcfg = cfg.clone();
        let shard = w.shard;
        let wtel = tel.clone();
        handles.push(thread::spawn(move || {
            worker_loop(i, shard, my_h, scale, &wcfg, pubs, rx, done, wtel)
        }));
    }
    drop(done_tx);

    // --- coordinator loop ---
    let mut tau = vec![0u64; n];
    let mut queues = vec![0.0f64; n];
    let mut residual = h_train.clone();
    let mut pulls = PullLedger::dense(n);
    let start = Instant::now();
    let mut cum_transfers = 0usize;
    let mut cum_bytes = 0.0f64;
    let mut pull_srcs: Vec<usize> = Vec::new();
    // in-flight pushed models: sender id + wire copy, per receiver;
    // replace-or-push keeps at most one entry per sender
    let mut inbox: Vec<Vec<(usize, Vec<f32>)>> = vec![Vec::new(); n];
    // declared outside the loop (cleared after each round record) so a
    // Crash at the *next* round's boundary lands its dropped in-flight
    // pushes in that round's `dropped_msgs` — same as the simulator
    let mut tally = DeliveryTally::default();
    // virtual clock mirroring the wall clock at `time_scale` — feeds
    // the activation trace so testbed traces line up with sim traces
    let mut vclock_s = 0.0f64;
    // dense↔global maps over present workers, rebuilt each round
    let mut ids: Vec<usize> = (0..n).collect();
    let mut gdx: Vec<usize> = (0..n).collect();
    let mut range_buf: Vec<usize> = Vec::new();
    let mut cand_buf: Vec<Vec<usize>> = Vec::new();

    for round in 1..=cfg.rounds {
        let t_round = tel.tick();
        // --- scenario events (round boundary, coordinator-side) ---
        // the shared skeleton owns the guards and membership flips; the
        // hook below is this backend's bookkeeping
        crate::scenario::apply_round_events(
            &scenario,
            round,
            &mut net,
            |ev| match *ev {
                ScenarioEvent::Join { worker } => {
                    // fresh device: reset the published snapshot and
                    // the coordinator's bookkeeping for this slot
                    published[worker].lock().unwrap().params =
                        trainer.init(cfg.seed.wrapping_add(worker as u64));
                    tau[worker] = 0;
                    queues[worker] = 0.0;
                    residual[worker] = h_train[worker];
                    pulls.reset_worker(worker);
                    // fresh device: receivers hold no codec history
                    transport.reset_worker(worker);
                }
                ScenarioEvent::Rejoin { worker } => {
                    // stale published model and accumulated τ kept
                    residual[worker] = h_train[worker];
                }
                // Leave/Crash: the membership flip parks the worker's
                // thread (no more EXECUTE messages until it rejoins).
                ScenarioEvent::Leave { worker } => {
                    // graceful: pending models addressed to the leaver
                    // depart with it; nothing *from* it is dropped
                    inbox[worker].clear();
                }
                ScenarioEvent::Crash { worker } => {
                    // own inbox vanishes silently (as on Leave), then
                    // every in-flight pushed model *from* the crashed
                    // worker drops on the floor, ledger'd so
                    // conservation holds (DESIGN.md §Scenarios)
                    inbox[worker].clear();
                    for q in inbox.iter_mut() {
                        if let Some(pos) =
                            q.iter().position(|(f, _)| *f == worker)
                        {
                            q.swap_remove(pos);
                            tally.crash_dropped += 1;
                        }
                    }
                }
                _ => {}
            },
            |rec| chain.scenario_event(&rec),
        );

        net.advance_round(cfg.seed, round as u64);

        // dense view over present workers (same compaction as the
        // virtual-clock engine — shared helpers in crate::scenario)
        let t_view = tel.tick();
        crate::scenario::rebuild_dense_maps(&net, &mut ids, &mut gdx);
        let p = ids.len();
        crate::scenario::build_dense_candidates(
            &net,
            &ids,
            &gdx,
            &mut range_buf,
            &mut cand_buf,
        );
        let d_tau: Vec<u64> = ids.iter().map(|&i| tau[i]).collect();
        let d_queues: Vec<f64> = ids.iter().map(|&i| queues[i]).collect();
        let d_residual: Vec<f64> = ids.iter().map(|&i| residual[i]).collect();
        let h_est: Vec<f64> = (0..p)
            .map(|k| {
                let gi = ids[k];
                let worst = cand_buf[k]
                    .iter()
                    .take(cfg.neighbor_cap)
                    .map(|&j| {
                        net.expected_transfer_time_s(ids[j], gi, wire_bits)
                    })
                    .fold(0.0f64, f64::max);
                residual[gi] + worst
            })
            .collect();
        let data_sizes: Vec<usize> = ids
            .iter()
            .map(|&i| published[i].lock().unwrap().data_size)
            .collect();
        let budgets: Vec<f64> = ids.iter().map(|&i| net.budgets[i]).collect();
        tel.tock(crate::telemetry::Phase::ViewRebuild, t_view);
        tel.inc(crate::telemetry::Counter::SchedViewRebuilds);
        let mut plan = {
            let view = SchedView {
                round,
                tau: &d_tau,
                queues: &d_queues,
                h_cmp: &d_residual,
                h_est: &h_est,
                data_sizes: &data_sizes,
                ids: &ids,
                label_dist: &label_dist,
                candidates: &cand_buf[..p],
                budgets: &budgets,
                pulls: &pulls,
                net: &net,
                params: SchedulerParams::from(&cfg),
            };
            scheduler.plan(&view, &mut rng)
        };
        // remap the dense plan to global worker ids
        crate::scenario::remap_plan_to_global(&mut plan, &ids);
        debug_assert!(plan.validate_present(net.present_mask()).is_ok());
        chain.plan(round, &plan);

        // transport: encode each pull source's published model once (a
        // broadcast), ascending sender order — the decoded
        // reconstruction is what receivers aggregate. Dense skips all
        // of it and workers read the published snapshots directly. With
        // an active adversary every outgoing payload first routes
        // through `transmit` (same fixed order, coordinator-side), so
        // codecs encode the *attacked* parameters.
        let adv_active = adversary.is_active();
        if !transport.is_dense() || adv_active {
            crate::transport::unique_pull_sources(
                &plan.pulls_from,
                &mut pull_srcs,
            );
            let t = tel.tick();
            let mut encoded = 0u64;
            for &j in &pull_srcs {
                let published_j = published[j].lock().unwrap();
                let payload: &[f32] = if adv_active {
                    adversary.transmit(j, &published_j.params)
                } else {
                    &published_j.params
                };
                if !transport.is_dense() {
                    transport.encode(j, payload);
                    encoded += 1;
                }
            }
            tel.tock(crate::telemetry::Phase::CodecEncode, t);
            tel.add(crate::telemetry::Counter::CodecEncodes, encoded);
            tel.add(
                crate::telemetry::Counter::CodecBytes,
                (encoded as f64 * transport.message_bytes()) as u64,
            );
        }

        // dispatch EXECUTE to the active workers with realised delays,
        // resolving each pull edge through the delivery layer first:
        // the same pure (seed, round, from, to) streams the
        // virtual-clock engine draws, so both backends produce the same
        // delivery ledger for the same seed. Dead-lettered senders are
        // removed from the message; their burned retry window rides
        // along as dead_wait_ms.
        let round_t0 = Instant::now();
        // (worker, compute_s, transfer_s, retry_s) per activation, in
        // plan order — emitted as trace records once h_round is known
        let mut acts: Vec<(usize, f64, f64, f64)> =
            Vec::with_capacity(plan.active.len());
        for (k, &i) in plan.active.iter().enumerate() {
            let mut neighbors: Vec<usize> =
                Vec::with_capacity(plan.pulls_from[k].len());
            let mut delays: Vec<u64> =
                Vec::with_capacity(plan.pulls_from[k].len());
            let mut dead_wait_ms = 0u64;
            let mut base_max = 0.0f64;
            let mut realized_max = 0.0f64;
            for &j in &plan.pulls_from[k] {
                let t = net.transfer_time_s(j, i, wire_bits, &mut rng);
                let out = delivery.resolve(round as u64, j, i);
                tally.add(&out);
                // pull history stays plan-level: a dead-lettered edge
                // was still attempted (and charged) — same as the
                // virtual-clock engine
                pulls.record(i, j);
                base_max = base_max.max(t);
                realized_max = realized_max.max(out.time_s(t));
                let d = (out.time_s(t) * opts.time_scale) as u64;
                if out.delivered {
                    neighbors.push(j);
                    delays.push(d);
                } else {
                    dead_wait_ms = dead_wait_ms.max(d);
                    chain.scenario_event(&EventRecord {
                        round,
                        kind: "dead-letter",
                        worker: Some(i),
                        population: p,
                    });
                }
            }
            acts.push((
                i,
                h_train[i],
                base_max,
                (realized_max - base_max).max(0.0),
            ));
            // drain this worker's pushed-model inbox; senders it
            // freshly pulls this round would double-count, so they are
            // filtered (their fresher model arrives via the pull)
            let pushed: Vec<(usize, Vec<f32>)> =
                std::mem::take(&mut inbox[i])
                    .into_iter()
                    .filter(|(from, _)| {
                        *from != i && !neighbors.contains(from)
                    })
                    .collect();
            let models = if transport.is_dense() {
                if adv_active {
                    // dense codec normally skips the wire entirely, but
                    // an exchange-mutating attacker must still be
                    // observed: ship the adversary's wire copies instead
                    // of letting receivers read published snapshots.
                    Some(
                        neighbors
                            .iter()
                            .map(|&j| {
                                let p = published[j].lock().unwrap();
                                adversary
                                    .exchange_view(j, &p.params, true)
                                    .to_vec()
                            })
                            .collect(),
                    )
                } else {
                    None
                }
            } else {
                let t = tel.tick();
                let dec = Some(
                    neighbors
                        .iter()
                        .map(|&j| {
                            transport
                                .decoded(j)
                                .expect("non-dense codec keeps reconstructions")
                                .to_vec()
                        })
                        .collect(),
                );
                tel.tock(crate::telemetry::Phase::CodecDecode, t);
                tel.add(
                    crate::telemetry::Counter::CodecDecodes,
                    neighbors.len() as u64,
                );
                dec
            };
            exec_txs[i]
                .send(Execute::Round {
                    neighbors,
                    pull_delays_ms: delays,
                    models,
                    dead_wait_ms,
                    pushed,
                })
                .map_err(|_| {
                    ExperimentError::Backend(format!(
                        "worker {i} hung up (thread died?)"
                    ))
                })?;
        }

        // wait for completions (the synchronization point is per-plan,
        // matching the round abstraction of Alg. 1)
        let mut losses = Vec::with_capacity(plan.active.len());
        for _ in &plan.active {
            let d = done_rx.recv().map_err(|_| {
                ExperimentError::Backend(
                    "a worker thread died mid-round".into(),
                )
            })?;
            debug_assert!(plan.active.contains(&d.id));
            losses.push(d.loss);
        }
        let h_round = round_t0.elapsed().as_secs_f64();

        // push edges (plan order): the sender's *post-training*
        // published model lands in the receiver's inbox for its next
        // activation — once-per-sender wire prep (attack + encode)
        // under a non-dense codec or an active adversary, then
        // replace-or-push so each receiver holds the latest copy per
        // sender. Same port as the virtual-clock engine's push pass.
        if !plan.pushes.is_empty() {
            let mut push_enc: Vec<usize> = Vec::new();
            for &(from, to) in &plan.pushes {
                if (!transport.is_dense() || adv_active)
                    && !push_enc.contains(&from)
                {
                    let src = published[from].lock().unwrap();
                    let payload: &[f32] = if adv_active {
                        adversary.transmit(from, &src.params)
                    } else {
                        &src.params
                    };
                    if !transport.is_dense() {
                        transport.encode(from, payload);
                    }
                    push_enc.push(from);
                }
                let src = published[from].lock().unwrap();
                let wire = adversary
                    .exchange_view(
                        from,
                        transport.view(from, &src.params),
                        transport.is_dense(),
                    )
                    .to_vec();
                match inbox[to].iter_mut().find(|(f, _)| *f == from) {
                    Some(slot) => slot.1 = wire,
                    None => inbox[to].push((from, wire)),
                }
            }
        }

        // activation trace (plan order): the wall-clock round mapped
        // back onto the virtual timeline, so testbed Perfetto tracks
        // align with the simulator's
        let h_virtual = h_round / opts.time_scale * 1000.0; // ms→virtual s
        for &(i, compute_s, transfer_s, retry_s) in &acts {
            chain.activation(&ActivationRecord {
                round,
                worker: i,
                start_s: vclock_s,
                compute_s,
                transfer_s,
                retry_s,
                wait_s: (h_virtual - compute_s - transfer_s - retry_s)
                    .max(0.0),
            });
        }
        vclock_s += h_virtual;

        // adversary bookkeeping: stale-bomb history feeds on the
        // *post-round* published models (every slot, fixed order), and
        // first-activation latches become auditable events
        if adversary.has_stale_bombers() {
            for (i, pub_i) in published.iter().enumerate() {
                let p = pub_i.lock().unwrap();
                adversary.record_round_end(i, &p.params);
            }
        }
        if adv_active {
            for (w, kind) in adversary.drain_activations() {
                chain.scenario_event(&EventRecord {
                    round,
                    kind,
                    worker: Some(w),
                    population: p,
                });
            }
        }

        // staleness + queues + residual bookkeeping (Eqs. 6/33/7);
        // absent workers keep aging (τ) but queues/residual freeze
        let mut active_mask = vec![false; n];
        for &i in &plan.active {
            active_mask[i] = true;
        }
        for i in 0..n {
            if !net.is_present(i) {
                tau[i] += 1;
                continue;
            }
            residual[i] = (residual[i] - h_virtual).max(0.0);
            if active_mask[i] {
                tau[i] = 0;
                residual[i] = h_train[i];
            } else {
                tau[i] += 1;
            }
            queues[i] =
                (queues[i] + tau[i] as f64 - cfg.tau_bound as f64).max(0.0);
        }

        let transfers = plan.transfers();
        cum_transfers += transfers;
        // byte ledger: planned transfers plus every delivery
        // retransmission, at the codec's measured wire size (clean
        // profile: zero retransmissions — the old ledger exactly)
        let bytes_sent = (transfers + tally.retransmissions) as f64
            * transport.message_bytes();
        cum_bytes += bytes_sent;
        let mut tau_sum = 0u64;
        let mut max_tau = 0u64;
        for &i in &ids {
            tau_sum += tau[i];
            max_tau = max_tau.max(tau[i]);
        }
        chain.round_end(&RoundRecord {
            round,
            time_s: start.elapsed().as_secs_f64(),
            duration_s: h_round,
            active: plan.active.len(),
            population: p,
            adversaries: adversary.count_present(&ids),
            transfers,
            bytes_sent,
            avg_staleness: tau_sum as f64 / p as f64,
            max_staleness: max_tau,
            train_loss: losses.iter().sum::<f64>() / losses.len().max(1) as f64,
            retransmissions: tally.retransmissions,
            dropped_msgs: tally.dropped_msgs(),
            corrupt_detected: tally.corrupt,
        });
        if tel.is_enabled() {
            use crate::telemetry::{Counter, Gauge, Phase};
            tel.add(Counter::DeliveryMsgs, transfers as u64);
            tel.add(Counter::DeliveryRetries, tally.retransmissions as u64);
            tel.add(
                Counter::DeliveryDeadLetters,
                tally.dropped_msgs() as u64,
            );
            tel.add(Counter::DeliveryCorrupt, tally.corrupt as u64);
            tel.inc(Counter::Rounds);
            let secs = tel.elapsed_s(t_round);
            if secs > 0.0 {
                let samples =
                    plan.active.len() * cfg.local_steps * cfg.batch;
                tel.set_gauge(
                    Gauge::TrainThroughput,
                    samples as f64 / secs,
                );
            }
            tel.set_gauge(Gauge::ClockVirtualS, vclock_s);
            tel.set_gauge(Gauge::Population, p as f64);
            tel.tock(Phase::Round, t_round);
        }
        tally.clear();

        if round % cfg.eval_every.max(1) == 0 || round == cfg.rounds {
            // evaluate the present population's published models
            let mut acc_sum = 0.0;
            let mut loss_sum = 0.0;
            for &i in &ids {
                let params = published[i].lock().unwrap().params.clone();
                let (l, a) = trainer.evaluate(&params, &test);
                acc_sum += a;
                loss_sum += l;
            }
            chain.eval(&EvalRecord {
                round,
                time_s: start.elapsed().as_secs_f64(),
                avg_accuracy: acc_sum / p as f64,
                avg_loss: loss_sum / p as f64,
                cum_transfers,
                cum_bytes,
            });
        }
    }

    for tx in &exec_txs {
        let _ = tx.send(Execute::Shutdown);
    }
    for h in handles {
        let _ = h.join();
    }
    chain.run_end().map_err(ExperimentError::Backend)?;
    Ok(chain.into_result())
}

/// The per-worker updating thread (Alg. 1 lines 3–7).
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    id: usize,
    shard: Dataset,
    h_train_s: f64,
    time_scale: f64,
    cfg: &ExperimentConfig,
    published: Vec<Arc<Mutex<Published>>>,
    rx: mpsc::Receiver<Execute>,
    done: mpsc::Sender<Done>,
    tel: crate::telemetry::Telemetry,
) {
    // one trainer per worker thread, driving the configured
    // `workload.model` (the builder already adopted file-corpus dims)
    let mut trainer = NativeTrainer::from_config(cfg);
    let mut rng = crate::util::rng::Pcg::new(cfg.seed ^ 0xBEEF, id as u64);
    // coordinator-side robust aggregation rule (mean = the historical
    // trainer path, bit-identical); scratch reused across rounds
    let mut aggregator = Aggregator::from_config(&cfg.adversary);
    let mut agg: Vec<f32> = Vec::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            Execute::Shutdown => break,
            Execute::Round {
                neighbors,
                pull_delays_ms,
                models: decoded,
                dead_wait_ms,
                pushed,
            } => {
                // PULL: read each neighbor's published snapshot (the
                // "pushing thread" contract), paying the channel delay.
                // Under a non-dense codec the coordinator already
                // encoded each sender; the message carries the decoded
                // reconstruction instead.
                let mut models: Vec<Vec<f32>> =
                    Vec::with_capacity(neighbors.len() + 1);
                let mut sizes: Vec<usize> =
                    Vec::with_capacity(neighbors.len() + 1);
                {
                    let own = published[id].lock().unwrap();
                    models.push(own.params.clone());
                    sizes.push(own.data_size);
                }
                // dead-lettered edges deliver nothing but their retry
                // window was still waited out before degrading
                let worst_delay = pull_delays_ms
                    .iter()
                    .copied()
                    .max()
                    .unwrap_or(0)
                    .max(dead_wait_ms);
                match decoded {
                    Some(dec) => {
                        debug_assert_eq!(dec.len(), neighbors.len());
                        for (&j, m) in neighbors.iter().zip(dec) {
                            // data sizes are cheap metadata, not part of
                            // the compressed model payload
                            sizes.push(published[j].lock().unwrap().data_size);
                            models.push(m);
                        }
                    }
                    None => {
                        for &j in &neighbors {
                            let p = published[j].lock().unwrap();
                            models.push(p.params.clone());
                            sizes.push(p.data_size);
                        }
                    }
                }
                // pushed models merge after own + pulled (the
                // simulator's aggregation order); wire copies arrived
                // with the message, sizes are cheap metadata
                for (j, m) in pushed {
                    sizes.push(published[j].lock().unwrap().data_size);
                    models.push(m);
                }
                // pulls happen in parallel → pay only the slowest link
                thread::sleep(Duration::from_millis(worst_delay));

                // aggregate (Eq. 4) + emulated heterogeneous compute
                let refs: Vec<&[f32]> =
                    models.iter().map(|m| m.as_slice()).collect();
                let weights = data_size_weights(&sizes);
                let t = tel.tick();
                aggregator.aggregate_into(
                    &mut trainer,
                    &refs,
                    &weights,
                    &mut agg,
                );
                tel.tock(crate::telemetry::Phase::Aggregate, t);
                thread::sleep(Duration::from_millis(
                    (h_train_s * time_scale) as u64,
                ));
                // real local training (Eq. 5)
                let t = tel.tick();
                let (new_params, loss) = trainer.train(
                    &agg,
                    &shard,
                    cfg.local_steps,
                    cfg.batch,
                    cfg.lr,
                    &mut rng,
                );
                tel.tock(crate::telemetry::Phase::Train, t);
                tel.inc(crate::telemetry::Counter::Activations);
                tel.add(
                    crate::telemetry::Counter::TrainSamples,
                    (cfg.local_steps * cfg.batch) as u64,
                );
                published[id].lock().unwrap().params = new_params;
                let _ = done.send(Done { id, loss });
            }
        }
    }
}
