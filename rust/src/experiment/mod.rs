//! Unified experiment API: one builder, pluggable execution backends,
//! pluggable round observers.
//!
//! The paper evaluates DySTop in two harnesses — large-scale simulation
//! (§VI) and a real-device testbed (§VII). Both need the *same* setup:
//! synthetic corpus, Dirichlet partition, [`EdgeNetwork`] substrate,
//! per-worker compute heterogeneity, scheduler, trainer. This module owns
//! that setup exactly once and exposes it behind three small contracts:
//!
//! * [`Experiment::builder`] — fallible construction (every invalid
//!   config or trainer mismatch is an [`ExperimentError`], never a
//!   panic) of the shared substrate;
//! * [`Backend`] — how rounds are *executed*:
//!   [`VirtualClockBackend`] (deterministic virtual-clock simulation,
//!   §VI), [`ThreadedBackend`] (thread-per-worker with real message
//!   passing and compressed wall-clock delays, §VII), or
//!   [`SocketBackend`] (the deployment shape: workers behind real
//!   TCP/Unix sockets speaking the framed wire format, with the
//!   simulator's event/byte ledger preserved bit-for-bit);
//! * [`RoundObserver`] — how rounds are *watched*
//!   (`on_scenario_event`/`on_plan`/`on_round_end`/`on_eval`): metrics
//!   recording is itself the first observer ([`RunRecorder`]), and
//!   callers can attach more (figure capture, fault injection, live
//!   dashboards) without touching the engines.
//!
//! Population/environment dynamics come from the scenario layer
//! ([`crate::scenario`]): the builder generates a deterministic event
//! timeline from `cfg.scenario` (or an explicit
//! [`ExperimentBuilder::scenario`] script) and both backends apply it at
//! round boundaries.
//!
//! ```no_run
//! use dystop::config::{BackendKind, ExperimentConfig};
//! use dystop::experiment::Experiment;
//!
//! let cfg = ExperimentConfig { workers: 20, rounds: 50, ..Default::default() };
//! let res = Experiment::builder(cfg)
//!     .backend(BackendKind::Sim)
//!     .run()
//!     .expect("experiment failed");
//! println!("best accuracy {:.3}", res.best_accuracy());
//! ```
//!
//! The legacy facades `sim::SimEngine` / `testbed::run_testbed` (thin
//! deprecated wrappers kept through PR 1–2) are gone; this module is the
//! only construction path.

pub mod events;
mod observer;
mod socket;
mod threaded;
mod virtual_clock;

pub use events::{EventQueue, SimEvent};
pub use observer::{ObserverChain, RoundObserver, RunRecorder};
pub use socket::SocketBackend;
pub use threaded::{TestbedOptions, ThreadedBackend};
pub use virtual_clock::{VirtualClockBackend, VirtualClockEngine};

use crate::adversary::{Adversary, AdversaryPolicy};
use crate::config::{BackendKind, ExperimentConfig};
use crate::coordinator::{make_scheduler, Scheduler};
use crate::data::{dirichlet_partition, Dataset};
use crate::delivery::Delivery;
use crate::metrics::RunResult;
use crate::network::EdgeNetwork;
use crate::scenario::Scenario;
use crate::transport::Transport;
use crate::util::rng::Pcg;
use crate::worker::{default_trainer, Trainer, WorkerState};
use crate::workload::build_workload;
use std::fmt;

/// Everything that can go wrong constructing or executing an experiment.
#[derive(Debug)]
pub enum ExperimentError {
    /// The [`ExperimentConfig`] failed validation.
    InvalidConfig(String),
    /// The configured trainer kind has no default constructor; pass one
    /// explicitly via [`ExperimentBuilder::trainer`] (e.g. a
    /// `PjrtTrainer` loaded from AOT artifacts).
    TrainerRequired(String),
    /// The chosen backend cannot execute this configuration.
    Unsupported(String),
    /// A backend failed at runtime (e.g. a worker thread died).
    Backend(String),
}

impl fmt::Display for ExperimentError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExperimentError::InvalidConfig(m) => {
                write!(f, "invalid experiment config: {m}")
            }
            ExperimentError::TrainerRequired(m) => {
                write!(f, "trainer required: {m}")
            }
            ExperimentError::Unsupported(m) => {
                write!(f, "unsupported configuration: {m}")
            }
            ExperimentError::Backend(m) => write!(f, "backend failure: {m}"),
        }
    }
}

impl std::error::Error for ExperimentError {}

impl From<ExperimentError> for String {
    fn from(e: ExperimentError) -> String {
        e.to_string()
    }
}

/// An execution backend: consumes a fully-built [`Experiment`] and
/// drives Alg. 1 to completion, reporting through the experiment's
/// observers and returning the recorded [`RunResult`].
pub trait Backend {
    fn name(&self) -> &'static str;

    fn run(&mut self, exp: Experiment) -> Result<RunResult, ExperimentError>;
}

/// The single [`BackendKind`] → [`Backend`] dispatch point: every
/// built-in backend is constructed here, configured from its own config
/// section (`testbed.*`, `socket.*`). The builder's
/// [`backend`](ExperimentBuilder::backend) call and the config's
/// `run.backend` knob both route through this, so adding a backend is
/// one enum variant + one arm.
pub fn make_backend(
    kind: BackendKind,
    cfg: &ExperimentConfig,
) -> Box<dyn Backend> {
    match kind {
        BackendKind::Sim => Box::new(VirtualClockBackend::new()),
        BackendKind::Testbed => {
            Box::new(ThreadedBackend::from_config(&cfg.testbed))
        }
        BackendKind::Socket => {
            Box::new(SocketBackend::from_config(&cfg.socket))
        }
    }
}

/// The shared, backend-agnostic substrate of one experiment: config,
/// corpus, partitioned workers, edge network, scheduler, trainer, and
/// the RNG stream construction left off at (backends continue it so a
/// seeded run is deterministic end to end).
pub struct Experiment {
    pub cfg: ExperimentConfig,
    pub net: EdgeNetwork,
    pub workers: Vec<WorkerState>,
    pub test: Dataset,
    /// Per-worker label distributions over the static shards (PTCA
    /// phase-1 / EMD inputs).
    pub label_dist: Vec<Vec<f64>>,
    /// Bits of one model transfer on the simulated wire.
    pub model_bits: f64,
    /// The population/environment event timeline both backends apply at
    /// round boundaries (empty under `scenario.preset=stable`).
    pub scenario: Scenario,
    /// The model-transport layer (`transport.*` knobs): every model
    /// exchange in both backends is encoded/decoded through it and
    /// charged its measured wire bytes.
    pub transport: Transport,
    /// The adversary layer (`adversary.*` knobs): per-worker Byzantine
    /// policies applied at the model-exchange boundary in both backends
    /// (inactive — and branch-free on the hot path — by default).
    pub adversary: Adversary,
    /// The reliable delivery layer (`faults.*` knobs): every pull edge
    /// in both backends is resolved through its deterministic per-link
    /// fault model and ack/retry protocol (inactive — and branch-free
    /// on the hot path — under the default `clean` profile).
    pub delivery: Delivery,
    /// The wall-clock telemetry registry (`telemetry.*` knobs): every
    /// backend reports phase timings and event counts through it.
    /// Strictly an output — no backend ever reads it back — so the
    /// default inert handle and a live registry produce bit-identical
    /// ledgers (pinned by `tests/telemetry.rs`).
    pub telemetry: crate::telemetry::Telemetry,
    pub(crate) trainer: Box<dyn Trainer>,
    pub(crate) scheduler: Box<dyn Scheduler>,
    pub(crate) rng: Pcg,
    pub(crate) observers: Vec<Box<dyn RoundObserver>>,
}

impl Experiment {
    /// Start building an experiment from a config.
    pub fn builder(cfg: ExperimentConfig) -> ExperimentBuilder {
        ExperimentBuilder {
            cfg,
            trainer: None,
            backend: None,
            observers: Vec::new(),
            scenario: None,
            adversary: None,
        }
    }

    /// The scheduler's display name (labels results).
    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler.name()
    }
}

/// Fluent constructor for [`Experiment`]; terminal methods are
/// [`build`](Self::build) (substrate only) and [`run`](Self::run)
/// (build + execute on the selected backend).
pub struct ExperimentBuilder {
    cfg: ExperimentConfig,
    trainer: Option<Box<dyn Trainer>>,
    backend: Option<Box<dyn Backend>>,
    observers: Vec<Box<dyn RoundObserver>>,
    scenario: Option<Scenario>,
    adversary: Option<Vec<AdversaryPolicy>>,
}

impl ExperimentBuilder {
    /// Use an explicit training backend (e.g. `PjrtTrainer` over AOT
    /// artifacts). Without this, the config's [`TrainerKind`] must have
    /// a default constructor (native softmax regression).
    ///
    /// [`TrainerKind`]: crate::config::TrainerKind
    pub fn trainer(mut self, trainer: Box<dyn Trainer>) -> Self {
        self.trainer = Some(trainer);
        self
    }

    /// Select a built-in execution backend (overrides `cfg.backend`,
    /// the `run.backend=sim|testbed|socket` knob). Per-backend options
    /// are read from the config's `testbed.*`/`socket.*` sections.
    pub fn backend(self, kind: BackendKind) -> Self {
        let backend = make_backend(kind, &self.cfg);
        self.backend_impl(backend)
    }

    /// Select a custom execution backend implementation.
    pub fn backend_impl(mut self, backend: Box<dyn Backend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Attach a [`RoundObserver`]; may be called repeatedly. Observers
    /// fire after the built-in [`RunRecorder`], in attachment order.
    pub fn observer(mut self, obs: Box<dyn RoundObserver>) -> Self {
        self.observers.push(obs);
        self
    }

    /// Use an explicit, hand-scripted event timeline instead of the one
    /// generated from `cfg.scenario` (fault-injection tests, replays).
    pub fn scenario(mut self, scenario: Scenario) -> Self {
        self.scenario = Some(scenario);
        self
    }

    /// Script per-worker adversary policies (one entry per worker slot)
    /// instead of the seeded `⌊adversary.frac·n⌋` assignment generated
    /// from `cfg.adversary` (targeted Byzantine tests, replays).
    pub fn adversary(mut self, policies: Vec<AdversaryPolicy>) -> Self {
        self.adversary = Some(policies);
        self
    }

    /// Perform the shared setup once: corpus, Dirichlet partition, edge
    /// network, heterogeneous worker speeds, scheduler, trainer.
    ///
    /// Deterministic given `cfg.seed` — the RNG draw order here is the
    /// contract the seeded-parity tests pin down; change it and every
    /// recorded curve shifts.
    pub fn build(self) -> Result<Experiment, ExperimentError> {
        let mut cfg = self.cfg;
        cfg.validate().map_err(ExperimentError::InvalidConfig)?;

        // the workload registry owns corpus construction (and the eval
        // protocol baked into the test set); it draws from dedicated
        // RNG streams only, so the default synthetic corpus — and the
        // builder stream below — are bit-identical to the pre-workload
        // path
        let wl =
            build_workload(&cfg).map_err(ExperimentError::InvalidConfig)?;
        // file-backed corpora define their own shape: adopt it so the
        // trainer, transport and metrics all see the real dimensions,
        // then re-check the model's shape constraints against it
        // (config validation skips model_fits for file datasets — this
        // is the authoritative check on that path)
        if cfg.feature_dim != wl.train.dim
            || cfg.num_classes != wl.train.num_classes
        {
            cfg.feature_dim = wl.train.dim;
            cfg.num_classes = wl.train.num_classes;
        }
        cfg.workload
            .model_fits(cfg.feature_dim)
            .map_err(ExperimentError::InvalidConfig)?;
        let (train, test) = (wl.train, wl.test);

        let trainer: Box<dyn Trainer> = match self.trainer {
            Some(t) => t,
            None => default_trainer(&cfg).ok_or_else(|| {
                ExperimentError::TrainerRequired(format!(
                    "trainer kind {:?} has no default constructor; pass one \
                     via ExperimentBuilder::trainer (e.g. PjrtTrainer from \
                     AOT artifacts)",
                    cfg.trainer
                ))
            })?,
        };

        let mut rng = Pcg::new(cfg.seed, 0x51B);
        let min_per = cfg.batch.max(cfg.train_per_worker / 4);
        // partition coverage: with at least min_per samples per worker
        // available, the rebalancer can never terminate with an empty
        // shard (which would panic at train time). The synthetic path
        // guarantees this by construction (train_per_worker × workers);
        // file corpora bring their own size, so check it here.
        if train.len() < cfg.workers * min_per {
            return Err(ExperimentError::InvalidConfig(format!(
                "corpus has {} training samples but {} workers need at \
                 least {min_per} each (max of train.batch and \
                 train_per_worker/4); lower sim.workers or train.batch",
                train.len(),
                cfg.workers
            )));
        }
        let (shards, stats) =
            dirichlet_partition(&train, cfg.workers, cfg.phi, min_per, &mut rng);

        let net = EdgeNetwork::new(cfg.workers, cfg.network.clone(), &mut rng);

        // heterogeneous compute: h_i = mean × lognormal(0, jitter).
        // Edge-device speeds are heavy-tailed (the paper's Table II spans
        // ~10× between Jetson Nano and Orin) — the lognormal gives the
        // straggler regime the synchronous baselines suffer in (§VI-B1).
        let mut workers: Vec<WorkerState> = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                let coeff = rng.normal_ms(0.0, cfg.compute_jitter).exp();
                let h = cfg.compute_mean_s * coeff;
                let params = trainer.init(cfg.seed.wrapping_add(i as u64));
                WorkerState::new(i, params, shard, h)
            })
            .collect();

        // wall-clock telemetry: a live registry when any telemetry.*
        // knob asks for one, the inert no-op handle otherwise. Strictly
        // write-only from the engines' perspective, so this choice can
        // never move a bit in the run ledger.
        let telemetry = if cfg.telemetry.active() {
            crate::telemetry::Telemetry::enabled()
        } else {
            crate::telemetry::Telemetry::disabled()
        };

        let mut scheduler = make_scheduler(cfg.scheduler);
        scheduler.attach_telemetry(telemetry.clone());
        let model_bits = if cfg.network.payload_bits > 0.0 {
            cfg.network.payload_bits
        } else {
            trainer.param_count() as f64 * 32.0
        };
        let label_dist = stats.label_distributions;

        // the event timeline draws from its own dedicated RNG stream, so
        // scenario generation never perturbs the substrate construction
        // above (stable preset ⇒ empty timeline ⇒ pre-scenario bits)
        let scenario = self.scenario.unwrap_or_else(|| {
            Scenario::generate(
                &cfg.scenario,
                cfg.workers,
                cfg.rounds,
                cfg.seed,
            )
        });
        // hand-scripted timelines are unchecked input: reject worker ids
        // beyond the population before an engine can index out of bounds
        if let Some(w) = scenario.max_worker() {
            if w >= cfg.workers {
                return Err(ExperimentError::InvalidConfig(format!(
                    "scenario references worker {w} but sim.workers = {}",
                    cfg.workers
                )));
            }
        }

        // the transport layer compresses what crosses the wire: the
        // semantic transform runs on the real parameter vector, the byte
        // accounting on the simulated payload (model_bits)
        let transport = Transport::new(
            cfg.transport,
            cfg.workers,
            trainer.param_count(),
            model_bits,
        );

        // the adversary cast draws from its own dedicated RNG stream
        // (like the scenario timeline), so assignment never perturbs the
        // substrate construction above (frac=0 ⇒ all honest ⇒
        // pre-adversary bits)
        let mut adversary = match self.adversary {
            Some(policies) => {
                if policies.len() != cfg.workers {
                    return Err(ExperimentError::InvalidConfig(format!(
                        "scripted adversary has {} policies but \
                         sim.workers = {}",
                        policies.len(),
                        cfg.workers
                    )));
                }
                Adversary::scripted(policies, &cfg.adversary)
            }
            None => {
                Adversary::from_config(&cfg.adversary, cfg.workers, cfg.seed)
            }
        };
        for (i, w) in workers.iter_mut().enumerate() {
            // label-flip poisons the attacker's *data* once, at build
            // time: its honest-looking training then pushes
            // anti-gradients through the ordinary exchange path in both
            // backends, with zero hot-path special-casing
            if adversary.policy(i) == AdversaryPolicy::LabelFlip {
                let c = w.shard.num_classes as u32;
                for y in &mut w.shard.labels {
                    *y = c - 1 - *y;
                }
            }
            // stateful attacks snapshot the initial parameters (the
            // free-rider's frozen payload, the stale-bomber's history)
            adversary.observe_init(i, &w.params);
        }

        // delivery is stateless (config + seed): each pull edge's fate is
        // a pure function of (seed, round, from, to) via its own dedicated
        // RNG stream, so faults never perturb the substrate construction
        // above (clean profile ⇒ every edge CLEAN ⇒ pre-delivery bits)
        let delivery = Delivery::from_config(&cfg.faults, cfg.seed);

        // streaming metrics sink (metrics.sink=csv|jsonl): attached as an
        // ordinary observer, after any caller-attached ones
        let mut observers = self.observers;
        if let Some(sink) =
            crate::metrics::sink::make_sink(&cfg.metrics).map_err(|e| {
                ExperimentError::InvalidConfig(format!(
                    "metrics.out {:?}: {e}",
                    cfg.metrics.out
                ))
            })?
        {
            observers.push(sink);
        }

        // Perfetto trace sink (trace.out=<path>): Trace Event JSON with
        // one track per worker, emitted by any backend
        if !cfg.trace.out.is_empty() {
            let sink = crate::metrics::trace::TraceSink::to_path(
                &cfg.trace.out,
            )
            .map_err(|e| {
                ExperimentError::InvalidConfig(format!(
                    "trace.out {:?}: {e}",
                    cfg.trace.out
                ))
            })?;
            observers.push(Box::new(sink));
        }

        // telemetry exposures: run-info labels for the exposition, the
        // /metrics server (telemetry.addr), and the JSONL snapshot sink
        // (telemetry.out) — all three ride the one registry above
        if telemetry.is_enabled() {
            telemetry.set_info("scheduler", scheduler.name());
            telemetry.set_info(
                "aggregator",
                &format!("{:?}", cfg.adversary.aggregator).to_lowercase(),
            );
            telemetry
                .set_info("backend", &format!("{:?}", cfg.backend).to_lowercase());
            telemetry.set_gauge(
                crate::telemetry::Gauge::Population,
                cfg.workers as f64,
            );
            if !cfg.telemetry.addr.is_empty() {
                telemetry.serve(&cfg.telemetry.addr).map_err(|e| {
                    ExperimentError::InvalidConfig(format!("telemetry.addr: {e}"))
                })?;
            }
            if !cfg.telemetry.out.is_empty() {
                let sink = crate::telemetry::TelemetrySink::create(
                    telemetry.clone(),
                    std::path::Path::new(&cfg.telemetry.out),
                    cfg.telemetry.snapshot_every,
                )
                .map_err(|e| {
                    ExperimentError::InvalidConfig(format!(
                        "telemetry.out {:?}: {e}",
                        cfg.telemetry.out
                    ))
                })?;
                observers.push(Box::new(sink));
            }
        }

        Ok(Experiment {
            cfg,
            net,
            workers,
            test,
            label_dist,
            model_bits,
            scenario,
            transport,
            adversary,
            delivery,
            telemetry,
            trainer,
            scheduler,
            rng,
            observers,
        })
    }

    /// Build and execute: dispatches to the selected backend (explicit
    /// [`backend`](Self::backend)/[`backend_impl`](Self::backend_impl)
    /// call, else the config's `run.backend` knob).
    pub fn run(mut self) -> Result<RunResult, ExperimentError> {
        let mut backend: Box<dyn Backend> = match self.backend.take() {
            Some(b) => b,
            None => make_backend(self.cfg.backend, &self.cfg),
        };
        let exp = self.build()?;
        backend.run(exp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SchedulerKind, TrainerKind};

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            workers: 6,
            rounds: 8,
            train_per_worker: 48,
            test_samples: 100,
            eval_every: 4,
            target_accuracy: 2.0,
            ..Default::default()
        }
    }

    #[test]
    fn builder_constructs_shared_substrate() {
        let exp = Experiment::builder(tiny_cfg()).build().unwrap();
        assert_eq!(exp.workers.len(), 6);
        assert_eq!(exp.label_dist.len(), 6);
        assert!(exp.model_bits > 0.0);
        assert_eq!(exp.scheduler_name(), "dystop");
        assert!(!exp.test.is_empty());
        // default transport: the dense identity codec, whose message
        // size on the wire IS the dense payload, bit for bit
        assert!(exp.transport.is_dense());
        assert_eq!(
            exp.transport.message_bits().to_bits(),
            exp.model_bits.to_bits()
        );
    }

    #[test]
    fn builder_generates_scenario_from_config() {
        use crate::config::{ScenarioConfig, ScenarioPreset};
        use crate::scenario::{Scenario, ScenarioEvent};
        // default config → stable → empty timeline
        let exp = Experiment::builder(tiny_cfg()).build().unwrap();
        assert!(exp.scenario.is_empty());
        // diurnal preset → generated timeline
        let mut cfg = tiny_cfg();
        cfg.workers = 20;
        cfg.rounds = 80;
        cfg.scenario = ScenarioConfig::preset(ScenarioPreset::Diurnal);
        let exp = Experiment::builder(cfg).build().unwrap();
        assert!(!exp.scenario.is_empty());
        // explicit timeline overrides generation
        let script = Scenario::from_events(vec![(
            2,
            ScenarioEvent::Leave { worker: 1 },
        )]);
        let exp = Experiment::builder(tiny_cfg())
            .scenario(script)
            .build()
            .unwrap();
        assert_eq!(exp.scenario.len(), 1);
    }

    #[test]
    fn invalid_config_is_err_not_panic() {
        let mut cfg = tiny_cfg();
        cfg.workers = 0;
        match Experiment::builder(cfg).build() {
            Err(ExperimentError::InvalidConfig(m)) => {
                assert!(m.contains("workers"), "{m}");
            }
            Err(other) => panic!("expected InvalidConfig, got {other:?}"),
            Ok(_) => panic!("expected InvalidConfig, got Ok"),
        }
    }

    #[test]
    fn pjrt_without_trainer_is_err_not_panic() {
        let mut cfg = tiny_cfg();
        cfg.trainer = TrainerKind::Pjrt;
        match Experiment::builder(cfg).build() {
            Err(ExperimentError::TrainerRequired(m)) => {
                assert!(m.contains("Pjrt"), "{m}");
            }
            Err(other) => panic!("expected TrainerRequired, got {other:?}"),
            Ok(_) => panic!("expected TrainerRequired, got Ok"),
        }
    }

    #[test]
    fn run_dispatches_on_config_backend() {
        let mut cfg = tiny_cfg();
        cfg.scheduler = SchedulerKind::DySTop;
        let res = Experiment::builder(cfg).run().unwrap();
        assert_eq!(res.rounds.len(), 8);
        assert_eq!(res.label, "dystop");
    }

    #[test]
    fn errors_render_cleanly() {
        let e = ExperimentError::InvalidConfig("sim.workers must be > 0".into());
        let s: String = e.into();
        assert!(s.starts_with("invalid experiment config"));
    }
}
