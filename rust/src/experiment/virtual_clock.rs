//! Virtual-clock execution backend (paper §VI).
//!
//! Drives Alg. 1 end to end over the edge-network substrate: each round
//! the engine snapshots worker state into a [`SchedView`], asks the
//! configured [`Scheduler`](crate::coordinator::Scheduler) for a
//! [`RoundPlan`], executes the plan (pull-aggregate-train per Eqs. 3–5,
//! *real* training through the configured trainer), advances the virtual
//! clock by the realised round duration H_t (Eqs. 7–9), and updates
//! staleness (Eq. 6) and the Lyapunov queues (Eq. 33).

use super::observer::{ObserverChain, RunRecorder};
use super::{Backend, Experiment, ExperimentError};
use crate::config::ExperimentConfig;
use crate::coordinator::{RoundPlan, SchedView, Scheduler, SchedulerParams};
use crate::data::Dataset;
use crate::metrics::{EvalRecord, RoundRecord, RunResult};
use crate::network::EdgeNetwork;
use crate::util::rng::Pcg;
use crate::worker::{data_size_weights, Trainer, WorkerState};

/// Virtual-clock [`Backend`]: deterministic, single-threaded, fast —
/// the harness behind every figure and the large-scale sweeps.
pub struct VirtualClockBackend {
    early_stop: bool,
}

impl VirtualClockBackend {
    /// Early-stops once `target_accuracy` holds for two consecutive
    /// snapshots (the CLI `train` behaviour).
    pub fn new() -> Self {
        VirtualClockBackend { early_stop: true }
    }

    /// Never early-stops: full curves for figures.
    pub fn full_curves() -> Self {
        VirtualClockBackend { early_stop: false }
    }
}

impl Default for VirtualClockBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for VirtualClockBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run(&mut self, exp: Experiment) -> Result<RunResult, ExperimentError> {
        Ok(VirtualClockEngine::new(exp).run(self.early_stop))
    }
}

/// The assembled simulation engine. Public so callers that need
/// fine-grained control (benches stepping round by round, tests probing
/// mid-run state) can drive it manually; everyone else goes through
/// [`VirtualClockBackend`].
pub struct VirtualClockEngine {
    pub cfg: ExperimentConfig,
    pub net: EdgeNetwork,
    pub workers: Vec<WorkerState>,
    pub test: Dataset,
    trainer: Box<dyn Trainer>,
    scheduler: Box<dyn Scheduler>,
    /// pulls\[i\]\[j\]: times worker i pulled from j (Eq. 47's history).
    pulls: Vec<Vec<u64>>,
    /// Pushed-model inboxes: models received via PUSH wait here until the
    /// receiver's next activation (SA-ADFL semantics — receivers don't
    /// interrupt training to merge).
    inbox: Vec<Vec<(usize, Vec<f32>)>>,
    clock_s: f64,
    round: usize,
    cum_transfers: usize,
    rng: Pcg,
    observers: ObserverChain,
    /// Precomputed label distributions per worker (static shards).
    label_dist: Vec<Vec<f64>>,
    model_bits: f64,
}

impl VirtualClockEngine {
    /// Assemble the engine around a built [`Experiment`].
    pub fn new(exp: Experiment) -> Self {
        let n = exp.cfg.workers;
        let recorder =
            RunRecorder::new(exp.scheduler.name(), exp.model_bits);
        VirtualClockEngine {
            observers: ObserverChain::new(recorder, exp.observers),
            cfg: exp.cfg,
            net: exp.net,
            workers: exp.workers,
            test: exp.test,
            trainer: exp.trainer,
            scheduler: exp.scheduler,
            pulls: vec![vec![0; n]; n],
            inbox: vec![Vec::new(); n],
            clock_s: 0.0,
            round: 0,
            cum_transfers: 0,
            rng: exp.rng,
            label_dist: exp.label_dist,
            model_bits: exp.model_bits,
        }
    }

    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }

    /// Estimated per-worker round cost H_t^i (Eq. 8): residual compute
    /// plus the worst expected pull transfer over its (≤ s nearest)
    /// candidates.
    fn estimate_h(&self, candidates: &[Vec<usize>]) -> Vec<f64> {
        let s = self.cfg.neighbor_cap;
        (0..self.workers.len())
            .map(|i| {
                // PTCA will pick ≤ s in-neighbors; estimate with the s
                // *nearest* candidates (best case the coordinator can
                // predict without knowing the realised priorities).
                let mut near: Vec<usize> = candidates[i].clone();
                near.sort_by(|&a, &b| {
                    self.net
                        .distance(i, a)
                        .partial_cmp(&self.net.distance(i, b))
                        .unwrap()
                });
                let worst = near
                    .iter()
                    .take(s)
                    .map(|&j| {
                        self.net
                            .expected_transfer_time_s(j, i, self.model_bits)
                    })
                    .fold(0.0f64, f64::max);
                self.workers[i].residual_s + worst
            })
            .collect()
    }

    /// Run one round of Alg. 1; returns the realised plan.
    pub fn step(&mut self) -> RoundPlan {
        self.round += 1;
        self.net.step(&mut self.rng);

        let candidates: Vec<Vec<usize>> = (0..self.workers.len())
            .map(|i| self.net.in_range(i))
            .collect();
        let h_cmp: Vec<f64> =
            self.workers.iter().map(|w| w.residual_s).collect();
        let h_est = self.estimate_h(&candidates);
        let tau: Vec<u64> = self.workers.iter().map(|w| w.staleness).collect();
        let queues: Vec<f64> = self.workers.iter().map(|w| w.queue).collect();
        let data_sizes: Vec<usize> =
            self.workers.iter().map(|w| w.data_size()).collect();

        let plan = {
            let view = SchedView {
                round: self.round,
                tau: &tau,
                queues: &queues,
                h_cmp: &h_cmp,
                h_est: &h_est,
                data_sizes: &data_sizes,
                label_dist: &self.label_dist,
                candidates: &candidates,
                budgets: &self.net.budgets,
                pulls: &self.pulls,
                net: &self.net,
                params: SchedulerParams::from(&self.cfg),
            };
            self.scheduler.plan(&view, &mut self.rng)
        };
        debug_assert!(plan.validate(self.workers.len()).is_ok());
        self.observers.plan(self.round, &plan);

        self.execute(&plan);
        plan
    }

    /// Execute a round plan: aggregate + train the active workers,
    /// advance the clock, update staleness/queues/ledgers.
    fn execute(&mut self, plan: &RoundPlan) {
        let n = self.workers.len();
        // --- realised round duration (Eqs. 7–9) ---
        let mut h_round = 0.0f64;
        let mut durations = Vec::with_capacity(plan.active.len());
        let channels = self.cfg.network.channels.max(1);
        for (k, &i) in plan.active.iter().enumerate() {
            // pulls beyond the radio's orthogonal channels serialize:
            // K transfers take ⌈K/channels⌉ slots of the worst link time
            let worst_pull = plan.pulls_from[k]
                .iter()
                .map(|&j| {
                    self.net
                        .transfer_time_s(j, i, self.model_bits, &mut self.rng)
                })
                .fold(0.0f64, f64::max);
            let pull_slots = plan.pulls_from[k].len().div_ceil(channels);
            // pushes originating at i (SA-ADFL's send-to-all) also occupy
            // its radio, serialized the same way
            let push_times: Vec<f64> = plan
                .pushes
                .iter()
                .filter(|&&(from, _)| from == i)
                .map(|&(_, to)| {
                    self.net
                        .transfer_time_s(i, to, self.model_bits, &mut self.rng)
                })
                .collect();
            let worst_push = push_times.iter().cloned().fold(0.0f64, f64::max);
            let push_slots = push_times.len().div_ceil(channels);
            let d = self.workers[i].residual_s
                + worst_pull * pull_slots as f64
                + worst_push * push_slots as f64;
            durations.push(d);
            h_round = h_round.max(d);
        }
        if plan.active.is_empty() {
            h_round = 0.01; // avoid stalling the clock
        }

        // --- aggregate + train (Eqs. 4–5), pull-count ledger ---
        // snapshot models first so intra-round pulls see pre-round state
        let mut losses = Vec::with_capacity(plan.active.len());
        let mut new_models: Vec<(usize, Vec<f32>, f64)> = Vec::new();
        for (k, &i) in plan.active.iter().enumerate() {
            let mut srcs: Vec<usize> = vec![i];
            srcs.extend(plan.pulls_from[k].iter().copied());
            let mut models: Vec<&[f32]> = srcs
                .iter()
                .map(|&j| self.workers[j].params.as_slice())
                .collect();
            let mut sizes: Vec<usize> =
                srcs.iter().map(|&j| self.workers[j].data_size()).collect();
            // pushed models waiting in the inbox join the aggregation
            // (skipping senders we just pulled fresh models from)
            for (from, params) in &self.inbox[i] {
                if !srcs.contains(from) {
                    models.push(params.as_slice());
                    sizes.push(self.workers[*from].data_size());
                }
            }
            let weights = data_size_weights(&sizes);
            let agg = self.trainer.aggregate(&models, &weights);
            let (trained, loss) = self.trainer.train(
                &agg,
                &self.workers[i].shard,
                self.cfg.local_steps,
                self.cfg.batch,
                self.cfg.lr,
                &mut self.rng,
            );
            new_models.push((i, trained, loss));
            losses.push(loss);
            for &j in &plan.pulls_from[k] {
                self.pulls[i][j] += 1;
            }
        }
        for (i, params, loss) in new_models {
            self.workers[i].params = params;
            self.workers[i].last_loss = loss;
            self.inbox[i].clear(); // consumed by this aggregation
        }

        // --- pushes (SA-ADFL): the updated model lands in each
        // receiver's inbox for *their* next aggregation (latest wins)
        for &(from, to) in &plan.pushes {
            let pushed = self.workers[from].params.clone();
            self.inbox[to].retain(|(f, _)| *f != from);
            self.inbox[to].push((from, pushed));
        }

        // --- clock + staleness + queues (Eqs. 6, 33) ---
        self.clock_s += h_round;
        let active_set: Vec<bool> = {
            let mut v = vec![false; n];
            for &i in &plan.active {
                v[i] = true;
            }
            v
        };
        for (i, w) in self.workers.iter_mut().enumerate() {
            w.advance(h_round);
            if active_set[i] {
                w.on_activated();
            } else {
                w.on_skipped();
            }
            w.update_queue(self.cfg.tau_bound);
        }

        // --- metrics ---
        let transfers = plan.transfers();
        self.cum_transfers += transfers;
        let avg_tau = self
            .workers
            .iter()
            .map(|w| w.staleness as f64)
            .sum::<f64>()
            / n as f64;
        let max_tau = self.workers.iter().map(|w| w.staleness).max().unwrap_or(0);
        let train_loss = if losses.is_empty() {
            f64::NAN
        } else {
            losses.iter().sum::<f64>() / losses.len() as f64
        };
        let rec = RoundRecord {
            round: self.round,
            time_s: self.clock_s,
            duration_s: h_round,
            active: plan.active.len(),
            transfers,
            avg_staleness: avg_tau,
            max_staleness: max_tau,
            train_loss,
        };
        self.observers.round_end(&rec);
    }

    /// Evaluate the average of all (or a sampled fraction of) workers'
    /// local models on the test set and record a snapshot.
    pub fn evaluate(&mut self) -> EvalRecord {
        let n = self.workers.len();
        let count = ((n as f64 * self.cfg.eval_worker_frac).round() as usize)
            .clamp(1, n);
        let ids: Vec<usize> = if count == n {
            (0..n).collect()
        } else {
            self.rng.sample_indices(n, count)
        };
        let mut acc_sum = 0.0;
        let mut loss_sum = 0.0;
        for &i in &ids {
            let (loss, acc) =
                self.trainer.evaluate(&self.workers[i].params, &self.test);
            acc_sum += acc;
            loss_sum += loss;
        }
        let rec = EvalRecord {
            round: self.round,
            time_s: self.clock_s,
            avg_accuracy: acc_sum / ids.len() as f64,
            avg_loss: loss_sum / ids.len() as f64,
            cum_transfers: self.cum_transfers,
        };
        self.observers.eval(&rec);
        rec
    }

    /// Run the configured number of rounds with periodic evaluation.
    /// With `early_stop`, stops once `target_accuracy` is reached *and*
    /// at least one later snapshot confirms it.
    pub fn run(mut self, early_stop: bool) -> RunResult {
        let rounds = self.cfg.rounds;
        let every = self.cfg.eval_every.max(1);
        let mut hits = 0;
        for t in 1..=rounds {
            self.step();
            if t % every == 0 || t == rounds {
                let rec = self.evaluate();
                if early_stop && rec.avg_accuracy >= self.cfg.target_accuracy
                {
                    hits += 1;
                    if hits >= 2 {
                        break;
                    }
                }
            }
        }
        self.into_result()
    }

    /// Immutable access to collected metrics (tests, mid-run probes).
    pub fn result(&self) -> &RunResult {
        self.observers.result()
    }

    /// Finish: hand back the recorded metrics.
    pub fn into_result(self) -> RunResult {
        self.observers.into_result()
    }
}
