//! Virtual-clock execution backend (paper §VI).
//!
//! Drives Alg. 1 end to end over the edge-network substrate: each round
//! the engine applies the scenario timeline (worker churn, failures,
//! environment shifts — [`crate::scenario`]), snapshots the *present*
//! workers into a compacted [`SchedView`], asks the configured
//! [`Scheduler`](crate::coordinator::Scheduler) for a [`RoundPlan`],
//! executes the plan (pull-aggregate-train per Eqs. 3–5, *real* training
//! through the configured trainer), advances the virtual clock by the
//! realised round duration H_t (Eqs. 7–9), and updates staleness (Eq. 6)
//! and the Lyapunov queues (Eq. 33).
//!
//! # Dynamic populations
//!
//! Scenario events apply at the *start* of a round, before edge dynamics
//! and scheduling. Membership lives on the [`EdgeNetwork`] as a
//! query-time mask; the engine builds the scheduler's view over present
//! workers only (dense indices) and remaps the returned plan back to
//! global ids, so schedulers carry no membership logic. While absent, a
//! worker's staleness keeps advancing (its model *is* getting stale) but
//! its queue and residual compute freeze; on `Rejoin` it resumes from
//! its stale parameters, on `Join` the slot restarts fresh.
//!
//! # Parallel round execution
//!
//! Activated workers are independent within a round — each aggregates a
//! pre-round snapshot and trains its own model — so the engine fans the
//! per-activation work (realised transfer times + aggregate + train)
//! across a hand-rolled [`std::thread::scope`] worker pool. Determinism
//! is preserved by construction, not by locking:
//!
//! * every activation draws from its own RNG stream keyed purely by
//!   `(seed, round, worker)` ([`Pcg::activation_stream`]), so no thread
//!   interleaving can reorder draws;
//! * tasks only read the shared pre-round state; results are applied
//!   sequentially in plan order, so every float reduction (`H_t` max,
//!   mean loss) happens in a fixed order;
//! * scenario events apply on the coordinator, never inside tasks.
//!
//! A run is therefore **bit-identical for every `run.threads` setting**
//! — with or without an active scenario or a stateful transport codec —
//! including the sequential fallback used when the trainer cannot be
//! cloned across threads (PJRT executables).
//!
//! # Transport
//!
//! Every model exchange routes through [`crate::transport`]: pull
//! sources are encoded on the coordinator (ascending id) before the
//! round's tasks spawn, push sources after training in plan order, and
//! receivers aggregate the decoded reconstructions. Realised transfer
//! times and the byte ledger (`RoundRecord::bytes_sent`) consume the
//! codec's *encoded* message size, so compression composes with
//! `BandwidthShift`/`MobilityBurst` channel dynamics. The default
//! `dense` codec is the stateless identity — bit-identical semantics
//! and byte accounting to the pre-transport engine.
//!
//! # Delivery
//!
//! Every pull edge additionally resolves through the reliable delivery
//! layer ([`crate::delivery`]): the per-link fault model decides loss /
//! duplication / CRC-detected corruption / latency spikes, and the
//! ack/retry protocol either delivers within the retry budget
//! (retransmissions charged real measured bytes) or dead-letters the
//! edge — the receiver degrades gracefully, aggregating whatever
//! arrived, while the wasted retry window still bounds H_t. Outcomes
//! are pure functions of `(seed, round, from, to)` on a dedicated RNG
//! stream, so thread count and dispatch order cannot perturb them, and
//! the default `faults.profile=clean` is knob-inert (every edge
//! resolves to the lossless identity without touching an RNG).
//!
//! # The discrete-event core (`run.engine=event`)
//!
//! The same engine runs as a **round-barrier event simulator**: per
//! round it pays only for what changed, not for N.
//!
//! * **Cached scheduler view.** The dense per-present-worker arrays
//!   (τ, queues, h_cmp, h_est, budgets, data sizes, candidate lists,
//!   worst expected transfer) persist across rounds and are patched in
//!   place at the round barrier for touched workers. A full rebuild
//!   happens only when something the view derives from moved:
//!   membership or environment scenario events, mobility, link fading,
//!   or budget jitter. Under a static geometry a round costs
//!   O(activations + pull edges + present) instead of O(N·degree).
//! * **Event queue.** Activation completions (and dead-letter retry
//!   timeouts) go through a deterministic binary-heap
//!   [`EventQueue`](super::events::EventQueue); the last completion
//!   popped is the realised H_t (Eq. 9) — bit-identical to the dense
//!   fold-max. Evaluation boundaries are scheduled up-front on a second
//!   queue and popped as rounds pass them.
//! * **Lazy absent workers.** While a worker is absent its slot is
//!   never touched; the staleness it would have accrued (one
//!   `on_skipped` per absent round — pure integer arithmetic) is
//!   reconstructed at `Rejoin` from the recorded leave round. Queue and
//!   residual freeze exactly as the dense engine freezes them.
//! * **Sparse pull ledger.** Eq. 47's pull history lives in a hash map
//!   keyed by `(puller, source)` instead of an N×N matrix (8 TB at
//!   N=1M), with identical counts.
//!
//! Every seeded run is **bit-identical across `run.engine`** (and
//! thread count); the cross-engine equivalence suite pins dense ≡ event
//! across scenarios, faults, codecs and adversaries.

use super::events::{EventQueue, SimEvent};
use super::observer::{ObserverChain, RunRecorder};
use super::{Backend, Experiment, ExperimentError};
use crate::adversary::{Adversary, Aggregator};
use crate::config::{AdversaryConfig, EngineKind, ExperimentConfig};
use crate::coordinator::{
    PullLedger, RoundPlan, SchedView, Scheduler, SchedulerParams,
};
use crate::data::Dataset;
use crate::delivery::{Delivery, DeliveryTally};
use crate::metrics::{
    ActivationRecord, EvalRecord, EventRecord, RoundRecord, RunResult,
};
use crate::network::EdgeNetwork;
use crate::scenario::{Scenario, ScenarioEvent};
use crate::transport::Transport;
use crate::util::rng::Pcg;
use crate::worker::{data_size_weights_into, Params, Trainer, WorkerState};
use std::thread;

/// Virtual-clock [`Backend`]: deterministic, parallel, fast — the
/// harness behind every figure and the large-scale sweeps.
pub struct VirtualClockBackend {
    early_stop: bool,
}

impl VirtualClockBackend {
    /// Early-stops once `target_accuracy` holds for two consecutive
    /// snapshots (the CLI `train` behaviour).
    pub fn new() -> Self {
        VirtualClockBackend { early_stop: true }
    }

    /// Never early-stops: full curves for figures.
    pub fn full_curves() -> Self {
        VirtualClockBackend { early_stop: false }
    }
}

impl Default for VirtualClockBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for VirtualClockBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run(&mut self, exp: Experiment) -> Result<RunResult, ExperimentError> {
        VirtualClockEngine::new(exp).run(self.early_stop)
    }
}

/// Reusable per-activation aggregation scratch — one per pool slot (and
/// one for the sequential path) so the aggregation path stops allocating
/// (the one exception: the short-lived `Vec<&[f32]>` of model refs,
/// which cannot live in scratch without self-referential lifetimes).
struct ActScratch {
    srcs: Vec<usize>,
    sizes: Vec<usize>,
    weights: Vec<f32>,
    agg: Params,
    /// The configured aggregation rule (`mean` delegates to the trainer
    /// — the bit-identical pre-adversary path).
    aggregator: Aggregator,
}

impl ActScratch {
    fn new(cfg: &AdversaryConfig) -> Self {
        ActScratch {
            srcs: Vec::new(),
            sizes: Vec::new(),
            weights: Vec::new(),
            agg: Params::new(),
            aggregator: Aggregator::from_config(cfg),
        }
    }
}

/// One slot of the hand-rolled worker pool: a cloned trainer plus its
/// scratch, kept across rounds so thread-local state is reused.
struct WorkerSlot {
    trainer: Box<dyn Trainer + Send>,
    scratch: ActScratch,
}

/// Shared read-only view of the pre-round state handed to every
/// activation task. All worker indices here are global ids.
struct RoundCtx<'a> {
    cfg: &'a ExperimentConfig,
    net: &'a EdgeNetwork,
    workers: &'a [WorkerState],
    inbox: &'a [Vec<(usize, Params)>],
    plan: &'a RoundPlan,
    /// Transport layer (read-only here): pulled models are read through
    /// its per-sender reconstruction; encode happened on the
    /// coordinator before the tasks were spawned.
    transport: &'a Transport,
    /// Adversary layer (read-only here): pulled models route through
    /// its exchange view; `transmit` happened on the coordinator before
    /// the tasks were spawned.
    adversary: &'a Adversary,
    /// Delivery layer (stateless): each pull edge's fate is a pure
    /// function of `(seed, round, from, to)`, so tasks resolve without
    /// coordination and any dispatch order yields the same ledger.
    delivery: &'a Delivery,
    /// Wire size of one encoded message, bits — what every realized
    /// transfer time consumes. Equals `model_bits` under `dense`.
    wire_bits: f64,
    round: usize,
    /// Wall-clock telemetry (write-only; never read back into the
    /// simulation, so virtual-time results stay bit-identical).
    tel: &'a crate::telemetry::Telemetry,
}

/// Output of one activation task (`k` indexes `plan.active`).
struct ActOut {
    k: usize,
    duration_s: f64,
    params: Params,
    loss: f64,
    /// This activation's delivery ledger (its pull edges only), folded
    /// into the round tally on the coordinator in plan order.
    tally: DeliveryTally,
    /// Pull senders whose retry budget exhausted: the receiver
    /// aggregated without them (empty under the clean profile).
    dead: Vec<usize>,
    /// Phase decomposition of `duration_s` for the activation trace:
    /// local training, fault-free transfer, and delivery-layer retry
    /// overhead (`duration_s = compute_s + transfer_s + retry_s`).
    compute_s: f64,
    transfer_s: f64,
    retry_s: f64,
}

/// Execute one activation: realised pull/push transfer times (Eqs. 7–9),
/// aggregate (Eq. 4) over self + pulls + inbox, then local training
/// (Eq. 5) — all on the activation's private RNG stream.
fn run_activation(
    trainer: &mut dyn Trainer,
    scr: &mut ActScratch,
    ctx: &RoundCtx<'_>,
    k: usize,
) -> ActOut {
    let i = ctx.plan.active[k];
    let mut rng = Pcg::activation_stream(
        ctx.cfg.seed,
        ctx.round as u64,
        i as u64,
    );
    // --- realised round duration (Eqs. 7–9) ---
    // pulls beyond the radio's orthogonal channels serialize: K transfers
    // take ⌈K/channels⌉ slots of the worst link time. Each pull edge
    // also resolves through the delivery layer: retries and backoff
    // stretch its realised time, and a dead-lettered edge still bounds
    // the round (the receiver waited out the retry budget) even though
    // its payload never arrives.
    let channels = ctx.cfg.network.channels.max(1);
    let mut tally = DeliveryTally::default();
    let mut dead: Vec<usize> = Vec::new();
    let mut worst_pull = 0.0f64;
    let mut worst_pull_base = 0.0f64;
    for &j in &ctx.plan.pulls_from[k] {
        let base = ctx.net.transfer_time_s(j, i, ctx.wire_bits, &mut rng);
        let out = ctx.delivery.resolve(ctx.round as u64, j, i);
        tally.add(&out);
        if !out.delivered {
            dead.push(j);
        }
        worst_pull_base = worst_pull_base.max(base);
        worst_pull = worst_pull.max(out.time_s(base));
    }
    let pull_slots = ctx.plan.pulls_from[k].len().div_ceil(channels);
    // pushes originating at i (SA-ADFL's send-to-all) also occupy its
    // radio, serialized the same way
    let mut worst_push = 0.0f64;
    let mut n_push = 0usize;
    for &(from, to) in &ctx.plan.pushes {
        if from == i {
            worst_push = worst_push
                .max(ctx.net.transfer_time_s(i, to, ctx.wire_bits, &mut rng));
            n_push += 1;
        }
    }
    let push_slots = n_push.div_ceil(channels);
    let duration_s = ctx.workers[i].residual_s
        + worst_pull * pull_slots as f64
        + worst_push * push_slots as f64;
    // phase decomposition for the activation trace: fault-free
    // transfer vs the extra time the delivery layer's retries/backoff
    // added (zero under the clean profile, where the sum reproduces
    // `duration_s` exactly; lossy profiles match up to FP rounding)
    let compute_s = ctx.workers[i].residual_s;
    let transfer_s = worst_pull_base * pull_slots as f64
        + worst_push * push_slots as f64;
    let retry_s = (worst_pull - worst_pull_base) * pull_slots as f64;

    // --- aggregate (Eq. 4) over the pre-round snapshot ---
    // graceful degradation: dead-lettered senders never arrived, so
    // they are excluded here — but their *older* pushed models already
    // sitting in the inbox still participate below (the receiver
    // aggregates whatever it has, exactly the staleness semantics)
    scr.srcs.clear();
    scr.srcs.push(i);
    scr.srcs.extend(
        ctx.plan.pulls_from[k]
            .iter()
            .copied()
            .filter(|j| !dead.contains(j)),
    );
    // own model is local (never transmitted); pulled neighbors arrive
    // through the transport layer — the receiver aggregates the codec
    // reconstruction, which under `dense` is the sender's exact params
    // — routed through the adversary's exchange view (under a non-dense
    // codec the attacked payload was already encoded, so the view
    // passes the reconstruction through)
    let dense = ctx.transport.is_dense();
    let t = ctx.tel.tick();
    let mut models: Vec<&[f32]> = Vec::with_capacity(scr.srcs.len());
    models.push(ctx.workers[i].params.as_slice());
    models.extend(scr.srcs[1..].iter().map(|&j| {
        ctx.adversary.exchange_view(
            j,
            ctx.transport.view(j, &ctx.workers[j].params),
            dense,
        )
    }));
    ctx.tel
        .tock(crate::telemetry::Phase::CodecDecode, t);
    ctx.tel.add(
        crate::telemetry::Counter::CodecDecodes,
        (scr.srcs.len() - 1) as u64,
    );
    scr.sizes.clear();
    scr.sizes
        .extend(scr.srcs.iter().map(|&j| ctx.workers[j].data_size()));
    // pushed models waiting in the inbox join the aggregation (skipping
    // senders we just pulled fresh models from)
    for (from, params) in &ctx.inbox[i] {
        if !scr.srcs.contains(from) {
            models.push(params.as_slice());
            scr.sizes.push(ctx.workers[*from].data_size());
        }
    }
    data_size_weights_into(&scr.sizes, &mut scr.weights);
    let t = ctx.tel.tick();
    scr.aggregator
        .aggregate_into(trainer, &models, &scr.weights, &mut scr.agg);
    ctx.tel.tock(crate::telemetry::Phase::Aggregate, t);

    // --- local training (Eq. 5) ---
    let t = ctx.tel.tick();
    let (params, loss) = trainer.train(
        &scr.agg,
        &ctx.workers[i].shard,
        ctx.cfg.local_steps,
        ctx.cfg.batch,
        ctx.cfg.lr,
        &mut rng,
    );
    ctx.tel.tock(crate::telemetry::Phase::Train, t);
    ctx.tel.inc(crate::telemetry::Counter::Activations);
    ctx.tel.add(
        crate::telemetry::Counter::TrainSamples,
        (ctx.cfg.local_steps * ctx.cfg.batch) as u64,
    );
    ActOut { k, duration_s, params, loss, tally, dead, compute_s, transfer_s, retry_s }
}

/// Estimated per-present-worker round cost H_t^i (Eq. 8): residual
/// compute plus the worst expected pull transfer over its (≤ s nearest)
/// candidates. `candidates` holds dense indices; `ids` maps them back to
/// global ids for the physical network.
///
/// Fills two aligned outputs: `worst_tx[k]` (the geometry-dependent
/// transfer half — a pure function of positions, tx powers and the wire
/// size, so the event core caches it across static rounds) and
/// `h_est[k] = residual + worst_tx[k]` (the sum the scheduler sees).
///
/// `residual_of` maps a *global* worker id to its residual compute
/// time; taking a closure (instead of `&[WorkerState]`) lets the
/// socket backend share this estimator verbatim — its plan state lives
/// in mirror arrays, not `WorkerState`s — which is what keeps its
/// `h_est` (and therefore its plans) bit-identical to this engine's.
pub(crate) fn estimate_h_into(
    net: &EdgeNetwork,
    residual_of: impl Fn(usize) -> f64,
    ids: &[usize],
    candidates: &[Vec<usize>],
    wire_bits: f64,
    s: usize,
    near: &mut Vec<usize>,
    worst_tx: &mut Vec<f64>,
    h_est: &mut Vec<f64>,
) {
    worst_tx.clear();
    h_est.clear();
    for k in 0..ids.len() {
        let gi = ids[k];
        // PTCA will pick ≤ s in-neighbors; estimate with the s
        // *nearest* candidates (best case the coordinator can
        // predict without knowing the realised priorities).
        let cand = &candidates[k];
        let nearest: &[usize] = if cand.len() > s {
            // only the s nearest matter — select into a reused
            // index buffer instead of clone + full sort
            near.clear();
            near.extend_from_slice(cand);
            near.select_nth_unstable_by(s - 1, |&a, &b| {
                net.distance(gi, ids[a])
                    .total_cmp(&net.distance(gi, ids[b]))
            });
            &near[..s]
        } else {
            cand
        };
        let worst = nearest
            .iter()
            .map(|&j| net.expected_transfer_time_s(ids[j], gi, wire_bits))
            .fold(0.0f64, f64::max);
        worst_tx.push(worst);
        h_est.push(residual_of(gi) + worst);
    }
}

/// The assembled simulation engine. Public so callers that need
/// fine-grained control (benches stepping round by round, tests probing
/// mid-run state) can drive it manually; everyone else goes through
/// [`VirtualClockBackend`].
pub struct VirtualClockEngine {
    pub cfg: ExperimentConfig,
    pub net: EdgeNetwork,
    pub workers: Vec<WorkerState>,
    pub test: Dataset,
    trainer: Box<dyn Trainer>,
    scheduler: Box<dyn Scheduler>,
    /// The event timeline applied at round boundaries.
    scenario: Scenario,
    /// Times worker i pulled from j (Eq. 47's history) — dense matrix
    /// under the dense engine, sparse hash map under the event engine.
    pulls: PullLedger,
    /// Pushed-model inboxes: models received via PUSH wait here until the
    /// receiver's next activation (SA-ADFL semantics — receivers don't
    /// interrupt training to merge).
    inbox: Vec<Vec<(usize, Params)>>,
    /// Retired parameter buffers, recycled for future inbox pushes so
    /// push delivery never allocates in steady state.
    inbox_free: Vec<Params>,
    clock_s: f64,
    round: usize,
    cum_transfers: usize,
    rng: Pcg,
    observers: ObserverChain,
    /// Precomputed label distributions per worker (static shards).
    label_dist: Vec<Vec<f64>>,
    model_bits: f64,
    /// Model-transport layer: every pull/push is encoded through it and
    /// realized transfer times consume its encoded message size.
    transport: Transport,
    /// Adversary layer: every outgoing payload routes through its
    /// coordinator-side `transmit` before the codec encodes it.
    adversary: Adversary,
    /// Reliable delivery layer: stateless per-edge fault resolution.
    delivery: Delivery,
    /// Per-round delivery ledger (includes scenario-crash in-flight
    /// drops), flushed into each [`RoundRecord`] and re-zeroed.
    tally: DeliveryTally,
    /// Cached `transport.message_bits()` (== `model_bits` under dense).
    wire_bits: f64,
    /// Cumulative measured wire bytes (transport layer).
    cum_bytes: f64,
    /// Scratch: unique pull sources of the current plan (ascending).
    pull_srcs: Vec<usize>,
    /// Scratch: push sources already encoded this round (plan order).
    push_enc: Vec<usize>,
    /// Worker pool for parallel round execution; empty ⇒ sequential
    /// (run.threads=1, or the trainer cannot be cloned across threads).
    slots: Vec<WorkerSlot>,
    /// Scratch for the sequential path.
    scratch: ActScratch,
    /// Dense→global map over present workers, rebuilt each round.
    ids: Vec<usize>,
    /// Global→dense inverse (usize::MAX for absent workers).
    gdx: Vec<usize>,
    /// Reusable dense candidate-list buffers (one per present worker).
    cand_buf: Vec<Vec<usize>>,
    /// Scratch for `EdgeNetwork::in_range_into`.
    range_buf: Vec<usize>,
    /// Reusable per-round buffers.
    active_mask: Vec<bool>,
    losses: Vec<f64>,
    near: Vec<usize>,
    /// Discrete-event core enabled (`run.engine=event`).
    event_mode: bool,
    /// Did this round's boundary apply any scenario event (population
    /// or environment)? Forces a view rebuild in event mode.
    events_applied: bool,
    /// Intra-round completion events; the last one popped is H_t.
    equeue: EventQueue,
    /// Inter-round schedule (evaluation boundaries), filled up-front.
    schedule: EventQueue,
    /// Round at which each currently-absent worker left (event mode):
    /// `Rejoin` reconstructs the staleness the dense engine would have
    /// accrued one `on_skipped` at a time.
    left_at: Vec<usize>,
    /// Workers whose `active_mask` bit is currently set — cleared
    /// per-entry instead of an O(N) fill.
    prev_active: Vec<usize>,
    // Cached scheduler-view arrays, aligned with `ids`. The dense
    // engine regathers them every round; the event engine patches them
    // at the round barrier and rebuilds only when geometry, membership,
    // link state or budgets moved.
    view_tau: Vec<u64>,
    view_queues: Vec<f64>,
    view_h_cmp: Vec<f64>,
    view_h_est: Vec<f64>,
    view_data_sizes: Vec<usize>,
    view_budgets: Vec<f64>,
    /// Worst expected pull-transfer time per present worker (the
    /// geometry half of Eq. 8) — valid while positions, membership and
    /// the wire size are static, so cached rounds recompute `h_est` as
    /// one addition per present worker.
    worst_tx: Vec<f64>,
    /// Wall-clock self-profiling registry. Strictly write-only from the
    /// engine: nothing the simulation computes ever reads it, so a
    /// telemetry-on run is bit-identical to telemetry-off (pinned by
    /// the inertness witnesses in `tests/telemetry.rs`).
    tel: crate::telemetry::Telemetry,
}

impl VirtualClockEngine {
    /// Assemble the engine around a built [`Experiment`].
    pub fn new(exp: Experiment) -> Self {
        let n = exp.cfg.workers;
        let event_mode = exp.cfg.engine == EngineKind::Event;
        let recorder = RunRecorder::with_window(
            exp.scheduler.name(),
            exp.model_bits,
            exp.cfg.metrics.window,
        );
        let requested = match exp.cfg.threads {
            0 => thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1),
            t => t,
        }
        // at most n activations can run concurrently — don't build
        // trainer clones that could never be used
        .min(n.max(1));
        let mut slots = Vec::new();
        if requested > 1 {
            for _ in 0..requested {
                match exp.trainer.clone_box() {
                    Some(t) => slots.push(WorkerSlot {
                        trainer: t,
                        scratch: ActScratch::new(&exp.cfg.adversary),
                    }),
                    None => {
                        // non-cloneable trainer: stay sequential
                        slots.clear();
                        break;
                    }
                }
            }
        }
        let wire_bits = exp.transport.message_bits();
        let scratch = ActScratch::new(&exp.cfg.adversary);
        VirtualClockEngine {
            observers: ObserverChain::new(recorder, exp.observers),
            cfg: exp.cfg,
            net: exp.net,
            workers: exp.workers,
            test: exp.test,
            trainer: exp.trainer,
            scheduler: exp.scheduler,
            scenario: exp.scenario,
            transport: exp.transport,
            adversary: exp.adversary,
            delivery: exp.delivery,
            tally: DeliveryTally::default(),
            wire_bits,
            cum_bytes: 0.0,
            pull_srcs: Vec::new(),
            push_enc: Vec::new(),
            // the event core never materialises the N×N pull matrix
            pulls: if event_mode {
                PullLedger::sparse()
            } else {
                PullLedger::dense(n)
            },
            inbox: vec![Vec::new(); n],
            inbox_free: Vec::new(),
            clock_s: 0.0,
            round: 0,
            cum_transfers: 0,
            rng: exp.rng,
            label_dist: exp.label_dist,
            model_bits: exp.model_bits,
            slots,
            scratch,
            ids: (0..n).collect(),
            gdx: (0..n).collect(),
            cand_buf: Vec::new(),
            range_buf: Vec::new(),
            active_mask: vec![false; n],
            losses: Vec::new(),
            near: Vec::new(),
            event_mode,
            events_applied: false,
            equeue: EventQueue::new(),
            schedule: EventQueue::new(),
            left_at: vec![0; n],
            prev_active: Vec::new(),
            view_tau: Vec::new(),
            view_queues: Vec::new(),
            view_h_cmp: Vec::new(),
            view_h_est: Vec::new(),
            view_data_sizes: Vec::new(),
            view_budgets: Vec::new(),
            worst_tx: Vec::new(),
            tel: exp.telemetry,
        }
    }

    pub fn clock_s(&self) -> f64 {
        self.clock_s
    }

    /// Resolved worker-pool width (1 = sequential execution).
    pub fn threads(&self) -> usize {
        self.slots.len().max(1)
    }

    /// Present workers after the last applied round boundary.
    pub fn population(&self) -> usize {
        self.ids.len()
    }

    /// Dense→global map of the present workers (ascending global ids).
    pub fn present_ids(&self) -> &[usize] {
        &self.ids
    }

    /// Apply this round's scenario events through the shared skeleton
    /// ([`crate::scenario::apply_round_events`] owns the guards and
    /// membership flips); the hook below is this engine's bookkeeping:
    /// inbox garbage collection and worker-state resets.
    fn apply_scenario_events(&mut self) {
        let round = self.round;
        // split disjoint field borrows for the two closures
        let scenario = &self.scenario;
        let net = &mut self.net;
        let workers = &mut self.workers;
        let inbox = &mut self.inbox;
        let inbox_free = &mut self.inbox_free;
        let pulls = &mut self.pulls;
        let trainer = &self.trainer;
        let transport = &mut self.transport;
        let tally = &mut self.tally;
        let left_at = &mut self.left_at;
        let lazy = self.event_mode;
        let seed = self.cfg.seed;
        let observers = &mut self.observers;
        let mut any = false;
        crate::scenario::apply_round_events(
            scenario,
            round,
            net,
            |ev| {
                any = true;
                match *ev {
                    ScenarioEvent::Leave { worker } => {
                        if lazy {
                            left_at[worker] = round;
                        }
                        // the departed worker's pending aggregation
                        // inputs are garbage-collected
                        for (_, buf) in inbox[worker].drain(..) {
                            inbox_free.push(buf);
                        }
                    }
                    ScenarioEvent::Crash { worker } => {
                        if lazy {
                            left_at[worker] = round;
                        }
                        for (_, buf) in inbox[worker].drain(..) {
                            inbox_free.push(buf);
                        }
                        // crash = no notice: its in-flight models (pushes
                        // already delivered but not merged) drop everywhere
                        // — routed through the delivery ledger so the loss
                        // lands in this round's `dropped_msgs`
                        for ib in inbox.iter_mut() {
                            if let Some(pos) =
                                ib.iter().position(|(f, _)| *f == worker)
                            {
                                let (_, buf) = ib.swap_remove(pos);
                                inbox_free.push(buf);
                                tally.crash_dropped += 1;
                            }
                        }
                    }
                    ScenarioEvent::Join { worker } => {
                        // fresh device on this slot: params re-initialised
                        // with the slot's builder seed, bookkeeping reset
                        let w = &mut workers[worker];
                        w.params =
                            trainer.init(seed.wrapping_add(worker as u64));
                        w.staleness = 0;
                        w.queue = 0.0;
                        w.residual_s = w.h_train_s;
                        w.last_loss = f64::NAN;
                        pulls.reset_worker(worker);
                        // receivers hold no transmission history for the
                        // fresh device — codec reconstruction restarts
                        transport.reset_worker(worker);
                    }
                    ScenarioEvent::Rejoin { worker } => {
                        // stale params and accumulated τ kept; the device
                        // restarts its local training job from scratch
                        let w = &mut workers[worker];
                        if lazy {
                            // catch up the staleness the dense engine
                            // accrued one `on_skipped` per absent round
                            // (rounds left_at .. round-1) — pure integer
                            // arithmetic, so lazy == eager exactly
                            w.staleness += (round - left_at[worker]) as u64;
                        }
                        w.residual_s = w.h_train_s;
                    }
                    _ => {}
                }
            },
            |rec| observers.scenario_event(&rec),
        );
        self.events_applied = any;
    }

    /// Rebuild the cached scheduler view from scratch: dense maps,
    /// candidate lists, and every per-present-worker array. The dense
    /// engine runs this each round; the event engine only when the
    /// round boundary invalidated the cache.
    fn rebuild_view(&mut self) {
        crate::scenario::rebuild_dense_maps(
            &self.net,
            &mut self.ids,
            &mut self.gdx,
        );
        let p = self.ids.len();
        crate::scenario::build_dense_candidates(
            &self.net,
            &self.ids,
            &self.gdx,
            &mut self.range_buf,
            &mut self.cand_buf,
        );
        self.view_h_cmp.clear();
        self.view_h_cmp
            .extend(self.ids.iter().map(|&i| self.workers[i].residual_s));
        let workers = &self.workers;
        estimate_h_into(
            &self.net,
            |gi| workers[gi].residual_s,
            &self.ids,
            &self.cand_buf[..p],
            self.wire_bits,
            self.cfg.neighbor_cap,
            &mut self.near,
            &mut self.worst_tx,
            &mut self.view_h_est,
        );
        self.view_tau.clear();
        self.view_tau
            .extend(self.ids.iter().map(|&i| self.workers[i].staleness));
        self.view_queues.clear();
        self.view_queues
            .extend(self.ids.iter().map(|&i| self.workers[i].queue));
        self.view_data_sizes.clear();
        self.view_data_sizes
            .extend(self.ids.iter().map(|&i| self.workers[i].data_size()));
        self.view_budgets.clear();
        self.view_budgets
            .extend(self.ids.iter().map(|&i| self.net.budgets[i]));
    }

    /// Run one round of Alg. 1; returns the realised plan (global ids).
    pub fn step(&mut self) -> RoundPlan {
        let t_round = self.tel.tick();
        self.round += 1;
        self.apply_scenario_events();
        self.net
            .advance_round(self.cfg.seed, self.round as u64);
        // The cached view survives the boundary only when nothing it
        // derives from moved: membership/environment events, mobility,
        // per-round link fading, or budget jitter. The dense engine
        // rebuilds unconditionally — same values either way, so the
        // two engines stay bit-identical.
        let cached_ok = self.event_mode
            && self.round > 1
            && !self.events_applied
            && self.net.effective_mobility() == 0.0
            && !self.net.link_drops_active()
            && self.cfg.network.budget_jitter == 0.0;
        if !cached_ok {
            let t = self.tel.tick();
            self.rebuild_view();
            self.tel
                .tock(crate::telemetry::Phase::ViewRebuild, t);
            self.tel
                .inc(crate::telemetry::Counter::SchedViewRebuilds);
        } else {
            self.tel
                .inc(crate::telemetry::Counter::SchedViewPatches);
        }
        let p = self.ids.len();

        let mut plan = {
            let view = SchedView {
                round: self.round,
                tau: &self.view_tau,
                queues: &self.view_queues,
                h_cmp: &self.view_h_cmp,
                h_est: &self.view_h_est,
                data_sizes: &self.view_data_sizes,
                ids: &self.ids,
                label_dist: &self.label_dist,
                candidates: &self.cand_buf[..p],
                budgets: &self.view_budgets,
                pulls: &self.pulls,
                net: &self.net,
                params: SchedulerParams::from(&self.cfg),
            };
            self.scheduler.plan(&view, &mut self.rng)
        };
        // schedulers plan in dense indices — remap to global worker ids
        // (identity when everyone is present)
        crate::scenario::remap_plan_to_global(&mut plan, &self.ids);
        debug_assert!(plan
            .validate_present(self.net.present_mask())
            .is_ok());
        self.observers.plan(self.round, &plan);

        self.execute(&plan);
        if self.tel.is_enabled() {
            use crate::telemetry::{Counter, Gauge, Phase};
            self.tel.inc(Counter::Rounds);
            let secs = self.tel.elapsed_s(t_round);
            if secs > 0.0 {
                let samples = plan.active.len()
                    * self.cfg.local_steps
                    * self.cfg.batch;
                self.tel.set_gauge(
                    Gauge::TrainThroughput,
                    samples as f64 / secs,
                );
            }
            self.tel.set_gauge(Gauge::ClockVirtualS, self.clock_s);
            self.tel
                .set_gauge(Gauge::Population, self.ids.len() as f64);
            self.tel.tock(Phase::Round, t_round);
        }
        plan
    }

    /// Run every activation of the plan: in parallel across the worker
    /// pool when available, sequentially otherwise. Results come back in
    /// plan order either way (tasks are stream-isolated, so the outcome
    /// is identical).
    fn run_activations(&mut self, plan: &RoundPlan) -> Vec<ActOut> {
        let n_act = plan.active.len();
        let ctx = RoundCtx {
            cfg: &self.cfg,
            net: &self.net,
            workers: &self.workers,
            inbox: &self.inbox,
            plan,
            transport: &self.transport,
            adversary: &self.adversary,
            delivery: &self.delivery,
            wire_bits: self.wire_bits,
            round: self.round,
            tel: &self.tel,
        };
        let mut outs: Vec<ActOut> = Vec::with_capacity(n_act);
        if self.slots.len() > 1 && n_act > 1 {
            let pool = self.slots.len().min(n_act);
            let slots = &mut self.slots[..pool];
            let ctx = &ctx;
            let parts: Vec<Vec<ActOut>> = thread::scope(|s| {
                let handles: Vec<_> = slots
                    .iter_mut()
                    .enumerate()
                    .map(|(si, slot)| {
                        s.spawn(move || {
                            let mut part = Vec::new();
                            let mut k = si;
                            while k < n_act {
                                part.push(run_activation(
                                    slot.trainer.as_mut(),
                                    &mut slot.scratch,
                                    ctx,
                                    k,
                                ));
                                k += pool;
                            }
                            part
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("round worker thread panicked"))
                    .collect()
            });
            for part in parts {
                outs.extend(part);
            }
            outs.sort_unstable_by_key(|o| o.k);
        } else {
            for k in 0..n_act {
                outs.push(run_activation(
                    self.trainer.as_mut(),
                    &mut self.scratch,
                    &ctx,
                    k,
                ));
            }
        }
        outs
    }

    /// Execute a round plan: aggregate + train the active workers,
    /// advance the clock, update staleness/queues/ledgers.
    fn execute(&mut self, plan: &RoundPlan) {
        let n = self.workers.len();

        // --- transport: encode this round's pull transmissions ---
        // each pull source broadcasts one encoded message of its
        // pre-round model; encoding mutates codec state, so it happens
        // here on the coordinator in a fixed order (ascending sender id)
        // before any task reads the reconstructions. Dense is stateless
        // — the hot path is untouched. With an active adversary every
        // outgoing payload first routes through `transmit` (same fixed
        // order), so codecs encode — and byte accounting measures — the
        // *attacked* parameters.
        let adv_active = self.adversary.is_active();
        if !self.transport.is_dense() || adv_active {
            crate::transport::unique_pull_sources(
                &plan.pulls_from,
                &mut self.pull_srcs,
            );
            let t = self.tel.tick();
            let mut encoded = 0u64;
            let transport = &mut self.transport;
            let adversary = &mut self.adversary;
            let workers = &self.workers;
            for &j in &self.pull_srcs {
                let payload: &[f32] = if adv_active {
                    adversary.transmit(j, &workers[j].params)
                } else {
                    &workers[j].params
                };
                if !transport.is_dense() {
                    transport.encode(j, payload);
                    encoded += 1;
                }
            }
            self.tel
                .tock(crate::telemetry::Phase::CodecEncode, t);
            self.tel
                .add(crate::telemetry::Counter::CodecEncodes, encoded);
            self.tel.add(
                crate::telemetry::Counter::CodecBytes,
                (encoded as f64 * self.transport.message_bytes()) as u64,
            );
        }

        let outs = self.run_activations(plan);

        // --- apply results in plan order (fixed reduction order) ---
        // The realised H_t (Eq. 9). The event core routes completions
        // through the deterministic event queue and takes the last one
        // popped; for finite non-negative durations that is the same
        // bits as the dense fold-max.
        let mut h_round = if self.event_mode {
            let mut depth = 0u64;
            for o in &outs {
                let i = plan.active[o.k];
                for &j in &o.dead {
                    // the receiver waited out the retry budget until
                    // its round work ended
                    self.equeue.push(
                        o.duration_s,
                        SimEvent::RetryTimeout { from: j, to: i },
                    );
                    depth += 1;
                }
                self.equeue
                    .push(o.duration_s, SimEvent::ActivationDone { worker: i });
                depth += 1;
            }
            let t = self.tel.tick();
            let h = self.equeue.drain_last_time().unwrap_or(0.0);
            if self.tel.is_enabled() {
                use crate::telemetry::{Counter, Gauge, Phase};
                self.tel.tock(Phase::EventDrain, t);
                self.tel.set_gauge(Gauge::EventQueueDepth, depth as f64);
                self.tel.add(Counter::EventsDrained, depth);
                let secs = self.tel.elapsed_s(t);
                if secs > 0.0 {
                    self.tel
                        .set_gauge(Gauge::EventDrainRate, depth as f64 / secs);
                }
            }
            h
        } else {
            outs.iter().fold(0.0f64, |a, o| a.max(o.duration_s))
        };
        if plan.active.is_empty() {
            h_round = 0.01; // avoid stalling the clock
        }
        self.losses.clear();
        for o in outs {
            let i = plan.active[o.k];
            // activation trace (plan order, before the clock advances:
            // `start_s` is the round-start clock)
            self.observers.activation(&ActivationRecord {
                round: self.round,
                worker: i,
                start_s: self.clock_s,
                compute_s: o.compute_s,
                transfer_s: o.transfer_s,
                retry_s: o.retry_s,
                wait_s: (h_round - o.duration_s).max(0.0),
            });
            // fold the activation's delivery ledger (fixed plan order)
            // and log each dead-lettered edge as a graceful-degradation
            // event on its receiver
            self.tally.merge(&o.tally);
            for _ in &o.dead {
                self.observers.scenario_event(&EventRecord {
                    round: self.round,
                    kind: "dead-letter",
                    worker: Some(i),
                    population: self.ids.len(),
                });
            }
            // recycle the replaced parameter buffer for future pushes
            let old =
                std::mem::replace(&mut self.workers[i].params, o.params);
            self.inbox_free.push(old);
            self.workers[i].last_loss = o.loss;
            self.losses.push(o.loss);
            // pull history stays plan-level: a dead-lettered edge was
            // still attempted (and charged), so PTCA's Eq. 47 history
            // counts it like any other planned pull
            for &j in &plan.pulls_from[o.k] {
                self.pulls.record(i, j);
            }
            // inbox consumed by this aggregation — recycle its buffers
            for (_, buf) in self.inbox[i].drain(..) {
                self.inbox_free.push(buf);
            }
        }

        // --- pushes (SA-ADFL): the updated model lands in each
        // receiver's inbox for *their* next aggregation (latest wins).
        // Non-dense codecs encode the post-training model once per
        // sender (plan order) and deliver the *decoded* reconstruction,
        // so inbox contents are exactly what crossed the wire.
        self.push_enc.clear();
        {
            let transport = &mut self.transport;
            let adversary = &mut self.adversary;
            let workers = &self.workers;
            let inbox = &mut self.inbox;
            let inbox_free = &mut self.inbox_free;
            let push_enc = &mut self.push_enc;
            let dense = transport.is_dense();
            for &(from, to) in &plan.pushes {
                // adversary payloads are (re)computed from the
                // post-training model once per sender, plan order
                if (!dense || adv_active) && !push_enc.contains(&from) {
                    let payload: &[f32] = if adv_active {
                        adversary.transmit(from, &workers[from].params)
                    } else {
                        &workers[from].params
                    };
                    if !dense {
                        transport.encode(from, payload);
                    }
                    push_enc.push(from);
                }
                let mut buf = inbox_free.pop().unwrap_or_default();
                buf.clear();
                buf.extend_from_slice(adversary.exchange_view(
                    from,
                    transport.view(from, &workers[from].params),
                    dense,
                ));
                if let Some(pos) =
                    inbox[to].iter().position(|(f, _)| *f == from)
                {
                    let (_, old) = inbox[to].swap_remove(pos);
                    inbox_free.push(old);
                }
                inbox[to].push((from, buf));
            }
        }
        // every activation retires a buffer but only pushes consume them:
        // cap the free list so pull-only schedulers don't grow it forever
        self.inbox_free.truncate(n);

        // --- adversary bookkeeping (coordinator-side, fixed order) ---
        if self.adversary.has_stale_bombers() {
            // post-round snapshot feeds the stale-bomb replay window
            for i in 0..n {
                self.adversary
                    .record_round_end(i, &self.workers[i].params);
            }
        }
        if adv_active {
            // first transmissions of each attack become log events
            let pop = self.ids.len();
            for (w, kind) in self.adversary.drain_activations() {
                self.observers.scenario_event(&EventRecord {
                    round: self.round,
                    kind,
                    worker: Some(w),
                    population: pop,
                });
            }
        }

        // --- clock + staleness + queues (Eqs. 6, 33) ---
        self.clock_s += h_round;
        // clear last round's mask entries and set this round's — an
        // O(|A_{t-1}| + |A_t|) swap instead of an O(N) fill
        for &i in &self.prev_active {
            self.active_mask[i] = false;
        }
        self.prev_active.clear();
        self.prev_active.extend_from_slice(&plan.active);
        for &i in &plan.active {
            self.active_mask[i] = true;
        }
        let pop = self.ids.len();
        let mut tau_sum = 0.0f64;
        let mut max_tau = 0u64;
        if self.event_mode {
            // Event core: touch only present workers. Absent workers'
            // slots stay frozen — the staleness they accrue is
            // reconstructed at Rejoin from `left_at` (integer
            // arithmetic, so lazy == the dense per-round increments
            // exactly). The τ statistics fold in the same ascending-id
            // order as the dense stats loop, and u64 sums in f64 are
            // exact below 2^53, so the records match bit for bit. The
            // cached view is patched in the same pass: next round's
            // h_est is the identical `residual + worst` addition the
            // dense rebuild would perform (Eq. 8).
            let t = self.tel.tick();
            for k in 0..pop {
                let i = self.ids[k];
                let w = &mut self.workers[i];
                w.advance(h_round);
                if self.active_mask[i] {
                    w.on_activated();
                } else {
                    w.on_skipped();
                }
                w.update_queue(self.cfg.tau_bound);
                let t = w.staleness;
                let q = w.queue;
                let r = w.residual_s;
                tau_sum += t as f64;
                max_tau = max_tau.max(t);
                self.view_tau[k] = t;
                self.view_queues[k] = q;
                self.view_h_cmp[k] = r;
                self.view_h_est[k] = r + self.worst_tx[k];
            }
            self.tel
                .tock(crate::telemetry::Phase::ViewPatch, t);
        } else {
            for i in 0..n {
                let w = &mut self.workers[i];
                if !self.net.is_present(i) {
                    // absent: the model keeps getting stale, but the
                    // queue and the local training job freeze until it
                    // returns
                    w.on_skipped();
                    continue;
                }
                w.advance(h_round);
                if self.active_mask[i] {
                    w.on_activated();
                } else {
                    w.on_skipped();
                }
                w.update_queue(self.cfg.tau_bound);
            }
            for &i in &self.ids {
                let t = self.workers[i].staleness;
                tau_sum += t as f64;
                max_tau = max_tau.max(t);
            }
        }

        // --- metrics (population = present workers) ---
        let transfers = plan.transfers();
        self.cum_transfers += transfers;
        // unicast byte ledger: one encoded message per transfer edge
        // plus every delivery retransmission, all at the codec's
        // measured wire size (clean profile: zero retransmissions —
        // exactly transfers × message_bytes, the old ledger)
        let bytes_sent = (transfers + self.tally.retransmissions) as f64
            * self.transport.message_bytes();
        self.cum_bytes += bytes_sent;
        let avg_tau = tau_sum / pop as f64;
        let train_loss = if self.losses.is_empty() {
            f64::NAN
        } else {
            self.losses.iter().sum::<f64>() / self.losses.len() as f64
        };
        let rec = RoundRecord {
            round: self.round,
            time_s: self.clock_s,
            duration_s: h_round,
            active: plan.active.len(),
            population: pop,
            adversaries: self.adversary.count_present(&self.ids),
            transfers,
            bytes_sent,
            avg_staleness: avg_tau,
            max_staleness: max_tau,
            train_loss,
            retransmissions: self.tally.retransmissions,
            dropped_msgs: self.tally.dropped_msgs(),
            corrupt_detected: self.tally.corrupt,
        };
        self.observers.round_end(&rec);
        if self.tel.is_enabled() {
            use crate::telemetry::Counter;
            self.tel.add(Counter::DeliveryMsgs, transfers as u64);
            self.tel.add(
                Counter::DeliveryRetries,
                self.tally.retransmissions as u64,
            );
            self.tel.add(
                Counter::DeliveryDeadLetters,
                self.tally.dropped_msgs() as u64,
            );
            self.tel
                .add(Counter::DeliveryCorrupt, self.tally.corrupt as u64);
        }
        self.tally.clear();
    }

    /// Evaluate the average of all (or a sampled fraction of) *present*
    /// workers' local models on the test set and record a snapshot.
    /// Per-worker evaluations fan across the pool; sums reduce in id
    /// order, so the snapshot is bit-identical for any thread count.
    pub fn evaluate(&mut self) -> EvalRecord {
        let p = self.ids.len();
        let count = ((p as f64 * self.cfg.eval_worker_frac).round() as usize)
            .clamp(1, p.max(1));
        let eval_ids: Vec<usize> = if count >= p {
            self.ids.clone()
        } else {
            self.rng
                .sample_indices(p, count)
                .into_iter()
                .map(|k| self.ids[k])
                .collect()
        };
        let mut pairs: Vec<(f64, f64)> = vec![(0.0, 0.0); eval_ids.len()];
        if self.slots.len() > 1 && eval_ids.len() > 1 {
            let pool = self.slots.len().min(eval_ids.len());
            let slots = &mut self.slots[..pool];
            let workers = &self.workers;
            let test = &self.test;
            let ids = &eval_ids;
            let parts: Vec<Vec<(usize, (f64, f64))>> = thread::scope(|s| {
                let handles: Vec<_> = slots
                    .iter_mut()
                    .enumerate()
                    .map(|(si, slot)| {
                        s.spawn(move || {
                            let mut part = Vec::new();
                            let mut pos = si;
                            while pos < ids.len() {
                                let i = ids[pos];
                                part.push((
                                    pos,
                                    slot.trainer.evaluate(
                                        &workers[i].params,
                                        test,
                                    ),
                                ));
                                pos += pool;
                            }
                            part
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("eval worker thread panicked"))
                    .collect()
            });
            for part in parts {
                for (pos, la) in part {
                    pairs[pos] = la;
                }
            }
        } else {
            for (pos, &i) in eval_ids.iter().enumerate() {
                pairs[pos] = self
                    .trainer
                    .evaluate(&self.workers[i].params, &self.test);
            }
        }
        let mut acc_sum = 0.0;
        let mut loss_sum = 0.0;
        for &(loss, acc) in &pairs {
            acc_sum += acc;
            loss_sum += loss;
        }
        let rec = EvalRecord {
            round: self.round,
            time_s: self.clock_s,
            avg_accuracy: acc_sum / eval_ids.len() as f64,
            avg_loss: loss_sum / eval_ids.len() as f64,
            cum_transfers: self.cum_transfers,
            cum_bytes: self.cum_bytes,
        };
        self.observers.eval(&rec);
        rec
    }

    /// Run the configured number of rounds with periodic evaluation.
    /// With `early_stop`, stops once `target_accuracy` is reached *and*
    /// at least one later snapshot confirms it.
    ///
    /// The event core schedules the evaluation boundaries up-front on
    /// its inter-round [`EventQueue`] (`every, 2·every, …, rounds` —
    /// exactly the rounds the dense modulo test fires on) and pops them
    /// as rounds pass; an early stop simply leaves the tail unfired.
    ///
    /// Errors deferred by observers (sink I/O failures) surface here,
    /// at the end of the run, as [`ExperimentError::Backend`].
    pub fn run(
        mut self,
        early_stop: bool,
    ) -> Result<RunResult, ExperimentError> {
        let rounds = self.cfg.rounds;
        let every = self.cfg.eval_every.max(1);
        if self.event_mode {
            let mut t = every;
            while t < rounds {
                self.schedule.push(t as f64, SimEvent::EvalDue { round: t });
                t = match t.checked_add(every) {
                    Some(next) => next,
                    None => break,
                };
            }
            if rounds > 0 {
                self.schedule
                    .push(rounds as f64, SimEvent::EvalDue { round: rounds });
            }
        }
        let mut hits = 0;
        for t in 1..=rounds {
            self.step();
            let eval_due = if self.event_mode {
                let mut due = false;
                while self.schedule.pop_due(t as f64).is_some() {
                    due = true;
                }
                due
            } else {
                t % every == 0 || t == rounds
            };
            if eval_due {
                let rec = self.evaluate();
                if early_stop && rec.avg_accuracy >= self.cfg.target_accuracy
                {
                    hits += 1;
                    if hits >= 2 {
                        break;
                    }
                }
            }
        }
        self.observers
            .run_end()
            .map_err(ExperimentError::Backend)?;
        Ok(self.into_result())
    }

    /// Immutable access to collected metrics (tests, mid-run probes).
    pub fn result(&self) -> &RunResult {
        self.observers.result()
    }

    /// Finish: hand back the recorded metrics.
    pub fn into_result(self) -> RunResult {
        self.observers.into_result()
    }
}
