//! Round observers: pluggable hooks both backends fire as a run
//! progresses. Metrics recording is itself the first observer
//! ([`RunRecorder`]), so figure capture, fault injection, or live
//! dashboards are additional plug-ins rather than engine fields.

use crate::coordinator::RoundPlan;
use crate::metrics::{
    ActivationRecord, EvalRecord, EventRecord, RoundRecord, RunResult,
};

/// Hooks fired by every [`Backend`](super::Backend) on the coordinator
/// thread (never concurrently). All methods default to no-ops so an
/// observer implements only what it watches.
pub trait RoundObserver {
    /// A scenario event (churn, failure, environment shift) was applied
    /// at a round boundary, before that round's plan.
    fn on_scenario_event(&mut self, rec: &EventRecord) {
        let _ = rec;
    }

    /// The scheduler produced (and the engine validated) a round plan,
    /// before execution.
    fn on_plan(&mut self, round: usize, plan: &RoundPlan) {
        let _ = (round, plan);
    }

    /// One worker activation finished, with its phase breakdown.
    /// Fired after the round executed, before [`Self::on_round_end`],
    /// once per activated worker in plan order.
    fn on_activation(&mut self, rec: &ActivationRecord) {
        let _ = rec;
    }

    /// A round finished executing and its record is final.
    fn on_round_end(&mut self, rec: &RoundRecord) {
        let _ = rec;
    }

    /// An evaluation snapshot was taken.
    fn on_eval(&mut self, rec: &EvalRecord) {
        let _ = rec;
    }

    /// The run is over: last chance to flush buffers and surface any
    /// I/O error accumulated while streaming. Backends call this once,
    /// after the final round/eval and before assembling the
    /// [`RunResult`]; an `Err` fails the run rather than silently
    /// truncating its artifacts.
    fn on_run_end(&mut self) -> Result<(), String> {
        Ok(())
    }
}

/// The built-in first observer: accumulates the [`RunResult`] every
/// backend returns.
pub struct RunRecorder {
    result: RunResult,
    /// Retain only the last `window` records per stream (0 = keep all).
    /// With a streaming sink the full run lives on disk, so a bounded
    /// window keeps the resident [`RunResult`] O(window) at N=1M
    /// (`metrics.window` knob).
    window: usize,
}

/// Push keeping at most `window` entries (0 = unbounded). `remove(0)`
/// is O(window) but window is small and constant, so this stays cheap
/// relative to a round's work.
fn bounded_push<T>(v: &mut Vec<T>, window: usize, rec: T) {
    if window > 0 && v.len() >= window {
        v.remove(0);
    }
    v.push(rec);
}

impl RunRecorder {
    pub fn new(label: impl Into<String>, model_bits: f64) -> Self {
        Self::with_window(label, model_bits, 0)
    }

    pub fn with_window(
        label: impl Into<String>,
        model_bits: f64,
        window: usize,
    ) -> Self {
        RunRecorder { result: RunResult::new(label, model_bits), window }
    }

    pub fn result(&self) -> &RunResult {
        &self.result
    }

    pub fn into_result(self) -> RunResult {
        self.result
    }
}

impl RoundObserver for RunRecorder {
    fn on_scenario_event(&mut self, rec: &EventRecord) {
        bounded_push(&mut self.result.events, self.window, rec.clone());
    }

    fn on_round_end(&mut self, rec: &RoundRecord) {
        bounded_push(&mut self.result.rounds, self.window, rec.clone());
    }

    fn on_eval(&mut self, rec: &EvalRecord) {
        bounded_push(&mut self.result.evals, self.window, rec.clone());
    }
}

/// The recorder plus any user-attached observers, dispatched in order
/// (recorder first). Owned by a backend for the duration of one run.
pub struct ObserverChain {
    recorder: RunRecorder,
    others: Vec<Box<dyn RoundObserver>>,
}

impl ObserverChain {
    pub fn new(
        recorder: RunRecorder,
        others: Vec<Box<dyn RoundObserver>>,
    ) -> Self {
        ObserverChain { recorder, others }
    }

    pub fn scenario_event(&mut self, rec: &EventRecord) {
        self.recorder.on_scenario_event(rec);
        for o in &mut self.others {
            o.on_scenario_event(rec);
        }
    }

    pub fn plan(&mut self, round: usize, plan: &RoundPlan) {
        self.recorder.on_plan(round, plan);
        for o in &mut self.others {
            o.on_plan(round, plan);
        }
    }

    pub fn activation(&mut self, rec: &ActivationRecord) {
        self.recorder.on_activation(rec);
        for o in &mut self.others {
            o.on_activation(rec);
        }
    }

    pub fn round_end(&mut self, rec: &RoundRecord) {
        self.recorder.on_round_end(rec);
        for o in &mut self.others {
            o.on_round_end(rec);
        }
    }

    pub fn eval(&mut self, rec: &EvalRecord) {
        self.recorder.on_eval(rec);
        for o in &mut self.others {
            o.on_eval(rec);
        }
    }

    /// Fire [`RoundObserver::on_run_end`] on every observer. Every
    /// observer runs even if an earlier one fails (flushes must not be
    /// skipped); the first error is returned.
    pub fn run_end(&mut self) -> Result<(), String> {
        let mut first_err = self.recorder.on_run_end().err();
        for o in &mut self.others {
            if let Err(e) = o.on_run_end() {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    pub fn result(&self) -> &RunResult {
        self.recorder.result()
    }

    pub fn into_result(self) -> RunResult {
        self.recorder.into_result()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_rec(round: usize) -> RoundRecord {
        RoundRecord {
            round,
            time_s: round as f64,
            duration_s: 1.0,
            active: 2,
            population: 4,
            adversaries: 0,
            transfers: 3,
            bytes_sent: 24.0,
            avg_staleness: 0.5,
            max_staleness: 1,
            train_loss: 0.9,
            retransmissions: 0,
            dropped_msgs: 0,
            corrupt_detected: 0,
        }
    }

    use std::cell::RefCell;
    use std::rc::Rc;

    /// (plans, rounds, evals) tallies shared out of the boxed observer.
    struct Counter(Rc<RefCell<(usize, usize, usize)>>);

    impl RoundObserver for Counter {
        fn on_plan(&mut self, _round: usize, _plan: &RoundPlan) {
            self.0.borrow_mut().0 += 1;
        }
        fn on_round_end(&mut self, _rec: &RoundRecord) {
            self.0.borrow_mut().1 += 1;
        }
        fn on_eval(&mut self, _rec: &EvalRecord) {
            self.0.borrow_mut().2 += 1;
        }
    }

    #[test]
    fn recorder_accumulates_scenario_events() {
        let mut chain =
            ObserverChain::new(RunRecorder::new("test", 64.0), vec![]);
        chain.scenario_event(&EventRecord {
            round: 1,
            kind: "crash",
            worker: Some(2),
            population: 9,
        });
        chain.round_end(&round_rec(1));
        let res = chain.into_result();
        assert_eq!(res.events.len(), 1);
        assert_eq!(res.events[0].kind, "crash");
        assert_eq!(res.events[0].population, 9);
    }

    #[test]
    fn recorder_accumulates_run_result() {
        let mut chain = ObserverChain::new(
            RunRecorder::new("test", 64.0),
            vec![],
        );
        chain.plan(1, &RoundPlan::default());
        chain.round_end(&round_rec(1));
        chain.eval(&EvalRecord {
            round: 1,
            time_s: 1.0,
            avg_accuracy: 0.5,
            avg_loss: 1.0,
            cum_transfers: 3,
            cum_bytes: 24.0,
        });
        let res = chain.into_result();
        assert_eq!(res.label, "test");
        assert_eq!(res.rounds.len(), 1);
        assert_eq!(res.evals.len(), 1);
        assert_eq!(res.model_bits, 64.0);
    }

    #[test]
    fn bounded_window_keeps_only_the_tail() {
        let mut rec = RunRecorder::with_window("test", 64.0, 2);
        for t in 1..=5 {
            rec.on_round_end(&round_rec(t));
        }
        let rounds: Vec<usize> =
            rec.result().rounds.iter().map(|r| r.round).collect();
        assert_eq!(rounds, vec![4, 5]);
        // window 0 keeps everything
        let mut rec = RunRecorder::with_window("test", 64.0, 0);
        for t in 1..=5 {
            rec.on_round_end(&round_rec(t));
        }
        assert_eq!(rec.result().rounds.len(), 5);
    }

    #[test]
    fn user_observers_fire_after_recorder() {
        let counts = Rc::new(RefCell::new((0, 0, 0)));
        let mut chain = ObserverChain::new(
            RunRecorder::new("test", 64.0),
            vec![Box::new(Counter(counts.clone()))],
        );
        for t in 1..=3 {
            chain.plan(t, &RoundPlan::default());
            chain.round_end(&round_rec(t));
        }
        assert_eq!(chain.result().rounds.len(), 3);
        assert_eq!(*counts.borrow(), (3, 3, 0));
    }
}
