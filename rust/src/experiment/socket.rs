//! Socket deployment backend: worker threads speaking the
//! length-prefixed wire format ([`crate::transport::wire`]) over real
//! TCP or Unix-domain sockets.
//!
//! Where [`ThreadedBackend`](super::ThreadedBackend) shares memory
//! (`Arc<Mutex<Published>>` snapshots), this backend actually ships
//! every model across a socket: the coordinator serves pulls and
//! pushes as framed messages ([`Frame`] + CRC32 + per-sender
//! [`DedupWindow`]), workers hold nothing but their trainer, shard and
//! RNG. It is the deployment shape of the paper's system — one process
//! per box away from a real cluster — while staying a zero-dependency
//! `std::net`/`std::thread` implementation.
//!
//! # Determinism: the virtual-time mirror
//!
//! The coordinator keeps the *plan-relevant* state machine of the
//! virtual-clock engine, verbatim: per-activation transfer times drawn
//! from [`Pcg::activation_stream`] in the engine's exact order, `h_est`
//! through the shared [`estimate_h_into`], the realised `H_t` as the
//! same fold-max, staleness/queues via [`WorkerState`]'s own methods,
//! and the delivery/byte ledger from the same pure
//! `(seed, round, from, to)` streams. Wall-clock sleeps emulate the
//! drawn times (scaled by `socket.time_scale`) but never feed back
//! into the records, so for any scheduler and seed the socket backend
//! and the simulator produce **identical plans and identical
//! event/byte ledgers** (transfers, retransmissions, dead-letters,
//! `cum_bytes`) — pinned by `tests/socket.rs`. Training itself runs on
//! worker-local RNG streams, so losses/accuracies are real but the
//! ledger does not depend on them.
//!
//! # Protocol
//!
//! Every message is one [`Frame`] (`[magic][len][seq][payload][crc]`),
//! sequence numbers per direction, receiver-side CRC check + dedup:
//!
//! 1. workers connect and send `HELLO{id}`;
//! 2. per activation the coordinator sends `EXECUTE{round, waits, own
//!    model, pulled + pushed wire copies, data sizes}`;
//! 3. the worker sleeps its transfer wait, aggregates (Eq. 4), sleeps
//!    its compute time, trains (Eq. 5), replies `DONE{round, loss,
//!    params}`;
//! 4. at run end the coordinator sends `SHUTDOWN` and joins.
//!
//! Backpressure is structural: the coordinator writes at most one
//! outstanding `EXECUTE` per worker and drains `DONE`s in plan order,
//! so per-connection buffering is bounded by one model snapshot
//! (DESIGN.md §Deployment).

use super::observer::{ObserverChain, RunRecorder};
use super::virtual_clock::estimate_h_into;
use super::{Backend, Experiment, ExperimentError};
use crate::adversary::Aggregator;
use crate::config::{
    ExperimentConfig, SocketConfig, SocketTransportKind, TrainerKind,
};
use crate::coordinator::{PullLedger, SchedView, SchedulerParams};
use crate::data::Dataset;
use crate::delivery::{DedupWindow, DeliveryTally, Frame};
use crate::metrics::{
    ActivationRecord, EvalRecord, EventRecord, RoundRecord, RunResult,
};
use crate::scenario::ScenarioEvent;
use crate::transport::wire::{read_frame, write_frame};
use crate::util::rng::Pcg;
use crate::worker::{data_size_weights, NativeTrainer, Trainer};
use std::io::{self, Read, Write};
use std::net::TcpListener;
use std::path::PathBuf;
use std::thread;
use std::time::{Duration, Instant};

const MSG_HELLO: u8 = 0;
const MSG_EXECUTE: u8 = 1;
const MSG_DONE: u8 = 2;
const MSG_SHUTDOWN: u8 = 3;

/// How long the coordinator waits for all workers to connect.
const ACCEPT_TIMEOUT: Duration = Duration::from_secs(30);

/// Socket deployment [`Backend`] (`run.backend=socket`, `socket.*`
/// knobs).
#[derive(Clone, Debug, Default)]
pub struct SocketBackend {
    cfg: SocketConfig,
}

impl SocketBackend {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from the `[socket]` config section.
    pub fn from_config(cfg: &SocketConfig) -> Self {
        SocketBackend { cfg: cfg.clone() }
    }
}

impl Backend for SocketBackend {
    fn name(&self) -> &'static str {
        "socket"
    }

    fn run(&mut self, exp: Experiment) -> Result<RunResult, ExperimentError> {
        run_socket(exp, self.cfg.clone())
    }
}

// --- transport-agnostic socket plumbing ------------------------------

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Uds(std::os::unix::net::UnixListener),
}

enum Stream {
    Tcp(std::net::TcpStream),
    #[cfg(unix)]
    Uds(std::os::unix::net::UnixStream),
}

/// Where workers connect to (the listener's resolved address).
#[derive(Clone)]
enum Endpoint {
    Tcp(std::net::SocketAddr),
    #[cfg(unix)]
    Uds(PathBuf),
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Uds(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Uds(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Uds(s) => s.flush(),
        }
    }
}

fn backend_err(msg: impl std::fmt::Display) -> ExperimentError {
    ExperimentError::Backend(msg.to_string())
}

/// Bind the coordinator listener; returns the listener, the endpoint
/// workers connect to, and (for auto-named UDS) the path to unlink.
fn bind(
    cfg: &SocketConfig,
) -> Result<(Listener, Endpoint, Option<PathBuf>), ExperimentError> {
    match cfg.transport {
        SocketTransportKind::Tcp => {
            let addr: &str =
                if cfg.addr.is_empty() { "127.0.0.1:0" } else { &cfg.addr };
            let l = TcpListener::bind(addr)
                .map_err(|e| backend_err(format!("bind {addr}: {e}")))?;
            let local = l
                .local_addr()
                .map_err(|e| backend_err(format!("local_addr: {e}")))?;
            Ok((Listener::Tcp(l), Endpoint::Tcp(local), None))
        }
        SocketTransportKind::Uds => {
            #[cfg(unix)]
            {
                // pid + per-process counter keeps concurrent runs (and
                // concurrent tests) from colliding in temp_dir
                static COUNTER: std::sync::atomic::AtomicU64 =
                    std::sync::atomic::AtomicU64::new(0);
                let path = if cfg.addr.is_empty() {
                    std::env::temp_dir().join(format!(
                        "dystop-{}-{}.sock",
                        std::process::id(),
                        COUNTER.fetch_add(
                            1,
                            std::sync::atomic::Ordering::Relaxed
                        )
                    ))
                } else {
                    PathBuf::from(&cfg.addr)
                };
                let _ = std::fs::remove_file(&path);
                let l = std::os::unix::net::UnixListener::bind(&path)
                    .map_err(|e| {
                        backend_err(format!("bind {}: {e}", path.display()))
                    })?;
                Ok((Listener::Uds(l), Endpoint::Uds(path.clone()), Some(path)))
            }
            #[cfg(not(unix))]
            {
                Err(ExperimentError::Unsupported(
                    "socket.transport=uds needs a unix platform; use \
                     socket.transport=tcp"
                        .into(),
                ))
            }
        }
    }
}

fn connect(ep: &Endpoint) -> io::Result<Stream> {
    match ep {
        Endpoint::Tcp(addr) => std::net::TcpStream::connect(addr).map(Stream::Tcp),
        #[cfg(unix)]
        Endpoint::Uds(path) => {
            std::os::unix::net::UnixStream::connect(path).map(Stream::Uds)
        }
    }
}

/// Connect with retries: hundreds of workers dialing at once can
/// overflow the listener backlog, which surfaces as transient
/// connection errors rather than queued connects.
fn connect_with_retry(ep: &Endpoint) -> Option<Stream> {
    let deadline = Instant::now() + ACCEPT_TIMEOUT;
    loop {
        match connect(ep) {
            Ok(s) => return Some(s),
            Err(_) if Instant::now() < deadline => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(_) => return None,
        }
    }
}

/// Accept all `n` workers (non-blocking poll with a deadline so a
/// wedged worker fails the run instead of hanging it) and index their
/// connections by the id each announces in `HELLO`.
fn accept_workers(
    listener: &Listener,
    n: usize,
    dedup: &mut DedupWindow,
) -> Result<Vec<Stream>, ExperimentError> {
    match listener {
        Listener::Tcp(l) => l.set_nonblocking(true),
        #[cfg(unix)]
        Listener::Uds(l) => l.set_nonblocking(true),
    }
    .map_err(|e| backend_err(format!("listener nonblocking: {e}")))?;
    let deadline = Instant::now() + ACCEPT_TIMEOUT;
    let mut conns: Vec<Option<Stream>> = (0..n).map(|_| None).collect();
    let mut got = 0usize;
    while got < n {
        let accepted = match listener {
            Listener::Tcp(l) => {
                l.accept().map(|(s, _)| (s.set_nonblocking(false), Stream::Tcp(s)))
            }
            #[cfg(unix)]
            Listener::Uds(l) => {
                l.accept().map(|(s, _)| (s.set_nonblocking(false), Stream::Uds(s)))
            }
        };
        match accepted {
            Ok((blocking, mut s)) => {
                blocking.map_err(|e| {
                    backend_err(format!("stream nonblocking: {e}"))
                })?;
                let frame = read_frame(&mut s)
                    .map_err(|e| backend_err(format!("hello: {e}")))?;
                if !frame.check() {
                    return Err(backend_err("corrupt HELLO frame"));
                }
                let mut rd = Rd::new(&frame.payload);
                if rd.u8()? != MSG_HELLO {
                    return Err(backend_err("expected HELLO"));
                }
                let id = rd.u32()? as usize;
                if id >= n || conns[id].is_some() {
                    return Err(backend_err(format!("bad HELLO id {id}")));
                }
                if !dedup.accept(id, frame.seq) {
                    return Err(backend_err(format!(
                        "duplicate HELLO from worker {id}"
                    )));
                }
                conns[id] = Some(s);
                got += 1;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    return Err(backend_err(format!(
                        "only {got}/{n} workers connected within {}s",
                        ACCEPT_TIMEOUT.as_secs()
                    )));
                }
                thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(backend_err(format!("accept: {e}"))),
        }
    }
    Ok(conns.into_iter().map(|c| c.expect("all slots filled")).collect())
}

// --- message (de)serialization ---------------------------------------

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(b: &mut Vec<u8>, xs: &[f32]) {
    put_u32(b, xs.len() as u32);
    for x in xs {
        b.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked little-endian reader over a frame payload.
struct Rd<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Self {
        Rd { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ExperimentError> {
        let end = self.i.checked_add(n).filter(|&e| e <= self.b.len());
        match end {
            Some(end) => {
                let s = &self.b[self.i..end];
                self.i = end;
                Ok(s)
            }
            None => Err(backend_err("truncated message payload")),
        }
    }

    fn u8(&mut self) -> Result<u8, ExperimentError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ExperimentError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ExperimentError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, ExperimentError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self) -> Result<Vec<f32>, ExperimentError> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| {
            backend_err("model length overflow")
        })?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Frame + send one message, advancing the per-direction sequence.
fn send_msg(
    s: &mut Stream,
    seq: &mut u64,
    payload: Vec<u8>,
) -> io::Result<()> {
    *seq += 1;
    write_frame(s, &Frame::new(*seq, payload))?;
    s.flush()
}

/// Receive one CRC-checked, dedup-accepted message from worker `i`.
fn recv_msg(
    s: &mut Stream,
    dedup: &mut DedupWindow,
    i: usize,
) -> Result<Vec<u8>, ExperimentError> {
    loop {
        let frame = read_frame(s)
            .map_err(|e| backend_err(format!("worker {i} read: {e}")))?;
        if !frame.check() {
            return Err(backend_err(format!(
                "CRC mismatch on frame from worker {i}"
            )));
        }
        if !dedup.accept(i, frame.seq) {
            continue; // stale duplicate — drop and keep reading
        }
        return Ok(frame.payload);
    }
}

// --- the worker process (one thread per worker, socket-only state) ---

/// One deployment worker: everything it knows arrives over the socket.
/// Exits on shutdown, connection loss, or any protocol violation (the
/// coordinator then reports the broken connection).
fn worker_main(id: usize, shard: Dataset, cfg: ExperimentConfig, ep: Endpoint) {
    let Some(mut stream) = connect_with_retry(&ep) else { return };
    let mut trainer = NativeTrainer::from_config(&cfg);
    let mut rng = Pcg::new(cfg.seed ^ 0x50C4E7, id as u64);
    let mut aggregator = Aggregator::from_config(&cfg.adversary);
    let mut agg: Vec<f32> = Vec::new();
    let mut tx_seq = 0u64;
    let mut dedup = DedupWindow::new(1);
    let mut hello = vec![MSG_HELLO];
    put_u32(&mut hello, id as u32);
    if send_msg(&mut stream, &mut tx_seq, hello).is_err() {
        return;
    }
    loop {
        let Ok(frame) = read_frame(&mut stream) else { return };
        if !frame.check() || !dedup.accept(0, frame.seq) {
            return;
        }
        let mut rd = Rd::new(&frame.payload);
        match rd.u8() {
            Ok(MSG_SHUTDOWN) => return,
            Ok(MSG_EXECUTE) => {
                let Ok(round) = rd.u32() else { return };
                let (Ok(wait_ms), Ok(train_ms)) = (rd.u64(), rd.u64()) else {
                    return;
                };
                // own model first, then pulled + pushed wire copies —
                // the simulator's aggregation order
                let mut sizes: Vec<usize> = Vec::new();
                let mut models: Vec<Vec<f32>> = Vec::new();
                let Ok(own_size) = rd.u64() else { return };
                let Ok(own) = rd.f32s() else { return };
                sizes.push(own_size as usize);
                models.push(own);
                let Ok(n_models) = rd.u32() else { return };
                for _ in 0..n_models {
                    let (Ok(sz), Ok(m)) = (rd.u64(), rd.f32s()) else {
                        return;
                    };
                    sizes.push(sz as usize);
                    models.push(m);
                }
                // emulated channel wait (slowest link already folded in
                // by the coordinator), then aggregate + compute + train
                thread::sleep(Duration::from_millis(wait_ms));
                let refs: Vec<&[f32]> =
                    models.iter().map(|m| m.as_slice()).collect();
                let weights = data_size_weights(&sizes);
                aggregator.aggregate_into(
                    &mut trainer,
                    &refs,
                    &weights,
                    &mut agg,
                );
                thread::sleep(Duration::from_millis(train_ms));
                let (params, loss) = trainer.train(
                    &agg,
                    &shard,
                    cfg.local_steps,
                    cfg.batch,
                    cfg.lr,
                    &mut rng,
                );
                let mut done = vec![MSG_DONE];
                put_u32(&mut done, round);
                put_f64(&mut done, loss);
                put_f32s(&mut done, &params);
                if send_msg(&mut stream, &mut tx_seq, done).is_err() {
                    return;
                }
            }
            _ => return,
        }
    }
}

// --- the coordinator -------------------------------------------------

/// Per-activation virtual-time data, computed on the coordinator in
/// plan order (the engine-mirroring RNG draws live here).
struct ActMeta {
    duration_s: f64,
    compute_s: f64,
    transfer_s: f64,
    retry_s: f64,
    tally: DeliveryTally,
    dead: Vec<usize>,
    /// Wall-clock mark taken when the EXECUTE frame went out; the DONE
    /// receipt closes it as one `wire_rtt_ns` sample.
    sent: crate::telemetry::Tick,
}

fn run_socket(
    exp: Experiment,
    sopts: SocketConfig,
) -> Result<RunResult, ExperimentError> {
    let Experiment {
        cfg,
        mut net,
        mut workers,
        test,
        label_dist,
        model_bits,
        scenario,
        mut transport,
        mut adversary,
        delivery,
        mut trainer,
        mut scheduler,
        mut rng,
        observers,
        telemetry: tel,
    } = exp;
    if cfg.trainer != TrainerKind::Native {
        return Err(ExperimentError::Unsupported(
            "the socket backend trains with one NativeTrainer per worker; \
             run.backend=sim for PJRT trainers"
                .into(),
        ));
    }
    let n = cfg.workers;
    let time_scale = sopts.time_scale;
    let wire_bits = transport.message_bits();
    let recorder = RunRecorder::with_window(
        format!("socket-{}", scheduler.name()),
        model_bits,
        cfg.metrics.window,
    );
    let mut chain = ObserverChain::new(recorder, observers);

    // --- bring the deployment up ---
    let (listener, endpoint, sock_path) = bind(&sopts)?;
    let mut handles = Vec::with_capacity(n);
    for w in &workers {
        let shard = w.shard.clone();
        let wcfg = cfg.clone();
        let ep = endpoint.clone();
        let id = w.id;
        handles.push(thread::spawn(move || worker_main(id, shard, wcfg, ep)));
    }
    let mut rx_dedup = DedupWindow::new(n);
    let mut conns = match accept_workers(&listener, n, &mut rx_dedup) {
        Ok(c) => c,
        Err(e) => {
            // failed bring-up: close what connected so threads exit
            if let Some(p) = &sock_path {
                let _ = std::fs::remove_file(p);
            }
            return Err(e);
        }
    };
    let mut tx_seq = vec![0u64; n];

    // --- virtual-time mirror state (the simulator's, verbatim) ---
    let mut pulls = PullLedger::dense(n);
    let mut inbox: Vec<Vec<(usize, Vec<f32>)>> = vec![Vec::new(); n];
    let mut tally = DeliveryTally::default();
    let mut clock_s = 0.0f64;
    let mut cum_transfers = 0usize;
    let mut cum_bytes = 0.0f64;
    let mut pull_srcs: Vec<usize> = Vec::new();
    let mut ids: Vec<usize> = (0..n).collect();
    let mut gdx: Vec<usize> = (0..n).collect();
    let mut range_buf: Vec<usize> = Vec::new();
    let mut cand_buf: Vec<Vec<usize>> = Vec::new();
    let mut near: Vec<usize> = Vec::new();
    let mut worst_tx: Vec<f64> = Vec::new();
    let mut h_est: Vec<f64> = Vec::new();
    let mut hits = 0usize;

    let result = 'run: {
        for round in 1..=cfg.rounds {
            let t_round = tel.tick();
            // --- scenario events (identical hook to the simulator) ---
            crate::scenario::apply_round_events(
                &scenario,
                round,
                &mut net,
                |ev| match *ev {
                    ScenarioEvent::Leave { worker } => {
                        inbox[worker].clear();
                    }
                    ScenarioEvent::Crash { worker } => {
                        inbox[worker].clear();
                        for q in inbox.iter_mut() {
                            if let Some(pos) =
                                q.iter().position(|(f, _)| *f == worker)
                            {
                                q.swap_remove(pos);
                                tally.crash_dropped += 1;
                            }
                        }
                    }
                    ScenarioEvent::Join { worker } => {
                        let w = &mut workers[worker];
                        w.params =
                            trainer.init(cfg.seed.wrapping_add(worker as u64));
                        w.staleness = 0;
                        w.queue = 0.0;
                        w.residual_s = w.h_train_s;
                        w.last_loss = f64::NAN;
                        pulls.reset_worker(worker);
                        transport.reset_worker(worker);
                    }
                    ScenarioEvent::Rejoin { worker } => {
                        let w = &mut workers[worker];
                        w.residual_s = w.h_train_s;
                    }
                    _ => {}
                },
                |rec| chain.scenario_event(&rec),
            );

            net.advance_round(cfg.seed, round as u64);

            // --- scheduler view (dense rebuild, simulator order) ---
            let t_view = tel.tick();
            crate::scenario::rebuild_dense_maps(&net, &mut ids, &mut gdx);
            let p = ids.len();
            crate::scenario::build_dense_candidates(
                &net,
                &ids,
                &gdx,
                &mut range_buf,
                &mut cand_buf,
            );
            let d_tau: Vec<u64> =
                ids.iter().map(|&i| workers[i].staleness).collect();
            let d_queues: Vec<f64> =
                ids.iter().map(|&i| workers[i].queue).collect();
            let d_residual: Vec<f64> =
                ids.iter().map(|&i| workers[i].residual_s).collect();
            {
                let ws = &workers;
                estimate_h_into(
                    &net,
                    |gi| ws[gi].residual_s,
                    &ids,
                    &cand_buf[..p],
                    wire_bits,
                    cfg.neighbor_cap,
                    &mut near,
                    &mut worst_tx,
                    &mut h_est,
                );
            }
            let data_sizes: Vec<usize> =
                ids.iter().map(|&i| workers[i].data_size()).collect();
            let budgets: Vec<f64> =
                ids.iter().map(|&i| net.budgets[i]).collect();
            tel.tock(crate::telemetry::Phase::ViewRebuild, t_view);
            tel.inc(crate::telemetry::Counter::SchedViewRebuilds);
            let mut plan = {
                let view = SchedView {
                    round,
                    tau: &d_tau,
                    queues: &d_queues,
                    h_cmp: &d_residual,
                    h_est: &h_est,
                    data_sizes: &data_sizes,
                    ids: &ids,
                    label_dist: &label_dist,
                    candidates: &cand_buf[..p],
                    budgets: &budgets,
                    pulls: &pulls,
                    net: &net,
                    params: SchedulerParams::from(&cfg),
                };
                scheduler.plan(&view, &mut rng)
            };
            crate::scenario::remap_plan_to_global(&mut plan, &ids);
            debug_assert!(plan.validate_present(net.present_mask()).is_ok());
            chain.plan(round, &plan);

            // --- transport encode pass (simulator order) ---
            let adv_active = adversary.is_active();
            let dense = transport.is_dense();
            if !dense || adv_active {
                crate::transport::unique_pull_sources(
                    &plan.pulls_from,
                    &mut pull_srcs,
                );
                let t = tel.tick();
                let mut encoded = 0u64;
                for &j in &pull_srcs {
                    let payload: &[f32] = if adv_active {
                        adversary.transmit(j, &workers[j].params)
                    } else {
                        &workers[j].params
                    };
                    if !dense {
                        transport.encode(j, payload);
                        encoded += 1;
                    }
                }
                tel.tock(crate::telemetry::Phase::CodecEncode, t);
                tel.add(crate::telemetry::Counter::CodecEncodes, encoded);
                tel.add(
                    crate::telemetry::Counter::CodecBytes,
                    (encoded as f64 * transport.message_bytes()) as u64,
                );
            }

            // --- dispatch EXECUTE (plan order) ---
            // virtual times first: the per-activation RNG stream draws
            // in the simulator's exact order (pulls in plan order, then
            // this worker's pushes), so durations — and therefore H_t,
            // staleness, queues, and every later plan — are
            // bit-identical to the virtual-clock engine's
            let channels = cfg.network.channels.max(1);
            let mut metas: Vec<ActMeta> =
                Vec::with_capacity(plan.active.len());
            for (k, &i) in plan.active.iter().enumerate() {
                let mut act_rng = Pcg::activation_stream(
                    cfg.seed,
                    round as u64,
                    i as u64,
                );
                let mut act_tally = DeliveryTally::default();
                let mut dead: Vec<usize> = Vec::new();
                let mut worst_pull = 0.0f64;
                let mut worst_pull_base = 0.0f64;
                for &j in &plan.pulls_from[k] {
                    let base =
                        net.transfer_time_s(j, i, wire_bits, &mut act_rng);
                    let out = delivery.resolve(round as u64, j, i);
                    act_tally.add(&out);
                    if !out.delivered {
                        dead.push(j);
                    }
                    worst_pull_base = worst_pull_base.max(base);
                    worst_pull = worst_pull.max(out.time_s(base));
                }
                let pull_slots =
                    plan.pulls_from[k].len().div_ceil(channels);
                let mut worst_push = 0.0f64;
                let mut n_push = 0usize;
                for &(from, to) in &plan.pushes {
                    if from == i {
                        worst_push = worst_push.max(net.transfer_time_s(
                            i,
                            to,
                            wire_bits,
                            &mut act_rng,
                        ));
                        n_push += 1;
                    }
                }
                let push_slots = n_push.div_ceil(channels);
                let duration_s = workers[i].residual_s
                    + worst_pull * pull_slots as f64
                    + worst_push * push_slots as f64;
                let compute_s = workers[i].residual_s;
                let transfer_s = worst_pull_base * pull_slots as f64
                    + worst_push * push_slots as f64;
                let retry_s =
                    (worst_pull - worst_pull_base) * pull_slots as f64;

                // the EXECUTE message: own model + delivered pulls
                // (wire copies through transport/adversary) + pending
                // pushed models (senders freshly pulled are filtered —
                // their fresher model just arrived via the pull)
                let srcs: Vec<usize> = plan.pulls_from[k]
                    .iter()
                    .copied()
                    .filter(|j| !dead.contains(j))
                    .collect();
                let pushed: Vec<(usize, Vec<f32>)> =
                    std::mem::take(&mut inbox[i])
                        .into_iter()
                        .filter(|(from, _)| {
                            *from != i && !srcs.contains(from)
                        })
                        .collect();
                let wait_ms =
                    ((worst_pull * pull_slots as f64
                        + worst_push * push_slots as f64)
                        * time_scale) as u64;
                let train_ms = (workers[i].residual_s * time_scale) as u64;
                let mut msg = vec![MSG_EXECUTE];
                put_u32(&mut msg, round as u32);
                put_u64(&mut msg, wait_ms);
                put_u64(&mut msg, train_ms);
                put_u64(&mut msg, workers[i].data_size() as u64);
                put_f32s(&mut msg, &workers[i].params);
                put_u32(&mut msg, (srcs.len() + pushed.len()) as u32);
                for &j in &srcs {
                    put_u64(&mut msg, workers[j].data_size() as u64);
                    put_f32s(
                        &mut msg,
                        adversary.exchange_view(
                            j,
                            transport.view(j, &workers[j].params),
                            dense,
                        ),
                    );
                }
                for (from, m) in &pushed {
                    put_u64(&mut msg, workers[*from].data_size() as u64);
                    put_f32s(&mut msg, m);
                }
                let msg_bytes = msg.len() as u64;
                if let Err(e) = send_msg(&mut conns[i], &mut tx_seq[i], msg)
                {
                    break 'run Err(backend_err(format!(
                        "worker {i} hung up: {e}"
                    )));
                }
                tel.inc(crate::telemetry::Counter::WireFramesSent);
                tel.add(
                    crate::telemetry::Counter::WireBytesSent,
                    msg_bytes,
                );
                metas.push(ActMeta {
                    duration_s,
                    compute_s,
                    transfer_s,
                    retry_s,
                    tally: act_tally,
                    dead,
                    sent: tel.tick(),
                });
            }

            // realised H_t: the simulator's fold-max in plan order
            let mut h_round = metas
                .iter()
                .fold(0.0f64, |a, m| a.max(m.duration_s));
            if plan.active.is_empty() {
                h_round = 0.01; // avoid stalling the clock
            }

            // --- collect DONEs and apply results (plan order) ---
            let mut losses: Vec<f64> =
                Vec::with_capacity(plan.active.len());
            for (k, &i) in plan.active.iter().enumerate() {
                let payload = match recv_msg(&mut conns[i], &mut rx_dedup, i)
                {
                    Ok(pl) => pl,
                    Err(e) => break 'run Err(e),
                };
                tel.tock(crate::telemetry::Phase::WireRtt, metas[k].sent);
                tel.inc(crate::telemetry::Counter::WireFramesRecv);
                tel.add(
                    crate::telemetry::Counter::WireBytesRecv,
                    payload.len() as u64,
                );
                let mut rd = Rd::new(&payload);
                let parsed = (|| {
                    if rd.u8()? != MSG_DONE {
                        return Err(backend_err(format!(
                            "worker {i}: expected DONE"
                        )));
                    }
                    let r = rd.u32()? as usize;
                    if r != round {
                        return Err(backend_err(format!(
                            "worker {i}: DONE for round {r}, expected \
                             {round}"
                        )));
                    }
                    Ok((rd.f64()?, rd.f32s()?))
                })();
                let (loss, params) = match parsed {
                    Ok(x) => x,
                    Err(e) => break 'run Err(e),
                };
                let m = &metas[k];
                chain.activation(&ActivationRecord {
                    round,
                    worker: i,
                    start_s: clock_s,
                    compute_s: m.compute_s,
                    transfer_s: m.transfer_s,
                    retry_s: m.retry_s,
                    wait_s: (h_round - m.duration_s).max(0.0),
                });
                tally.merge(&m.tally);
                for _ in &m.dead {
                    chain.scenario_event(&EventRecord {
                        round,
                        kind: "dead-letter",
                        worker: Some(i),
                        population: p,
                    });
                }
                workers[i].params = params;
                workers[i].last_loss = loss;
                losses.push(loss);
                tel.inc(crate::telemetry::Counter::Activations);
                tel.add(
                    crate::telemetry::Counter::TrainSamples,
                    (cfg.local_steps * cfg.batch) as u64,
                );
                for &j in &plan.pulls_from[k] {
                    pulls.record(i, j);
                }
            }

            // --- pushes (post-training params, simulator semantics) ---
            if !plan.pushes.is_empty() {
                let mut push_enc: Vec<usize> = Vec::new();
                for &(from, to) in &plan.pushes {
                    if (!dense || adv_active) && !push_enc.contains(&from) {
                        let payload: &[f32] = if adv_active {
                            adversary.transmit(from, &workers[from].params)
                        } else {
                            &workers[from].params
                        };
                        if !dense {
                            transport.encode(from, payload);
                        }
                        push_enc.push(from);
                    }
                    let wire = adversary
                        .exchange_view(
                            from,
                            transport.view(from, &workers[from].params),
                            dense,
                        )
                        .to_vec();
                    match inbox[to].iter_mut().find(|(f, _)| *f == from) {
                        Some(slot) => slot.1 = wire,
                        None => inbox[to].push((from, wire)),
                    }
                }
            }

            // --- adversary bookkeeping (simulator order) ---
            if adversary.has_stale_bombers() {
                for i in 0..n {
                    adversary.record_round_end(i, &workers[i].params);
                }
            }
            if adv_active {
                for (w, kind) in adversary.drain_activations() {
                    chain.scenario_event(&EventRecord {
                        round,
                        kind,
                        worker: Some(w),
                        population: p,
                    });
                }
            }

            // --- clock + staleness + queues (simulator formulas) ---
            clock_s += h_round;
            let mut active_mask = vec![false; n];
            for &i in &plan.active {
                active_mask[i] = true;
            }
            for i in 0..n {
                let w = &mut workers[i];
                if !net.is_present(i) {
                    w.on_skipped();
                    continue;
                }
                w.advance(h_round);
                if active_mask[i] {
                    w.on_activated();
                } else {
                    w.on_skipped();
                }
                w.update_queue(cfg.tau_bound);
            }
            let mut tau_sum = 0.0f64;
            let mut max_tau = 0u64;
            for &i in &ids {
                let t = workers[i].staleness;
                tau_sum += t as f64;
                max_tau = max_tau.max(t);
            }

            // --- round record (simulator formulas) ---
            let transfers = plan.transfers();
            cum_transfers += transfers;
            let bytes_sent = (transfers + tally.retransmissions) as f64
                * transport.message_bytes();
            cum_bytes += bytes_sent;
            let train_loss = if losses.is_empty() {
                f64::NAN
            } else {
                losses.iter().sum::<f64>() / losses.len() as f64
            };
            chain.round_end(&RoundRecord {
                round,
                time_s: clock_s,
                duration_s: h_round,
                active: plan.active.len(),
                population: p,
                adversaries: adversary.count_present(&ids),
                transfers,
                bytes_sent,
                avg_staleness: tau_sum / p as f64,
                max_staleness: max_tau,
                train_loss,
                retransmissions: tally.retransmissions,
                dropped_msgs: tally.dropped_msgs(),
                corrupt_detected: tally.corrupt,
            });
            if tel.is_enabled() {
                use crate::telemetry::{Counter, Gauge, Phase};
                tel.add(Counter::DeliveryMsgs, transfers as u64);
                tel.add(
                    Counter::DeliveryRetries,
                    tally.retransmissions as u64,
                );
                tel.add(
                    Counter::DeliveryDeadLetters,
                    tally.dropped_msgs() as u64,
                );
                tel.add(Counter::DeliveryCorrupt, tally.corrupt as u64);
                tel.inc(Counter::Rounds);
                let secs = tel.elapsed_s(t_round);
                if secs > 0.0 {
                    let samples =
                        plan.active.len() * cfg.local_steps * cfg.batch;
                    tel.set_gauge(
                        Gauge::TrainThroughput,
                        samples as f64 / secs,
                    );
                }
                tel.set_gauge(Gauge::ClockVirtualS, clock_s);
                tel.set_gauge(Gauge::Population, p as f64);
                tel.tock(Phase::Round, t_round);
            }
            tally.clear();

            // --- evaluation (coordinator-side, simulator cadence) ---
            if round % cfg.eval_every.max(1) == 0 || round == cfg.rounds {
                let count = ((p as f64 * cfg.eval_worker_frac).round()
                    as usize)
                    .clamp(1, p.max(1));
                let eval_ids: Vec<usize> = if count >= p {
                    ids.clone()
                } else {
                    rng.sample_indices(p, count)
                        .into_iter()
                        .map(|k| ids[k])
                        .collect()
                };
                let mut acc_sum = 0.0;
                let mut loss_sum = 0.0;
                for &i in &eval_ids {
                    let (l, a) =
                        trainer.evaluate(&workers[i].params, &test);
                    acc_sum += a;
                    loss_sum += l;
                }
                let rec = EvalRecord {
                    round,
                    time_s: clock_s,
                    avg_accuracy: acc_sum / eval_ids.len() as f64,
                    avg_loss: loss_sum / eval_ids.len() as f64,
                    cum_transfers,
                    cum_bytes,
                };
                chain.eval(&rec);
                // the CLI early-stop contract (two confirming snapshots)
                if rec.avg_accuracy >= cfg.target_accuracy {
                    hits += 1;
                    if hits >= 2 {
                        break 'run Ok(());
                    }
                }
            }
        }
        Ok(())
    };

    // --- tear the deployment down (also on mid-run errors) ---
    for (i, s) in conns.iter_mut().enumerate() {
        if send_msg(s, &mut tx_seq[i], vec![MSG_SHUTDOWN]).is_ok() {
            tel.inc(crate::telemetry::Counter::WireFramesSent);
        }
    }
    drop(conns);
    for h in handles {
        let _ = h.join();
    }
    if let Some(p) = &sock_path {
        let _ = std::fs::remove_file(p);
    }
    result?;
    chain.run_end().map_err(ExperimentError::Backend)?;
    Ok(chain.into_result())
}
