//! Discrete-event machinery for the event-driven simulation core
//! (`run.engine=event`).
//!
//! A binary-heap priority queue of typed [`SimEvent`]s ordered by
//! `(timestamp, insertion sequence)`. The timestamp comparison uses
//! `f64::total_cmp` and ties break FIFO on the insertion sequence, so a
//! given push order always drains in the same order — determinism does
//! not depend on `BinaryHeap`'s internal layout.
//!
//! The engine uses two queues:
//!
//! * an **intra-round** queue of activation/transfer completions whose
//!   drained maximum is the realised round duration H_t (Eq. 9) — for
//!   finite non-negative times the heap maximum is bit-identical to the
//!   dense engine's fold-max over activation outputs;
//! * an **inter-round** schedule of evaluation boundaries, pushed
//!   up-front and popped as virtual rounds pass them.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What completed (or came due) at an event's timestamp.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SimEvent {
    /// An activated worker finished its round work — residual compute
    /// plus serialized pull/push transfers (Eqs. 7–9). The last
    /// `ActivationDone` popped defines H_t.
    ActivationDone { worker: usize },
    /// A pull edge resolved as delivered at the receiver.
    TransferDone { from: usize, to: usize },
    /// A pull edge exhausted its retry budget (dead-lettered); the
    /// receiver waited out the backoff schedule until its round work
    /// ended.
    RetryTimeout { from: usize, to: usize },
    /// An evaluation snapshot is due at this round boundary.
    EvalDue { round: usize },
    /// The scenario timeline has entries to apply at this round
    /// boundary.
    ScenarioDue { round: usize },
}

struct Entry {
    time: f64,
    seq: u64,
    ev: SimEvent,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time) == Ordering::Equal
            && self.seq == other.seq
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed on both keys: BinaryHeap pops its maximum, we want
        // the earliest time and, within a time, the earliest insertion
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-queue of [`SimEvent`]s.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Enqueue `ev` at `time` (virtual seconds or rounds — the queue is
    /// unit-agnostic).
    pub fn push(&mut self, time: f64, ev: SimEvent) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time, seq, ev });
    }

    /// Timestamp of the earliest pending event.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the earliest pending event.
    pub fn pop(&mut self) -> Option<(f64, SimEvent)> {
        self.heap.pop().map(|e| (e.time, e.ev))
    }

    /// Pop the earliest pending event iff its timestamp is ≤ `time`.
    pub fn pop_due(&mut self, time: f64) -> Option<(f64, SimEvent)> {
        match self.heap.peek() {
            Some(e) if e.time.total_cmp(&time) != Ordering::Greater => {
                self.pop()
            }
            _ => None,
        }
    }

    /// Drain every pending event and return the latest timestamp — the
    /// round barrier H_t when the queue holds one round's completions.
    /// `None` when the queue is empty (an empty plan).
    pub fn drain_last_time(&mut self) -> Option<f64> {
        let mut last = None;
        while let Some(e) = self.heap.pop() {
            last = Some(e.time);
        }
        last
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_with_fifo_ties() {
        let mut q = EventQueue::new();
        q.push(2.0, SimEvent::ActivationDone { worker: 0 });
        q.push(1.0, SimEvent::ActivationDone { worker: 1 });
        q.push(2.0, SimEvent::RetryTimeout { from: 3, to: 4 });
        q.push(1.0, SimEvent::TransferDone { from: 5, to: 6 });
        assert_eq!(q.len(), 4);
        // time 1.0 first, FIFO within the tie
        assert_eq!(q.pop(), Some((1.0, SimEvent::ActivationDone { worker: 1 })));
        assert_eq!(q.pop(), Some((1.0, SimEvent::TransferDone { from: 5, to: 6 })));
        assert_eq!(q.pop(), Some((2.0, SimEvent::ActivationDone { worker: 0 })));
        assert_eq!(q.pop(), Some((2.0, SimEvent::RetryTimeout { from: 3, to: 4 })));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn drain_last_time_is_the_maximum_timestamp() {
        let mut q = EventQueue::new();
        assert_eq!(q.drain_last_time(), None);
        for (t, w) in [(0.5, 0), (3.25, 1), (1.75, 2)] {
            q.push(t, SimEvent::ActivationDone { worker: w });
        }
        // drained max must equal the fold-max bit-for-bit
        let fold = [0.5f64, 3.25, 1.75].iter().fold(0.0f64, |a, &b| a.max(b));
        assert_eq!(q.drain_last_time().unwrap().to_bits(), fold.to_bits());
        assert!(q.is_empty());
    }

    #[test]
    fn pop_due_respects_the_boundary() {
        let mut q = EventQueue::new();
        q.push(10.0, SimEvent::EvalDue { round: 10 });
        q.push(20.0, SimEvent::EvalDue { round: 20 });
        assert_eq!(q.pop_due(9.0), None);
        assert_eq!(q.pop_due(10.0), Some((10.0, SimEvent::EvalDue { round: 10 })));
        assert_eq!(q.pop_due(10.0), None);
        assert_eq!(q.peek_time(), Some(20.0));
        assert_eq!(q.pop_due(25.0), Some((20.0, SimEvent::EvalDue { round: 20 })));
        assert!(q.is_empty());
    }

    #[test]
    fn identical_push_sequences_drain_identically() {
        let seq = [
            (1.5, SimEvent::ActivationDone { worker: 7 }),
            (0.25, SimEvent::ScenarioDue { round: 3 }),
            (1.5, SimEvent::TransferDone { from: 1, to: 2 }),
            (0.25, SimEvent::ActivationDone { worker: 9 }),
            (2.0, SimEvent::RetryTimeout { from: 0, to: 7 }),
        ];
        let drain = |events: &[(f64, SimEvent)]| {
            let mut q = EventQueue::new();
            for &(t, e) in events {
                q.push(t, e);
            }
            let mut out = Vec::new();
            while let Some(x) = q.pop() {
                out.push(x);
            }
            out
        };
        assert_eq!(drain(&seq), drain(&seq));
        // and the order itself is the (time, insertion) order
        let got = drain(&seq);
        assert_eq!(got[0].1, SimEvent::ScenarioDue { round: 3 });
        assert_eq!(got[1].1, SimEvent::ActivationDone { worker: 9 });
        assert_eq!(got[2].1, SimEvent::ActivationDone { worker: 7 });
        assert_eq!(got[3].1, SimEvent::TransferDone { from: 1, to: 2 });
        assert_eq!(got[4].1, SimEvent::RetryTimeout { from: 0, to: 7 });
    }

    #[test]
    fn clear_resets_pending_events() {
        let mut q = EventQueue::new();
        q.push(1.0, SimEvent::ActivationDone { worker: 0 });
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
    }
}
