//! Model transport layer: what actually crosses the wire when a model
//! is exchanged, and what it costs in bytes.
//!
//! The paper's headline result is a 57.1% cut in communication resource
//! consumption, but `transfers × model_bits` accounting makes every push
//! cost the same dense payload regardless of content. This layer makes
//! the comm-overhead axis a measured quantity: every model exchange in
//! both execution backends is routed through a codec
//! (`transport.codec=dense|topk|int8`), realized transfer times scale
//! with the *encoded* payload size, and the metrics record real bytes
//! ([`RoundRecord::bytes_sent`](crate::metrics::RoundRecord),
//! [`RunResult::cum_bytes`](crate::metrics::RunResult)).
//!
//! # Codecs
//!
//! * **`dense`** (default) — the identity transport: full f32 payload,
//!   bit-identical semantics *and* byte accounting to the pre-transport
//!   engine (`bytes = transfers × model_bits / 8`).
//! * **`topk`** — delta sparsification with per-worker error feedback:
//!   each sender tracks the reconstruction its receivers hold and
//!   transmits the k largest-magnitude entries of
//!   `delta = params − reconstruction`; untransmitted coordinates stay
//!   in the delta and are retried next time (the classic error-feedback
//!   residual), so the reconstruction converges to the true model over
//!   repeated transmissions. Payload: k × (4-byte index + 4-byte value)
//!   + an 8-byte header.
//! * **`int8`** — uniform quantization into 255 levels over
//!   `[-clip, +clip]` (`transport.int8_clip`): decode error is bounded
//!   by `clip / 255` for in-range values. Payload: 1 byte per parameter
//!   + a 4-byte scale.
//!
//! # Wire size vs. semantic size
//!
//! The simulator deliberately decouples the *simulated* wire payload
//! (`net.payload_bits`, ~a small CNN) from the *actual* trained model
//! (a tiny softmax regression), so topology efficiency matters at
//! paper-realistic transfer times while sims stay fast. Codecs preserve
//! that split: the **semantic** transform (what values receivers
//! aggregate) runs on the real parameter vector, while the **byte
//! accounting** applies the codec's compression profile to the simulated
//! payload. Both backends charge one encoded message per transfer edge
//! (unicast accounting, matching the pre-transport ledger).
//!
//! # Determinism
//!
//! Codec state mutates only on the coordinator (encode happens at round
//! boundaries in a fixed order: pull sources ascending, push sources in
//! plan order), and pool tasks only *read* reconstructions, so runs stay
//! bit-identical for every `run.threads` setting with any codec active
//! — witnessed by `determinism_topk_threads_1_vs_4` in `BENCH_sim.json`
//! and pinned by `tests/transport.rs`.

pub mod wire;

use crate::config::{CodecKind, TransportConfig};
use crate::worker::Params;

/// Collect the unique pull sources of a round plan into `buf`,
/// **ascending** — the fixed encode order both backends share. The
/// ordering is load-bearing: stateful codecs mutate per-sender state on
/// encode, so the cross-backend/cross-thread-count determinism contract
/// (DESIGN.md §Transport) requires every engine to encode the same
/// senders in the same sequence.
pub fn unique_pull_sources(pulls_from: &[Vec<usize>], buf: &mut Vec<usize>) {
    buf.clear();
    for pf in pulls_from {
        buf.extend(pf.iter().copied());
    }
    buf.sort_unstable();
    buf.dedup();
}

/// Per-run transport state: the codec configuration plus, for stateful
/// codecs, the per-worker reconstruction every receiver observes.
///
/// Mutation (`encode`, `reset_worker`) is coordinator-only; shared
/// references are handed to pool tasks, which only read (`view`).
pub struct Transport {
    cfg: TransportConfig,
    /// Actual parameter count (dimension of the semantic transform).
    param_count: usize,
    /// Simulated dense payload of one message, in bits (the engine's
    /// `model_bits`: `net.payload_bits`, or `param_count × 32` when 0).
    dense_bits: f64,
    /// TopK: entries kept per encode on the real parameter vector.
    k: usize,
    /// Wire size of one encoded message, in bytes (data-independent:
    /// TopK pads to k entries, Int8 is fixed-width).
    bytes_per_msg: f64,
    /// Per-worker reconstruction (what receivers observe). Empty for
    /// the dense codec — the identity transport keeps no state.
    recon: Vec<Params>,
    /// Scratch: current delta (TopK), reused across encodes.
    delta: Vec<f32>,
    /// Scratch: index buffer for top-k selection.
    idx: Vec<usize>,
}

impl Transport {
    /// Build the transport for `workers` slots over a `param_count`-dim
    /// model whose simulated dense payload is `dense_bits` bits.
    pub fn new(
        cfg: TransportConfig,
        workers: usize,
        param_count: usize,
        dense_bits: f64,
    ) -> Self {
        let k = ((cfg.topk_frac * param_count as f64).ceil() as usize)
            .clamp(1, param_count.max(1));
        // wire-side entry count: the codec's profile applied to the
        // simulated payload (dense_bits/32 f32 "wire parameters")
        let wire_params = dense_bits / 32.0;
        let bytes_per_msg = match cfg.codec {
            CodecKind::Dense => dense_bits / 8.0,
            // k × (4-byte index + 4-byte value) + 8-byte header
            CodecKind::TopK => {
                (cfg.topk_frac * wire_params).ceil().max(1.0) * 8.0 + 8.0
            }
            // 1 byte per wire parameter + 4-byte scale
            CodecKind::Int8 => wire_params + 4.0,
        };
        let recon = match cfg.codec {
            CodecKind::Dense => Vec::new(),
            _ => vec![vec![0.0; param_count]; workers],
        };
        Transport {
            cfg,
            param_count,
            dense_bits,
            k,
            bytes_per_msg,
            recon,
            delta: Vec::new(),
            idx: Vec::new(),
        }
    }

    pub fn codec(&self) -> CodecKind {
        self.cfg.codec
    }

    /// Is this the identity transport? Engines skip encode/decode state
    /// entirely on this path, keeping it bit-identical to the
    /// pre-transport hot path.
    pub fn is_dense(&self) -> bool {
        matches!(self.cfg.codec, CodecKind::Dense)
    }

    /// Wire size of one encoded message, in bytes.
    pub fn message_bytes(&self) -> f64 {
        self.bytes_per_msg
    }

    /// Wire size of one encoded message, in bits — what realized
    /// transfer times consume. Dense returns the engine's `model_bits`
    /// value verbatim (no arithmetic round trip).
    pub fn message_bits(&self) -> f64 {
        match self.cfg.codec {
            CodecKind::Dense => self.dense_bits,
            _ => self.bytes_per_msg * 8.0,
        }
    }

    /// Encode worker `w`'s current model for transmission, advancing the
    /// codec state receivers mirror; returns the message size in bytes.
    /// Dense is a stateless no-op. Coordinator-only: call once per
    /// transmitting worker per round, in a deterministic order.
    pub fn encode(&mut self, w: usize, params: &[f32]) -> f64 {
        match self.cfg.codec {
            CodecKind::Dense => {}
            CodecKind::TopK => {
                debug_assert_eq!(params.len(), self.param_count);
                let recon = &self.recon[w];
                self.delta.clear();
                self.delta.extend(
                    params.iter().zip(recon.iter()).map(|(p, r)| p - r),
                );
                self.idx.clear();
                self.idx.extend(0..params.len());
                if self.k < params.len() {
                    let delta = &self.delta;
                    // descending |delta|: the k largest land in ..k
                    self.idx.select_nth_unstable_by(self.k - 1, |&a, &b| {
                        delta[b].abs().total_cmp(&delta[a].abs())
                    });
                }
                let recon = &mut self.recon[w];
                for &i in &self.idx[..self.k.min(params.len())] {
                    // the transmitted value is the f32 delta itself;
                    // receivers apply it to their mirrored reconstruction
                    recon[i] += self.delta[i];
                }
            }
            CodecKind::Int8 => {
                let clip = self.cfg.int8_clip as f32;
                // 255 levels over [-clip, clip]: half-step = clip/255
                let scale = clip / 127.5;
                let recon = &mut self.recon[w];
                for (r, &x) in recon.iter_mut().zip(params) {
                    let q = (x.clamp(-clip, clip) / scale)
                        .round()
                        .clamp(-127.0, 127.0);
                    *r = q * scale;
                }
            }
        }
        self.bytes_per_msg
    }

    /// The model receivers observe for worker `w`: the codec
    /// reconstruction, or `dense` (the worker's true parameters) for the
    /// identity transport.
    pub fn view<'a>(&'a self, w: usize, dense: &'a [f32]) -> &'a [f32] {
        if self.recon.is_empty() {
            dense
        } else {
            &self.recon[w]
        }
    }

    /// The decoded reconstruction for worker `w`, or `None` under the
    /// dense codec (receivers read the true parameters directly).
    pub fn decoded(&self, w: usize) -> Option<&[f32]> {
        if self.recon.is_empty() {
            None
        } else {
            Some(&self.recon[w])
        }
    }

    /// Scenario `Join`: a fresh device takes the slot, so receivers
    /// have no transmission history for it — reset its reconstruction.
    pub fn reset_worker(&mut self, w: usize) {
        if let Some(r) = self.recon.get_mut(w) {
            r.fill(0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CodecKind, TransportConfig};
    use crate::util::rng::Pcg;

    fn cfg(codec: CodecKind) -> TransportConfig {
        TransportConfig { codec, ..Default::default() }
    }

    fn random_params(p: usize, seed: u64) -> Vec<f32> {
        Pcg::seeded(seed).normal_vec(p, 0.0, 0.5)
    }

    #[test]
    fn dense_is_stateless_identity() {
        let mut t = Transport::new(cfg(CodecKind::Dense), 4, 100, 3200.0);
        let params = random_params(100, 1);
        assert!(t.is_dense());
        assert_eq!(t.encode(0, &params), 400.0);
        // view hands back the exact dense slice — same pointer, same bits
        let v = t.view(0, &params);
        assert!(std::ptr::eq(v, params.as_slice()));
        assert!(t.decoded(0).is_none());
        // message_bits is the dense payload verbatim, no round trip
        assert_eq!(t.message_bits().to_bits(), 3200f64.to_bits());
    }

    #[test]
    fn topk_error_feedback_converges_on_frozen_params() {
        // repeated transmissions of the same model must drain the
        // residual: after ceil(1/frac) encodes every coordinate has been
        // transmitted at least once, and a couple more passes absorb the
        // f32 rounding of the += application
        let mut t = Transport::new(cfg(CodecKind::TopK), 2, 200, 6400.0);
        let params = random_params(200, 2);
        for _ in 0..14 {
            t.encode(0, &params);
        }
        let recon = t.decoded(0).unwrap();
        let err = recon
            .iter()
            .zip(&params)
            .map(|(r, p)| (r - p).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-5, "residual not drained: max err {err}");
    }

    #[test]
    fn topk_transmitted_updates_plus_residual_sum_to_true_delta() {
        // over rounds of a *moving* model: Σ transmitted sparse updates
        // (telescoping reconstruction diffs) + the current residual must
        // equal the total model displacement from the zero reference
        let p = 64;
        let mut t = Transport::new(cfg(CodecKind::TopK), 1, p, 2048.0);
        let mut sum_updates = vec![0.0f32; p];
        let mut params = random_params(p, 3);
        for round in 0..6 {
            // the model drifts between transmissions
            for (i, v) in params.iter_mut().enumerate() {
                *v += ((round * p + i) % 7) as f32 * 0.01 - 0.03;
            }
            let before: Vec<f32> = t.decoded(0).unwrap().to_vec();
            t.encode(0, &params);
            for ((s, a), b) in
                sum_updates.iter_mut().zip(t.decoded(0).unwrap()).zip(&before)
            {
                *s += a - b;
            }
        }
        let recon = t.decoded(0).unwrap();
        for (i, ((s, r), pv)) in
            sum_updates.iter().zip(recon).zip(&params).enumerate()
        {
            // updates telescope exactly to the reconstruction
            assert!((s - r).abs() < 1e-6, "entry {i}: sum {s} vs recon {r}");
            // reconstruction + residual = params, by residual definition
            let residual = pv - r;
            assert!(
                (r + residual - pv).abs() < 1e-6,
                "entry {i}: recon {r} + residual {residual} != {pv}"
            );
        }
    }

    #[test]
    fn topk_only_k_entries_change_per_encode() {
        let p = 100;
        let mut t = Transport::new(
            TransportConfig {
                codec: CodecKind::TopK,
                topk_frac: 0.1,
                ..Default::default()
            },
            1,
            p,
            3200.0,
        );
        let params = random_params(p, 4);
        t.encode(0, &params);
        let changed =
            t.decoded(0).unwrap().iter().filter(|&&v| v != 0.0).count();
        assert!(changed <= 10, "k=10 but {changed} entries changed");
        assert!(changed > 0);
    }

    #[test]
    fn int8_decode_error_bounded_by_clip_over_255() {
        let p = 500;
        let clip = 0.8f64;
        let mut t = Transport::new(
            TransportConfig {
                codec: CodecKind::Int8,
                int8_clip: clip,
                ..Default::default()
            },
            1,
            p,
            16000.0,
        );
        // values spanning the full in-range band, including ±clip
        let params: Vec<f32> = (0..p)
            .map(|i| (i as f32 / (p - 1) as f32 * 2.0 - 1.0) * clip as f32)
            .collect();
        t.encode(0, &params);
        let bound = (clip / 255.0) as f32;
        for (i, (r, x)) in t.decoded(0).unwrap().iter().zip(&params).enumerate()
        {
            let err = (r - x).abs();
            assert!(
                err <= bound * 1.001 + 1e-7,
                "entry {i}: |{r} - {x}| = {err} > clip/255 = {bound}"
            );
        }
    }

    #[test]
    fn int8_out_of_range_values_clamp_to_clip() {
        let mut t = Transport::new(
            TransportConfig {
                codec: CodecKind::Int8,
                int8_clip: 1.0,
                ..Default::default()
            },
            1,
            2,
            64.0,
        );
        t.encode(0, &[5.0, -5.0]);
        let r = t.decoded(0).unwrap();
        let top = 127.0f32 / 127.5;
        assert!((r[0] - top).abs() < 1e-6);
        assert!((r[1] + top).abs() < 1e-6);
    }

    #[test]
    fn wire_bytes_follow_codec_profiles() {
        // simulated payload: 2e6 bits = 250 KB dense, 62500 wire params
        let bits = 2.0e6;
        let dense = Transport::new(cfg(CodecKind::Dense), 1, 330, bits);
        let topk = Transport::new(cfg(CodecKind::TopK), 1, 330, bits);
        let int8 = Transport::new(cfg(CodecKind::Int8), 1, 330, bits);
        assert_eq!(dense.message_bytes(), 250_000.0);
        // topk_frac=0.1 → 6250 entries × 8 B + 8 B header = 50008 B: 5×
        assert_eq!(topk.message_bytes(), 50_008.0);
        assert!(dense.message_bytes() / topk.message_bytes() > 4.0);
        // int8 → 62500 B + 4 B scale: ~4×
        assert_eq!(int8.message_bytes(), 62_504.0);
        assert!(dense.message_bytes() / int8.message_bytes() > 3.9);
    }

    #[test]
    fn unique_pull_sources_is_ascending_and_deduped() {
        let plan = vec![vec![5, 2], vec![2, 9, 0], vec![], vec![5]];
        let mut buf = vec![99]; // stale content must be cleared
        unique_pull_sources(&plan, &mut buf);
        assert_eq!(buf, vec![0, 2, 5, 9]);
        unique_pull_sources(&[], &mut buf);
        assert!(buf.is_empty());
    }

    #[test]
    fn reset_worker_clears_reconstruction() {
        let mut t = Transport::new(cfg(CodecKind::TopK), 2, 50, 1600.0);
        let params = random_params(50, 6);
        t.encode(1, &params);
        assert!(t.decoded(1).unwrap().iter().any(|&v| v != 0.0));
        t.reset_worker(1);
        assert!(t.decoded(1).unwrap().iter().all(|&v| v == 0.0));
        // dense: a no-op, never panics
        let mut d = Transport::new(cfg(CodecKind::Dense), 2, 50, 1600.0);
        d.reset_worker(1);
    }
}
