//! Length-prefixed wire format for the socket deployment backend.
//!
//! Every message on a deployment socket is one frame:
//!
//! ```text
//! [magic  u32 LE = "DYF1"]
//! [len    u32 LE]          payload length in bytes
//! [seq    u64 LE]          per-sender sequence number (dedup window)
//! [payload len bytes]
//! [crc    u32 LE]          CRC32 over the payload (delivery::crc32)
//! ```
//!
//! The CRC is carried verbatim from [`Frame`], so a frame read off the
//! wire still fails [`Frame::check`] if the payload was corrupted in
//! flight — the same end-to-end integrity check the simulated delivery
//! layer models. Garbage prefixes (bad magic) and absurd lengths are
//! rejected with [`io::ErrorKind::InvalidData`] before any allocation;
//! truncated streams surface as [`io::ErrorKind::UnexpectedEof`] from
//! `read_exact`.

use std::io::{self, Read, Write};

use crate::delivery::Frame;

/// Frame preamble: `b"DYF1"` read as a little-endian u32. A peer that
/// is not speaking this protocol fails on the first four bytes.
pub const FRAME_MAGIC: u32 = u32::from_le_bytes(*b"DYF1");

/// Upper bound on a single frame payload (64 MiB). Far above any model
/// snapshot this repo ships; its job is to turn a corrupted length
/// field into a clean error instead of an OOM-sized allocation.
pub const MAX_PAYLOAD_BYTES: usize = 64 << 20;

/// Serialize one frame to `w`. Errors only on I/O failure or a payload
/// exceeding [`MAX_PAYLOAD_BYTES`].
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> io::Result<()> {
    if frame.payload.len() > MAX_PAYLOAD_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!(
                "frame payload {} bytes exceeds cap {}",
                frame.payload.len(),
                MAX_PAYLOAD_BYTES
            ),
        ));
    }
    let mut header = [0u8; 16];
    header[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    header[4..8].copy_from_slice(&(frame.payload.len() as u32).to_le_bytes());
    header[8..16].copy_from_slice(&frame.seq.to_le_bytes());
    w.write_all(&header)?;
    w.write_all(&frame.payload)?;
    w.write_all(&frame.crc.to_le_bytes())
}

/// Read one frame from `r`, validating magic and length before
/// allocating. The wire CRC is preserved (not recomputed), so callers
/// detect in-flight corruption via [`Frame::check`].
pub fn read_frame(r: &mut impl Read) -> io::Result<Frame> {
    let mut header = [0u8; 16];
    r.read_exact(&mut header)?;
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != FRAME_MAGIC {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("bad frame magic {magic:#010x} (expected {FRAME_MAGIC:#010x})"),
        ));
    }
    let len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds cap {MAX_PAYLOAD_BYTES}"),
        ));
    }
    let seq = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let mut crc = [0u8; 4];
    r.read_exact(&mut crc)?;
    Ok(Frame { seq, payload, crc: u32::from_le_bytes(crc) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_round_trips() {
        let frame = Frame::new(7, vec![1, 2, 3, 250, 0, 9]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        assert_eq!(buf.len(), 16 + frame.payload.len() + 4);
        let back = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(back.seq, frame.seq);
        assert_eq!(back.payload, frame.payload);
        assert_eq!(back.crc, frame.crc);
        assert!(back.check());
    }

    #[test]
    fn empty_payload_round_trips() {
        let frame = Frame::new(0, vec![]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        let back = read_frame(&mut buf.as_slice()).unwrap();
        assert!(back.check());
        assert!(back.payload.is_empty());
    }

    #[test]
    fn bad_magic_is_invalid_data() {
        let frame = Frame::new(1, vec![5; 8]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        buf[0] ^= 0xFF;
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn oversize_length_is_invalid_data() {
        let frame = Frame::new(1, vec![5; 8]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        buf[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut buf.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn truncation_is_unexpected_eof() {
        let frame = Frame::new(3, vec![9; 16]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        for cut in 0..buf.len() {
            let err = read_frame(&mut &buf[..cut]).unwrap_err();
            assert_eq!(
                err.kind(),
                io::ErrorKind::UnexpectedEof,
                "prefix of {cut} bytes"
            );
        }
    }

    #[test]
    fn corrupted_payload_fails_crc_check() {
        let frame = Frame::new(2, vec![0xAB; 32]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        buf[16] ^= 0x01; // first payload byte
        let back = read_frame(&mut buf.as_slice()).unwrap();
        assert!(!back.check());
    }
}
