//! Reliable delivery layer: deterministic lossy-link fault injection +
//! ack/retry/backoff with graceful per-round degradation.
//!
//! Every model exchange shipped so far succeeds atomically — only the
//! scenario engine's wholesale `Crash` ever drops an in-flight model.
//! This layer sits between the transport codecs and both execution
//! backends and makes link failure a first-class, measured quantity:
//!
//! * **Fault model** — per-frame loss, duplication, single/multi-bit
//!   corruption and latency spikes (`faults.loss`, `faults.dup`,
//!   `faults.corrupt`, `faults.delay_spike`; preset
//!   `faults.profile=clean|wifi|cellular|hostile`). Outcomes are drawn
//!   on a dedicated per-edge RNG stream keyed purely by
//!   `(seed, round, from, to)` ([`Pcg::edge_stream`]) — like the
//!   scenario and adversary streams, nothing delivery-related touches
//!   the substrate streams, so seeded runs stay bit-identical across
//!   thread counts *and* both backends resolve identical outcomes for
//!   the same edge regardless of dispatch order.
//! * **Reliable protocol** — every encoded payload travels in a
//!   [`Frame`] carrying a per-edge sequence number and a CRC32 over the
//!   encoded bytes. Corruption is detected post-codec by the CRC check;
//!   lost or corrupt frames are retransmitted after an ack timeout with
//!   capped exponential backoff plus deterministic jitter, up to a
//!   per-edge retry budget (`faults.retries`). Duplicated frames are
//!   discarded by the receiver's sequence check, so they cost wire
//!   bytes but never double-aggregate.
//! * **Graceful degradation** — a pull edge that exhausts its budget
//!   inside the round deadline is **dead-lettered**: the receiver
//!   aggregates whatever arrived (the paper's staleness semantics
//!   already tolerate missing neighbors), and the drop is recorded in
//!   the round metrics
//!   ([`RoundRecord::dropped_msgs`](crate::metrics::RoundRecord)) and
//!   the event log (`dead-letter` [`EventRecord`]s).
//!
//! # Accounting identities
//!
//! Per resolved edge, [`EdgeOutcome`] satisfies
//! `frames = delivered + duplicates + lost + corrupt` (every frame on
//! the wire is accepted, discarded as a duplicate, dropped in transit,
//! or rejected by CRC) and `retransmissions = frames − 1` (the first
//! transmission is the planned transfer; everything beyond it is
//! surcharge). Engines charge retransmitted frames real measured bytes
//! — `bytes_sent = (transfers + retransmissions) × message_bytes` — so
//! the codec figures show comm overhead growing with loss.
//!
//! The default (`faults.profile=clean`) is knob-inert:
//! [`Delivery::is_active`] is `false`, [`Delivery::resolve`] returns
//! [`EdgeOutcome::CLEAN`] without constructing an RNG, both engines
//! skip every delivery branch, and runs stay bit-identical to the
//! pre-delivery engine for every backend × codec × model.
//!
//! [`EventRecord`]: crate::metrics::EventRecord

use crate::config::FaultConfig;
use crate::util::rng::Pcg;

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) lookup table,
/// built at compile time — the crate carries no dependencies, so the
/// checksum is hand-rolled.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 (IEEE) of `data` — the frame check every encoded payload
/// carries. Detects all single-bit flips (and all burst errors up to 32
/// bits) in the payload.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// One wire frame of the delivery protocol: a per-edge sequence number,
/// the encoded payload bytes, and a CRC32 over the payload. Receivers
/// reject frames whose CRC check fails (triggering a retransmission)
/// and discard frames whose sequence number they have already accepted
/// (so duplicates never double-aggregate).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    /// Per-edge sequence number (monotone per `(from, to)` link).
    pub seq: u64,
    /// Encoded payload bytes (post-codec).
    pub payload: Vec<u8>,
    /// CRC32 over `payload`, computed at send time.
    pub crc: u32,
}

impl Frame {
    /// Seal `payload` into a frame, computing its CRC.
    pub fn new(seq: u64, payload: Vec<u8>) -> Self {
        let crc = crc32(&payload);
        Frame { seq, payload, crc }
    }

    /// Receiver-side integrity check: does the payload still match the
    /// CRC computed at send time?
    pub fn check(&self) -> bool {
        crc32(&self.payload) == self.crc
    }

    /// Flip one payload bit in place (fault injection: single-bit
    /// corruption in transit). `bit` indexes the payload bit-string;
    /// out-of-range is a no-op.
    pub fn flip_bit(&mut self, bit: usize) {
        if let Some(byte) = self.payload.get_mut(bit / 8) {
            *byte ^= 1 << (bit % 8);
        }
    }
}

/// Receiver-side duplicate suppression: tracks the highest sequence
/// number accepted per link and rejects replays. One instance per
/// receiver; links are keyed by sender id.
#[derive(Clone, Debug, Default)]
pub struct DedupWindow {
    /// Highest accepted seq per sender, `None` until the first accept.
    accepted: Vec<Option<u64>>,
}

impl DedupWindow {
    pub fn new(senders: usize) -> Self {
        DedupWindow { accepted: vec![None; senders] }
    }

    /// Accept `seq` from `sender` if it is fresh; returns `false` for a
    /// duplicate (already-accepted) frame, which the caller must
    /// discard without aggregating.
    pub fn accept(&mut self, sender: usize, seq: u64) -> bool {
        match self.accepted[sender] {
            Some(last) if seq <= last => false,
            _ => {
                self.accepted[sender] = Some(seq);
                true
            }
        }
    }
}

/// The resolved fate of one directed pull edge in one round: how many
/// frames crossed the wire, what happened to each, and what the retry
/// protocol cost in time. A pure function of `(seed, round, from, to)`
/// and the fault knobs — see [`Delivery::resolve`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EdgeOutcome {
    /// Did the payload get through within the retry budget? `false`
    /// means the edge was dead-lettered and the receiver aggregates
    /// without it.
    pub delivered: bool,
    /// Total frames on the wire: attempts plus the suppressed
    /// duplicate, if any. Always ≥ 1.
    pub frames: u32,
    /// Frames dropped in transit (never reached the receiver).
    pub lost: u32,
    /// Frames that arrived corrupted and were rejected by the CRC
    /// check (treated as loss: retransmitted).
    pub corrupt: u32,
    /// Did the accepted frame also arrive duplicated? The duplicate is
    /// discarded by the sequence check — charged bytes, never
    /// aggregated, adds no time.
    pub duplicate: bool,
    /// Σ per-attempt transfer-time multipliers (1.0 per clean attempt,
    /// `faults.delay_spike_factor` per spiked one). The edge's transfer
    /// time is `base × transfer_mult + backoff_s`.
    pub transfer_mult: f64,
    /// Σ ack-timeout backoff seconds accrued between attempts (capped
    /// exponential with deterministic jitter).
    pub backoff_s: f64,
}

impl EdgeOutcome {
    /// The lossless identity outcome: delivered first try, one frame,
    /// no surcharge. What [`Delivery::resolve`] returns — without
    /// touching an RNG — when the fault model is inactive.
    pub const CLEAN: EdgeOutcome = EdgeOutcome {
        delivered: true,
        frames: 1,
        lost: 0,
        corrupt: 0,
        duplicate: false,
        transfer_mult: 1.0,
        backoff_s: 0.0,
    };

    /// Frames beyond the planned first transmission — the byte-ledger
    /// surcharge this edge incurred.
    pub fn retransmissions(&self) -> usize {
        self.frames as usize - 1
    }

    /// Realized wall time of this edge given the clean one-attempt
    /// transfer time `base_s`.
    pub fn time_s(&self, base_s: f64) -> f64 {
        base_s * self.transfer_mult + self.backoff_s
    }
}

/// Per-round delivery ledger: the sums both engines accumulate on the
/// coordinator and flush into
/// [`RoundRecord`](crate::metrics::RoundRecord) at round end.
/// Conservation — `frames = delivered + duplicates + lost + corrupt` —
/// holds by construction because it holds per [`EdgeOutcome`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeliveryTally {
    /// Total frames on the wire this round (pull edges).
    pub frames: usize,
    /// Frames accepted by receivers (one per delivered edge).
    pub delivered: usize,
    /// Duplicate frames discarded by the sequence check.
    pub duplicates: usize,
    /// Frames dropped in transit.
    pub lost: usize,
    /// Frames rejected by the CRC check.
    pub corrupt: usize,
    /// Frames beyond the planned transmissions (the byte surcharge).
    pub retransmissions: usize,
    /// Pull edges that exhausted their retry budget this round.
    pub dead_lettered: usize,
    /// In-flight models dropped by scenario `Crash` events this round
    /// (push-path losses, routed through this ledger so every dropped
    /// message is accounted in one place).
    pub crash_dropped: usize,
}

impl DeliveryTally {
    /// Fold one resolved edge into the round sums.
    pub fn add(&mut self, out: &EdgeOutcome) {
        self.frames += out.frames as usize;
        self.delivered += out.delivered as usize;
        self.duplicates += out.duplicate as usize;
        self.lost += out.lost as usize;
        self.corrupt += out.corrupt as usize;
        self.retransmissions += out.retransmissions();
        self.dead_lettered += !out.delivered as usize;
    }

    /// Fold another tally (one activation's partial sums, folded on the
    /// coordinator in plan order) into this round's ledger.
    pub fn merge(&mut self, other: &DeliveryTally) {
        self.frames += other.frames;
        self.delivered += other.delivered;
        self.duplicates += other.duplicates;
        self.lost += other.lost;
        self.corrupt += other.corrupt;
        self.retransmissions += other.retransmissions;
        self.dead_lettered += other.dead_lettered;
        self.crash_dropped += other.crash_dropped;
    }

    /// Messages that never reached an aggregation: transit losses,
    /// plus in-flight models dropped by crashes — the
    /// `RoundRecord::dropped_msgs` column. (CRC rejections are reported
    /// separately as `corrupt_detected`.)
    pub fn dropped_msgs(&self) -> usize {
        self.lost + self.crash_dropped
    }

    /// Reset for the next round.
    pub fn clear(&mut self) {
        *self = DeliveryTally::default();
    }
}

/// The per-run delivery state: the fault knobs plus the run seed that
/// keys every per-edge stream. Deliberately stateless beyond
/// configuration — outcome resolution is a pure function of
/// `(seed, round, from, to)`, which is what lets both backends (and any
/// thread count) agree on every ledger entry.
#[derive(Clone, Debug)]
pub struct Delivery {
    cfg: FaultConfig,
    seed: u64,
    active: bool,
}

impl Delivery {
    /// Build from the `faults.*` knobs and the run seed.
    pub fn from_config(cfg: &FaultConfig, seed: u64) -> Self {
        Delivery { cfg: *cfg, seed, active: cfg.is_active() }
    }

    /// The lossless no-op delivery layer (the `clean` profile).
    pub fn inactive() -> Self {
        Self::from_config(&FaultConfig::default(), 0)
    }

    /// `true` when any fault channel can fire. Both engines gate every
    /// delivery branch on this, so the clean default costs nothing.
    pub fn is_active(&self) -> bool {
        self.active
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Resolve the fate of the pull edge `from → to` in `round`: a pure
    /// function of the key and the knobs, drawn on the edge's dedicated
    /// stream ([`Pcg::edge_stream`]). Per attempt the draws are, in
    /// fixed order: latency spike, transit fate (one uniform split into
    /// loss / corruption / delivery — `validate` guarantees
    /// `loss + corrupt < 1`), then on delivery the duplication draw, or
    /// on failure the backoff jitter draw. Retries stop at delivery or
    /// after `faults.retries` retransmissions, whichever comes first;
    /// budget exhaustion dead-letters the edge.
    pub fn resolve(&self, round: u64, from: usize, to: usize) -> EdgeOutcome {
        if !self.active {
            return EdgeOutcome::CLEAN;
        }
        let mut rng =
            Pcg::edge_stream(self.seed, round, from as u64, to as u64);
        let budget = self.cfg.retries + 1;
        let mut out = EdgeOutcome {
            delivered: false,
            frames: 0,
            lost: 0,
            corrupt: 0,
            duplicate: false,
            transfer_mult: 0.0,
            backoff_s: 0.0,
        };
        for attempt in 0..budget {
            out.frames += 1;
            let spiked = rng.f64() < self.cfg.delay_spike;
            out.transfer_mult += if spiked {
                self.cfg.delay_spike_factor
            } else {
                1.0
            };
            let fate = rng.f64();
            if fate < self.cfg.loss {
                out.lost += 1;
            } else if fate < self.cfg.loss + self.cfg.corrupt {
                out.corrupt += 1;
            } else {
                out.delivered = true;
                if rng.f64() < self.cfg.dup {
                    // a lost ack made the sender retransmit a frame the
                    // receiver already accepted: the duplicate costs
                    // wire bytes, fails the sequence check, and is
                    // discarded without aggregating
                    out.duplicate = true;
                    out.frames += 1;
                }
                break;
            }
            // failed attempt: ack timeout, then capped exponential
            // backoff with deterministic jitter before the next try
            if attempt + 1 < budget {
                let base = (self.cfg.backoff_base_s
                    * 2f64.powi(attempt as i32))
                .min(self.cfg.backoff_cap_s);
                out.backoff_s += base * (1.0 + self.cfg.jitter * rng.f64());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FaultProfile;

    fn faulty(loss: f64, dup: f64, corrupt: f64) -> FaultConfig {
        FaultConfig {
            loss,
            dup,
            corrupt,
            ..FaultConfig::preset(FaultProfile::Clean)
        }
    }

    #[test]
    fn crc32_matches_the_ieee_check_value() {
        // the standard CRC-32 test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn crc_detects_every_single_bit_flip() {
        let payload: Vec<u8> = (0..64u32)
            .flat_map(|i| (i as f32 * 0.37 - 3.0).to_le_bytes())
            .collect();
        let frame = Frame::new(0, payload.clone());
        assert!(frame.check());
        for bit in 0..payload.len() * 8 {
            let mut f = frame.clone();
            f.flip_bit(bit);
            assert!(!f.check(), "bit {bit} flip went undetected");
        }
        // flipping the same bit twice restores integrity
        let mut f = frame.clone();
        f.flip_bit(100);
        f.flip_bit(100);
        assert!(f.check());
    }

    #[test]
    fn dedup_window_discards_replays_but_accepts_fresh_seqs() {
        let mut w = DedupWindow::new(2);
        assert!(w.accept(0, 1));
        assert!(!w.accept(0, 1), "exact replay must be discarded");
        assert!(!w.accept(0, 0), "stale seq must be discarded");
        assert!(w.accept(0, 2));
        // links are independent
        assert!(w.accept(1, 1));
    }

    #[test]
    fn inactive_resolve_is_the_clean_identity() {
        let d = Delivery::inactive();
        assert!(!d.is_active());
        for (r, i, j) in [(0u64, 0usize, 1usize), (5, 3, 2), (99, 7, 0)] {
            assert_eq!(d.resolve(r, i, j), EdgeOutcome::CLEAN);
        }
        let c = EdgeOutcome::CLEAN;
        assert_eq!(c.retransmissions(), 0);
        assert_eq!(c.time_s(0.25).to_bits(), 0.25f64.to_bits());
    }

    #[test]
    fn resolve_is_deterministic_and_edge_keyed() {
        let cfg = FaultConfig::preset(FaultProfile::Cellular);
        let a = Delivery::from_config(&cfg, 7);
        let b = Delivery::from_config(&cfg, 7);
        let mut differs = 0;
        for r in 0..20u64 {
            for i in 0..6 {
                for j in 0..6 {
                    if i == j {
                        continue;
                    }
                    assert_eq!(a.resolve(r, i, j), b.resolve(r, i, j));
                    if a.resolve(r, i, j) != a.resolve(r, j, i) {
                        differs += 1;
                    }
                }
            }
        }
        // directedness: the reversed edge resolves independently
        assert!(differs > 0, "edge outcomes must be directed");
        // a different seed changes outcomes somewhere
        let c = Delivery::from_config(&cfg, 8);
        assert!(
            (0..50u64).any(|r| a.resolve(r, 0, 1) != c.resolve(r, 0, 1)),
            "seed must enter the edge key"
        );
    }

    #[test]
    fn conservation_holds_for_every_outcome() {
        let cfg = FaultConfig {
            retries: 2,
            ..FaultConfig::preset(FaultProfile::Hostile)
        };
        let d = Delivery::from_config(&cfg, 11);
        let mut tally = DeliveryTally::default();
        let (mut seen_dead, mut seen_dup, mut seen_retry) =
            (false, false, false);
        for r in 0..200u64 {
            for to in 0..4usize {
                let out = d.resolve(r, 5, to);
                // per-edge conservation: every frame is accounted once
                assert_eq!(
                    out.frames,
                    out.delivered as u32
                        + out.duplicate as u32
                        + out.lost
                        + out.corrupt,
                    "frames must split exactly: {out:?}"
                );
                assert!(out.frames >= 1);
                if !out.delivered {
                    // dead-letter ⇒ the whole budget burned, no dup
                    assert_eq!(out.frames, cfg.retries as u32 + 1);
                    assert!(!out.duplicate);
                    seen_dead = true;
                }
                seen_dup |= out.duplicate;
                seen_retry |= out.retransmissions() > 0;
                tally.add(&out);
            }
        }
        assert!(seen_dead && seen_dup && seen_retry);
        // the round ledger inherits conservation
        assert_eq!(
            tally.frames,
            tally.delivered + tally.duplicates + tally.lost + tally.corrupt
        );
        assert_eq!(
            tally.delivered + tally.dead_lettered,
            200 * 4,
            "every edge ends delivered or dead-lettered"
        );
        assert_eq!(tally.dropped_msgs(), tally.lost);
    }

    #[test]
    fn lossless_active_profile_delivers_first_try_with_dups_charged() {
        // dup-only faults: every edge delivered on attempt 1; duplicates
        // cost a frame + a retransmission but change nothing else
        let d = Delivery::from_config(&faulty(0.0, 1.0, 0.0), 3);
        assert!(d.is_active());
        let out = d.resolve(0, 1, 2);
        assert!(out.delivered && out.duplicate);
        assert_eq!(out.frames, 2);
        assert_eq!(out.retransmissions(), 1);
        assert_eq!(out.lost + out.corrupt, 0);
        assert_eq!(out.transfer_mult.to_bits(), 1f64.to_bits());
        assert_eq!(out.backoff_s, 0.0);
    }

    #[test]
    fn backoff_is_exponential_capped_and_jitter_free_when_disabled() {
        let cfg = FaultConfig {
            loss: 0.9,
            retries: 5,
            backoff_base_s: 0.1,
            backoff_cap_s: 0.3,
            jitter: 0.0,
            ..FaultConfig::preset(FaultProfile::Clean)
        };
        let d = Delivery::from_config(&cfg, 19);
        for r in 0..100u64 {
            let out = d.resolve(r, 0, 1);
            let fails = (out.lost + out.corrupt) as usize;
            // backoff accrues after every failed attempt except a
            // budget-exhausting final one: 0.1, 0.2, then capped at 0.3
            let waits = if out.delivered { fails } else { fails - 1 };
            let expect: f64 = (0..waits)
                .map(|k| (0.1 * 2f64.powi(k as i32)).min(0.3))
                .sum();
            assert!(
                (out.backoff_s - expect).abs() < 1e-12,
                "round {r}: backoff {} != {expect} ({out:?})",
                out.backoff_s
            );
        }
    }

    #[test]
    fn delay_spikes_inflate_transfer_time() {
        let cfg = FaultConfig {
            delay_spike: 1.0,
            delay_spike_factor: 4.0,
            ..FaultConfig::preset(FaultProfile::Clean)
        };
        let d = Delivery::from_config(&cfg, 23);
        let out = d.resolve(0, 0, 1);
        assert!(out.delivered);
        assert_eq!(out.transfer_mult, 4.0);
        assert_eq!(out.time_s(2.0), 8.0);
    }

    #[test]
    fn zero_retries_dead_letters_on_first_loss() {
        let cfg = FaultConfig {
            loss: 0.5,
            retries: 0,
            ..FaultConfig::preset(FaultProfile::Clean)
        };
        let d = Delivery::from_config(&cfg, 29);
        let outs: Vec<EdgeOutcome> =
            (0..200u64).map(|r| d.resolve(r, 0, 1)).collect();
        assert!(outs.iter().any(|o| !o.delivered));
        for o in &outs {
            assert_eq!(o.frames, 1 + o.duplicate as u32);
            assert_eq!(o.backoff_s, 0.0, "no retries ⇒ no backoff");
        }
        // without retries every loss is a dead letter
        let dead = outs.iter().filter(|o| !o.delivered).count();
        assert!((60..140).contains(&dead), "≈50% expected, got {dead}");
    }

    #[test]
    fn presets_order_by_severity() {
        let seed = 31;
        let dead_rate = |p: FaultProfile| {
            let d = Delivery::from_config(&FaultConfig::preset(p), seed);
            (0..2000u64)
                .filter(|&r| !d.resolve(r, 1, 2).delivered)
                .count()
        };
        let clean = dead_rate(FaultProfile::Clean);
        let wifi = dead_rate(FaultProfile::Wifi);
        let hostile = dead_rate(FaultProfile::Hostile);
        assert_eq!(clean, 0);
        assert!(wifi < hostile, "wifi {wifi} vs hostile {hostile}");
        assert!(hostile > 0);
    }

    #[test]
    fn tally_clear_resets_everything() {
        let d = Delivery::from_config(
            &FaultConfig::preset(FaultProfile::Hostile),
            37,
        );
        let mut t = DeliveryTally::default();
        for r in 0..50u64 {
            t.add(&d.resolve(r, 0, 1));
        }
        t.crash_dropped += 3;
        assert!(t.frames > 0 && t.dropped_msgs() >= 3);
        // merge doubles every sum
        let snapshot = t;
        t.merge(&snapshot);
        assert_eq!(t.frames, snapshot.frames * 2);
        assert_eq!(t.crash_dropped, snapshot.crash_dropped * 2);
        t.clear();
        assert_eq!(t, DeliveryTally::default());
    }
}
