//! Dataset generators beyond the Dirichlet synthetic corpus: the
//! `workload.dataset` registry entries.
//!
//! * [`clusters_corpus`] — **shifted-cluster label-skew**: every class
//!   is a pair of antipodal Gaussian clusters (`+μ_c` and `−μ_c`) whose
//!   mixture weights are skewed linearly across classes
//!   (`workload.cluster_skew`). A linear separator caps out near the
//!   majority-cluster share (its score `w·x` cannot be large at both
//!   `+μ` and `−μ`), while the nonlinear models resolve both modes —
//!   this is the workload where the model axis actually separates
//!   (Fig. 28).
//! * [`drift_corpus`] — **rotated/drifting features**: the base
//!   Gaussian mixture with a rotation applied in the fixed coordinate
//!   planes `(0,1), (2,3), …`. Training samples drift progressively
//!   from 0 up to `workload.drift_deg`; the test set sits at the full
//!   angle, so the eval protocol scores the *drifted* distribution.
//!   [`rotate_dataset`] is the composable primitive: scenario-driven
//!   concept drift can re-rotate shards between rounds.
//! * [`load_file_corpus`] — **on-disk IDX/CSV loader**: drop real
//!   MNIST-class data in without a new build, either as an
//!   `"images.idx,labels.idx"` pair (IDX u8 payloads, pixels scaled to
//!   `[0,1]`) or a `label,f1,f2,…` CSV.

use crate::data::{make_corpus, Dataset, SyntheticSpec};
use crate::util::rng::Pcg;
use std::path::Path;

/// Shifted-cluster label-skew corpus: class `c` mixes `N(+μ_c, I)` and
/// `N(−μ_c, I)` with a `+`-cluster share of
/// `0.5 + (c/(C−1) − 0.5)·skew` (skew 0 ⇒ balanced antipodal pairs,
/// skew 1 ⇒ class 0 fully on `−μ`, class C−1 fully on `+μ`).
///
/// Class means are *waveforms* (class-dependent frequency, random
/// phase) rather than random Gaussian directions: the means carry
/// local pattern structure along the feature axis, the kind a
/// convolution's shared filters exploit — so the workload separates
/// `linear` (capped by the antipodal flip) from `mlp` *and* `cnn-s`,
/// not just from the MLP.
pub fn clusters_corpus(spec: &SyntheticSpec, skew: f64) -> (Dataset, Dataset) {
    let mut rng = Pcg::new(spec.seed, 0xC1A5);
    let tau = std::f64::consts::TAU;
    let means: Vec<Vec<f32>> = (0..spec.num_classes)
        .map(|c| {
            let phase = rng.f64() * tau;
            let freq = (c + 1) as f64;
            let v: Vec<f64> = (0..spec.dim)
                .map(|d| {
                    (tau * freq * d as f64 / spec.dim as f64 + phase).sin()
                })
                .collect();
            let norm =
                v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-9);
            v.iter()
                .map(|x| (x / norm * spec.class_sep) as f32)
                .collect()
        })
        .collect();
    let c_n = spec.num_classes;
    let shares: Vec<f64> = (0..c_n)
        .map(|c| {
            if c_n == 1 {
                0.5
            } else {
                0.5 + (c as f64 / (c_n - 1) as f64 - 0.5) * skew
            }
        })
        .collect();

    let gen = |n: usize, rng: &mut Pcg| -> Dataset {
        let mut features = Vec::with_capacity(n * spec.dim);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            // stratified labels, like the base corpus
            let y = (i % c_n) as u32;
            labels.push(y);
            let sign: f32 =
                if rng.f64() < shares[y as usize] { 1.0 } else { -1.0 };
            let mu = &means[y as usize];
            for d in 0..spec.dim {
                features.push(mu[d] * sign + rng.normal() as f32);
            }
        }
        let ds = Dataset {
            dim: spec.dim,
            num_classes: c_n,
            features,
            labels,
        };
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        ds.subset(&idx)
    };

    let train = gen(spec.train_samples, &mut rng);
    let test = gen(spec.test_samples, &mut rng);
    (train, test)
}

/// Rotate every feature row in place by `angle_deg` degrees, applied in
/// the fixed coordinate planes `(0,1), (2,3), …` (an odd final
/// dimension is left untouched). Norm-preserving, deterministic, and
/// composable: calling it per scenario round yields concept drift.
pub fn rotate_dataset(ds: &mut Dataset, angle_deg: f64) {
    let theta = angle_deg.to_radians();
    let (sin, cos) = (theta.sin() as f32, theta.cos() as f32);
    let dim = ds.dim;
    for row in ds.features.chunks_mut(dim) {
        rotate_row(row, sin, cos);
    }
}

fn rotate_row(row: &mut [f32], sin: f32, cos: f32) {
    let mut j = 0;
    while j + 1 < row.len() {
        let (a, b) = (row[j], row[j + 1]);
        row[j] = a * cos - b * sin;
        row[j + 1] = a * sin + b * cos;
        j += 2;
    }
}

/// Rotated/drifting-features corpus: the base Gaussian mixture with
/// training rows rotated progressively from 0 up to `drift_deg` across
/// the (shuffled) corpus, and the test set rotated by the full
/// `drift_deg` — evaluation scores the drifted distribution.
pub fn drift_corpus(spec: &SyntheticSpec, drift_deg: f64) -> (Dataset, Dataset) {
    let (mut train, mut test) = make_corpus(spec);
    let n = train.len();
    let dim = train.dim;
    let denom = n.saturating_sub(1).max(1) as f64;
    for (i, row) in train.features.chunks_mut(dim).enumerate() {
        let th = (drift_deg * i as f64 / denom).to_radians();
        rotate_row(row, th.sin() as f32, th.cos() as f32);
    }
    rotate_dataset(&mut test, drift_deg);
    (train, test)
}

/// Load an on-disk corpus and split off a deterministic test set.
/// `path` is either `"features.idx,labels.idx"` (IDX pair) or a
/// `label,f1,f2,…` CSV file. The test split takes `test_samples` rows
/// (clamped to at most half the data) after a seeded shuffle.
pub fn load_file_corpus(
    path: &str,
    test_samples: usize,
    seed: u64,
) -> Result<(Dataset, Dataset), String> {
    // route by extension first: a .csv path may legally contain commas
    // in its directory or file name
    let ds = if path.ends_with(".csv") {
        load_csv(Path::new(path))?
    } else if let Some((images, labels)) = path.split_once(',') {
        load_idx(Path::new(images.trim()), Path::new(labels.trim()))?
    } else {
        return Err(format!(
            "workload.path {path:?}: expected \"features.idx,labels.idx\" \
             or a .csv file"
        ));
    };
    if ds.len() < 2 {
        return Err(format!(
            "workload.path {path:?}: corpus has {} samples (need ≥ 2)",
            ds.len()
        ));
    }
    let mut idx: Vec<usize> = (0..ds.len()).collect();
    Pcg::new(seed, 0xF11E).shuffle(&mut idx);
    let t = test_samples.clamp(1, ds.len() / 2);
    let (test_idx, train_idx) = idx.split_at(t);
    Ok((ds.subset(train_idx), ds.subset(test_idx)))
}

fn read_be_u32(bytes: &[u8], off: usize, what: &str) -> Result<u32, String> {
    let s = bytes
        .get(off..off + 4)
        .ok_or_else(|| format!("IDX {what}: truncated header"))?;
    Ok(u32::from_be_bytes([s[0], s[1], s[2], s[3]]))
}

/// Parse one IDX file (u8 payload only — the MNIST family). Returns
/// `(sample count, per-sample length, data)`.
fn parse_idx<'a>(
    bytes: &'a [u8],
    what: &str,
) -> Result<(usize, usize, &'a [u8]), String> {
    if bytes.len() < 4 {
        return Err(format!("IDX {what}: file too short"));
    }
    if bytes[0] != 0 || bytes[1] != 0 {
        return Err(format!("IDX {what}: bad magic prefix"));
    }
    if bytes[2] != 0x08 {
        return Err(format!(
            "IDX {what}: dtype 0x{:02x} unsupported (only u8/0x08)",
            bytes[2]
        ));
    }
    let ndims = bytes[3] as usize;
    if !(1..=3).contains(&ndims) {
        return Err(format!("IDX {what}: {ndims} dims unsupported (1–3)"));
    }
    let n = read_be_u32(bytes, 4, what)? as usize;
    let mut per = 1usize;
    for d in 1..ndims {
        per *= read_be_u32(bytes, 4 + 4 * d, what)? as usize;
    }
    let data = &bytes[4 + 4 * ndims..];
    if data.len() != n * per {
        return Err(format!(
            "IDX {what}: payload {} bytes, header promises {}×{}",
            data.len(),
            n,
            per
        ));
    }
    Ok((n, per, data))
}

/// Load an IDX image/label pair (MNIST-class data). Pixels scale to
/// `[0,1]`; `num_classes` is `max label + 1` (at least 2).
fn load_idx(images: &Path, labels: &Path) -> Result<Dataset, String> {
    let img = std::fs::read(images)
        .map_err(|e| format!("read {}: {e}", images.display()))?;
    let lab = std::fs::read(labels)
        .map_err(|e| format!("read {}: {e}", labels.display()))?;
    let (n_img, dim, pixels) = parse_idx(&img, "features")?;
    let (n_lab, per_lab, label_bytes) = parse_idx(&lab, "labels")?;
    if per_lab != 1 {
        return Err("IDX labels: expected 1 value per sample".into());
    }
    if n_img != n_lab {
        return Err(format!(
            "IDX pair mismatch: {n_img} feature rows vs {n_lab} labels"
        ));
    }
    if dim == 0 {
        return Err("IDX features: zero-length rows".into());
    }
    let features: Vec<f32> =
        pixels.iter().map(|&b| b as f32 / 255.0).collect();
    let labels: Vec<u32> = label_bytes.iter().map(|&b| b as u32).collect();
    let num_classes =
        labels.iter().copied().max().unwrap_or(0) as usize + 1;
    Ok(Dataset {
        dim,
        num_classes: num_classes.max(2),
        features,
        labels,
    })
}

/// Load a `label,f1,f2,…` CSV (one sample per line; an initial header
/// line is skipped if its first field is not numeric).
fn load_csv(path: &Path) -> Result<Dataset, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    // a UTF-8 BOM would otherwise glue itself onto the first label and
    // silently demote a real data row to a "header"
    let text = text.strip_prefix('\u{feff}').unwrap_or(&text);
    let mut features: Vec<f32> = Vec::new();
    let mut labels: Vec<u32> = Vec::new();
    let mut dim: Option<usize> = None;
    let mut first_row = true;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let mut fields = line.split(',');
        let first = fields.next().unwrap_or("").trim();
        let was_first_row = first_row;
        first_row = false;
        let label: f64 = match first.parse() {
            Ok(v) => v,
            // tolerate one header line (the first non-empty row)
            Err(_) if was_first_row => continue,
            Err(_) => {
                return Err(format!(
                    "{} line {}: bad label {first:?}",
                    path.display(),
                    lineno + 1
                ))
            }
        };
        // labels become class indices (num_classes = max + 1): bound
        // them so a stray huge value cannot size the model's output
        // layer into an OOM instead of a clean error
        const MAX_CLASSES: f64 = 4096.0;
        if label < 0.0 || label.fract() != 0.0 || label >= MAX_CLASSES {
            return Err(format!(
                "{} line {}: label {label} is not an integer in \
                 [0, {MAX_CLASSES})",
                path.display(),
                lineno + 1
            ));
        }
        let mut row_len = 0usize;
        for f in fields {
            let v: f32 = f.trim().parse().map_err(|_| {
                format!(
                    "{} line {}: bad feature {f:?}",
                    path.display(),
                    lineno + 1
                )
            })?;
            // "nan"/"inf" parse as f32 but would silently poison every
            // downstream loss — reject them like any other bad field
            if !v.is_finite() {
                return Err(format!(
                    "{} line {}: non-finite feature {f:?}",
                    path.display(),
                    lineno + 1
                ));
            }
            features.push(v);
            row_len += 1;
        }
        match dim {
            None => {
                if row_len == 0 {
                    return Err(format!(
                        "{} line {}: no feature columns",
                        path.display(),
                        lineno + 1
                    ));
                }
                dim = Some(row_len);
            }
            Some(d) if d != row_len => {
                return Err(format!(
                    "{} line {}: {row_len} features, expected {d}",
                    path.display(),
                    lineno + 1
                ))
            }
            _ => {}
        }
        labels.push(label as u32);
    }
    let dim = dim.ok_or_else(|| format!("{}: no data rows", path.display()))?;
    let num_classes =
        labels.iter().copied().max().unwrap_or(0) as usize + 1;
    Ok(Dataset {
        dim,
        num_classes: num_classes.max(2),
        features,
        labels,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(n: usize, t: usize) -> SyntheticSpec {
        SyntheticSpec {
            train_samples: n,
            test_samples: t,
            class_sep: 3.0,
            ..Default::default()
        }
    }

    #[test]
    fn clusters_deterministic_and_stratified() {
        let s = spec(500, 100);
        let (a, at) = clusters_corpus(&s, 0.6);
        let (b, _) = clusters_corpus(&s, 0.6);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.len(), 500);
        assert_eq!(at.len(), 100);
        assert!(a.label_histogram().iter().all(|&c| c == 50));
    }

    #[test]
    fn clusters_are_antipodal_with_skewed_shares() {
        // wide separation keeps the cross-cluster sign flips negligible
        let s = SyntheticSpec { class_sep: 4.0, ..spec(4000, 100) };
        let skew = 0.6;
        let (train, _) = clusters_corpus(&s, skew);
        // recover each class's + cluster share from the sign of the
        // projection onto the class direction (estimated from the data:
        // the dominant ± direction is the per-class mean of sign-folded
        // rows; we just need a consistent axis, so use the first sample
        // of the class as the probe direction)
        let c_n = s.num_classes;
        for c in 0..c_n {
            let rows: Vec<&[f32]> = (0..train.len())
                .filter(|&i| train.labels[i] as usize == c)
                .map(|i| train.feature_row(i))
                .collect();
            let probe = rows[0];
            let frac_pos = rows
                .iter()
                .filter(|r| {
                    r.iter().zip(probe).map(|(a, b)| a * b).sum::<f32>() > 0.0
                })
                .count() as f64
                / rows.len() as f64;
            // the probe sits in one of the two clusters, so the
            // same-side fraction must match that cluster's share (or
            // its complement) — never ~0.5-with-one-mode
            let expect = 0.5 + (c as f64 / (c_n - 1) as f64 - 0.5) * skew;
            let ok = (frac_pos - expect).abs() < 0.1
                || (frac_pos - (1.0 - expect)).abs() < 0.1;
            assert!(ok, "class {c}: frac_pos {frac_pos}, share {expect}");
        }
    }

    #[test]
    fn rotate_preserves_norms_and_zero_angle_is_identity() {
        let s = spec(64, 16);
        let (orig, _) = make_corpus(&s);
        let mut ds = orig.clone();
        rotate_dataset(&mut ds, 0.0);
        assert_eq!(ds.features, orig.features);
        rotate_dataset(&mut ds, 37.0);
        assert_ne!(ds.features, orig.features);
        for i in 0..ds.len() {
            let n0: f64 = orig
                .feature_row(i)
                .iter()
                .map(|x| (*x as f64).powi(2))
                .sum();
            let n1: f64 = ds
                .feature_row(i)
                .iter()
                .map(|x| (*x as f64).powi(2))
                .sum();
            assert!((n0.sqrt() - n1.sqrt()).abs() < 1e-3, "row {i}");
        }
    }

    #[test]
    fn drift_rotates_test_fully_and_train_progressively() {
        let s = spec(200, 50);
        let (train_d, test_d) = drift_corpus(&s, 45.0);
        let (train_0, test_0) = make_corpus(&s);
        // first train row is at angle ~0 → (nearly) untouched
        let first_delta: f32 = train_d
            .feature_row(0)
            .iter()
            .zip(train_0.feature_row(0))
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(first_delta < 1e-3, "first row moved by {first_delta}");
        // last train row is at the full angle → moved
        let last = train_d.len() - 1;
        assert_ne!(train_d.feature_row(last), train_0.feature_row(last));
        // test set fully rotated, labels untouched
        assert_ne!(test_d.features, test_0.features);
        assert_eq!(test_d.labels, test_0.labels);
        // drift 0 is exactly the base corpus
        let (t0, e0) = drift_corpus(&s, 0.0);
        assert_eq!(t0.features, train_0.features);
        assert_eq!(e0.features, test_0.features);
    }

    fn write_idx_pair(dir: &Path, n: usize, dim: usize) -> String {
        let img_p = dir.join("feat.idx");
        let lab_p = dir.join("lab.idx");
        let mut img = vec![0u8, 0, 0x08, 2];
        img.extend((n as u32).to_be_bytes());
        img.extend((dim as u32).to_be_bytes());
        for i in 0..n * dim {
            img.push((i % 251) as u8);
        }
        let mut lab = vec![0u8, 0, 0x08, 1];
        lab.extend((n as u32).to_be_bytes());
        for i in 0..n {
            lab.push((i % 3) as u8);
        }
        std::fs::write(&img_p, img).unwrap();
        std::fs::write(&lab_p, lab).unwrap();
        format!("{},{}", img_p.display(), lab_p.display())
    }

    #[test]
    fn idx_pair_loads_and_splits_deterministically() {
        let dir = std::env::temp_dir()
            .join(format!("dystop_idx_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = write_idx_pair(&dir, 30, 6);
        let (train, test) = load_file_corpus(&path, 10, 5).unwrap();
        assert_eq!(train.dim, 6);
        assert_eq!(train.num_classes, 3);
        assert_eq!(train.len() + test.len(), 30);
        assert_eq!(test.len(), 10);
        // pixels scaled into [0,1]
        assert!(train.features.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // deterministic split
        let (train2, _) = load_file_corpus(&path, 10, 5).unwrap();
        assert_eq!(train.features, train2.features);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn csv_loads_with_optional_header() {
        let dir = std::env::temp_dir()
            .join(format!("dystop_csv_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("data.csv");
        let mut text = String::from("label,f0,f1\n");
        for i in 0..20 {
            text.push_str(&format!("{},{}.5,-{}\n", i % 4, i, i));
        }
        std::fs::write(&p, text).unwrap();
        let (train, test) =
            load_file_corpus(p.to_str().unwrap(), 5, 1).unwrap();
        assert_eq!(train.dim, 2);
        assert_eq!(train.num_classes, 4);
        assert_eq!(train.len() + test.len(), 20);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn malformed_files_are_clean_errors() {
        let dir = std::env::temp_dir()
            .join(format!("dystop_badfile_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // nonexistent
        assert!(load_file_corpus("nope.csv", 4, 1).is_err());
        assert!(load_file_corpus("a.idx,b.idx", 4, 1).is_err());
        // not idx-pair, not csv
        assert!(load_file_corpus("whatever.bin", 4, 1).is_err());
        // truncated idx payload
        let img_p = dir.join("bad.idx");
        let lab_p = dir.join("badlab.idx");
        let mut img = vec![0u8, 0, 0x08, 2];
        img.extend(4u32.to_be_bytes());
        img.extend(3u32.to_be_bytes());
        img.extend([1, 2, 3]); // promises 12 bytes, has 3
        std::fs::write(&img_p, img).unwrap();
        let mut lab = vec![0u8, 0, 0x08, 1];
        lab.extend(4u32.to_be_bytes());
        lab.extend([0, 1, 0, 1]);
        std::fs::write(&lab_p, lab).unwrap();
        let err = load_file_corpus(
            &format!("{},{}", img_p.display(), lab_p.display()),
            2,
            1,
        )
        .unwrap_err();
        assert!(err.contains("payload"), "{err}");
        // csv with a bad row
        let p = dir.join("bad.csv");
        std::fs::write(&p, "1,2.0\nx,3.0\n").unwrap();
        let err = load_file_corpus(p.to_str().unwrap(), 1, 1).unwrap_err();
        assert!(err.contains("bad label"), "{err}");
        // csv with an absurd label: clean error, not a giant model
        let p = dir.join("huge.csv");
        std::fs::write(&p, "4000000000,1.0\n0,2.0\n").unwrap();
        let err = load_file_corpus(p.to_str().unwrap(), 1, 1).unwrap_err();
        assert!(err.contains("not an integer in"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
