//! Native model zoo: the [`Model`] contract behind `NativeTrainer` and
//! the three dependency-free architectures that implement it.
//!
//! Every model describes its own flattened parameter vector through
//! [`ParamLayout`] — init, the gradient accumulator, and the layout
//! assertions in the trainer are all derived from that one description,
//! so they cannot drift apart (the pre-workload `NativeTrainer`
//! hardcoded `dim·C + C` in three separate places).
//!
//! # Architectures
//!
//! * [`LinearModel`] — softmax regression, **bit-compatible** with the
//!   historical trainer: identical op order, identical RNG draws in
//!   `init`, so `workload.model=linear` (the default) reproduces
//!   pre-workload runs exactly.
//! * [`MlpModel`] — one ReLU hidden layer (`workload.hidden` units),
//!   fused feature-major backward reusing the allocation-free scratch
//!   discipline of the trainer hot path.
//! * [`CnnSModel`] — a small 1-D conv net: im2col over the feature-major
//!   input (each output position's taps land in one contiguous patch
//!   row, turning the convolution into an `[L,K]×[K,F]` matmul), ReLU,
//!   then a dense classifier head.
//!
//! All scratch lives on the model (one clone per pool slot via
//! `Trainer::clone_box`), so the per-sample forward/backward allocates
//! nothing.

use crate::util::rng::Pcg;
use crate::worker::Params;

/// One contiguous, named segment of the flattened parameter vector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Segment {
    pub name: &'static str,
    pub offset: usize,
    pub len: usize,
}

/// Model-described parameter layout: named segments covering the flat
/// vector exactly, in order, with no gaps.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParamLayout {
    segments: Vec<Segment>,
}

impl ParamLayout {
    /// Build from `(name, len)` pairs; offsets are assigned
    /// contiguously in order.
    pub fn of(parts: &[(&'static str, usize)]) -> Self {
        let mut segments = Vec::with_capacity(parts.len());
        let mut offset = 0;
        for &(name, len) in parts {
            segments.push(Segment { name, offset, len });
            offset += len;
        }
        ParamLayout { segments }
    }

    /// Total flattened length — the one source of truth for
    /// `param_count`, init length and gradient-buffer size.
    pub fn total(&self) -> usize {
        self.segments.last().map(|s| s.offset + s.len).unwrap_or(0)
    }

    pub fn segments(&self) -> &[Segment] {
        &self.segments
    }

    /// Look up a segment by name.
    pub fn segment(&self, name: &str) -> Option<&Segment> {
        self.segments.iter().find(|s| s.name == name)
    }
}

/// A native model architecture: parameter layout, initialisation, and
/// the per-sample forward/backward the SGD driver iterates.
///
/// The contract the rest of the system relies on:
///
/// * `init(seed).len() == layout().total()` — every parameter vector in
///   the system (worker state, codec reconstructions, aggregation
///   buffers) has this length;
/// * `grad_sample` accumulates `∂loss/∂params` into `grad` (same layout
///   as `params`) and is deterministic — all randomness comes from the
///   trainer's minibatch sampling, never from the model;
/// * aggregation stays a flat weighted sum (`aggregate_native_into`):
///   layouts are position-stable across workers, so Eq. 4 never needs
///   to know the architecture.
pub trait Model: Send {
    /// Registry name (the `workload.model` knob value).
    fn name(&self) -> &'static str;

    /// Expected feature-vector length.
    fn input_dim(&self) -> usize;

    /// The flattened parameter layout.
    fn layout(&self) -> &ParamLayout;

    /// Total flattened parameter count (derived from the layout).
    fn param_count(&self) -> usize {
        self.layout().total()
    }

    /// Fresh initial parameters, deterministic per seed.
    fn init(&self, seed: u64) -> Params;

    /// One sample's forward + backward: accumulate the gradient into
    /// `grad` and return the sample's cross-entropy loss.
    fn grad_sample(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: usize,
        grad: &mut [f32],
    ) -> f64;

    /// Forward only: `(sample loss, predicted class)`.
    fn predict(&mut self, params: &[f32], x: &[f32], y: usize)
        -> (f64, usize);

    /// Clone for one pool slot (scratch is per-clone).
    fn clone_model(&self) -> Box<dyn Model>;
}

/// In-place softmax over the logits scratch; returns log-sum-exp.
///
/// Op-for-op identical to the pre-workload trainer's private softmax —
/// the linear path's bit-compatibility depends on it.
fn softmax_in_place(logits: &mut [f32]) -> f32 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
    let mut sum = 0.0f32;
    for v in logits.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in logits.iter_mut() {
        *v *= inv;
    }
    m + sum.ln()
}

/// Total-order argmax over probabilities: NaNs (reachable with a hot LR
/// blowing up the params) never win and never panic.
fn argmax(probs: &[f32]) -> usize {
    let mut pred = 0usize;
    let mut best = f32::NEG_INFINITY;
    for (k, &v) in probs.iter().enumerate() {
        if v > best {
            best = v;
            pred = k;
        }
    }
    pred
}

// ---------------------------------------------------------------------
// Linear (softmax regression)
// ---------------------------------------------------------------------

/// Softmax regression over the raw features. Layout:
/// `[w (dim × C) feature-major, b (C)]` — the historical trainer's
/// contract, preserved bit-for-bit.
#[derive(Clone, Debug)]
pub struct LinearModel {
    dim: usize,
    classes: usize,
    layout: ParamLayout,
    /// Scratch: per-class logits, softmaxed in place to probabilities.
    logits: Vec<f32>,
    /// Scratch: per-class logit gradient δ_k = p_k − 1[k==y].
    delta: Vec<f32>,
}

impl LinearModel {
    pub fn new(dim: usize, classes: usize) -> Self {
        assert!(dim > 0 && classes > 0);
        LinearModel {
            dim,
            classes,
            layout: ParamLayout::of(&[("w", dim * classes), ("b", classes)]),
            logits: vec![0.0; classes],
            delta: vec![0.0; classes],
        }
    }

    fn compute_logits(&mut self, params: &[f32], x: &[f32]) {
        let c = self.classes;
        let d = self.dim;
        self.logits.copy_from_slice(&params[d * c..]);
        // w feature-major [d][c]: logit_k += x_j * w[j][k]
        for (j, &xj) in x.iter().enumerate() {
            if xj == 0.0 {
                continue;
            }
            let row = &params[j * c..(j + 1) * c];
            for (l, &w) in self.logits.iter_mut().zip(row) {
                *l += xj * w;
            }
        }
    }
}

impl Model for LinearModel {
    fn name(&self) -> &'static str {
        "linear"
    }

    fn input_dim(&self) -> usize {
        self.dim
    }

    fn layout(&self) -> &ParamLayout {
        &self.layout
    }

    fn init(&self, seed: u64) -> Params {
        let mut rng = Pcg::new(seed, 0x1217);
        let std = (2.0 / self.dim as f64).sqrt() * 0.5;
        let mut p = rng.normal_vec(self.dim * self.classes, 0.0, std);
        p.extend(std::iter::repeat(0.0f32).take(self.classes));
        p
    }

    fn grad_sample(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: usize,
        grad: &mut [f32],
    ) -> f64 {
        let c = self.classes;
        let d = self.dim;
        self.compute_logits(params, x);
        let gold = self.logits[y];
        let lse = softmax_in_place(&mut self.logits);
        let (gw, gb) = grad.split_at_mut(d * c);
        // δ_k = p_k − 1[k==y]; the bias gradient accumulates directly
        for (k, (dv, gv)) in
            self.delta.iter_mut().zip(gb.iter_mut()).enumerate()
        {
            let dk = self.logits[k] - if k == y { 1.0 } else { 0.0 };
            *dv = dk;
            *gv += dk;
        }
        // fused feature-major pass: each nonzero x_j touches one
        // contiguous gw row, instead of C strided feature sweeps
        for (j, &xj) in x.iter().enumerate() {
            if xj == 0.0 {
                continue;
            }
            let row = &mut gw[j * c..(j + 1) * c];
            for (g, &dk) in row.iter_mut().zip(&self.delta) {
                *g += dk * xj;
            }
        }
        (lse - gold) as f64
    }

    fn predict(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: usize,
    ) -> (f64, usize) {
        self.compute_logits(params, x);
        let gold = self.logits[y];
        let lse = softmax_in_place(&mut self.logits);
        ((lse - gold) as f64, argmax(&self.logits))
    }

    fn clone_model(&self) -> Box<dyn Model> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// MLP (one ReLU hidden layer)
// ---------------------------------------------------------------------

/// One-hidden-layer ReLU perceptron. Layout:
/// `[w1 (dim × H) feature-major, b1 (H), w2 (H × C) unit-major, b2 (C)]`.
#[derive(Clone, Debug)]
pub struct MlpModel {
    dim: usize,
    hidden: usize,
    classes: usize,
    layout: ParamLayout,
    /// Scratch: hidden pre-activations (kept for the ReLU mask).
    h_pre: Vec<f32>,
    /// Scratch: hidden activations.
    h_act: Vec<f32>,
    /// Scratch: hidden-layer deltas.
    h_delta: Vec<f32>,
    logits: Vec<f32>,
    delta: Vec<f32>,
}

impl MlpModel {
    pub fn new(dim: usize, hidden: usize, classes: usize) -> Self {
        assert!(dim > 0 && hidden > 0 && classes > 0);
        MlpModel {
            dim,
            hidden,
            classes,
            layout: ParamLayout::of(&[
                ("w1", dim * hidden),
                ("b1", hidden),
                ("w2", hidden * classes),
                ("b2", classes),
            ]),
            h_pre: vec![0.0; hidden],
            h_act: vec![0.0; hidden],
            h_delta: vec![0.0; hidden],
            logits: vec![0.0; classes],
            delta: vec![0.0; classes],
        }
    }

    /// Forward pass into the scratch buffers (h_pre, h_act, logits).
    fn forward(&mut self, params: &[f32], x: &[f32]) {
        let (d, h, c) = (self.dim, self.hidden, self.classes);
        let w1 = &params[..d * h];
        let b1 = &params[d * h..d * h + h];
        let w2 = &params[d * h + h..d * h + h + h * c];
        let b2 = &params[d * h + h + h * c..];
        self.h_pre.copy_from_slice(b1);
        // fused feature-major pass over w1 rows
        for (j, &xj) in x.iter().enumerate() {
            if xj == 0.0 {
                continue;
            }
            let row = &w1[j * h..(j + 1) * h];
            for (hp, &w) in self.h_pre.iter_mut().zip(row) {
                *hp += xj * w;
            }
        }
        for (a, &pre) in self.h_act.iter_mut().zip(&self.h_pre) {
            *a = pre.max(0.0);
        }
        self.logits.copy_from_slice(b2);
        for (k, &hk) in self.h_act.iter().enumerate() {
            if hk == 0.0 {
                continue;
            }
            let row = &w2[k * c..(k + 1) * c];
            for (l, &w) in self.logits.iter_mut().zip(row) {
                *l += hk * w;
            }
        }
    }
}

impl Model for MlpModel {
    fn name(&self) -> &'static str {
        "mlp"
    }

    fn input_dim(&self) -> usize {
        self.dim
    }

    fn layout(&self) -> &ParamLayout {
        &self.layout
    }

    fn init(&self, seed: u64) -> Params {
        // He-style init, damped like the linear path; biases zero
        let mut rng = Pcg::new(seed, 0x1217);
        let s1 = (2.0 / self.dim as f64).sqrt() * 0.5;
        let mut p = rng.normal_vec(self.dim * self.hidden, 0.0, s1);
        p.extend(std::iter::repeat(0.0f32).take(self.hidden));
        let s2 = (2.0 / self.hidden as f64).sqrt() * 0.5;
        p.extend(rng.normal_vec(self.hidden * self.classes, 0.0, s2));
        p.extend(std::iter::repeat(0.0f32).take(self.classes));
        p
    }

    fn grad_sample(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: usize,
        grad: &mut [f32],
    ) -> f64 {
        let (d, h, c) = (self.dim, self.hidden, self.classes);
        self.forward(params, x);
        let gold = self.logits[y];
        let lse = softmax_in_place(&mut self.logits);
        let (gw1, rest) = grad.split_at_mut(d * h);
        let (gb1, rest) = rest.split_at_mut(h);
        let (gw2, gb2) = rest.split_at_mut(h * c);
        // output delta + head gradients
        for (k, (dv, gv)) in
            self.delta.iter_mut().zip(gb2.iter_mut()).enumerate()
        {
            let dk = self.logits[k] - if k == y { 1.0 } else { 0.0 };
            *dv = dk;
            *gv += dk;
        }
        for (k, &hk) in self.h_act.iter().enumerate() {
            if hk == 0.0 {
                continue;
            }
            let row = &mut gw2[k * c..(k + 1) * c];
            for (g, &dk) in row.iter_mut().zip(&self.delta) {
                *g += dk * hk;
            }
        }
        // backprop through the ReLU into the hidden deltas
        let w2 = &params[d * h + h..d * h + h + h * c];
        for (k, hd) in self.h_delta.iter_mut().enumerate() {
            *hd = if self.h_pre[k] > 0.0 {
                let row = &w2[k * c..(k + 1) * c];
                let mut s = 0.0f32;
                for (w, &dk) in row.iter().zip(&self.delta) {
                    s += w * dk;
                }
                s
            } else {
                0.0
            };
        }
        for (gv, &hd) in gb1.iter_mut().zip(&self.h_delta) {
            *gv += hd;
        }
        // fused feature-major pass over gw1 rows
        for (j, &xj) in x.iter().enumerate() {
            if xj == 0.0 {
                continue;
            }
            let row = &mut gw1[j * h..(j + 1) * h];
            for (g, &hd) in row.iter_mut().zip(&self.h_delta) {
                *g += hd * xj;
            }
        }
        (lse - gold) as f64
    }

    fn predict(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: usize,
    ) -> (f64, usize) {
        self.forward(params, x);
        let gold = self.logits[y];
        let lse = softmax_in_place(&mut self.logits);
        ((lse - gold) as f64, argmax(&self.logits))
    }

    fn clone_model(&self) -> Box<dyn Model> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// CNN-S (small 1-D conv net via im2col)
// ---------------------------------------------------------------------

/// Small 1-D convolutional net: `F` filters of kernel `K` and stride
/// `S` slide over the feature vector, ReLU, then a dense classifier
/// over all `L × F` activations. Layout:
/// `[conv_w (K × F) tap-major, conv_b (F), fc_w (L·F × C), fc_b (C)]`.
///
/// The convolution runs as im2col on the feature-major layout: each of
/// the `L` output positions copies its `K` input taps into one
/// contiguous patch row, so the conv is a plain `[L,K] × [K,F]` matmul
/// with the same fused row-major inner loops as the other models.
#[derive(Clone, Debug)]
pub struct CnnSModel {
    dim: usize,
    classes: usize,
    filters: usize,
    kernel: usize,
    stride: usize,
    /// Conv output positions L = (dim − K)/S + 1.
    out_len: usize,
    layout: ParamLayout,
    /// Scratch: im2col patch matrix `[L][K]`.
    im2col: Vec<f32>,
    /// Scratch: conv pre-activations `[L][F]` (kept for the ReLU mask).
    a_pre: Vec<f32>,
    /// Scratch: conv activations `[L][F]`.
    a_act: Vec<f32>,
    /// Scratch: conv-layer deltas `[L][F]`.
    a_delta: Vec<f32>,
    logits: Vec<f32>,
    delta: Vec<f32>,
}

impl CnnSModel {
    pub fn new(
        dim: usize,
        classes: usize,
        filters: usize,
        kernel: usize,
        stride: usize,
    ) -> Self {
        assert!(dim > 0 && classes > 0 && filters > 0 && stride > 0);
        assert!(
            kernel >= 1 && kernel <= dim,
            "cnn-s kernel {kernel} must be in [1, dim={dim}]"
        );
        let out_len = (dim - kernel) / stride + 1;
        let units = out_len * filters;
        CnnSModel {
            dim,
            classes,
            filters,
            kernel,
            stride,
            out_len,
            layout: ParamLayout::of(&[
                ("conv_w", kernel * filters),
                ("conv_b", filters),
                ("fc_w", units * classes),
                ("fc_b", classes),
            ]),
            im2col: vec![0.0; out_len * kernel],
            a_pre: vec![0.0; units],
            a_act: vec![0.0; units],
            a_delta: vec![0.0; units],
            logits: vec![0.0; classes],
            delta: vec![0.0; classes],
        }
    }

    /// Forward pass into the scratch buffers (im2col, a_pre, a_act,
    /// logits).
    fn forward(&mut self, params: &[f32], x: &[f32]) {
        let (kk, f, c) = (self.kernel, self.filters, self.classes);
        let l_out = self.out_len;
        let cw = &params[..kk * f];
        let cb = &params[kk * f..kk * f + f];
        // im2col: one contiguous K-tap patch row per output position
        for l in 0..l_out {
            let start = l * self.stride;
            self.im2col[l * kk..(l + 1) * kk]
                .copy_from_slice(&x[start..start + kk]);
        }
        // conv as [L,K]×[K,F]: fused tap-major rows over cw
        for l in 0..l_out {
            let pre = &mut self.a_pre[l * f..(l + 1) * f];
            pre.copy_from_slice(cb);
            let patch = &self.im2col[l * kk..(l + 1) * kk];
            for (k, &xv) in patch.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let row = &cw[k * f..(k + 1) * f];
                for (pv, &w) in pre.iter_mut().zip(row) {
                    *pv += xv * w;
                }
            }
        }
        for (a, &pre) in self.a_act.iter_mut().zip(&self.a_pre) {
            *a = pre.max(0.0);
        }
        // dense head over all L×F activations
        let fc_off = kk * f + f;
        let units = l_out * f;
        let fw = &params[fc_off..fc_off + units * c];
        let fb = &params[fc_off + units * c..];
        self.logits.copy_from_slice(fb);
        for (u, &au) in self.a_act.iter().enumerate() {
            if au == 0.0 {
                continue;
            }
            let row = &fw[u * c..(u + 1) * c];
            for (lv, &w) in self.logits.iter_mut().zip(row) {
                *lv += au * w;
            }
        }
    }
}

impl Model for CnnSModel {
    fn name(&self) -> &'static str {
        "cnn-s"
    }

    fn input_dim(&self) -> usize {
        self.dim
    }

    fn layout(&self) -> &ParamLayout {
        &self.layout
    }

    fn init(&self, seed: u64) -> Params {
        let mut rng = Pcg::new(seed, 0x1217);
        let sc = (2.0 / self.kernel as f64).sqrt() * 0.5;
        let mut p = rng.normal_vec(self.kernel * self.filters, 0.0, sc);
        p.extend(std::iter::repeat(0.0f32).take(self.filters));
        let units = self.out_len * self.filters;
        let sf = (2.0 / units as f64).sqrt() * 0.5;
        p.extend(rng.normal_vec(units * self.classes, 0.0, sf));
        p.extend(std::iter::repeat(0.0f32).take(self.classes));
        p
    }

    fn grad_sample(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: usize,
        grad: &mut [f32],
    ) -> f64 {
        let (kk, f, c) = (self.kernel, self.filters, self.classes);
        let l_out = self.out_len;
        let units = l_out * f;
        self.forward(params, x);
        let gold = self.logits[y];
        let lse = softmax_in_place(&mut self.logits);
        let (gcw, rest) = grad.split_at_mut(kk * f);
        let (gcb, rest) = rest.split_at_mut(f);
        let (gfw, gfb) = rest.split_at_mut(units * c);
        // output delta + head gradients
        for (k, (dv, gv)) in
            self.delta.iter_mut().zip(gfb.iter_mut()).enumerate()
        {
            let dk = self.logits[k] - if k == y { 1.0 } else { 0.0 };
            *dv = dk;
            *gv += dk;
        }
        for (u, &au) in self.a_act.iter().enumerate() {
            if au == 0.0 {
                continue;
            }
            let row = &mut gfw[u * c..(u + 1) * c];
            for (g, &dk) in row.iter_mut().zip(&self.delta) {
                *g += dk * au;
            }
        }
        // backprop through the ReLU into the conv deltas
        let fc_off = kk * f + f;
        let fw = &params[fc_off..fc_off + units * c];
        for (u, ad) in self.a_delta.iter_mut().enumerate() {
            *ad = if self.a_pre[u] > 0.0 {
                let row = &fw[u * c..(u + 1) * c];
                let mut s = 0.0f32;
                for (w, &dk) in row.iter().zip(&self.delta) {
                    s += w * dk;
                }
                s
            } else {
                0.0
            };
        }
        // conv gradients off the im2col patches (the [K,F] matmul
        // transpose, fused over contiguous gcw rows)
        for l in 0..l_out {
            let ad = &self.a_delta[l * f..(l + 1) * f];
            for (gv, &dv) in gcb.iter_mut().zip(ad) {
                *gv += dv;
            }
            let patch = &self.im2col[l * kk..(l + 1) * kk];
            for (k, &xv) in patch.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let row = &mut gcw[k * f..(k + 1) * f];
                for (g, &dv) in row.iter_mut().zip(ad) {
                    *g += dv * xv;
                }
            }
        }
        (lse - gold) as f64
    }

    fn predict(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: usize,
    ) -> (f64, usize) {
        self.forward(params, x);
        let gold = self.logits[y];
        let lse = softmax_in_place(&mut self.logits);
        ((lse - gold) as f64, argmax(&self.logits))
    }

    fn clone_model(&self) -> Box<dyn Model> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn models() -> Vec<Box<dyn Model>> {
        vec![
            Box::new(LinearModel::new(32, 10)),
            Box::new(MlpModel::new(32, 16, 10)),
            Box::new(CnnSModel::new(32, 10, 8, 5, 2)),
        ]
    }

    #[test]
    fn layouts_are_contiguous_and_cover_init() {
        for m in models() {
            let layout = m.layout().clone();
            let mut expect = 0usize;
            for s in layout.segments() {
                assert_eq!(s.offset, expect, "{}: segment {}", m.name(), s.name);
                assert!(s.len > 0);
                expect += s.len;
            }
            assert_eq!(layout.total(), expect);
            assert_eq!(m.param_count(), layout.total());
            assert_eq!(m.init(3).len(), layout.total(), "{}", m.name());
        }
    }

    #[test]
    fn linear_layout_matches_historical_contract() {
        let m = LinearModel::new(32, 10);
        assert_eq!(m.param_count(), 32 * 10 + 10);
        let w = m.layout().segment("w").unwrap();
        let b = m.layout().segment("b").unwrap();
        assert_eq!((w.offset, w.len), (0, 320));
        assert_eq!((b.offset, b.len), (320, 10));
    }

    #[test]
    fn init_is_deterministic_per_seed_and_differs_across_seeds() {
        for m in models() {
            assert_eq!(m.init(7), m.init(7), "{}", m.name());
            assert_ne!(m.init(7), m.init(8), "{}", m.name());
        }
    }

    #[test]
    fn grad_matches_finite_differences() {
        // spot-check the analytic gradient of every architecture against
        // central differences on a handful of coordinates
        let x: Vec<f32> = (0..32).map(|i| ((i % 5) as f32 - 2.0) * 0.3).collect();
        let y = 3usize;
        for mut m in models() {
            let p = m.init(11);
            let mut g = vec![0.0f32; p.len()];
            m.grad_sample(&p, &x, y, &mut g);
            let eps = 1e-3f32;
            // probe a spread of coordinates incl. first/last segment
            let n = p.len();
            for &i in &[0usize, 1, n / 2, n - 2, n - 1] {
                let mut pp = p.clone();
                pp[i] += eps;
                let (lp, _) = m.predict(&pp, &x, y);
                pp[i] = p[i] - eps;
                let (lm, _) = m.predict(&pp, &x, y);
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                assert!(
                    (g[i] - fd).abs() < 2e-2,
                    "{} coord {i}: analytic {} vs fd {fd}",
                    m.name(),
                    g[i]
                );
            }
        }
    }

    #[test]
    fn predict_handles_nan_params() {
        for mut m in models() {
            let p = vec![f32::NAN; m.param_count()];
            let x = vec![0.5f32; 32];
            let (loss, pred) = m.predict(&p, &x, 0);
            assert!(loss.is_nan(), "{}", m.name());
            assert!(pred < 10);
        }
    }

    #[test]
    fn clone_model_is_independent_and_identical() {
        for mut m in models() {
            let mut c = m.clone_model();
            let p = m.init(5);
            let x = vec![0.25f32; 32];
            let mut ga = vec![0.0f32; p.len()];
            let mut gb = vec![0.0f32; p.len()];
            let la = m.grad_sample(&p, &x, 2, &mut ga);
            let lb = c.grad_sample(&p, &x, 2, &mut gb);
            assert_eq!(la.to_bits(), lb.to_bits(), "{}", m.name());
            assert_eq!(ga, gb);
        }
    }

    #[test]
    #[should_panic(expected = "kernel")]
    fn cnn_kernel_larger_than_dim_panics() {
        CnnSModel::new(4, 10, 8, 5, 2);
    }
}
