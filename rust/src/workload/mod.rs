//! Workload subsystem: the pluggable (model × dataset × partition)
//! triple every experiment axis runs over.
//!
//! The paper demonstrates DySTop's gains across several model/dataset
//! pairs; this registry makes that axis real in the reproduction. Two
//! contracts split the responsibility:
//!
//! * [`Model`] — architecture: parameter layout
//!   ([`ParamLayout`] — init, gradients and the trainer's assertions
//!   are all derived from this single description), initialisation, and
//!   the per-sample forward/backward the SGD driver iterates. Three
//!   native, dependency-free models ship: [`LinearModel`] (softmax
//!   regression, bit-compatible with the pre-workload trainer),
//!   [`MlpModel`] and [`CnnSModel`].
//! * [`Workload`] — task: corpus construction (the `workload.dataset`
//!   generators in [`datasets`]) plus the eval protocol (which test
//!   distribution accuracy is scored on — e.g. the `drift` workload
//!   evaluates the *rotated* distribution). Partitioning stays the
//!   shared Dirichlet splitter (`data::dirichlet_partition`) — the
//!   non-IID axis composes with every dataset.
//!
//! Selection is pure config: `workload.model=linear|mlp|cnn-s` and
//! `workload.dataset=synthetic|clusters|drift|file` thread through
//! `ExperimentConfig`, the CLI `--set` surface, sweeps and benches. The
//! defaults (`linear` × `synthetic`) reproduce pre-workload runs
//! bit-identically. See DESIGN.md §Workloads for the layout rules and
//! the recipe for adding a model or dataset.

mod datasets;
mod models;

pub use datasets::{
    clusters_corpus, drift_corpus, load_file_corpus, rotate_dataset,
};
pub use models::{
    CnnSModel, LinearModel, MlpModel, Model, ParamLayout, Segment,
};

use crate::config::{DatasetKind, ExperimentConfig, ModelArch, WorkloadConfig};
use crate::data::{make_corpus, Dataset, SyntheticSpec};

/// Every registered model architecture, in registry order — tests,
/// benches and the Fig. 28 harness iterate this so a new model is
/// picked up everywhere by adding it here (and in [`build_model`]).
pub const MODELS: [ModelArch; 3] =
    [ModelArch::Linear, ModelArch::Mlp, ModelArch::CnnS];

/// Every registered dataset generator, in registry order.
pub const DATASETS: [DatasetKind; 4] = [
    DatasetKind::Synthetic,
    DatasetKind::Clusters,
    DatasetKind::Drift,
    DatasetKind::File,
];

/// Instantiate the configured model architecture over a
/// `dim`-dimensional, `classes`-way task. Infallible once the config
/// has validated (`WorkloadConfig::model_fits` guards the shape
/// constraints).
pub fn build_model(
    w: &WorkloadConfig,
    dim: usize,
    classes: usize,
) -> Box<dyn Model> {
    match w.model {
        ModelArch::Linear => Box::new(LinearModel::new(dim, classes)),
        ModelArch::Mlp => Box::new(MlpModel::new(dim, w.hidden, classes)),
        ModelArch::CnnS => Box::new(CnnSModel::new(
            dim,
            classes,
            w.conv_filters,
            w.conv_kernel,
            w.conv_stride,
        )),
    }
}

/// One constructed workload: the corpus pair plus its identity labels.
/// `test` already reflects the workload's eval protocol (e.g. rotated
/// under `drift`), so engines evaluate it unchanged.
pub struct Workload {
    /// `workload.dataset` registry name.
    pub dataset: &'static str,
    pub train: Dataset,
    pub test: Dataset,
}

/// Build the configured corpus. Deterministic per `cfg.seed`; draws
/// from dedicated RNG streams only, never from the experiment builder's
/// stream — `workload.dataset=synthetic` (the default) is byte-for-byte
/// the pre-workload corpus.
pub fn build_workload(cfg: &ExperimentConfig) -> Result<Workload, String> {
    let spec = SyntheticSpec {
        dim: cfg.feature_dim,
        num_classes: cfg.num_classes,
        train_samples: cfg.train_per_worker * cfg.workers,
        test_samples: cfg.test_samples,
        class_sep: cfg.class_sep,
        seed: cfg.seed,
    };
    let w = &cfg.workload;
    let (train, test) = match w.dataset {
        DatasetKind::Synthetic => make_corpus(&spec),
        DatasetKind::Clusters => clusters_corpus(&spec, w.cluster_skew),
        DatasetKind::Drift => drift_corpus(&spec, w.drift_deg),
        DatasetKind::File => {
            load_file_corpus(&w.path, cfg.test_samples, cfg.seed)?
        }
    };
    Ok(Workload { dataset: w.dataset.name(), train, test })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_builds_every_model() {
        for arch in MODELS {
            let w = WorkloadConfig { model: arch, ..Default::default() };
            let m = build_model(&w, 32, 10);
            assert_eq!(m.name(), arch.name());
            assert_eq!(m.input_dim(), 32);
            assert_eq!(m.init(1).len(), m.param_count());
        }
    }

    #[test]
    fn registry_names_roundtrip_through_config() {
        for arch in MODELS {
            assert_eq!(ModelArch::parse(arch.name()).unwrap(), arch);
        }
        for ds in DATASETS {
            assert_eq!(DatasetKind::parse(ds.name()).unwrap(), ds);
        }
    }

    #[test]
    fn default_workload_is_the_base_synthetic_corpus() {
        let cfg = ExperimentConfig {
            workers: 4,
            train_per_worker: 32,
            test_samples: 40,
            ..Default::default()
        };
        let wl = build_workload(&cfg).unwrap();
        let spec = SyntheticSpec {
            dim: cfg.feature_dim,
            num_classes: cfg.num_classes,
            train_samples: 128,
            test_samples: 40,
            class_sep: cfg.class_sep,
            seed: cfg.seed,
        };
        let (train, test) = make_corpus(&spec);
        assert_eq!(wl.dataset, "synthetic");
        assert_eq!(wl.train.features, train.features);
        assert_eq!(wl.train.labels, train.labels);
        assert_eq!(wl.test.features, test.features);
    }

    #[test]
    fn every_dataset_generator_builds() {
        let dir = std::env::temp_dir()
            .join(format!("dystop_wl_reg_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // a tiny CSV backs the `file` registry entry
        let p = dir.join("tiny.csv");
        let mut text = String::new();
        for i in 0..24 {
            text.push_str(&format!("{},{}.0,{}.5,1.0\n", i % 3, i, i));
        }
        std::fs::write(&p, text).unwrap();
        for ds in DATASETS {
            let mut cfg = ExperimentConfig {
                workers: 4,
                train_per_worker: 16,
                test_samples: 8,
                ..Default::default()
            };
            cfg.workload.dataset = ds;
            if ds == DatasetKind::File {
                cfg.workload.path = p.to_str().unwrap().to_string();
            }
            let wl = build_workload(&cfg).unwrap();
            assert!(!wl.train.is_empty(), "{}", ds.name());
            assert!(!wl.test.is_empty(), "{}", ds.name());
            assert_eq!(wl.train.dim, wl.test.dim);
            assert_eq!(wl.train.num_classes, wl.test.num_classes);
        }
        // file kind without a path is a clean error
        let cfg = ExperimentConfig {
            workload: WorkloadConfig {
                dataset: DatasetKind::File,
                path: String::new(),
                ..Default::default()
            },
            ..Default::default()
        };
        assert!(build_workload(&cfg).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
