//! Wireless channel model (paper §VI-A1).
//!
//! Transmission rate from Shannon capacity:
//!
//! ```text
//! r = B · log2(1 + p·g / γ²)
//! ```
//!
//! with channel gain `g ~ Exp(mean = G0 · d⁻⁴)` (exponential fading over a
//! d⁻⁴ path-loss law, refs \[33\]\[34\]), `G0 = −43 dB` at 1 m,
//! noise power `γ² = 1e-13 W`, `B = 1 MHz`.

use crate::config::NetworkConfig;
use crate::util::rng::Pcg;

pub fn dbm_to_watts(dbm: f64) -> f64 {
    10f64.powf(dbm / 10.0) * 1e-3
}

pub fn db_to_linear(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// Stateless channel calculator.
#[derive(Clone, Debug)]
pub struct ChannelModel {
    pub bandwidth_hz: f64,
    pub g0_linear: f64,
    pub noise_w: f64,
}

impl ChannelModel {
    pub fn from_config(cfg: &NetworkConfig) -> Self {
        ChannelModel {
            bandwidth_hz: cfg.bandwidth_hz,
            g0_linear: db_to_linear(cfg.g0_db),
            noise_w: cfg.noise_w,
        }
    }

    /// Mean channel gain at distance `d` meters (d⁻⁴ path loss).
    pub fn mean_gain(&self, d: f64) -> f64 {
        self.g0_linear * d.max(1.0).powi(-4)
    }

    /// One transfer's effective rate, bits/s.
    ///
    /// A model transfer lasts many channel coherence intervals, so the
    /// *effective* rate is the average Shannon rate over independent
    /// fading draws (a single draw would make a deep fade stall a whole
    /// multi-second transfer — unphysical and numerically explosive).
    pub fn rate_bps(&self, tx_watts: f64, d: f64, rng: &mut Pcg) -> f64 {
        const COHERENCE_BLOCKS: usize = 16;
        let mean_gain = self.mean_gain(d);
        let mut acc = 0.0;
        for _ in 0..COHERENCE_BLOCKS {
            let g = rng.exponential(mean_gain);
            acc += self.shannon(tx_watts * g);
        }
        acc / COHERENCE_BLOCKS as f64
    }

    /// Rate at the mean gain (no fading), bits/s.
    pub fn mean_rate_bps(&self, tx_watts: f64, d: f64) -> f64 {
        self.shannon(tx_watts * self.mean_gain(d))
    }

    fn shannon(&self, signal_w: f64) -> f64 {
        self.bandwidth_hz * (1.0 + signal_w / self.noise_w).log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ChannelModel {
        ChannelModel::from_config(&NetworkConfig::default())
    }

    #[test]
    fn dbm_conversion() {
        assert!((dbm_to_watts(0.0) - 1e-3).abs() < 1e-12);
        assert!((dbm_to_watts(30.0) - 1.0).abs() < 1e-9);
        assert!((dbm_to_watts(10.0) - 0.01).abs() < 1e-9);
    }

    #[test]
    fn path_loss_is_quartic() {
        let m = model();
        let g10 = m.mean_gain(10.0);
        let g20 = m.mean_gain(20.0);
        assert!((g10 / g20 - 16.0).abs() < 1e-6);
    }

    #[test]
    fn paper_scale_rate_is_plausible() {
        // 15 dBm at 30 m over 1 MHz should land in the single-to-tens of
        // Mbps band — the regime the paper's §VI-A1 constants imply.
        let m = model();
        let r = m.mean_rate_bps(dbm_to_watts(15.0), 30.0);
        assert!(r > 1e5 && r < 1e8, "rate {r}");
    }

    #[test]
    fn rate_monotone_in_power_and_distance() {
        let m = model();
        assert!(
            m.mean_rate_bps(dbm_to_watts(20.0), 30.0)
                > m.mean_rate_bps(dbm_to_watts(10.0), 30.0)
        );
        assert!(
            m.mean_rate_bps(dbm_to_watts(15.0), 10.0)
                > m.mean_rate_bps(dbm_to_watts(15.0), 50.0)
        );
    }

    #[test]
    fn fading_averages_near_mean_gain() {
        let m = model();
        let mut rng = Pcg::seeded(9);
        let d = 25.0;
        let n = 20000;
        let mean_g = m.mean_gain(d);
        let avg: f64 = (0..n)
            .map(|_| rng.exponential(mean_g))
            .sum::<f64>()
            / n as f64;
        assert!((avg / mean_g - 1.0).abs() < 0.05);
    }
}
